//! Bench: streaming request lifecycle latency — TTFT and inter-token
//! latency (TPOT) percentiles, streaming vs batch collection, at 1 and 4
//! workers.
//!
//! The streaming mode consumes each request's per-token `Event` stream
//! (`ServePool::submit` handles) and timestamps every token at arrival:
//! TTFT is first-token arrival minus submission, TPOT the gap between
//! consecutive tokens of one request.  The batch mode reads only the
//! aggregate results channel, so the first output a client can see is the
//! whole completion — its "TTFT" column is the full request latency.  The
//! gap between those two columns is the point of the streaming API.
//!
//! Streamed token sequences are asserted bit-identical to the batch
//! results (streaming changes delivery, never tokens).
//!
//! `--json PATH` writes a machine-readable record (uploaded as a CI
//! artifact to track the latency trajectory over time).
//!
//! Run: cargo bench --bench streaming_latency [-- --requests 24 --json out.json]

use std::time::{Duration, Instant};

use fastmamba::backend::{self, BackendKind};
use fastmamba::coordinator::{serve_pool, EngineConfig, Event, Metrics, PoolConfig, Request};
use fastmamba::obs::SortedSamples;
use fastmamba::util::cli::Args;
use fastmamba::util::json::{self, num, obj, s as js, Json};

struct Row {
    workers: usize,
    mode: &'static str,
    ttft_p50_ms: f64,
    ttft_p95_ms: f64,
    tpot_p50_ms: f64,
    tpot_p95_ms: f64,
    wall_s: f64,
    tok_per_s: f64,
    /// the pool's merged metrics for this run — exported whole under the
    /// shared `fastmamba.metrics.v1` schema
    metrics: Metrics,
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 24);
    let max_new = args.usize_or("max-new", 24);
    let max_active = args.usize_or("max-active", 8);
    let kind = BackendKind::from_name(&args.get_or("backend", "native"))
        .expect("--backend auto|pjrt|native");

    let probe = backend::load(kind)?;
    let vocab = probe.cfg().vocab_size;
    println!(
        "backend: {} ({n_requests} requests, max_new {max_new})",
        probe.name()
    );
    drop(probe); // workers construct their own

    let make_prompts = || -> Vec<Vec<u32>> {
        (0..n_requests)
            .map(|i| {
                let plen = [9usize, 17, 33, 48][i % 4];
                (0..plen).map(|j| ((i * 131 + j * 17) % vocab) as u32).collect()
            })
            .collect()
    };

    let make_pool = |n_workers: usize| {
        let pool = serve_pool(
            move || backend::load(kind),
            PoolConfig {
                engine: EngineConfig { max_active, greedy_chunking: true },
                n_workers,
                spec: None,
                cache: None,
                ..PoolConfig::default()
            },
        );
        // warm up outside the timed window: one tiny request per worker
        for w in 0..n_workers {
            pool.submit(Request::new(1_000_000 + w as u64, vec![1, 2, 3], 2, "fp32"))
                .unwrap();
        }
        for _ in 0..n_workers {
            pool.results.recv().expect("warmup result");
        }
        pool
    };

    let mut rows: Vec<Row> = Vec::new();
    for n_workers in [1usize, 4] {
        // --- streaming: consume per-request event streams, timestamping
        // every token at arrival
        let pool = make_pool(n_workers);
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(n_requests);
        let mut submit_at = Vec::with_capacity(n_requests);
        for (i, prompt) in make_prompts().into_iter().enumerate() {
            submit_at.push(Instant::now());
            handles.push(pool.submit(Request::new(i as u64, prompt, max_new, "fp32"))?);
        }
        let mut ttft = Vec::with_capacity(n_requests);
        let mut tpot = Vec::new();
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); n_requests];
        let mut last: Vec<Option<Instant>> = vec![None; n_requests];
        let mut done = 0usize;
        while done < n_requests {
            let mut progressed = false;
            for (i, h) in handles.iter().enumerate() {
                while let Some(ev) = h.try_event() {
                    progressed = true;
                    let now = Instant::now();
                    match ev {
                        Event::FirstToken => {}
                        Event::Token { tok, .. } => {
                            match last[i] {
                                Some(prev) => tpot.push((now - prev).as_secs_f64()),
                                None => ttft.push((now - submit_at[i]).as_secs_f64()),
                            }
                            last[i] = Some(now);
                            streams[i].push(tok);
                        }
                        Event::Finished(_) => done += 1,
                    }
                }
            }
            if !progressed {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        for _ in 0..n_requests {
            pool.results.recv().expect("buffered result"); // drain aggregate
        }
        let report = pool.finish()?;
        let toks: u64 = streams.iter().map(|s| s.len() as u64).sum();
        // nearest-rank percentiles, sorted once per sample set (obs)
        let (ttft, tpot) = (SortedSamples::new(ttft), SortedSamples::new(tpot));
        rows.push(Row {
            workers: n_workers,
            mode: "stream",
            ttft_p50_ms: ttft.pct(0.50) * 1e3,
            ttft_p95_ms: ttft.pct(0.95) * 1e3,
            tpot_p50_ms: tpot.pct(0.50) * 1e3,
            tpot_p95_ms: tpot.pct(0.95) * 1e3,
            wall_s: wall,
            tok_per_s: toks as f64 / wall,
            metrics: report.merged,
        });

        // --- batch: only the aggregate results channel; the first output
        // visible per request is its whole completion
        let pool = make_pool(n_workers);
        let t0 = Instant::now();
        let mut submit_at = Vec::with_capacity(n_requests);
        for (i, prompt) in make_prompts().into_iter().enumerate() {
            submit_at.push(Instant::now());
            pool.submit(Request::new(i as u64, prompt, max_new, "fp32"))?;
        }
        let mut first_visible = Vec::with_capacity(n_requests);
        let mut batch: Vec<(u64, Vec<u32>)> = Vec::with_capacity(n_requests);
        for _ in 0..n_requests {
            let f = pool.results.recv().expect("pool result");
            first_visible
                .push((Instant::now() - submit_at[f.id as usize]).as_secs_f64());
            batch.push((f.id, f.generated));
        }
        let wall = t0.elapsed().as_secs_f64();
        let report = pool.finish()?;
        let toks: u64 = batch.iter().map(|(_, g)| g.len() as u64).sum();
        let first_visible = SortedSamples::new(first_visible);
        rows.push(Row {
            workers: n_workers,
            mode: "batch",
            ttft_p50_ms: first_visible.pct(0.50) * 1e3,
            ttft_p95_ms: first_visible.pct(0.95) * 1e3,
            tpot_p50_ms: 0.0,
            tpot_p95_ms: 0.0,
            wall_s: wall,
            tok_per_s: toks as f64 / wall,
            metrics: report.merged,
        });

        // streaming changes delivery, never tokens
        batch.sort();
        let streamed: Vec<(u64, Vec<u32>)> = streams
            .iter()
            .enumerate()
            .map(|(i, s)| (i as u64, s.clone()))
            .collect();
        assert_eq!(streamed, batch, "streamed tokens diverged from batch output");
        println!("workers={n_workers}: streamed == batch (token-identical)");
    }

    for r in &rows {
        println!(
            "workers={} mode={:<6} ttft_p50={:.2}ms ttft_p95={:.2}ms \
             tpot_p50={:.3}ms tpot_p95={:.3}ms wall={:.3}s tok/s={:.1}",
            r.workers,
            r.mode,
            r.ttft_p50_ms,
            r.ttft_p95_ms,
            r.tpot_p50_ms,
            r.tpot_p95_ms,
            r.wall_s,
            r.tok_per_s
        );
    }

    if let Some(path) = args.get("json") {
        // each run embeds its pool's full metrics under the same
        // `fastmamba.metrics.v1` schema that `serve --metrics-json` and
        // the throughput bench emit
        let runs: Vec<Json> = rows
            .iter()
            .map(|r| {
                obj(vec![
                    ("workers", num(r.workers as f64)),
                    ("mode", js(r.mode)),
                    ("ttft_p50_ms", num(r.ttft_p50_ms)),
                    ("ttft_p95_ms", num(r.ttft_p95_ms)),
                    ("tpot_p50_ms", num(r.tpot_p50_ms)),
                    ("tpot_p95_ms", num(r.tpot_p95_ms)),
                    ("wall_s", num(r.wall_s)),
                    ("tok_per_s", num(r.tok_per_s)),
                    ("metrics", r.metrics.to_json()),
                ])
            })
            .collect();
        let doc = obj(vec![
            ("bench", js("streaming_latency")),
            ("requests", num(n_requests as f64)),
            ("max_new", num(max_new as f64)),
            ("max_active", num(max_active as f64)),
            ("runs", Json::Arr(runs)),
        ]);
        std::fs::write(path, json::to_string(&doc))?;
        println!("wrote {path}");
    }
    Ok(())
}
