//! Bench: HTTP/SSE serving overhead — requests/s and TTFT through the
//! OpenAI-style frontend vs direct in-process `ServePool::submit`, at 1
//! and 4 workers.
//!
//! The direct mode consumes each request's `Event` stream off its
//! `SubmitHandle` (no sockets anywhere); the HTTP mode drives the same
//! pool through `serve_http` with one raw-TCP client thread per request,
//! POSTing `stream: true` completions and timestamping the first SSE
//! token frame.  The delta between the two TTFT columns is the wire +
//! frontend cost; tokens are asserted identical per prompt (greedy
//! decoding is deterministic, so transport must never change output).
//!
//! `--json PATH` writes a machine-readable record (uploaded as a CI
//! artifact to track the serving-overhead trajectory over time).
//!
//! Run: cargo bench --bench http_serving [-- --requests 24 --json out.json]

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastmamba::backend::{self, BackendKind};
use fastmamba::coordinator::{serve_pool, EngineConfig, Event, Metrics, PoolConfig, Request};
use fastmamba::obs::SortedSamples;
use fastmamba::server::{serve_http, ApiConfig, ChannelSubmitter, HttpConfig};
use fastmamba::util::cli::Args;
use fastmamba::util::json::{self, num, obj, s as js, Json};

struct Row {
    workers: usize,
    mode: &'static str,
    reqs_per_s: f64,
    ttft_p50_ms: f64,
    ttft_p95_ms: f64,
    wall_s: f64,
    tok_per_s: f64,
    metrics: Metrics,
}

/// One streamed completion over raw TCP: returns the token stream and the
/// client-observed TTFT (request written → first token frame parsed).
fn http_stream_completion(addr: SocketAddr, body: &str) -> anyhow::Result<(Vec<u32>, f64)> {
    let mut stream = TcpStream::connect(addr)?;
    let t0 = Instant::now();
    write!(
        stream,
        "POST /v1/completions HTTP/1.1\r\nHost: bench\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    let mut raw: Vec<u8> = Vec::with_capacity(4096);
    let mut chunk = [0u8; 4096];
    let mut cursor = 0usize; // next unparsed byte
    let mut head_done = false;
    let mut tokens: Vec<u32> = Vec::new();
    let mut ttft = None;
    'read: loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        raw.extend_from_slice(&chunk[..n]);
        if !head_done {
            match raw.windows(4).position(|w| w == b"\r\n\r\n") {
                Some(p) => {
                    let head = std::str::from_utf8(&raw[..p])?;
                    anyhow::ensure!(head.starts_with("HTTP/1.1 200"), "bad response: {head}");
                    cursor = p + 4;
                    head_done = true;
                }
                None => continue,
            }
        }
        // complete SSE frames end with \n\n (the head's \r\n\r\n cannot
        // false-match)
        while let Some(p) = raw[cursor..].windows(2).position(|w| w == b"\n\n") {
            let frame = std::str::from_utf8(&raw[cursor..cursor + p])?;
            cursor += p + 2;
            let payload = frame.strip_prefix("data: ").unwrap_or(frame);
            if payload == "[DONE]" {
                break 'read;
            }
            let v = Json::parse(payload)?;
            let choice = &v.arr_field("choices")?[0];
            if let Some(tok) = choice.get("token").and_then(Json::as_usize) {
                if tokens.is_empty() {
                    ttft = Some(t0.elapsed().as_secs_f64());
                }
                tokens.push(tok as u32);
            }
        }
    }
    Ok((tokens, ttft.unwrap_or_else(|| t0.elapsed().as_secs_f64())))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 24);
    let max_new = args.usize_or("max-new", 16);
    let max_active = args.usize_or("max-active", 8);
    let kind = BackendKind::from_name(&args.get_or("backend", "native"))
        .expect("--backend auto|pjrt|native");

    let probe = backend::load(kind)?;
    let vocab = probe.cfg().vocab_size;
    let variants = probe.variants();
    println!(
        "backend: {} ({n_requests} requests, max_new {max_new})",
        probe.name()
    );
    drop(probe); // workers construct their own

    let make_prompts = || -> Vec<Vec<u32>> {
        (0..n_requests)
            .map(|i| {
                let plen = [9usize, 17, 33, 48][i % 4];
                (0..plen).map(|j| ((i * 131 + j * 17) % vocab) as u32).collect()
            })
            .collect()
    };

    let make_pool = |n_workers: usize| {
        let pool = serve_pool(
            move || backend::load(kind),
            PoolConfig {
                engine: EngineConfig { max_active, greedy_chunking: true },
                n_workers,
                ..PoolConfig::default()
            },
        );
        // warm up outside the timed window: one tiny request per worker
        for w in 0..n_workers {
            pool.submit(Request::new(1_000_000 + w as u64, vec![1, 2, 3], 2, "fp32"))
                .unwrap();
        }
        for _ in 0..n_workers {
            pool.results.recv().expect("warmup result");
        }
        pool
    };

    let mut rows: Vec<Row> = Vec::new();
    for n_workers in [1usize, 4] {
        // --- direct: in-process SubmitHandle event streams
        let pool = make_pool(n_workers);
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(n_requests);
        let mut submit_at = Vec::with_capacity(n_requests);
        for (i, prompt) in make_prompts().into_iter().enumerate() {
            submit_at.push(Instant::now());
            handles.push(pool.submit(Request::new(i as u64, prompt, max_new, "fp32"))?);
        }
        let mut direct: Vec<Vec<u32>> = vec![Vec::new(); n_requests];
        let mut ttft = Vec::with_capacity(n_requests);
        let mut done = 0usize;
        while done < n_requests {
            let mut progressed = false;
            for (i, h) in handles.iter().enumerate() {
                while let Some(ev) = h.try_event() {
                    progressed = true;
                    match ev {
                        Event::FirstToken => {}
                        Event::Token { tok, .. } => {
                            if direct[i].is_empty() {
                                ttft.push((Instant::now() - submit_at[i]).as_secs_f64());
                            }
                            direct[i].push(tok);
                        }
                        Event::Finished(_) => done += 1,
                    }
                }
            }
            if !progressed {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        for _ in 0..n_requests {
            pool.results.recv().expect("buffered result");
        }
        let report = pool.finish()?;
        let toks: u64 = direct.iter().map(|s| s.len() as u64).sum();
        let ttft = SortedSamples::new(ttft);
        rows.push(Row {
            workers: n_workers,
            mode: "direct",
            reqs_per_s: n_requests as f64 / wall,
            ttft_p50_ms: ttft.pct(0.50) * 1e3,
            ttft_p95_ms: ttft.pct(0.95) * 1e3,
            wall_s: wall,
            tok_per_s: toks as f64 / wall,
            metrics: report.merged,
        });

        // --- http: same pool topology behind the SSE frontend, one raw-TCP
        // client thread per request
        let pool = make_pool(n_workers);
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server = serve_http(
            "127.0.0.1:0",
            submitter,
            HttpConfig::new(ApiConfig {
                variant: "fp32".into(),
                variants: variants.clone(),
                vocab_size: vocab,
                default_max_tokens: max_new,
            }),
        )?;
        let addr = server.addr();
        let t0 = Instant::now();
        let clients: Vec<_> = make_prompts()
            .into_iter()
            .map(|prompt| {
                std::thread::spawn(move || {
                    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
                    let body = format!(
                        r#"{{"prompt": [{}], "max_tokens": {max_new}, "stream": true}}"#,
                        toks.join(", ")
                    );
                    http_stream_completion(addr, &body)
                })
            })
            .collect();
        let mut http: Vec<Vec<u32>> = Vec::with_capacity(n_requests);
        let mut ttft = Vec::with_capacity(n_requests);
        for c in clients {
            let (tokens, t) = c.join().expect("client thread")?;
            http.push(tokens);
            ttft.push(t);
        }
        let wall = t0.elapsed().as_secs_f64();
        server.shutdown();
        for _ in 0..n_requests {
            pool.results.recv().expect("buffered result");
        }
        let report = pool.finish()?;
        let toks: u64 = http.iter().map(|s| s.len() as u64).sum();
        let ttft = SortedSamples::new(ttft);
        rows.push(Row {
            workers: n_workers,
            mode: "http",
            reqs_per_s: n_requests as f64 / wall,
            ttft_p50_ms: ttft.pct(0.50) * 1e3,
            ttft_p95_ms: ttft.pct(0.95) * 1e3,
            wall_s: wall,
            tok_per_s: toks as f64 / wall,
            metrics: report.merged,
        });

        // transport must never change output: greedy decoding of the same
        // prompt yields the same tokens over HTTP as in-process (both
        // vectors are indexed by prompt order)
        assert_eq!(http, direct, "HTTP tokens diverged from direct submit");
        println!("workers={n_workers}: http == direct (token-identical)");
    }

    for r in &rows {
        println!(
            "workers={} mode={:<6} req/s={:.1} ttft_p50={:.2}ms ttft_p95={:.2}ms \
             wall={:.3}s tok/s={:.1}",
            r.workers, r.mode, r.reqs_per_s, r.ttft_p50_ms, r.ttft_p95_ms, r.wall_s, r.tok_per_s
        );
    }

    if let Some(path) = args.get("json") {
        let runs: Vec<Json> = rows
            .iter()
            .map(|r| {
                obj(vec![
                    ("workers", num(r.workers as f64)),
                    ("mode", js(r.mode)),
                    ("reqs_per_s", num(r.reqs_per_s)),
                    ("ttft_p50_ms", num(r.ttft_p50_ms)),
                    ("ttft_p95_ms", num(r.ttft_p95_ms)),
                    ("wall_s", num(r.wall_s)),
                    ("tok_per_s", num(r.tok_per_s)),
                    ("metrics", r.metrics.to_json()),
                ])
            })
            .collect();
        let doc = obj(vec![
            ("schema", js("fastmamba.http_serving.v1")),
            ("bench", js("http_serving")),
            ("requests", num(n_requests as f64)),
            ("max_new", num(max_new as f64)),
            ("max_active", num(max_active as f64)),
            ("runs", Json::Arr(runs)),
        ]);
        std::fs::write(path, json::to_string(&doc))?;
        println!("wrote {path}");
    }
    Ok(())
}
