//! Bench: speculative decode throughput vs draft length k and acceptance
//! rate, alongside `table3_decode_throughput`.
//!
//! Two parts: (a) the accelerator-model prediction (Mamba2-2.7B on the
//! VC709 performance model) of tokens/s and speedup across k ∈ {2, 4, 8}
//! and acceptance rates; (b) *measured* PJRT speculative decode on the
//! tiny serving model — fastmamba drafter + fp32 verifier vs plain greedy
//! fp32 decode on the same trace, with the acceptance rate that trace
//! actually achieves.

use fastmamba::config::{AcceleratorConfig, ModelConfig};
use fastmamba::coordinator::{
    DrafterBackend, Engine, EngineConfig, Request, SpecConfig, SpecEngine,
};
use fastmamba::eval::load_corpus;
use fastmamba::runtime::Runtime;
use fastmamba::sim::SpecSim;
use fastmamba::util::bench::Table;
use fastmamba::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // (a) accelerator-model prediction at 2.7B (DRAM-bound decode)
    let sim = SpecSim::new(AcceleratorConfig::default(), ModelConfig::mamba2_2_7b());
    let base = sim.perf.decode(1).tokens_per_s;
    println!(
        "sim baseline decode (Mamba2-2.7B): {base:.2} tok/s; drafter step = \
         {:.2}x a verifier step",
        sim.draft_cost_ratio
    );
    let mut t = Table::new(&["k", "accept", "committed/round", "sim tok/s", "speedup"]);
    for k in [2usize, 4, 8] {
        for p in [0.6f64, 0.8, 0.9, 1.0] {
            let pt = sim.point(k, p);
            t.row(&[
                k.to_string(),
                format!("{p:.2}"),
                format!("{:.2}", pt.committed_per_round),
                format!("{:.2}", pt.tokens_per_s),
                format!("{:.2}x", pt.speedup),
            ]);
        }
    }
    t.print();

    // (b) measured PJRT speculative decode on the tiny serving model
    let rt = match Runtime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("(measured part skipped: {e})");
            return Ok(());
        }
    };
    let corpus = load_corpus(&rt.dir)?;
    let vocab = rt.weights_host.cfg.vocab_size as u32;
    let n_requests = 8usize;
    let max_new = 32usize;
    let trace = |seed: u64| -> Vec<Request> {
        let mut rng = Rng::new(seed);
        (0..n_requests)
            .map(|id| {
                let plen = [24usize, 40, 70, 100][rng.below(4)];
                let start = rng.below(corpus.len() - plen - 1);
                let prompt: Vec<u32> =
                    corpus[start..start + plen].iter().map(|t| t % vocab).collect();
                Request::new(id as u64, prompt, max_new, "fp32")
            })
            .collect()
    };

    let mut base_eng = Engine::new(&rt, EngineConfig { max_active: 1, greedy_chunking: true });
    for r in trace(3) {
        base_eng.submit(r);
    }
    base_eng.run()?;
    let base_tps = base_eng.metrics.decode_tokens_per_s();
    println!("\nmeasured baseline (greedy fp32, B=1): {base_tps:.1} gen tok/s");

    let mut t2 = Table::new(&["k", "drafter", "gen tok/s", "speedup", "accept", "rollbacks"]);
    let cases = [
        (2usize, DrafterBackend::Native),
        (4, DrafterBackend::Native),
        (8, DrafterBackend::Native),
        (4, DrafterBackend::Pjrt),
    ];
    for (k, backend) in cases {
        let mut spec = SpecEngine::new(
            &rt,
            SpecConfig {
                draft_k: k,
                max_active: 1,
                drafter_backend: backend,
                ..SpecConfig::default()
            },
        );
        for r in trace(3) {
            spec.submit(r);
        }
        spec.run()?;
        let tps = spec.metrics.decode_tokens_per_s();
        t2.row(&[
            k.to_string(),
            format!("{backend:?}").to_lowercase(),
            format!("{tps:.1}"),
            format!("{:.2}x", tps / base_tps),
            format!("{:.1}%", spec.metrics.acceptance_rate() * 100.0),
            spec.metrics.rollbacks.to_string(),
        ]);
    }
    t2.print();
    Ok(())
}
