//! Bench: speculative decode throughput vs draft length k and acceptance
//! rate, alongside `table3_decode_throughput`.
//!
//! Two parts: (a) the accelerator-model prediction (Mamba2-2.7B on the
//! VC709 performance model) of tokens/s and speedup across k ∈ {2, 4, 8}
//! and acceptance rates; (b) *measured* speculative decode on the tiny
//! serving model — fastmamba drafter + fp32 verifier vs plain greedy
//! fp32 decode on the same trace, with the acceptance rate that trace
//! actually achieves — on whichever backend is available.

use fastmamba::backend::{self, BackendKind, InferenceBackend, NativeBackend};
use fastmamba::config::{AcceleratorConfig, ModelConfig};
use fastmamba::coordinator::{Engine, EngineConfig, Request, SpecConfig, SpecEngine};
use fastmamba::eval::corpus_for;
use fastmamba::sim::SpecSim;
use fastmamba::util::bench::Table;
use fastmamba::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // (a) accelerator-model prediction at 2.7B (DRAM-bound decode)
    let sim = SpecSim::new(AcceleratorConfig::default(), ModelConfig::mamba2_2_7b());
    let base = sim.perf.decode(1).tokens_per_s;
    println!(
        "sim baseline decode (Mamba2-2.7B): {base:.2} tok/s; drafter step = \
         {:.2}x a verifier step",
        sim.draft_cost_ratio
    );
    let mut t = Table::new(&["k", "accept", "committed/round", "sim tok/s", "speedup"]);
    for k in [2usize, 4, 8] {
        for p in [0.6f64, 0.8, 0.9, 1.0] {
            let pt = sim.point(k, p);
            t.row(&[
                k.to_string(),
                format!("{p:.2}"),
                format!("{:.2}", pt.committed_per_round),
                format!("{:.2}", pt.tokens_per_s),
                format!("{:.2}x", pt.speedup),
            ]);
        }
    }
    t.print();

    // (b) measured speculative decode on the tiny serving model
    let be = backend::load(BackendKind::Auto)?;
    println!("\nmeasured backend: {}", be.name());
    let corpus = corpus_for(be.as_ref());
    let vocab = be.cfg().vocab_size as u32;
    let n_requests = 8usize;
    let max_new = 32usize;
    let trace = |seed: u64| -> Vec<Request> {
        let mut rng = Rng::new(seed);
        (0..n_requests)
            .map(|id| {
                let plen = [24usize, 40, 70, 100][rng.below(4)];
                let start = rng.below(corpus.len() - plen - 1);
                let prompt: Vec<u32> =
                    corpus[start..start + plen].iter().map(|t| t % vocab).collect();
                Request::new(id as u64, prompt, max_new, "fp32")
            })
            .collect()
    };

    let mut base_eng = Engine::new(
        be.as_ref(),
        EngineConfig { max_active: 1, greedy_chunking: true },
    );
    for r in trace(3) {
        base_eng.submit(r);
    }
    base_eng.run()?;
    let base_tps = base_eng.metrics.decode_tokens_per_s();
    println!("measured baseline (greedy fp32, B=1): {base_tps:.1} gen tok/s");

    // a separate in-process drafter only makes sense next to a device
    // verifier; on a native serving backend "native" == "shared"
    let native_drafter: Option<NativeBackend> = if be.name() == "native" {
        None
    } else {
        Some(NativeBackend::load_default()?)
    };
    let mut t2 = Table::new(&["k", "drafter", "gen tok/s", "speedup", "accept", "rollbacks"]);
    let cases: [(usize, &str); 4] =
        [(2, "native"), (4, "native"), (8, "native"), (4, "shared")];
    for (k, wiring) in cases {
        let drafter: &dyn InferenceBackend = match (wiring, &native_drafter) {
            ("native", Some(d)) => d,
            _ => be.as_ref(),
        };
        let mut spec = SpecEngine::with_drafter(
            drafter,
            be.as_ref(),
            SpecConfig { draft_k: k, max_active: 1, ..SpecConfig::default() },
        );
        for r in trace(3) {
            spec.submit(r);
        }
        spec.run()?;
        let tps = spec.metrics.decode_tokens_per_s();
        t2.row(&[
            k.to_string(),
            wiring.to_string(),
            format!("{tps:.1}"),
            format!("{:.2}x", tps / base_tps),
            format!("{:.1}%", spec.metrics.acceptance_rate() * 100.0),
            spec.metrics.rollbacks.to_string(),
        ]);
    }
    t2.print();
    Ok(())
}
