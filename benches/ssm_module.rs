//! Bench: SSM Module — host throughput of the fixed-point Step 1-3 datapath
//! and the simulated cycle rates, plus the dataflow-pipelining ablation
//! (the paper's "pipelined execution dataflow" gain).

use fastmamba::config::{AcceleratorConfig, ModelConfig};
use fastmamba::sim::ssm_module::{ssm_cycles_per_token, SsmModule};
use fastmamba::sim::PerfModel;
use fastmamba::util::bench::{bench_quick, Table};
use fastmamba::util::rng::Rng;

fn main() {
    let cfg = ModelConfig::tiny();
    let acc = AcceleratorConfig::default();
    let m = SsmModule::new(&acc);
    let mut rng = Rng::new(5);
    let nh = cfg.nheads();
    let x = rng.normal_vec(nh * cfg.headdim, 1.0);
    let dt_raw = rng.normal_vec(nh, 0.3);
    let dt_bias = vec![-3.0f32; nh];
    let a_neg = vec![-1.5f32; nh];
    let b = rng.normal_vec(cfg.d_state, 0.4);
    let c = rng.normal_vec(cfg.d_state, 0.4);
    let d = vec![1.0f32; nh];
    let mut st = SsmModule::zero_state(&cfg);

    let stt = bench_quick("ssm fixed step (tiny)", || {
        let y = m.step(&x, &dt_raw, &dt_bias, &a_neg, &b, &c, &d, &mut st, &cfg);
        std::hint::black_box(y);
    });
    println!("{stt}");
    let elems = (nh * cfg.headdim * cfg.d_state) as f64;
    println!(
        "host fixed-point state-update rate: {:.1} Melem/s",
        elems / stt.median_s / 1e6
    );

    println!("\nsimulated SSM cycles/token:");
    let mut t = Table::new(&["model", "cycles/token", "µs/token @250MHz"]);
    for cfg in [ModelConfig::tiny(), ModelConfig::mamba2_130m(), ModelConfig::mamba2_2_7b()] {
        let cyc = ssm_cycles_per_token(&acc, &cfg);
        t.row(&[cfg.name.clone(), cyc.to_string(), format!("{:.2}", cyc as f64 / 250.0)]);
    }
    t.print();

    println!("\ndataflow pipelining ablation (130M prefill L=512):");
    let mut pm = PerfModel::new(acc, ModelConfig::mamba2_130m());
    let piped = pm.prefill(512);
    pm.pipelined_dataflow = false;
    let seq = pm.prefill(512);
    println!(
        "pipelined {:.2} ms vs sequential {:.2} ms -> {:.2}x gain (bottleneck: {})",
        piped.seconds * 1e3,
        seq.seconds * 1e3,
        seq.seconds / piped.seconds,
        piped.bottleneck
    );
}
