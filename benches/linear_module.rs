//! Bench: Hadamard-based Linear Module — host throughput of the functional
//! quantized linear vs NormalQ vs fp32 (the Algorithm 1 overhead), and the
//! module's simulated cycle counts per paper-sized layer.

use fastmamba::config::AcceleratorConfig;
use fastmamba::quant::hadamard::{self, prepare_weight};
use fastmamba::quant::int8;
use fastmamba::sim::linear_module::linear_cycles;
use fastmamba::util::bench::{bench_quick, Table};
use fastmamba::util::rng::Rng;

fn main() {
    let (l, d, q) = (32usize, 768usize, 768usize);
    let mut rng = Rng::new(3);
    let x = rng.normal_vec(l * d, 1.0);
    let w = rng.normal_vec(q * d, 0.05);
    let mut y = vec![0.0f32; l * q];

    let mut t = Table::new(&["path", "median ms", "GMAC/s"]);
    let macs = (l * d * q) as f64;

    let st = bench_quick("fp32", || {
        for r in 0..l {
            for j in 0..q {
                let mut acc = 0.0f32;
                for k in 0..d {
                    acc += x[r * d + k] * w[j * d + k];
                }
                y[r * q + j] = acc;
            }
        }
        std::hint::black_box(&y);
    });
    t.row(&["fp32 matmul".into(), format!("{:.2}", st.median_s * 1e3),
            format!("{:.2}", macs / st.median_s / 1e9)]);

    let st = bench_quick("normalq", || {
        int8::normalq_linear(&x, l, &w, q, d, None, &mut y);
        std::hint::black_box(&y);
    });
    t.row(&["NormalQ W8A8".into(), format!("{:.2}", st.median_s * 1e3),
            format!("{:.2}", macs / st.median_s / 1e9)]);

    let pw = prepare_weight(&w, q, d, 64);
    let st = bench_quick("hadamard", || {
        hadamard::hadamard_linear(&x, l, &pw, None, &mut y);
        std::hint::black_box(&y);
    });
    t.row(&["Hadamard W8A8 (Alg.1)".into(), format!("{:.2}", st.median_s * 1e3),
            format!("{:.2}", macs / st.median_s / 1e9)]);
    t.print();

    println!("\nsimulated module cycles (250 MHz):");
    let acc = AcceleratorConfig::default();
    let mut t2 = Table::new(&["layer", "cycles", "µs", "eff int8 GMAC/s"]);
    for (name, ll, dd, qq) in [
        ("130M in_proj L=512", 512u64, 768u64, 3352u64),
        ("130M out_proj L=512", 512, 1536, 768),
        ("130M lm_head L=1", 1, 768, 50288),
    ] {
        let cyc = linear_cycles(&acc, ll, dd, qq);
        let us = cyc as f64 / 250e6 * 1e6;
        let rate = (ll * dd * qq) as f64 / (cyc as f64 / 250e6) / 1e9;
        t2.row(&[name.into(), cyc.to_string(), format!("{us:.1}"), format!("{rate:.0}")]);
    }
    t2.print();
}
