//! Bench: Table I VPUs + NAU — functional throughput of the simulator's
//! fixed-point units on this host (elements/s), and the cycle-model rates
//! they represent at 250 MHz.

use fastmamba::config::FixedSpec;
use fastmamba::sim::nau::{Nau, NauMode};
use fastmamba::sim::vpu::{Vpu, VpuKind};
use fastmamba::util::bench::{bench_quick, Table};

fn main() {
    let n = 4096usize;
    let spec = FixedSpec::default();
    let a: Vec<i32> = (0..n).map(|i| ((i * 37) % 2048) as i32 - 1024).collect();
    let b: Vec<i32> = (0..n).map(|i| ((i * 53) % 2048) as i32 - 1024).collect();
    let c: Vec<i32> = (0..n).map(|i| ((i * 71) % 2048) as i32 - 1024).collect();
    let mut out = vec![0i32; n];
    let _ = spec;

    let mut t = Table::new(&["unit", "host Melem/s", "sim cycles (n=4096 as 64-wide ops)"]);
    let pau = Vpu::new(VpuKind::Pau, 64);
    let st = bench_quick("pau", || pau.pau(&a, &b, &mut out));
    t.row(&["PAU".into(), format!("{:.1}", n as f64 / st.median_s / 1e6),
            pau.cycles((n / 64) as u64).to_string()]);
    let pmu = Vpu::new(VpuKind::Pmu, 64);
    let st = bench_quick("pmu", || pmu.pmu(&a, &b, &mut out));
    t.row(&["PMU".into(), format!("{:.1}", n as f64 / st.median_s / 1e6),
            pmu.cycles((n / 64) as u64).to_string()]);
    let pma = Vpu::new(VpuKind::Pma, 64);
    let st = bench_quick("pma", || pma.pma(&a, &b, &c, &mut out));
    t.row(&["PMA".into(), format!("{:.1}", n as f64 / st.median_s / 1e6),
            pma.cycles((n / 64) as u64).to_string()]);
    let hat = Vpu::new(VpuKind::Hat, 64);
    let st = bench_quick("hat", || {
        let mut s = 0i64;
        for ch in a.chunks(64) {
            s += hat.hat(ch) as i64;
        }
        std::hint::black_box(s);
    });
    t.row(&["HAT".into(), format!("{:.1}", n as f64 / st.median_s / 1e6),
            hat.cycles((n / 64) as u64).to_string()]);
    let mat = Vpu::new(VpuKind::Mat, 64);
    let st = bench_quick("mat", || {
        let mut s = 0i64;
        for (ca, cb) in a.chunks(64).zip(b.chunks(64)) {
            s += mat.mat(ca, cb) as i64;
        }
        std::hint::black_box(s);
    });
    t.row(&["MAT".into(), format!("{:.1}", n as f64 / st.median_s / 1e6),
            mat.cycles((n / 64) as u64).to_string()]);

    let nau = Nau::new(24);
    let mut no = vec![0i32; n];
    let st = bench_quick("nau.exp", || nau.eval(&a, NauMode::Exp, &mut no));
    t.row(&["NAU exp".into(), format!("{:.1}", n as f64 / st.median_s / 1e6),
            nau.cycles(n as u64).to_string()]);
    let st = bench_quick("nau.softplus", || nau.eval(&a, NauMode::SoftPlus, &mut no));
    t.row(&["NAU softplus".into(), format!("{:.1}", n as f64 / st.median_s / 1e6),
            nau.cycles(n as u64).to_string()]);
    t.print();
    println!(
        "(hardware rates at 250 MHz: PAU/PMU/PMA 64 lanes = 16 Gelem/s; NAU 24 lanes = 6 Gelem/s)"
    );
}
