//! Bench: SSM state-cache effectiveness on the two workloads it exists
//! for — shared system prompts and multi-turn sessions.
//!
//! Because Mamba2 state is constant-size, a prompt-cache hit costs one
//! O(state) snapshot copy instead of O(tokens) of KV memory; what this
//! bench measures is the serving payoff: prefill tokens actually skipped
//! and the resulting tok/s, cache on vs off.
//!
//! * **shared-prefix**: N requests sharing one long system prompt with
//!   short unique tails.  Cache-on output is asserted bit-identical to
//!   cache-off (prefix hits replay the identical chunk plan), and the
//!   prefill-token reduction is asserted > 50%.
//! * **sessions**: S chats x T turns, each turn replaying the whole
//!   transcript plus fresh input; resumed turns skip the transcript.
//!
//! `--json PATH` writes a machine-readable record (uploaded as a CI
//! artifact alongside `multi_worker_throughput`).
//!
//! Run: cargo bench --bench prefix_cache [-- --requests 24 --prefix-len 192 --json out.json]

use std::sync::Arc;
use std::time::Instant;

use fastmamba::backend::{self, BackendKind};
use fastmamba::coordinator::{Engine, EngineConfig, Metrics, Request};
use fastmamba::statecache::{CacheConfig, StateCache};
use fastmamba::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 24);
    let prefix_len = args.usize_or("prefix-len", 192);
    let max_new = args.usize_or("max-new", 8);
    let sessions = args.usize_or("sessions", 4);
    let turns = args.usize_or("turns", 3);
    let cache_mb = args.usize_or("state-cache-mb", 64);
    let kind = BackendKind::from_name(&args.get_or("backend", "native"))
        .expect("--backend auto|pjrt|native");

    let be = backend::load(kind)?;
    let vocab = be.cfg().vocab_size as u32;
    println!(
        "backend: {} (requests {n_requests}, prefix {prefix_len}, cache {cache_mb} MiB)",
        be.name()
    );

    // ---- workload A: shared system prompt ---------------------------------
    let sys: Vec<u32> = (0..prefix_len as u32).map(|j| (j * 7 + 3) % vocab).collect();
    let make_reqs = || -> Vec<Request> {
        (0..n_requests)
            .map(|i| {
                let mut prompt = sys.clone();
                prompt.extend(
                    (0..8 + 3 * (i % 9) as u32).map(|j| (i as u32 * 131 + j * 17) % vocab),
                );
                Request::new(i as u64, prompt, max_new, "fp32")
            })
            .collect()
    };
    let run = |cache: Option<Arc<StateCache>>| -> (Vec<(u64, Vec<u32>)>, Metrics, f64) {
        let mut eng = Engine::new(be.as_ref(), EngineConfig::default());
        if let Some(c) = cache {
            eng = eng.with_cache(c);
        }
        let t0 = Instant::now();
        for r in make_reqs() {
            eng.submit(r);
        }
        eng.run().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let mut got: Vec<(u64, Vec<u32>)> =
            eng.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        got.sort();
        (got, eng.metrics, wall)
    };

    let (out_off, m_off, wall_off) = run(None);
    let cache = Arc::new(StateCache::new(CacheConfig::with_mb(cache_mb)));
    let (out_on, m_on, wall_on) = run(Some(Arc::clone(&cache)));
    assert_eq!(out_off, out_on, "state cache changed generated tokens");

    let total_prompt = m_on.prompt_tokens;
    let saved = m_on.cache_tokens_saved;
    let reduction = saved as f64 / total_prompt.max(1) as f64;
    let gen_toks: u64 = out_on.iter().map(|(_, g)| g.len() as u64).sum();
    let tok_s_off = gen_toks as f64 / wall_off;
    let tok_s_on = gen_toks as f64 / wall_on;
    println!("shared-prefix cache off: {}", m_off.summary());
    println!("shared-prefix cache on : {}", m_on.summary());
    println!(
        "shared-prefix: {saved}/{total_prompt} prefill tokens skipped \
         ({:.1}% reduction), {tok_s_off:.1} -> {tok_s_on:.1} gen tok/s",
        reduction * 100.0
    );
    assert!(
        m_on.cache_hits > 0 && m_on.summary().contains("cache_hit="),
        "nonzero hit rate must be reported: {}",
        m_on.summary()
    );
    assert!(
        reduction > 0.5,
        "shared-system-prompt workload must skip >50% of prefill tokens, got {:.1}%",
        reduction * 100.0
    );

    // ---- workload B: multi-turn sessions ----------------------------------
    let run_sessions = |cache: Option<Arc<StateCache>>| -> (Metrics, f64) {
        let mut eng = Engine::new(be.as_ref(), EngineConfig::default());
        if let Some(c) = cache {
            eng = eng.with_cache(c);
        }
        let mut history: Vec<Vec<u32>> = (0..sessions)
            .map(|s| {
                (0..48 + 8 * (s as u32 % 4)).map(|j| (s as u32 * 211 + j * 13 + 1) % vocab).collect()
            })
            .collect();
        let t0 = Instant::now();
        for turn in 0..turns {
            for (sid, h) in history.iter().enumerate() {
                eng.submit(
                    Request::new((turn * sessions + sid) as u64, h.clone(), max_new, "fp32")
                        .with_session(sid as u64),
                );
            }
            eng.run().unwrap();
            for f in eng.finished.drain(..) {
                let sid = (f.id as usize) % sessions;
                history[sid].extend_from_slice(&f.generated);
                let t = history[sid].len() as u32;
                history[sid].extend((0..16u32).map(|j| (t * 31 + j * 13) % vocab));
            }
        }
        (eng.metrics, t0.elapsed().as_secs_f64())
    };

    let (sm_off, swall_off) = run_sessions(None);
    let scache = Arc::new(StateCache::new(CacheConfig::with_mb(cache_mb)));
    let (sm_on, swall_on) = run_sessions(Some(Arc::clone(&scache)));
    let s_reduction = sm_on.cache_tokens_saved as f64 / sm_on.prompt_tokens.max(1) as f64;
    println!("sessions cache off: {}", sm_off.summary());
    println!("sessions cache on : {}", sm_on.summary());
    println!(
        "sessions ({sessions} x {turns} turns): {}/{} prompt tokens skipped \
         ({:.1}% reduction), wall {swall_off:.3}s -> {swall_on:.3}s",
        sm_on.cache_tokens_saved,
        sm_on.prompt_tokens,
        s_reduction * 100.0
    );
    assert!(
        sm_on.cache_hits >= (sessions * (turns - 1)) as u64,
        "every resumed turn must hit the session cache: {}",
        sm_on.summary()
    );

    println!("cache: {}", cache.stats().summary());
    if let Some(path) = args.get("json") {
        let json = format!(
            "{{\"bench\":\"prefix_cache\",\"requests\":{n_requests},\
             \"prefix_len\":{prefix_len},\"max_new\":{max_new},\
             \"shared_prefix\":{{\"prompt_tokens\":{},\"tokens_saved\":{},\
             \"reduction\":{:.4},\"hits\":{},\"misses\":{},\
             \"wall_s_off\":{:.6},\"wall_s_on\":{:.6},\
             \"tok_per_s_off\":{:.2},\"tok_per_s_on\":{:.2}}},\
             \"sessions\":{{\"sessions\":{sessions},\"turns\":{turns},\
             \"prompt_tokens\":{},\"tokens_saved\":{},\"reduction\":{:.4},\
             \"hits\":{},\"wall_s_off\":{:.6},\"wall_s_on\":{:.6}}}}}\n",
            total_prompt,
            saved,
            reduction,
            m_on.cache_hits,
            m_on.cache_misses,
            wall_off,
            wall_on,
            tok_s_off,
            tok_s_on,
            sm_on.prompt_tokens,
            sm_on.cache_tokens_saved,
            s_reduction,
            sm_on.cache_hits,
            swall_off,
            swall_on,
        );
        std::fs::write(path, json)?;
        println!("wrote {path}");
    }
    Ok(())
}
