//! Bench: Fig. 1 — prefill runtime breakdown by component vs sequence
//! length, on both the GPU model (the paper's measurement) and the FastMamba
//! simulator (showing how the accelerator re-balances the components).

use fastmamba::baseline::GpuModel;
use fastmamba::config::{AcceleratorConfig, ModelConfig};
use fastmamba::sim::PerfModel;
use fastmamba::util::bench::Table;

fn main() {
    let cfg = ModelConfig::mamba2_130m();
    let gpu = GpuModel::default();
    println!("GPU (RTX 3090 model) prefill breakdown, Mamba2-130M:");
    let mut t = Table::new(&["seq_len", "linear%", "conv%", "ssm%", "norm+silu%", "total_ms"]);
    for l in [64usize, 128, 256, 512, 1024, 2048] {
        let b = gpu.prefill_breakdown(&cfg, l);
        let f = b.fractions();
        t.row(&[
            l.to_string(),
            format!("{:.1}", f[0].1 * 100.0),
            format!("{:.1}", f[1].1 * 100.0),
            format!("{:.1}", f[2].1 * 100.0),
            format!("{:.1}", f[3].1 * 100.0),
            format!("{:.2}", b.total() * 1e3),
        ]);
    }
    t.print();

    println!("\nFastMamba simulator compute-cycle breakdown (same model):");
    let fpga = PerfModel::new(AcceleratorConfig::default(), cfg);
    let mut t2 = Table::new(&["seq_len", "linear%", "conv%", "ssm%", "norm+silu%", "ms"]);
    for l in [64usize, 256, 1024] {
        let p = fpga.prefill(l);
        let f = p.breakdown.fractions();
        t2.row(&[
            l.to_string(),
            format!("{:.1}", f[0].1 * 100.0),
            format!("{:.1}", f[1].1 * 100.0),
            format!("{:.1}", f[2].1 * 100.0),
            format!("{:.1}", f[3].1 * 100.0),
            format!("{:.2}", p.seconds * 1e3),
        ]);
    }
    t2.print();
}
