//! Bench: Fig. 9 — prefill latency/speedup (FPGA sim vs measured CPU vs GPU
//! model) across sequence lengths on Mamba2-130M dimensions.  Also measures
//! the *actual* tiny-model prefill on this host to validate the CPU
//! composition model.

use fastmamba::baseline::{CpuBaseline, GpuModel};
use fastmamba::config::{AcceleratorConfig, ModelConfig};
use fastmamba::model::{ModelWeights};
use fastmamba::sim::PerfModel;
use fastmamba::util::bench::{bench_quick, Table};

fn main() {
    let cfg = ModelConfig::mamba2_130m();
    let fpga = PerfModel::new(AcceleratorConfig::default(), cfg.clone());
    let gpu = GpuModel::default();
    let cpu = CpuBaseline::measure();

    let mut t = Table::new(&[
        "seq_len", "fpga_ms", "gpu_ms", "cpu_raw_ms", "cpu_calib_ms", "vs_gpu", "vs_cpu",
    ]);
    for l in [64usize, 128, 256, 512, 1024, 2048] {
        let f = fpga.prefill(l).seconds;
        let g = gpu.prefill_seconds(&cfg, l);
        let c_raw = cpu.prefill_seconds(&cfg, l);
        let c = cpu.prefill_seconds_calibrated(&cfg, l);
        t.row(&[
            l.to_string(),
            format!("{:.2}", f * 1e3),
            format!("{:.2}", g * 1e3),
            format!("{:.0}", c_raw * 1e3),
            format!("{:.1}", c * 1e3),
            format!("{:.2}x", g / f),
            format!("{:.1}x", c / f),
        ]);
    }
    t.print();

    // validate the CPU model against a real measured prefill (tiny config)
    let tiny = ModelConfig::tiny();
    let w = ModelWeights::random(&tiny, 1);
    let st = bench_quick("tiny fp32 prefill L=64 (measured)", || {
        let _ = CpuBaseline::measure_prefill(&w, 64);
    });
    println!("{st}");
    println!(
        "model-predicted tiny L=64: {:.1} ms (measured median {:.1} ms)",
        cpu.prefill_seconds(&tiny, 64) * 1e3,
        st.median_s * 1e3
    );
}
