//! Bench: Table III — decode throughput & energy efficiency.
//!
//! Two parts: (a) the paper's Mamba2-2.7B comparison via the accelerator
//! simulator + GPU model + power models; (b) *measured* decode throughput
//! of the tiny serving model across batch buckets on whichever backend is
//! available — PJRT artifacts or the native model (the real serving hot
//! path on this host).

use fastmamba::backend::{self, BackendKind};
use fastmamba::baseline::GpuModel;
use fastmamba::config::{AcceleratorConfig, ModelConfig};
use fastmamba::sim::power::{accelerator_power_w, tokens_per_s_per_w};
use fastmamba::sim::PerfModel;
use fastmamba::util::bench::{bench_quick, Table};

fn main() -> anyhow::Result<()> {
    // (a) paper comparison at 2.7B
    let cfg = ModelConfig::mamba2_2_7b();
    let fpga = PerfModel::new(AcceleratorConfig::default(), cfg.clone());
    let gpu = GpuModel::default();
    let f = fpga.decode(1);
    let f_w = accelerator_power_w(&fpga.acc, 0.85);
    let g_tps = gpu.decode_tokens_per_s(&cfg);
    let mut t = Table::new(&["platform", "tok/s", "W", "tok/(s*W)"]);
    t.row(&["RTX3090 (model)".into(), format!("{g_tps:.1}"), "300".into(),
            format!("{:.3}", tokens_per_s_per_w(g_tps, 300.0))]);
    t.row(&["FastMamba (sim)".into(), format!("{:.2}", f.tokens_per_s),
            format!("{f_w:.1}"), format!("{:.3}", tokens_per_s_per_w(f.tokens_per_s, f_w))]);
    t.print();
    println!(
        "energy-efficiency ratio: {:.2}x (paper 1.65x) | FPGA decode is {}",
        tokens_per_s_per_w(f.tokens_per_s, f_w) / tokens_per_s_per_w(g_tps, 300.0),
        if f.compute_bound { "compute-bound" } else { "DRAM-bound" }
    );
    // batching sweep on the simulator
    let mut t2 = Table::new(&["batch", "sim tok/s"]);
    for b in [1usize, 2, 4, 8, 16, 32] {
        t2.row(&[b.to_string(), format!("{:.2}", fpga.decode(b).tokens_per_s)]);
    }
    t2.print();

    // (b) measured decode on the tiny serving model (PJRT artifacts when
    // available, the native backend otherwise)
    let be = backend::load(BackendKind::Auto)?;
    let cfg = be.cfg().clone();
    println!("\nmeasured backend: {}", be.name());
    let mut t3 = Table::new(&["variant", "batch", "ms/step", "tok/s"]);
    for variant in ["fp32", "fastmamba"] {
        for &b in &be.decode_batches() {
            let conv = vec![0.0f32; b * cfg.conv_state_len()];
            let ssm = vec![0.0f32; b * cfg.ssm_state_len()];
            let toks: Vec<i32> = (0..b as i32).collect();
            // warm the executable cache outside the timer
            be.decode(variant, b, &conv, &ssm, &toks)?;
            let st = bench_quick(&format!("decode {variant} B{b}"), || {
                let _ = be.decode(variant, b, &conv, &ssm, &toks).unwrap();
            });
            t3.row(&[
                variant.into(),
                b.to_string(),
                format!("{:.2}", st.median_s * 1e3),
                format!("{:.1}", b as f64 / st.median_s),
            ]);
        }
    }
    t3.print();
    Ok(())
}
