//! Bench: overload-safe scheduling — per-priority-class TTFT/TPOT
//! percentiles and SLO attainment under 4:1 bursty high:low traffic, with
//! priority aging off vs on, plus a preemption exactness check.
//!
//! Traffic: bursts of 4 high-priority (5) + 1 low-priority (0) requests
//! into a single worker whose pending queue is priority-ordered.  With
//! aging off, the sustained high-priority stream starves the low class —
//! its TTFT p99 grows with the backlog.  With `age_rate` > 0, a queued
//! low-priority request gains effective priority as it waits and is
//! promoted past steady high-priority arrivals, bounding its TTFT.  Both
//! runs assert zero requests lost (every submission retires `Length`).
//!
//! The preemption scenario runs the same request twice — once alone, once
//! preempted mid-decode by a high-priority arrival on a one-slot engine
//! with a state cache attached — and asserts the two token streams are
//! bit-identical (the snapshot/resume path changes latency, never tokens).
//!
//! `--json PATH` writes a machine-readable record (uploaded as a CI
//! artifact to track scheduling behavior over time).
//!
//! Run: cargo bench --bench overload_scheduling [-- --bursts 8 --json out.json]

use std::sync::Arc;
use std::time::{Duration, Instant};

use fastmamba::backend::{self, BackendKind};
use fastmamba::coordinator::{
    serve_pool, Engine, EngineConfig, Event, FinishReason, Metrics, PoolConfig, Request,
    SchedPolicy,
};
use fastmamba::obs::SortedSamples;
use fastmamba::statecache::{CacheConfig, StateCache};
use fastmamba::util::cli::Args;
use fastmamba::util::json::{self, num, obj, s as js, Json};

struct ClassStats {
    class: &'static str,
    priority: i32,
    n: usize,
    ttft_p50_ms: f64,
    ttft_p99_ms: f64,
    tpot_p99_ms: f64,
    /// fraction of the class meeting the TTFT SLO
    slo_attained: f64,
}

fn pct_or_zero(samples: Vec<f64>, p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    SortedSamples::new(samples).pct(p)
}

/// One traffic run at the given aging rate: submit 4:1 bursty traffic,
/// timestamp every token off the per-request event streams, and fold the
/// samples into per-priority-class percentiles.
#[allow(clippy::too_many_arguments)]
fn run_traffic(
    kind: BackendKind,
    age_rate: f64,
    bursts: usize,
    max_new: usize,
    max_active: usize,
    slo_ms: f64,
    vocab: usize,
) -> anyhow::Result<(Vec<ClassStats>, Metrics)> {
    let pool = serve_pool(
        move || backend::load(kind),
        PoolConfig {
            engine: EngineConfig { max_active, greedy_chunking: true },
            n_workers: 1,
            sched: SchedPolicy { age_rate, ..SchedPolicy::default() },
            ..PoolConfig::default()
        },
    );
    // warm up outside the measured window
    pool.submit(Request::new(1_000_000, vec![1, 2, 3], 2, "fp32"))?;
    pool.results.recv().expect("warmup result");

    let mut handles = Vec::with_capacity(bursts * 5);
    let mut meta: Vec<(i32, Instant)> = Vec::with_capacity(bursts * 5);
    let mut id = 0u64;
    for b in 0..bursts {
        for k in 0..5 {
            let prio = if k < 4 { 5 } else { 0 };
            let plen = [9usize, 17, 33, 17, 33][(b + k) % 5];
            let prompt: Vec<u32> = (0..plen)
                .map(|j| ((id as usize * 131 + j * 17) % vocab) as u32)
                .collect();
            meta.push((prio, Instant::now()));
            handles.push(
                pool.submit(Request::new(id, prompt, max_new, "fp32").with_priority(prio))?,
            );
            id += 1;
        }
        std::thread::sleep(Duration::from_millis(2));
    }

    let n = handles.len();
    let mut ttft: Vec<Option<f64>> = vec![None; n];
    let mut last: Vec<Option<Instant>> = vec![None; n];
    let mut tpot: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut reasons: Vec<Option<FinishReason>> = vec![None; n];
    let mut done = 0usize;
    while done < n {
        let mut progressed = false;
        for (i, h) in handles.iter().enumerate() {
            while let Some(ev) = h.try_event() {
                progressed = true;
                let now = Instant::now();
                match ev {
                    Event::FirstToken => {}
                    Event::Token { .. } => {
                        match last[i] {
                            Some(prev) => tpot[i].push((now - prev).as_secs_f64()),
                            None => ttft[i] = Some((now - meta[i].1).as_secs_f64()),
                        }
                        last[i] = Some(now);
                    }
                    Event::Finished(f) => {
                        reasons[i] = Some(f.finish_reason);
                        done += 1;
                    }
                }
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    for _ in 0..n {
        pool.results.recv().expect("buffered result"); // drain aggregate
    }
    let report = pool.finish()?;
    // zero requests lost: the queue is unbounded here, so every submission
    // must run to its full length — aging reorders, it never drops
    assert!(
        reasons.iter().all(|r| *r == Some(FinishReason::Length)),
        "requests lost under load: {reasons:?}"
    );

    let mut stats = Vec::new();
    for (class, prio) in [("high", 5i32), ("low", 0i32)] {
        let idx: Vec<usize> = (0..n).filter(|&i| meta[i].0 == prio).collect();
        let t: Vec<f64> = idx.iter().map(|&i| ttft[i].expect("ttft sample")).collect();
        let slo_attained =
            t.iter().filter(|v| **v * 1e3 <= slo_ms).count() as f64 / t.len() as f64;
        let tp: Vec<f64> = idx.iter().flat_map(|&i| tpot[i].iter().copied()).collect();
        stats.push(ClassStats {
            class,
            priority: prio,
            n: idx.len(),
            ttft_p50_ms: pct_or_zero(t.clone(), 0.50) * 1e3,
            ttft_p99_ms: pct_or_zero(t, 0.99) * 1e3,
            tpot_p99_ms: pct_or_zero(tp, 0.99) * 1e3,
            slo_attained,
        });
    }
    Ok((stats, report.merged))
}

/// The same request run unpreempted and preempted must produce identical
/// tokens: preemption snapshots the constant-size Mamba2 state, the
/// resume is a state-cache session hit, and sampling is position-keyed.
fn preempt_exactness(kind: BackendKind, max_new: usize) -> anyhow::Result<(usize, u64)> {
    let be = backend::load(kind)?;
    let vocab = be.cfg().vocab_size;
    let prompt: Vec<u32> = (0..33).map(|i| (i * 7 % vocab) as u32).collect();

    // reference: the victim alone, start to finish
    let want = {
        let mut eng =
            Engine::new(be.as_ref(), EngineConfig { max_active: 1, greedy_chunking: true });
        eng.submit(Request::new(0, prompt.clone(), max_new, "fp32"));
        eng.run()?;
        eng.finished[0].generated.clone()
    };

    // preempted: stream a few tokens, then a high-priority arrival evicts
    // the victim from the only slot; it resumes off its snapshot
    let cache = Arc::new(StateCache::new(CacheConfig::with_mb(64)));
    let mut eng = Engine::new(be.as_ref(), EngineConfig { max_active: 1, greedy_chunking: true })
        .with_policy(SchedPolicy { preempt_threshold: Some(5), ..SchedPolicy::default() })
        .with_cache(cache);
    let h = eng.submit(Request::new(0, prompt, max_new, "fp32"));
    let mut streamed = 0usize;
    while streamed < 4 {
        eng.step()?;
        while let Some(ev) = h.try_event() {
            if matches!(ev, Event::Token { .. }) {
                streamed += 1;
            }
        }
    }
    let hi: Vec<u32> = (0..9).map(|i| ((i * 3 + 1) % vocab) as u32).collect();
    eng.submit(Request::new(1, hi, 2, "fp32").with_priority(9));
    eng.run()?;
    let victim = eng.finished.iter().find(|f| f.id == 0).expect("victim finished");
    assert_eq!(victim.finish_reason, FinishReason::Length);
    assert_eq!(
        victim.generated, want,
        "preempted run diverged from the unpreempted reference"
    );
    Ok((want.len(), eng.metrics.preempted_requests))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let bursts = args.usize_or("bursts", 8);
    let max_new = args.usize_or("max-new", 16);
    let max_active = args.usize_or("max-active", 2);
    let slo_ms = args.f64_or("slo-ms", 500.0);
    let age_rate = args.f64_or("age-rate", 40.0);
    let kind = BackendKind::from_name(&args.get_or("backend", "native"))
        .expect("--backend auto|pjrt|native");

    let probe = backend::load(kind)?;
    let vocab = probe.cfg().vocab_size;
    println!(
        "backend: {} ({} bursts x (4 high + 1 low), max_new {max_new}, \
         SLO {slo_ms} ms)",
        probe.name(),
        bursts
    );
    drop(probe);

    let (off, off_metrics) =
        run_traffic(kind, 0.0, bursts, max_new, max_active, slo_ms, vocab)?;
    let (on, on_metrics) =
        run_traffic(kind, age_rate, bursts, max_new, max_active, slo_ms, vocab)?;

    for (label, stats, metrics) in
        [("aging off", &off, &off_metrics), ("aging on ", &on, &on_metrics)]
    {
        for c in stats.iter() {
            println!(
                "{label} class={:<4} (prio {}) n={:<3} ttft_p50={:.1}ms \
                 ttft_p99={:.1}ms tpot_p99={:.2}ms slo={:.0}%",
                c.class,
                c.priority,
                c.n,
                c.ttft_p50_ms,
                c.ttft_p99_ms,
                c.tpot_p99_ms,
                c.slo_attained * 100.0
            );
        }
        println!("{label} aging_reorders={}", metrics.aging_reorders);
    }
    let low_off = off.iter().find(|c| c.class == "low").expect("low class");
    let low_on = on.iter().find(|c| c.class == "low").expect("low class");
    println!(
        "low-priority ttft_p99: {:.1}ms (aging off) -> {:.1}ms (aging on, \
         rate {age_rate}/s)",
        low_off.ttft_p99_ms, low_on.ttft_p99_ms
    );

    let (victim_tokens, preempted) = preempt_exactness(kind, max_new)?;
    assert!(preempted >= 1, "preemption scenario never preempted");
    println!(
        "preemption: {preempted} preempted, victim resumed token-exact \
         ({victim_tokens} tokens)"
    );

    if let Some(path) = args.get("json") {
        let class_json = |c: &ClassStats| {
            obj(vec![
                ("class", js(c.class)),
                ("priority", num(c.priority as f64)),
                ("n", num(c.n as f64)),
                ("ttft_p50_ms", num(c.ttft_p50_ms)),
                ("ttft_p99_ms", num(c.ttft_p99_ms)),
                ("tpot_p99_ms", num(c.tpot_p99_ms)),
                ("slo_attained", num(c.slo_attained)),
            ])
        };
        let run_json = |stats: &[ClassStats], metrics: &Metrics| {
            obj(vec![
                ("classes", Json::Arr(stats.iter().map(class_json).collect())),
                ("metrics", metrics.to_json()),
            ])
        };
        let doc = obj(vec![
            ("bench", js("overload_scheduling")),
            ("bursts", num(bursts as f64)),
            ("ratio", js("4:1 high:low")),
            ("max_new", num(max_new as f64)),
            ("max_active", num(max_active as f64)),
            ("slo_ms", num(slo_ms)),
            ("age_rate", num(age_rate)),
            ("aging_off", run_json(&off, &off_metrics)),
            ("aging_on", run_json(&on, &on_metrics)),
            (
                "preemption",
                obj(vec![
                    ("preempted_requests", num(preempted as f64)),
                    ("victim_tokens", num(victim_tokens as f64)),
                    ("token_exact", Json::Bool(true)),
                ]),
            ),
        ]);
        std::fs::write(path, json::to_string(&doc))?;
        println!("wrote {path}");
    }
    Ok(())
}
