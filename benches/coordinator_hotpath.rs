//! Bench: coordinator hot path — engine decode-step overhead over raw
//! backend execution (target: <5%), batcher planning throughput, and
//! state-pool gather/scatter rates.  Runs on whichever backend is
//! available (PJRT artifacts or the artifact-free native model).

use fastmamba::backend::{self, BackendKind};
use fastmamba::config::ModelConfig;
use fastmamba::coordinator::{DecodeBatcher, Engine, EngineConfig, Request, StatePool};
use fastmamba::eval::corpus_for;
use fastmamba::util::bench::{bench, bench_quick};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let be = backend::load(BackendKind::Auto)?;
    let cfg = be.cfg().clone();
    println!("backend: {}", be.name());

    // raw backend decode at B=8 (batch-major: one pass over the batch)
    let b = 8usize;
    let cl = cfg.conv_state_len();
    let sl = cfg.ssm_state_len();
    let conv = vec![0.0f32; b * cl];
    let ssm = vec![0.0f32; b * sl];
    let toks: Vec<i32> = (0..b as i32).collect();
    be.decode("fp32", b, &conv, &ssm, &toks)?; // warm
    let raw = bench_quick("raw backend decode B8 (batch-major)", || {
        let _ = be.decode("fp32", b, &conv, &ssm, &toks).unwrap();
    });
    println!("{raw}");

    // the retired shape: the same 8 sequences stepped one at a time —
    // what NativeBackend::decode used to do internally per DecodeState copy
    let per_seq = bench_quick("raw backend decode 8 x B1 (per-sequence)", || {
        for s in 0..b {
            let _ = be
                .decode(
                    "fp32",
                    1,
                    &conv[s * cl..(s + 1) * cl],
                    &ssm[s * sl..(s + 1) * sl],
                    &toks[s..s + 1],
                )
                .unwrap();
        }
    });
    println!("{per_seq}");
    println!(
        "batch-major speedup over per-sequence stepping: {:.2}x",
        per_seq.median_s / raw.median_s
    );

    // engine-driven decode at 8 active requests (same executable)
    let corpus = corpus_for(be.as_ref());
    let mut engine =
        Engine::new(be.as_ref(), EngineConfig { max_active: 8, greedy_chunking: true });
    for id in 0..8u64 {
        let prompt: Vec<u32> = corpus[id as usize * 50..id as usize * 50 + 33]
            .iter()
            .map(|t| t % cfg.vocab_size as u32)
            .collect();
        engine.submit(Request::new(id, prompt, 100_000, "fp32")); // never finishes
    }
    engine.step()?; // admit (prefill) once
    let eng = bench("engine decode step (8 active)", 2, 5, Duration::from_millis(300), || {
        engine.step().unwrap();
    });
    println!("{eng}");
    let overhead = (eng.median_s - raw.median_s) / raw.median_s * 100.0;
    println!("coordinator overhead over raw backend: {overhead:.1}% (target < 5%)");

    // batcher planning rate
    let batcher = DecodeBatcher::new(be.decode_batches());
    let plan = bench_quick("batcher.plan(1000 active)", || {
        std::hint::black_box(batcher.plan(1000));
    });
    println!("{plan}");

    // state pool gather/scatter
    let mut pool = StatePool::new(&ModelConfig::tiny(), 8);
    let slots: Vec<usize> = (0..8).map(|_| pool.alloc().unwrap()).collect();
    let gs = bench_quick("state gather+scatter (8 slots)", || {
        let (c, s) = pool.gather(&slots);
        pool.scatter(&slots, &c, &s);
    });
    println!("{gs}");
    let bytes = 8.0 * pool.slot_bytes() as f64 * 2.0;
    println!("state move bandwidth: {:.2} GB/s", bytes / gs.median_s / 1e9);
    Ok(())
}
