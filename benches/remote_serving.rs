//! Bench: distributed serving overhead — local threads vs remote worker
//! processes behind the same pool router.
//!
//! Drives one fixed-seed mixed-length request trace through three
//! topologies of equal total capacity — 2 local workers, 1 local +
//! 1 remote, 2 remote — where each "remote" is a real `serve
//! --worker-mode` loop behind a loopback TCP socket speaking the wire
//! protocol.  Outputs are token-identical across topologies (asserted),
//! so the numbers isolate what the wire costs: throughput delta plus
//! frames/bytes shipped per generated token.
//!
//! `--json PATH` additionally writes a machine-readable record (uploaded
//! as a CI artifact to track the overhead trajectory over time).
//!
//! Run: cargo bench --bench remote_serving [-- --requests 32 --json out.json]

use std::sync::Arc;
use std::time::Instant;

use fastmamba::backend::{self, BackendKind};
use fastmamba::coordinator::{serve_pool, EngineConfig, PoolConfig, Request};
use fastmamba::obs::TelemetryHub;
use fastmamba::remote::serve_worker;
use fastmamba::util::cli::Args;
use fastmamba::util::json::{self, num, obj, s as js, Json};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 32);
    let max_new = args.usize_or("max-new", 24);
    let max_active = args.usize_or("max-active", 8);
    let kind = BackendKind::from_name(&args.get_or("backend", "native"))
        .expect("--backend auto|pjrt|native");

    let probe = backend::load(kind)?;
    let vocab = probe.cfg().vocab_size;
    println!("backend: {} ({} requests, max_new {max_new})", probe.name(), n_requests);
    drop(probe); // workers construct their own

    let make_requests = || -> Vec<Request> {
        (0..n_requests)
            .map(|i| {
                let plen = [9usize, 17, 33, 48][i % 4];
                let prompt: Vec<u32> =
                    (0..plen).map(|j| ((i * 131 + j * 17) % vocab) as u32).collect();
                Request::new(i as u64, prompt, max_new, "fp32")
            })
            .collect()
    };

    // (label, local workers, remote workers)
    let topologies = [("2-local", 2usize, 0usize), ("1+1-mixed", 1, 1), ("2-remote", 0, 2)];
    let mut rows: Vec<Json> = Vec::new();
    let mut outputs: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
    for (label, n_local, n_remote) in topologies {
        let servers: Vec<_> = (0..n_remote)
            .map(|_| {
                serve_worker(
                    "127.0.0.1:0",
                    move || backend::load(kind),
                    PoolConfig {
                        engine: EngineConfig { max_active, greedy_chunking: true },
                        n_workers: 1,
                        ..PoolConfig::default()
                    },
                )
                .expect("bind remote worker")
            })
            .collect();
        let remote: Vec<String> = servers.iter().map(|s| s.addr().to_string()).collect();
        let hub = Arc::new(TelemetryHub::new());
        let pool = serve_pool(
            move || backend::load(kind),
            PoolConfig {
                engine: EngineConfig { max_active, greedy_chunking: true },
                n_workers: n_local,
                remote,
                hub: Some(Arc::clone(&hub)),
                ..PoolConfig::default()
            },
        );
        // warm up outside the timed window: one tiny request per worker
        // forces backend construction (and remote handshakes) to finish
        // before the clock starts
        let n_workers = n_local + n_remote;
        for w in 0..n_workers {
            pool.submit(Request::new(1_000_000 + w as u64, vec![1, 2, 3], 2, "fp32"))?;
        }
        for _ in 0..n_workers {
            pool.results.recv().expect("warmup result");
        }

        let t0 = Instant::now();
        for r in make_requests() {
            pool.submit(r)?;
        }
        let mut got: Vec<(u64, Vec<u32>)> = (0..n_requests)
            .map(|_| {
                let f = pool.results.recv().expect("pool result");
                (f.id, f.generated)
            })
            .collect();
        let wall = t0.elapsed().as_secs_f64();
        let report = pool.finish()?;
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        got.sort();

        let toks: u64 = got.iter().map(|(_, g)| g.len() as u64).sum();
        let (mut bytes, mut frames) = (0u64, 0u64);
        for t in hub.remotes() {
            bytes += t.bytes_in() + t.bytes_out();
            frames += t.frames_in() + t.frames_out();
        }
        let wire_bytes_per_tok =
            if n_remote > 0 { bytes as f64 / toks as f64 } else { 0.0 };
        println!(
            "{label:>10}: {:.2} tok/s  wall {:.3}s  wire {bytes} B / {frames} frames \
             ({wire_bytes_per_tok:.1} B/tok)",
            toks as f64 / wall,
            wall,
        );
        rows.push(obj(vec![
            ("topology", js(label)),
            ("local", num(n_local as f64)),
            ("remote", num(n_remote as f64)),
            ("tokens", num(toks as f64)),
            ("wall_s", num(wall)),
            ("tok_per_s", num(toks as f64 / wall)),
            ("wire_bytes", num(bytes as f64)),
            ("wire_frames", num(frames as f64)),
            ("wire_bytes_per_token", num(wire_bytes_per_tok)),
        ]));
        outputs.push(got);
        for s in servers {
            s.kill();
            let _ = s.wait();
        }
    }

    // the wire must never change tokens — only where they were computed
    for o in &outputs[1..] {
        assert_eq!(&outputs[0], o, "topology changed generated tokens");
    }
    println!("outputs token-identical across topologies ✓");

    if let Some(path) = args.get("json") {
        let doc = obj(vec![
            ("bench", js("remote_serving")),
            ("requests", num(n_requests as f64)),
            ("max_new", num(max_new as f64)),
            ("rows", Json::Arr(rows)),
        ]);
        std::fs::write(path, json::to_string(&doc))?;
        println!("json -> {path}");
    }
    Ok(())
}
