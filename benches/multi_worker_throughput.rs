//! Bench: multi-worker serving throughput scaling.
//!
//! Drives one fixed-seed mixed-length request trace through `serve_pool`
//! at N ∈ {1, 2, 4} workers (each worker owns its own backend instance)
//! and reports aggregate generated-token throughput per worker count —
//! the serving analogue of the paper's keep-every-unit-busy scaling
//! argument.  Outputs are token-identical across worker counts (asserted),
//! so the only thing that changes is wall clock.
//!
//! `--json PATH` additionally writes a machine-readable record (uploaded
//! as a CI artifact to track the scaling trajectory over time).
//!
//! Run: cargo bench --bench multi_worker_throughput [-- --requests 48 --json out.json]

use std::time::Instant;

use fastmamba::backend::{self, BackendKind};
use fastmamba::coordinator::{serve_pool, EngineConfig, Metrics, PoolConfig, Request};
use fastmamba::util::cli::Args;
use fastmamba::util::json::{self, num, obj, s as js, Json};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 48);
    let max_new = args.usize_or("max-new", 24);
    let max_active = args.usize_or("max-active", 8);
    let kind = BackendKind::from_name(&args.get_or("backend", "native"))
        .expect("--backend auto|pjrt|native");

    let probe = backend::load(kind)?;
    let vocab = probe.cfg().vocab_size;
    println!("backend: {} ({} requests, max_new {max_new})", probe.name(), n_requests);
    drop(probe); // workers construct their own

    let make_requests = || -> Vec<Request> {
        (0..n_requests)
            .map(|i| {
                let plen = [9usize, 17, 33, 48][i % 4];
                let prompt: Vec<u32> =
                    (0..plen).map(|j| ((i * 131 + j * 17) % vocab) as u32).collect();
                Request::new(i as u64, prompt, max_new, "fp32")
            })
            .collect()
    };

    let mut rows: Vec<(usize, u64, f64, f64, Metrics)> = Vec::new();
    let mut outputs: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
    for n_workers in [1usize, 2, 4] {
        let pool = serve_pool(
            move || backend::load(kind),
            PoolConfig {
                engine: EngineConfig { max_active, greedy_chunking: true },
                n_workers,
                spec: None,
                cache: None,
                ..PoolConfig::default()
            },
        );
        // warm up outside the timed window: one tiny request per worker
        // forces every worker to finish backend construction (and any lazy
        // compilation) before the clock starts
        for w in 0..n_workers {
            pool.submit(Request::new(1_000_000 + w as u64, vec![1, 2, 3], 2, "fp32"))?;
        }
        for _ in 0..n_workers {
            pool.results.recv().expect("warmup result");
        }

        let t0 = Instant::now();
        for r in make_requests() {
            pool.submit(r)?;
        }
        let mut got: Vec<(u64, Vec<u32>)> = (0..n_requests)
            .map(|_| {
                let f = pool.results.recv().expect("pool result");
                (f.id, f.generated)
            })
            .collect();
        let wall = t0.elapsed().as_secs_f64();
        let report = pool.finish()?;
        assert!(report.errors.is_empty(), "worker errors: {:?}", report.errors);
        got.sort();
        // count only the measured trace (the merged metrics include warmup)
        let toks: u64 = got.iter().map(|(_, g)| g.len() as u64).sum();
        outputs.push(got);
        let tok_s = toks as f64 / wall;
        println!(
            "workers={n_workers}: {toks} gen toks in {wall:.3}s -> {tok_s:.1} tok/s \
             (assignments {:?}, load peaks {:?})",
            report.assignments, report.load_peak
        );
        println!("  merged: {}", report.merged.summary());
        rows.push((n_workers, toks, wall, tok_s, report.merged));
    }

    for w in outputs.windows(2) {
        assert_eq!(w[0], w[1], "worker count changed generated tokens");
    }
    println!("outputs token-identical across worker counts: true");
    let monotonic = rows.windows(2).all(|w| w[1].3 >= w[0].3);
    println!("aggregate gen tok/s monotone non-decreasing 1 -> 4 workers: {monotonic}");

    if let Some(path) = args.get("json") {
        // each run embeds its pool's full metrics under the same
        // `fastmamba.metrics.v1` schema that `serve --metrics-json` and
        // the streaming bench emit
        let runs: Vec<Json> = rows
            .iter()
            .map(|(n, t, w, ts, m)| {
                obj(vec![
                    ("workers", num(*n as f64)),
                    ("gen_tokens", num(*t as f64)),
                    ("wall_s", num(*w)),
                    ("tok_per_s", num(*ts)),
                    ("metrics", m.to_json()),
                ])
            })
            .collect();
        let doc = obj(vec![
            ("bench", js("multi_worker_throughput")),
            ("requests", num(n_requests as f64)),
            ("max_new", num(max_new as f64)),
            ("max_active", num(max_active as f64)),
            ("monotonic", Json::Bool(monotonic)),
            ("runs", Json::Arr(runs)),
        ]);
        std::fs::write(path, json::to_string(&doc))?;
        println!("wrote {path}");
    }
    Ok(())
}
