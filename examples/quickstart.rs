//! Quickstart: load a backend, prefill a prompt, decode a few tokens —
//! the minimal end-to-end path through the execution contract.  With
//! artifacts present (and the `pjrt` feature) this exercises the full
//! three-layer stack (Pallas kernels -> JAX model -> HLO artifacts ->
//! PJRT -> Rust); without them the native backend serves the same calls.
//!
//! Run: cargo run --release --example quickstart [-- --backend auto|pjrt|native]

use fastmamba::backend::{self, BackendKind};
use fastmamba::coordinator::request::argmax;
use fastmamba::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let kind = BackendKind::from_name(&args.get_or("backend", "auto"))
        .expect("--backend auto|pjrt|native");
    let be = backend::load(kind)?;
    let cfg = be.cfg().clone();
    println!(
        "loaded {} backend: {} ({} layers, d_model {}, vocab {})",
        be.name(),
        cfg.name,
        cfg.n_layer,
        cfg.d_model,
        cfg.vocab_size
    );

    // 1. prefill a 32-token prompt (one artifact bucket) with each variant
    let prompt: Vec<i32> = (0..32).map(|i| (i * 11) % cfg.vocab_size as i32).collect();
    for variant in ["fp32", "fastmamba"] {
        let out = be.prefill_fresh(variant, &prompt)?;
        let last = &out.logits[(prompt.len() - 1) * cfg.vocab_size..];
        println!(
            "{variant:>9} prefill: argmax(next)={}, logit range [{:.2}, {:.2}]",
            argmax(last),
            last.iter().fold(f32::INFINITY, |a, b| a.min(*b)),
            last.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b)),
        );
    }

    // 2. greedy-decode 12 tokens from the fp32 prefill state
    let out = be.prefill_fresh("fp32", &prompt)?;
    let mut conv = out.conv_state;
    let mut ssm = out.ssm_state;
    let mut tok = argmax(&out.logits[(prompt.len() - 1) * cfg.vocab_size..]) as i32;
    let mut generated = vec![tok];
    for _ in 0..11 {
        let step = be.decode("fp32", 1, &conv, &ssm, &[tok])?;
        conv = step.conv_state;
        ssm = step.ssm_state;
        tok = argmax(&step.logits) as i32;
        generated.push(tok);
    }
    println!("generated: {generated:?}");
    println!("quickstart OK");
    Ok(())
}
