//! Table II reproduction: perplexity + seven synthetic zero-shot tasks for
//! every quantization method on the build-time-trained tiny Mamba2.
//!
//! Expected shape (the paper's ordinal result): NormalQ ≪ SmoothQ <
//! FastMamba-LQ ≈ FP16 and FastMamba within ~1 point of FastMamba-LQ.
//!
//! Run: cargo run --release --example quant_accuracy [-- --ppl-windows 12 --cloze-items 30]

use fastmamba::report;
use fastmamba::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    report::table2(
        args.usize_or("ppl-windows", 12),
        args.usize_or("cloze-items", 30),
    )?;
    Ok(())
}
