//! Speculative decoding: token-exact equivalence + measured speedup.
//!
//! Serves a mixed-length trace twice: (a) plain greedy fp32 decode, one
//! request at a time (the latency baseline — one fp32 decode call per
//! generated token), and (b) the speculative engine (int8+PoT `fastmamba`
//! drafter + fp32 verifier) at draft lengths k ∈ {2, 4, 8}.  Asserts the
//! generated tokens are identical for every request at every k — the
//! correctness contract of speculative decoding — and reports the draft
//! acceptance rate and the measured decode speedup.
//!
//! Both drafter backends run: `native` steps the quantized golden model
//! in-process (cheap drafts — the host analogue of the FPGA drafter's
//! smaller weight stream), `pjrt` runs the AOT fastmamba decode
//! executable (drafter and verifier sharing one device).  The speedup
//! gate applies to the best configuration.
//!
//! Run: cargo run --release --example spec_decode [-- --requests 16 --max-new 24]

use fastmamba::coordinator::{
    DrafterBackend, Engine, EngineConfig, Request, SpecConfig, SpecEngine,
};
use fastmamba::eval::load_corpus;
use fastmamba::runtime::Runtime;
use fastmamba::util::bench::Table;
use fastmamba::util::cli::Args;
use fastmamba::util::rng::Rng;

fn trace(corpus: &[u32], vocab: u32, n_requests: usize, max_new: usize) -> Vec<Request> {
    let mut rng = Rng::new(23);
    (0..n_requests)
        .map(|id| {
            // mixed prompt lengths exercise full-bucket prefill, verifier
            // debt carry-over, and the drafter catch-up path
            let plen = [16usize, 24, 40, 70, 100, 150][rng.below(6)];
            let start = rng.below(corpus.len() - plen - 1);
            let prompt: Vec<u32> =
                corpus[start..start + plen].iter().map(|t| t % vocab).collect();
            Request::new(id as u64, prompt, max_new, "fp32")
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 16);
    let max_new = args.usize_or("max-new", 24);
    assert!(n_requests >= 16, "equivalence demo needs >= 16 requests");

    let rt = Runtime::load_default()?;
    let corpus = load_corpus(&rt.dir)?;
    let vocab = rt.weights_host.cfg.vocab_size as u32;

    // (a) baseline: plain greedy fp32, one request at a time (B = 1)
    let mut base = Engine::new(&rt, EngineConfig { max_active: 1, greedy_chunking: true });
    for r in trace(&corpus, vocab, n_requests, max_new) {
        base.submit(r);
    }
    base.run()?;
    let base_tps = base.metrics.decode_tokens_per_s();
    let mut want: Vec<(u64, Vec<u32>)> =
        base.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
    want.sort();
    println!(
        "baseline greedy fp32: {} requests, {:.1} gen tok/s ({:.3}s wall)",
        n_requests,
        base_tps,
        base.metrics.wall_s()
    );

    // (b) speculative: fastmamba drafter + fp32 verifier
    let cases = [
        (2usize, DrafterBackend::Native),
        (4, DrafterBackend::Native),
        (8, DrafterBackend::Native),
        (4, DrafterBackend::Pjrt),
    ];
    let mut t = Table::new(&[
        "k", "drafter", "gen tok/s", "speedup", "accept", "rounds", "rollbacks",
    ]);
    let mut best: Option<(usize, f64, f64)> = None; // (k, speedup, accept)
    let mut n_cases = 0usize;
    for (k, backend) in cases {
        let mut spec = SpecEngine::new(
            &rt,
            SpecConfig {
                draft_k: k,
                max_active: 1,
                drafter_backend: backend,
                ..SpecConfig::default()
            },
        );
        for r in trace(&corpus, vocab, n_requests, max_new) {
            spec.submit(r);
        }
        spec.run()?;
        let mut got: Vec<(u64, Vec<u32>)> =
            spec.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        got.sort();
        assert_eq!(
            want, got,
            "k={k} {backend:?}: speculative output diverged from plain greedy fp32"
        );
        n_cases += 1;
        let tps = spec.metrics.decode_tokens_per_s();
        let speedup = tps / base_tps;
        let accept = spec.metrics.acceptance_rate();
        t.row(&[
            k.to_string(),
            format!("{backend:?}").to_lowercase(),
            format!("{tps:.1}"),
            format!("{speedup:.2}x"),
            format!("{:.1}%", accept * 100.0),
            spec.metrics.spec_rounds.to_string(),
            spec.metrics.rollbacks.to_string(),
        ]);
        if best.map(|(_, s, _)| speedup > s).unwrap_or(true) {
            best = Some((k, speedup, accept));
        }
    }
    t.print();

    let (k, speedup, accept) = best.unwrap();
    println!(
        "token-exact equivalence: OK ({n_requests} requests x {n_cases} \
         speculative configurations, {max_new} tokens each)"
    );
    println!(
        "best: k={k} -> {speedup:.2}x speedup over plain greedy fp32 decode \
         at {:.1}% draft acceptance",
        accept * 100.0
    );
    assert!(
        speedup > 1.0,
        "speculative decode must beat plain greedy fp32 decode (got {speedup:.2}x)"
    );
    println!("spec_decode OK");
    Ok(())
}
