//! Speculative decoding: token-exact equivalence + measured speedup.
//!
//! Serves a mixed-length trace twice: (a) plain greedy fp32 decode, one
//! request at a time (the latency baseline — one fp32 decode call per
//! generated token), and (b) the speculative engine (int8+PoT `fastmamba`
//! drafter + fp32 verifier) at draft lengths k ∈ {2, 4, 8}.  Asserts the
//! generated tokens are identical for every request at every k — the
//! correctness contract of speculative decoding — and reports the draft
//! acceptance rate and the measured decode speedup.
//!
//! Two drafter wirings run: `native` steps the quantized golden model
//! in-process (cheap drafts — the host analogue of the FPGA drafter's
//! smaller weight stream), `shared` drafts on the serving backend itself
//! (drafter and verifier sharing one device).  The speedup gate applies
//! to the best configuration, and only on the PJRT backend — a pure
//! native run has no marshalling asymmetry to exploit, so there the
//! example checks equivalence only.
//!
//! Run: cargo run --release --example spec_decode [-- --requests 16 --max-new 24]

use fastmamba::backend::{self, BackendKind, InferenceBackend, NativeBackend};
use fastmamba::coordinator::{Engine, EngineConfig, Request, SpecConfig, SpecEngine};
use fastmamba::eval::corpus_for;
use fastmamba::util::bench::Table;
use fastmamba::util::cli::Args;
use fastmamba::util::rng::Rng;

fn trace(corpus: &[u32], vocab: u32, n_requests: usize, max_new: usize) -> Vec<Request> {
    let mut rng = Rng::new(23);
    (0..n_requests)
        .map(|id| {
            // mixed prompt lengths exercise full-bucket prefill, verifier
            // debt carry-over, and the drafter catch-up path
            let plen = [16usize, 24, 40, 70, 100, 150][rng.below(6)];
            let start = rng.below(corpus.len() - plen - 1);
            let prompt: Vec<u32> =
                corpus[start..start + plen].iter().map(|t| t % vocab).collect();
            Request::new(id as u64, prompt, max_new, "fp32")
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 16);
    let max_new = args.usize_or("max-new", 24);
    assert!(n_requests >= 16, "equivalence demo needs >= 16 requests");

    let kind = BackendKind::from_name(&args.get_or("backend", "auto"))
        .expect("--backend auto|pjrt|native");
    let be = backend::load(kind)?;
    let corpus = corpus_for(be.as_ref());
    let vocab = be.cfg().vocab_size as u32;
    println!("verifier backend: {}", be.name());

    // (a) baseline: plain greedy fp32, one request at a time (B = 1)
    let mut base =
        Engine::new(be.as_ref(), EngineConfig { max_active: 1, greedy_chunking: true });
    for r in trace(&corpus, vocab, n_requests, max_new) {
        base.submit(r);
    }
    base.run()?;
    let base_tps = base.metrics.decode_tokens_per_s();
    let mut want: Vec<(u64, Vec<u32>)> =
        base.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
    want.sort();
    println!(
        "baseline greedy fp32: {} requests, {:.1} gen tok/s ({:.3}s wall)",
        n_requests,
        base_tps,
        base.metrics.wall_s()
    );

    // (b) speculative: fastmamba drafter + fp32 verifier.  A *separate*
    // in-process drafter only makes sense next to a device verifier; on a
    // native serving backend "native" and "shared" collapse to one wiring.
    let native_drafter: Option<NativeBackend> = if be.name() == "native" {
        None
    } else {
        Some(NativeBackend::load_default()?)
    };
    let cases: [(usize, &str); 4] =
        [(2, "native"), (4, "native"), (8, "native"), (4, "shared")];
    let mut t = Table::new(&[
        "k", "drafter", "gen tok/s", "speedup", "accept", "rounds", "rollbacks",
    ]);
    let mut best: Option<(usize, f64, f64)> = None; // (k, speedup, accept)
    let mut n_cases = 0usize;
    for (k, wiring) in cases {
        let drafter: &dyn InferenceBackend = match (wiring, &native_drafter) {
            ("native", Some(d)) => d,
            _ => be.as_ref(),
        };
        let mut spec = SpecEngine::with_drafter(
            drafter,
            be.as_ref(),
            SpecConfig { draft_k: k, max_active: 1, ..SpecConfig::default() },
        );
        for r in trace(&corpus, vocab, n_requests, max_new) {
            spec.submit(r);
        }
        spec.run()?;
        let mut got: Vec<(u64, Vec<u32>)> =
            spec.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        got.sort();
        assert_eq!(
            want, got,
            "k={k} drafter={wiring}: speculative output diverged from plain greedy fp32"
        );
        n_cases += 1;
        let tps = spec.metrics.decode_tokens_per_s();
        let speedup = tps / base_tps;
        let accept = spec.metrics.acceptance_rate();
        t.row(&[
            k.to_string(),
            wiring.to_string(),
            format!("{tps:.1}"),
            format!("{speedup:.2}x"),
            format!("{:.1}%", accept * 100.0),
            spec.metrics.spec_rounds.to_string(),
            spec.metrics.rollbacks.to_string(),
        ]);
        if best.map(|(_, s, _)| speedup > s).unwrap_or(true) {
            best = Some((k, speedup, accept));
        }
    }
    t.print();

    let (k, speedup, accept) = best.unwrap();
    println!(
        "token-exact equivalence: OK ({n_requests} requests x {n_cases} \
         speculative configurations, {max_new} tokens each)"
    );
    println!(
        "best: k={k} -> {speedup:.2}x speedup over plain greedy fp32 decode \
         at {:.1}% draft acceptance",
        accept * 100.0
    );
    if be.name() == "pjrt" {
        assert!(
            speedup > 1.0,
            "speculative decode must beat plain greedy fp32 decode (got {speedup:.2}x)"
        );
    } else {
        println!(
            "(speedup gate skipped on the {} backend: no per-call marshalling \
             asymmetry to exploit in-process)",
            be.name()
        );
    }
    println!("spec_decode OK");
    Ok(())
}
