//! Fig. 9 + Fig. 1 reproduction: prefill speedup of the simulated FastMamba
//! accelerator over the measured-calibrated CPU baseline and the analytical
//! RTX 3090 model, across sequence lengths, plus the GPU runtime breakdown
//! that motivates the design.
//!
//! Run: cargo run --release --example prefill_sweep

use fastmamba::baseline::CpuBaseline;
use fastmamba::report;

fn main() {
    report::fig1();
    let cpu = CpuBaseline::measure();
    println!(
        "\n(CPU microbench: {:.2} GMAC/s matmul, {:.2} Gop/s elementwise, x{} Xeon-4210R calibration)",
        cpu.cal.matmul_macs_per_s / 1e9,
        cpu.cal.elem_ops_per_s / 1e9,
        fastmamba::baseline::cpu::XEON_4210R_SCALE
    );
    report::fig9(Some(&cpu));
}
