//! Serving scenario: a Poisson-ish arrival trace of mixed-length prompts
//! batched through the engine, reporting TTFT / latency / throughput for
//! both the fp32 and fastmamba (quantized) variants — the end-to-end
//! driver proving all layers compose on a real workload, on whichever
//! backend is available (PJRT artifacts or the artifact-free native model).
//!
//! With `--workers N` (N > 1) the same trace additionally runs through the
//! multi-worker pool (`serve_pool`) and the outputs are asserted
//! token-identical to the single-engine run — worker fan-out changes
//! throughput, never tokens.  With `--state-cache-mb N` the pool workers
//! share one SSM state cache (prefix hits are bit-exact, so the equality
//! assertion still holds).
//!
//! With `--sessions S --turns T` (T > 1) a multi-turn chat scenario runs
//! on top: S concurrent sessions, each turn replaying the whole transcript
//! plus fresh user tokens.  Every resumed turn must hit the session cache
//! and skip its entire history prefill — the O(state) alternative to
//! O(tokens) KV prompt caching.
//!
//! With `--stream` the engine is stepped manually and every request's
//! lifecycle events (`FirstToken`, per-token `Token`, terminal `Finished`)
//! are printed as the SSM step produces them; the streamed token sequences
//! are asserted bit-identical to the batch `FinishedRequest` output (this
//! assertion runs in both modes — streaming changes delivery, never
//! tokens).
//!
//! Run: cargo run --release --example serve_requests [-- --requests 24 --backend native --workers 4 --sessions 4 --turns 3 --state-cache-mb 64 --stream]

use std::sync::Arc;

use fastmamba::backend::{self, BackendKind};
use fastmamba::coordinator::{
    serve_pool, Engine, EngineConfig, Event, Metrics, PoolConfig, Request,
};
use fastmamba::eval::corpus_for;
use fastmamba::obs::TraceSink;
use fastmamba::statecache::{CacheConfig, StateCache};
use fastmamba::util::cli::Args;
use fastmamba::util::json;
use fastmamba::util::rng::Rng;

/// Record a token event into the per-request stream transcript.
fn record(streams: &mut [Vec<u32>], id: u64, ev: &Event) {
    if let Event::Token { tok, .. } = ev {
        streams[id as usize].push(*tok);
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 16);
    let max_new = args.usize_or("max-new", 12);
    let max_active = args.usize_or("max-active", 16);
    let workers = args.usize_or("workers", 1);
    let sessions = args.usize_or("sessions", 4);
    let turns = args.usize_or("turns", 3);
    let cache_mb = args.usize_or("state-cache-mb", 64);
    let stream = args.bool("stream");
    // observability: --metrics-json writes one aggregated
    // `fastmamba.metrics.v1` snapshot merged over every phase below;
    // --trace-out records request spans across all of them
    let metrics_json = args.get("metrics-json");
    let trace_sample = args.usize_or("trace-sample", 1).max(1);
    let trace_sink: Option<Arc<TraceSink>> = args
        .get("trace-out")
        .is_some()
        .then(|| Arc::new(TraceSink::new(trace_sample as u64)));
    let mut agg = Metrics::default();
    // each engine/pool phase gets its own trace lane for its batch spans
    let mut lane = 0u32;

    let kind = BackendKind::from_name(&args.get_or("backend", "auto"))
        .expect("--backend auto|pjrt|native");
    let be = backend::load(kind)?;
    let corpus = corpus_for(be.as_ref());
    let vocab = be.cfg().vocab_size as u32;
    println!("backend: {}", be.name());

    for variant in ["fp32", "fastmamba"] {
        let trace = |id: usize, rng: &mut Rng| -> Request {
            // mixed prompt lengths exercise the chunk planner
            let plen = [24usize, 40, 70, 100, 150][rng.below(5)];
            let start = rng.below(corpus.len() - plen - 1);
            let prompt: Vec<u32> =
                corpus[start..start + plen].iter().map(|t| t % vocab).collect();
            Request::new(id as u64, prompt, max_new, variant)
        };

        let mut engine = Engine::new(
            be.as_ref(),
            EngineConfig { max_active, greedy_chunking: true },
        );
        if let Some(s) = &trace_sink {
            engine = engine.with_trace(Arc::clone(s), lane);
            lane += 1;
        }
        let mut rng = Rng::new(11);
        let mut handles = Vec::with_capacity(n_requests);
        for id in 0..n_requests {
            handles.push(engine.submit(trace(id, &mut rng)));
        }
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); n_requests];
        if stream {
            // manual drive: drain and print lifecycle events after every
            // engine step — tokens appear as the SSM step produces them
            let mut printed = 0usize;
            engine.metrics.start();
            while engine.n_pending() > 0 || engine.n_active() > 0 {
                engine.step()?;
                for h in &handles {
                    while let Some(ev) = h.try_event() {
                        if printed < 24 {
                            match &ev {
                                Event::FirstToken => println!(
                                    "[{variant}][stream] req {}: first token",
                                    h.id()
                                ),
                                Event::Token { tok, index } => println!(
                                    "[{variant}][stream] req {}: #{index} -> {tok}",
                                    h.id()
                                ),
                                Event::Finished(f) => println!(
                                    "[{variant}][stream] req {}: finished ({:?})",
                                    h.id(),
                                    f.finish_reason
                                ),
                            }
                            printed += 1;
                            if printed == 24 {
                                println!("[{variant}][stream] ... (output capped)");
                            }
                        }
                        record(&mut streams, h.id(), &ev);
                    }
                }
            }
            engine.metrics.stop();
        } else {
            engine.run()?;
        }
        println!("[{variant}] {}", engine.metrics.summary());
        println!(
            "[{variant}] decode batch padding waste: {:.1}% of slots",
            engine.metrics.padding_frac() * 100.0
        );
        // consistency: every request generated exactly max_new tokens
        assert_eq!(engine.finished.len(), n_requests);
        for f in &engine.finished {
            assert_eq!(f.generated.len(), max_new);
        }
        // streaming changes delivery, never tokens: the per-request event
        // streams must be bit-identical to the batch output (in batch mode
        // the events are drained here — they buffered during run())
        for h in &handles {
            while let Some(ev) = h.try_event() {
                record(&mut streams, h.id(), &ev);
            }
        }
        for f in &engine.finished {
            assert_eq!(
                streams[f.id as usize], f.generated,
                "[{variant}] req {}: stream diverged from batch output",
                f.id
            );
        }

        if workers > 1 {
            // the same trace through the worker pool: token-identical even
            // with a shared state cache (prefix hits are bit-exact)
            let pool_cache = (cache_mb > 0)
                .then(|| Arc::new(StateCache::new(CacheConfig::with_mb(cache_mb))));
            let pool = serve_pool(
                move || backend::load(kind),
                PoolConfig {
                    engine: EngineConfig { max_active, greedy_chunking: true },
                    n_workers: workers,
                    spec: None,
                    cache: pool_cache.clone(),
                    trace: trace_sink.clone(),
                    ..PoolConfig::default()
                },
            );
            let mut rng = Rng::new(11);
            for id in 0..n_requests {
                pool.submit(trace(id, &mut rng))?;
            }
            let mut pooled: Vec<(u64, Vec<u32>)> = (0..n_requests)
                .map(|_| {
                    let f = pool.results.recv().expect("pool result");
                    (f.id, f.generated)
                })
                .collect();
            let report = pool.finish()?;
            assert!(report.errors.is_empty(), "{:?}", report.errors);
            pooled.sort();
            let mut single: Vec<(u64, Vec<u32>)> = engine
                .finished
                .iter()
                .map(|f| (f.id, f.generated.clone()))
                .collect();
            single.sort();
            assert_eq!(single, pooled, "[{variant}] pool output diverged");
            println!("[{variant}] pool ({workers} workers): {}", report.merged.summary());
            println!(
                "[{variant}] pool assignments {:?}, load peaks {:?} — token-exact \
                 with the single engine",
                report.assignments, report.load_peak
            );
            if let Some(c) = &pool_cache {
                println!("[{variant}] pool state cache: {}", c.stats().summary());
            }
            agg.merge(&report.merged);
        }
        agg.merge(&engine.metrics);
    }

    if sessions > 0 && turns > 1 && cache_mb > 0 {
        // multi-turn session mode: every turn after the first replays the
        // whole transcript and must resume from the session cache instead
        // of re-prefilling it
        let cache = Arc::new(StateCache::new(CacheConfig::with_mb(cache_mb)));
        let mut engine = Engine::new(
            be.as_ref(),
            EngineConfig { max_active, greedy_chunking: true },
        )
        .with_cache(Arc::clone(&cache));
        if let Some(s) = &trace_sink {
            engine = engine.with_trace(Arc::clone(s), lane);
            lane += 1;
        }
        let mut rng = Rng::new(23);
        // per-session transcript so far (prompt of the next turn)
        let mut history: Vec<Vec<u32>> = (0..sessions)
            .map(|_| {
                let plen = 48 + 8 * rng.below(5);
                let start = rng.below(corpus.len() - plen - 1);
                corpus[start..start + plen].iter().map(|t| t % vocab).collect()
            })
            .collect();
        for turn in 0..turns {
            for (sid, h) in history.iter().enumerate() {
                let req = Request::new((turn * sessions + sid) as u64, h.clone(), max_new, "fp32")
                    .with_session(sid as u64);
                engine.submit(req);
            }
            engine.run()?;
            let finished: Vec<_> = engine.finished.drain(..).collect();
            for f in finished {
                let sid = (f.id as usize) % sessions;
                // next turn: transcript + the model's reply + new user input
                let h = &mut history[sid];
                h.extend_from_slice(&f.generated);
                let start = rng.below(corpus.len() - 17);
                h.extend(corpus[start..start + 16].iter().map(|t| t % vocab));
            }
        }
        let m = &engine.metrics;
        println!("sessions ({sessions} x {turns} turns): {}", m.summary());
        println!("session state cache: {}", cache.stats().summary());
        // every turn after the first resumes its session mid-transcript
        assert!(
            m.cache_hits >= (sessions * (turns - 1)) as u64,
            "every resumed turn must hit the session cache: {}",
            m.summary()
        );
        assert!(
            m.cache_tokens_saved > 0,
            "resumed turns must skip transcript prefill"
        );
        println!(
            "session resume skipped {} of {} transcript prompt tokens",
            m.cache_tokens_saved, m.prompt_tokens
        );
        agg.merge(m);
    }
    if let (Some(sink), Some(path)) = (&trace_sink, args.get("trace-out")) {
        sink.write(path)?;
        println!("trace: {} events -> {path}", sink.len());
    }
    if let Some(path) = metrics_json {
        std::fs::write(path, json::to_string(&agg.to_json()))?;
        println!("metrics json -> {path}");
    }
    println!("serve_requests OK");
    Ok(())
}
