//! Serving scenario: a Poisson-ish arrival trace of mixed-length prompts
//! batched through the engine, reporting TTFT / latency / throughput for
//! both the fp32 and fastmamba (quantized) variants — the end-to-end
//! driver proving all layers compose on a real workload, on whichever
//! backend is available (PJRT artifacts or the artifact-free native model).
//!
//! With `--workers N` (N > 1) the same trace additionally runs through the
//! multi-worker pool (`serve_pool`) and the outputs are asserted
//! token-identical to the single-engine run — worker fan-out changes
//! throughput, never tokens.
//!
//! Run: cargo run --release --example serve_requests [-- --requests 24 --backend native --workers 4]

use fastmamba::backend::{self, BackendKind};
use fastmamba::coordinator::{serve_pool, Engine, EngineConfig, PoolConfig, Request};
use fastmamba::eval::corpus_for;
use fastmamba::util::cli::Args;
use fastmamba::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 16);
    let max_new = args.usize_or("max-new", 12);
    let max_active = args.usize_or("max-active", 16);
    let workers = args.usize_or("workers", 1);

    let kind = BackendKind::from_name(&args.get_or("backend", "auto"))
        .expect("--backend auto|pjrt|native");
    let be = backend::load(kind)?;
    let corpus = corpus_for(be.as_ref());
    let vocab = be.cfg().vocab_size as u32;
    println!("backend: {}", be.name());

    for variant in ["fp32", "fastmamba"] {
        let trace = |id: usize, rng: &mut Rng| -> Request {
            // mixed prompt lengths exercise the chunk planner
            let plen = [24usize, 40, 70, 100, 150][rng.below(5)];
            let start = rng.below(corpus.len() - plen - 1);
            let prompt: Vec<u32> =
                corpus[start..start + plen].iter().map(|t| t % vocab).collect();
            Request::new(id as u64, prompt, max_new, variant)
        };

        let mut engine = Engine::new(
            be.as_ref(),
            EngineConfig { max_active, greedy_chunking: true },
        );
        let mut rng = Rng::new(11);
        for id in 0..n_requests {
            engine.submit(trace(id, &mut rng));
        }
        engine.run()?;
        println!("[{variant}] {}", engine.metrics.summary());
        println!(
            "[{variant}] decode batch padding waste: {:.1}% of slots",
            engine.metrics.padding_frac() * 100.0
        );
        // consistency: every request generated exactly max_new tokens
        assert_eq!(engine.finished.len(), n_requests);
        for f in &engine.finished {
            assert_eq!(f.generated.len(), max_new);
        }

        if workers > 1 {
            // the same trace through the worker pool: token-identical
            let pool = serve_pool(
                move || backend::load(kind),
                PoolConfig {
                    engine: EngineConfig { max_active, greedy_chunking: true },
                    n_workers: workers,
                    spec: None,
                },
            );
            let mut rng = Rng::new(11);
            for id in 0..n_requests {
                pool.submit(trace(id, &mut rng))?;
            }
            let mut pooled: Vec<(u64, Vec<u32>)> = (0..n_requests)
                .map(|_| {
                    let f = pool.results.recv().expect("pool result");
                    (f.id, f.generated)
                })
                .collect();
            let report = pool.finish()?;
            assert!(report.errors.is_empty(), "{:?}", report.errors);
            pooled.sort();
            let mut single: Vec<(u64, Vec<u32>)> = engine
                .finished
                .iter()
                .map(|f| (f.id, f.generated.clone()))
                .collect();
            single.sort();
            assert_eq!(single, pooled, "[{variant}] pool output diverged");
            println!("[{variant}] pool ({workers} workers): {}", report.merged.summary());
            println!(
                "[{variant}] pool assignments {:?}, load peaks {:?} — token-exact \
                 with the single engine",
                report.assignments, report.load_peak
            );
        }
    }
    println!("serve_requests OK");
    Ok(())
}
