//! Accelerator hardware reports: Table I (VPU configuration), Table IV
//! (resource utilization), Fig. 10 (NAU vs FP16 nonlinear unit), plus the
//! power/energy summary behind Table III.
//!
//! Run: cargo run --release --example accelerator_report

use fastmamba::config::AcceleratorConfig;
use fastmamba::report;
use fastmamba::sim::power::accelerator_power_w;

fn main() {
    report::table1();
    report::table4();
    report::fig10();
    let acc = AcceleratorConfig::default();
    println!(
        "\nestimated board power @85% activity: {:.1} W (paper-implied ~9.3 W class)",
        accelerator_power_w(&acc, 0.85)
    );
}
