"""NAU (Eq. 3-6) correctness: Pallas kernel vs bit-exact reference vs math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.config import FXP
from compile.kernels import nonlinear, ref

RNG = np.random.RandomState(0)


def fx(vals):
    return jnp.asarray(np.asarray(vals, np.int32))


class TestExpFixedRef:
    def test_zero(self):
        assert int(ref.exp_fixed_ref(fx([0]))[0]) == FXP.scale

    def test_monotone_nonincreasing_in_magnitude(self):
        xs = fx(-np.arange(0, 8 * FXP.scale, 13))
        ys = np.asarray(ref.exp_fixed_ref(xs))
        assert (np.diff(ys) <= 0).all()

    def test_matches_true_exp(self):
        x = RNG.uniform(-12, 0, 4096).astype(np.float32)
        got = np.asarray(ref.exp_approx_f32(jnp.asarray(x)))
        np.testing.assert_allclose(got, np.exp(x), atol=4e-3)

    def test_underflow_to_zero(self):
        assert int(ref.exp_fixed_ref(fx([FXP.qmin]))[0]) == 0

    def test_range(self):
        xs = fx(-RNG.randint(0, 1 << 15, 1000))
        ys = np.asarray(ref.exp_fixed_ref(xs))
        assert (ys >= 0).all() and (ys <= FXP.scale).all()


class TestSoftplusFixedRef:
    def test_symmetry_identity(self):
        """Eq. 4: SoftPlus(x) = x + SoftPlus(-x), exactly in fixed point."""
        xs = fx(RNG.randint(-(1 << 14), 1 << 14, 2000))
        sp_pos = np.asarray(ref.softplus_fixed_ref(xs))
        sp_neg = np.asarray(ref.softplus_fixed_ref(-xs))
        np.testing.assert_array_equal(sp_pos - sp_neg, np.asarray(xs))

    def test_matches_true_softplus(self):
        x = RNG.uniform(-10, 10, 4096).astype(np.float32)
        got = np.asarray(ref.softplus_approx_f32(jnp.asarray(x)))
        # Eq. 5 is itself an approximation: ln(1+e^x) ~= e^x has error up to
        # ~0.31 at x=0 (1 - ln 2); that is the paper's accepted error.
        np.testing.assert_allclose(got, np.log1p(np.exp(x)), atol=0.32)

    def test_large_positive_is_identity_plus_eps(self):
        x = fx([20 * FXP.scale])
        assert abs(int(ref.softplus_fixed_ref(x)[0]) - 20 * FXP.scale) <= 2

    def test_nonnegative(self):
        xs = fx(RNG.randint(FXP.qmin, FXP.qmax, 2000))
        assert (np.asarray(ref.softplus_fixed_ref(xs)) >= 0).all()


class TestNauKernel:
    """The Pallas NAU must be bit-identical to the reference datapath."""

    @pytest.mark.parametrize("n", [1, 23, 24, 100, 256, 1000])
    def test_exp_bitexact(self, n):
        xs = fx(-RNG.randint(0, 1 << 15, n))
        np.testing.assert_array_equal(
            np.asarray(nonlinear.exp_fixed(xs)), np.asarray(ref.exp_fixed_ref(xs))
        )

    @pytest.mark.parametrize("n", [1, 24, 257, 1000])
    def test_softplus_bitexact(self, n):
        xs = fx(RNG.randint(-(1 << 14), 1 << 14, n))
        np.testing.assert_array_equal(
            np.asarray(nonlinear.softplus_fixed(xs)),
            np.asarray(ref.softplus_fixed_ref(xs)),
        )

    def test_2d_shape_preserved(self):
        xs = fx(-RNG.randint(0, 1 << 14, (13, 7)))
        out = nonlinear.exp_fixed(xs)
        assert out.shape == (13, 7)

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(min_value=-(1 << 15), max_value=(1 << 15) - 1),
                    min_size=1, max_size=64))
    def test_softplus_hypothesis_bitexact(self, vals):
        xs = fx(vals)
        np.testing.assert_array_equal(
            np.asarray(nonlinear.softplus_fixed(xs)),
            np.asarray(ref.softplus_fixed_ref(xs)),
        )


class TestPwlTables:
    def test_eight_segments(self):
        intercept, slope = ref.pwl_tables()
        assert intercept.shape == (8,) and slope.shape == (8,)

    def test_intercepts_decreasing(self):
        intercept, _ = ref.pwl_tables()
        assert (np.diff(intercept) < 0).all()

    def test_pwl_error_bound(self):
        """8-segment PWL of 2^v on (-1, 0] has error ~<= 2^-9."""
        rem = np.arange(0, FXP.scale)
        intercept, slope = ref.pwl_tables()
        seg_w = FXP.scale // 8
        seg = rem // seg_w
        approx = (intercept[seg] + slope[seg] * (rem - seg * seg_w)) / (
            1 << FXP.coeff_frac_bits
        )
        true = 2.0 ** (-rem / FXP.scale)
        assert np.abs(approx - true).max() < 5e-3
