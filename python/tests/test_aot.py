"""AOT pipeline tests: HLO text emission, manifest integrity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, mamba2
from compile.config import TINY


class TestHloText:
    def test_simple_fn_emits_parseable_text(self):
        def fn(x, y):
            return (x @ y + 1.0,)

        spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        lowered = jax.jit(fn).lower(spec, spec)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "parameter" in text.lower()

    def test_pallas_kernel_lowers_to_plain_hlo(self):
        """interpret=True Pallas must not leave custom-calls the CPU PJRT
        client cannot execute."""
        from compile.kernels import nonlinear

        lowered = jax.jit(nonlinear.exp_fixed).lower(
            jax.ShapeDtypeStruct((256,), jnp.int32)
        )
        text = aot.to_hlo_text(lowered)
        assert "mosaic" not in text.lower()

    def test_decode_graph_shapes(self):
        cfg = TINY
        params = mamba2.init_params(cfg, 0)
        arrays, _ = mamba2.flatten_params(params)
        n_flat = len(arrays)

        def decode_fn(*args):
            p = mamba2.unflatten_params(list(args[:n_flat]), cfg.n_layer)
            return mamba2.decode_step_batched(
                p, args[n_flat], args[n_flat + 1], args[n_flat + 2], cfg, "fp32")

        conv_s = jax.ShapeDtypeStruct((2, cfg.n_layer, cfg.d_conv - 1, cfg.conv_dim),
                                      jnp.float32)
        ssm_s = jax.ShapeDtypeStruct(
            (2, cfg.n_layer, cfg.nheads, cfg.headdim, cfg.d_state), jnp.float32)
        tok_s = jax.ShapeDtypeStruct((2,), jnp.int32)
        out = jax.eval_shape(decode_fn,
                             *[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrays],
                             conv_s, ssm_s, tok_s)
        logits, conv2, ssm2 = out
        assert logits.shape == (2, cfg.vocab_size)
        assert conv2.shape == conv_s.shape and ssm2.shape == ssm_s.shape


ARTI = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTI, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTI, "manifest.json")) as f:
            return json.load(f)

    def test_all_artifacts_exist(self, manifest):
        for a in manifest["artifacts"]:
            assert os.path.exists(os.path.join(ARTI, a["file"])), a["file"]

    def test_all_weights_exist_with_right_size(self, manifest):
        for p in manifest["params"]:
            path = os.path.join(ARTI, p["file"])
            assert os.path.exists(path)
            n = int(np.prod(p["shape"])) if p["shape"] else 1
            assert os.path.getsize(path) == 4 * n, p["name"]

    def test_expected_artifact_set(self, manifest):
        names = {a["name"] for a in manifest["artifacts"]}
        for v in manifest["variants"]:
            for l in manifest["prefill_lens"]:
                assert f"mamba2-tiny_prefill_{v}_L{l}" in names
            for b in manifest["decode_batches"]:
                assert f"mamba2-tiny_decode_{v}_B{b}" in names
        for k in ("kernel_hadamard_linear", "kernel_nau", "kernel_conv1d",
                  "kernel_ssd_scan"):
            assert k in names

    def test_prefill_hlo_mentions_no_python(self, manifest):
        a = manifest["artifacts"][0]
        with open(os.path.join(ARTI, a["file"])) as f:
            head = f.read(200)
        assert head.startswith("HloModule")

    def test_param_count_matches(self, manifest):
        cfg = TINY
        assert len(manifest["params"]) == 2 + 9 * cfg.n_layer
