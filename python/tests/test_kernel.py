"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

hypothesis sweeps shapes; assertions are exact (int paths) or allclose
(float paths), per kernel contract.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quantize
from compile.kernels import conv1d, hadamard_matmul, ref, ssd_scan

RNG = np.random.RandomState(42)


def randf(*shape, scale=1.0, rng=RNG):
    return jnp.asarray(rng.randn(*shape).astype(np.float32) * scale)


class TestHadamardTransformPallas:
    @pytest.mark.parametrize("l,d,group", [(4, 64, 64), (64, 128, 64),
                                           (65, 256, 64), (1, 64, 32), (100, 128, 128)])
    def test_matches_ref(self, l, d, group):
        x = randf(l, d)
        got = hadamard_matmul.hadamard_transform_pallas(x, group)
        want = quantize.hadamard_transform(x, group)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(l=st.integers(1, 130), k=st.sampled_from([1, 2, 4]))
    def test_hypothesis_shapes(self, l, k):
        d = 64 * k
        x = randf(l, d, rng=np.random.RandomState(l * 7 + k))
        got = hadamard_matmul.hadamard_transform_pallas(x, 64)
        want = quantize.hadamard_transform(x, 64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


class TestInt8MatmulPallas:
    @pytest.mark.parametrize("l,d,q", [(4, 64, 8), (64, 128, 128), (65, 192, 200),
                                       (1, 64, 1), (128, 256, 512)])
    def test_exact_int(self, l, d, q):
        rng = np.random.RandomState(l + d + q)
        x = jnp.asarray(rng.randint(-128, 128, (l, d)), jnp.int8)
        w = jnp.asarray(rng.randint(-128, 128, (d, q)), jnp.int8)
        got = hadamard_matmul.int8_matmul_pallas(x, w)
        want = x.astype(jnp.int32) @ w.astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestHadamardLinearPallas:
    @pytest.mark.parametrize("l,d,q", [(16, 128, 96), (3, 64, 64), (64, 256, 40)])
    def test_bitexact_vs_algorithm1(self, l, d, q):
        x = randf(l, d)
        w = randf(q, d)
        w_q_t, s_w = quantize.hadamard_prepare_weight(w, 64)
        got = hadamard_matmul.hadamard_linear_pallas(x, w_q_t, s_w, 64)
        want = ref.hadamard_linear_ref(x, w, 64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)

    def test_leading_dims(self):
        x = randf(2, 5, 64)
        w = randf(32, 64)
        w_q_t, s_w = quantize.hadamard_prepare_weight(w, 64)
        got = hadamard_matmul.hadamard_linear_pallas(x, w_q_t, s_w, 64)
        assert got.shape == (2, 5, 32)


class TestConv1dPallas:
    @pytest.mark.parametrize("l,c,k", [(1, 8, 4), (17, 70, 4), (128, 640, 4),
                                       (5, 64, 2), (33, 100, 3)])
    def test_matches_ref(self, l, c, k):
        rng = np.random.RandomState(l * 31 + c)
        x = randf(l, c, rng=rng)
        w = randf(c, k, rng=rng)
        b = randf(c, rng=rng)
        got = conv1d.conv1d_pallas(x, w, b)
        want = ref.conv1d_ref(x, w, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_causality(self):
        """Changing x[t] must not affect y[<t]."""
        x = randf(20, 16)
        w, b = randf(16, 4), randf(16)
        y0 = np.asarray(conv1d.conv1d_pallas(x, w, b))
        x2 = x.at[10].add(100.0)
        y1 = np.asarray(conv1d.conv1d_pallas(x2, w, b))
        np.testing.assert_array_equal(y0[:10], y1[:10])
        assert np.abs(y1[10:14] - y0[10:14]).max() > 0

    @settings(max_examples=15, deadline=None)
    @given(l=st.integers(1, 64), c=st.integers(1, 96))
    def test_hypothesis_shapes(self, l, c):
        rng = np.random.RandomState(l * 131 + c)
        x = randf(l, c, rng=rng)
        w = randf(c, 4, rng=rng)
        b = randf(c, rng=rng)
        np.testing.assert_allclose(
            np.asarray(conv1d.conv1d_pallas(x, w, b)),
            np.asarray(ref.conv1d_ref(x, w, b)),
            rtol=1e-5, atol=1e-5,
        )


class TestSsdScanPallas:
    def _run(self, h, l, p, n, seed=0, h0_zero=True):
        rng = np.random.RandomState(seed)
        x = randf(h, l, p, rng=rng)
        dt = jnp.asarray(rng.uniform(0.001, 0.3, (h, l)).astype(np.float32))
        a = jnp.asarray(-rng.uniform(0.2, 4.0, h).astype(np.float32))
        abar = jnp.exp(dt * a[:, None])
        b = randf(l, n, rng=rng)
        c = randf(l, n, rng=rng)
        d = randf(h, rng=rng)
        h0 = (jnp.zeros((h, p, n), jnp.float32) if h0_zero
              else randf(h, p, n, rng=rng))
        y_k, h_k = ssd_scan.ssd_scan_pallas(x, dt, abar, b, c, d, h0)
        y_r, h_r = ref.ssd_scan_multihead_ref(
            x.transpose(1, 0, 2), dt.T, a, b, c, d, h0
        )
        return np.asarray(y_k), np.asarray(h_k), np.asarray(y_r.transpose(1, 0, 2)), np.asarray(h_r)

    @pytest.mark.parametrize("h,l,p,n", [(1, 1, 4, 4), (3, 12, 8, 16),
                                         (16, 64, 32, 64), (2, 100, 16, 32)])
    def test_matches_ref(self, h, l, p, n):
        y_k, h_k, y_r, h_r = self._run(h, l, p, n, seed=h + l)
        np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(h_k, h_r, rtol=1e-4, atol=1e-4)

    def test_nonzero_initial_state(self):
        y_k, h_k, y_r, h_r = self._run(2, 8, 4, 8, seed=3, h0_zero=False)
        np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(h_k, h_r, rtol=1e-4, atol=1e-4)

    def test_state_chaining_equals_full_scan(self):
        """Running [0:l1] then [l1:] with carried state == full scan."""
        h, l, p, n = 2, 24, 8, 16
        rng = np.random.RandomState(9)
        x = randf(h, l, p, rng=rng)
        dt = jnp.asarray(rng.uniform(0.01, 0.3, (h, l)).astype(np.float32))
        a = jnp.asarray(-rng.uniform(0.5, 2.0, h).astype(np.float32))
        abar = jnp.exp(dt * a[:, None])
        b, c = randf(l, n, rng=rng), randf(l, n, rng=rng)
        d = randf(h, rng=rng)
        h0 = jnp.zeros((h, p, n), jnp.float32)
        y_full, h_full = ssd_scan.ssd_scan_pallas(x, dt, abar, b, c, d, h0)
        l1 = 10
        y1, hmid = ssd_scan.ssd_scan_pallas(
            x[:, :l1], dt[:, :l1], abar[:, :l1], b[:l1], c[:l1], d, h0)
        y2, hend = ssd_scan.ssd_scan_pallas(
            x[:, l1:], dt[:, l1:], abar[:, l1:], b[l1:], c[l1:], d, hmid)
        np.testing.assert_allclose(
            np.asarray(y_full), np.concatenate([y1, y2], axis=1), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_full), np.asarray(hend), rtol=1e-4, atol=1e-4)

    def test_decay_only(self):
        """x = 0: state decays by prod(abar); y = 0."""
        h, l, p, n = 2, 6, 4, 8
        rng = np.random.RandomState(11)
        x = jnp.zeros((h, l, p), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.05, 0.2, (h, l)).astype(np.float32))
        a = jnp.asarray(-rng.uniform(0.5, 1.0, h).astype(np.float32))
        abar = jnp.exp(dt * a[:, None])
        b, c = randf(l, n, rng=rng), randf(l, n, rng=rng)
        d = randf(h, rng=rng)
        h0 = randf(h, p, n, rng=rng)
        y, h_out = ssd_scan.ssd_scan_pallas(x, dt, abar, b, c, d, h0)
        decay = np.prod(np.asarray(abar), axis=1)[:, None, None]
        np.testing.assert_allclose(np.asarray(h_out), np.asarray(h0) * decay, rtol=1e-4)
