"""Quantization algorithm tests: Algorithm 1, PoT, baselines."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quantize

RNG = np.random.RandomState(0)


class TestHadamardMatrix:
    @pytest.mark.parametrize("n", [1, 2, 4, 8, 64, 128])
    def test_orthogonal(self, n):
        h = quantize.hadamard_matrix(n)
        np.testing.assert_allclose(h @ h.T, n * np.eye(n), atol=1e-4)

    @pytest.mark.parametrize("n", [3, 6, 0, 100])
    def test_rejects_non_pow2(self, n):
        with pytest.raises(ValueError):
            quantize.hadamard_matrix(n)

    def test_entries_pm1(self):
        h = quantize.hadamard_matrix(32)
        assert set(np.unique(h)) == {-1.0, 1.0}


class TestHadamardTransform:
    def test_involution_up_to_scale(self):
        """H H^T = n I: transforming twice recovers n*x."""
        x = jnp.asarray(RNG.randn(5, 128).astype(np.float32))
        y = quantize.hadamard_transform(quantize.hadamard_transform(x, 64), 64)
        np.testing.assert_allclose(np.asarray(y), 64 * np.asarray(x), rtol=1e-3,
                                   atol=1e-4)

    def test_norm_preserved_up_to_scale(self):
        x = jnp.asarray(RNG.randn(7, 256).astype(np.float32))
        y = quantize.hadamard_transform(x, 64)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(y), axis=1),
            np.sqrt(64) * np.linalg.norm(np.asarray(x), axis=1),
            rtol=1e-4,
        )

    def test_outlier_dispersal(self):
        """Fig. 3: a single huge channel spreads evenly across the group."""
        x = np.zeros((1, 64), np.float32)
        x[0, 17] = 100.0
        y = np.asarray(quantize.hadamard_transform(jnp.asarray(x), 64))
        assert np.abs(y).max() == pytest.approx(100.0)
        assert np.abs(y).min() == pytest.approx(100.0)  # perfectly dispersed

    def test_rejects_bad_group(self):
        with pytest.raises(ValueError):
            quantize.hadamard_transform(jnp.zeros((2, 100)), 64)


class TestHadamardLinear:
    def test_close_to_fp32(self):
        x = jnp.asarray(RNG.randn(16, 128).astype(np.float32))
        w = jnp.asarray(RNG.randn(96, 128).astype(np.float32))
        y = np.asarray(quantize.hadamard_linear(x, w, 64))
        y_fp = np.asarray(x @ w.T)
        rel = np.abs(y - y_fp).max() / np.abs(y_fp).max()
        assert rel < 0.03

    def test_beats_normalq_under_outliers(self):
        """The paper's core claim: with activation outliers, Hadamard W8A8
        is far more accurate than per-tensor absmax W8A8."""
        x = RNG.randn(32, 256).astype(np.float32)
        x[:, 3] *= 80.0  # severe channel outlier
        x[:, 200] *= 50.0
        w = RNG.randn(128, 256).astype(np.float32)
        xj, wj = jnp.asarray(x), jnp.asarray(w)
        y_fp = np.asarray(xj @ wj.T)
        err_had = np.abs(np.asarray(quantize.hadamard_linear(xj, wj, 64)) - y_fp).mean()
        err_norm = np.abs(np.asarray(quantize.normalq_linear(xj, wj)) - y_fp).mean()
        assert err_had < err_norm / 2

    def test_bias_applied(self):
        x = jnp.asarray(RNG.randn(4, 64).astype(np.float32))
        w = jnp.asarray(RNG.randn(8, 64).astype(np.float32))
        b = jnp.asarray(RNG.randn(8).astype(np.float32))
        y0 = np.asarray(quantize.hadamard_linear(x, w, 64))
        y1 = np.asarray(quantize.hadamard_linear(x, w, 64, bias=b))
        np.testing.assert_allclose(y1 - y0, np.broadcast_to(b, (4, 8)), atol=1e-5)

    def test_prepared_weight_matches_inline(self):
        x = jnp.asarray(RNG.randn(4, 128).astype(np.float32))
        w = jnp.asarray(RNG.randn(32, 128).astype(np.float32))
        w_q_t, s_w = quantize.hadamard_prepare_weight(w, 64)
        x_h = quantize.hadamard_transform(x, 64)
        s_x = jnp.max(jnp.abs(x_h)) / 127.0
        x_q = quantize.quantize_int8(x_h, s_x).astype(jnp.int32)
        y_manual = (x_q @ w_q_t.astype(jnp.int32)).astype(jnp.float32) * (
            s_x * s_w / 64
        )
        np.testing.assert_allclose(
            np.asarray(y_manual), np.asarray(quantize.hadamard_linear(x, w, 64)),
            rtol=1e-5, atol=1e-5,
        )


class TestSmoothQuant:
    def test_improves_on_normalq_with_outliers(self):
        x = RNG.randn(32, 128).astype(np.float32)
        x[:, 5] *= 60.0
        w = RNG.randn(64, 128).astype(np.float32)
        xj, wj = jnp.asarray(x), jnp.asarray(w)
        y_fp = np.asarray(xj @ wj.T)
        err_s = np.abs(np.asarray(quantize.smoothq_linear(xj, wj)) - y_fp).mean()
        err_n = np.abs(np.asarray(quantize.normalq_linear(xj, wj)) - y_fp).mean()
        assert err_s < err_n

    def test_factors_positive(self):
        s = quantize.smoothq_factors(
            jnp.abs(jnp.asarray(RNG.randn(64).astype(np.float32))) + 0.1,
            jnp.asarray(RNG.randn(32, 64).astype(np.float32)),
        )
        assert (np.asarray(s) > 0).all()


class TestPoT:
    def test_scale_is_power_of_two(self):
        x = jnp.asarray(RNG.randn(100).astype(np.float32) * 7)
        q = np.asarray(quantize.pot_fake_quant(x, bits=16))
        # every dequantized value is an integer multiple of a single 2^p
        nz = q[q != 0]
        exps = np.log2(np.abs(nz))
        # representable on the 2^p grid: value / 2^p integral for the tensor p
        p = int(np.asarray(quantize.pot_exponent(jnp.max(jnp.abs(x)))))
        assert np.allclose(nz / (2.0**p), np.round(nz / (2.0**p)))

    def test_error_bound(self):
        x = jnp.asarray(RNG.randn(4096).astype(np.float32))
        q = np.asarray(quantize.pot_fake_quant(x, bits=16))
        p = int(np.asarray(quantize.pot_exponent(jnp.max(jnp.abs(x)))))
        assert np.abs(q - np.asarray(x)).max() <= 2.0**p / 2 + 1e-9

    def test_fine_grained_beats_per_tensor(self):
        """The paper's *fine-grained* PoT: per-channel exponents reduce error
        when channel magnitudes differ."""
        x = RNG.randn(64, 32).astype(np.float32)
        x[:, 0] *= 100.0
        xj = jnp.asarray(x)
        e_tensor = np.abs(np.asarray(quantize.pot_fake_quant(xj, bits=8)) - x).mean()
        e_chan = np.abs(
            np.asarray(quantize.pot_fake_quant(xj, bits=8, axis=0)) - x
        ).mean()
        assert e_chan < e_tensor

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=4, max_value=16))
    def test_idempotent(self, bits):
        x = jnp.asarray(RNG.randn(128).astype(np.float32))
        q1 = quantize.pot_fake_quant(x, bits=bits)
        q2 = quantize.pot_fake_quant(q1, bits=bits)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-7)


class TestInt8Helpers:
    def test_quantize_range(self):
        x = jnp.asarray(RNG.randn(1000).astype(np.float32) * 100)
        s = jnp.max(jnp.abs(x)) / 127.0
        q = np.asarray(quantize.quantize_int8(x, s))
        assert q.min() >= -128 and q.max() <= 127

    def test_roundtrip_error(self):
        x = jnp.asarray(RNG.randn(1000).astype(np.float32))
        s = jnp.max(jnp.abs(x)) / 127.0
        q = quantize.quantize_int8(x, s)
        err = np.abs(np.asarray(q, np.float32) * float(s) - np.asarray(x)).max()
        assert err <= float(s) / 2 + 1e-7
