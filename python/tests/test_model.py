"""L2 model tests: shapes, variants, decode consistency, Table II ordering."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import mamba2
from compile.config import TINY

CFG = TINY


@pytest.fixture(scope="module")
def params():
    return mamba2.init_params(CFG, 0)


@pytest.fixture(scope="module")
def tokens():
    return jnp.asarray(np.random.RandomState(0).randint(0, CFG.vocab_size, 24),
                       jnp.int32)


class TestShapes:
    def test_prefill_shapes(self, params, tokens):
        logits, cs, ss = mamba2.prefill(params, tokens, CFG, "fp32")
        assert logits.shape == (24, CFG.vocab_size)
        assert cs.shape == (CFG.n_layer, CFG.d_conv - 1, CFG.conv_dim)
        assert ss.shape == (CFG.n_layer, CFG.nheads, CFG.headdim, CFG.d_state)

    def test_decode_shapes(self, params):
        cs, ss = mamba2.init_decode_state(CFG)
        logits, cs2, ss2 = mamba2.decode_step(params, cs, ss, jnp.int32(5), CFG, "fp32")
        assert logits.shape == (CFG.vocab_size,)
        assert cs2.shape == cs.shape and ss2.shape == ss.shape

    def test_batched_decode(self, params):
        cs, ss = mamba2.init_decode_state(CFG, batch=4)
        toks = jnp.asarray([1, 2, 3, 4], jnp.int32)
        logits, cs2, ss2 = mamba2.decode_step_batched(params, cs, ss, toks, CFG, "fp32")
        assert logits.shape == (4, CFG.vocab_size)

    @pytest.mark.parametrize("variant", mamba2.VARIANTS)
    def test_all_variants_finite(self, params, tokens, variant):
        logits, _, _ = mamba2.prefill(params, tokens, CFG, variant)
        assert bool(jnp.all(jnp.isfinite(logits)))


class TestDecodeConsistency:
    """Prefill(L) must equal prefill(L-1) + decode(1) — the contract the
    serving scheduler relies on when switching a request between phases."""

    def test_prefill_then_decode_fp32(self, params, tokens):
        lg_full, cs_f, ss_f = mamba2.prefill(params, tokens, CFG, "fp32")
        _, cs1, ss1 = mamba2.prefill(params, tokens[:-1], CFG, "fp32")
        lg2, cs2, ss2 = mamba2.decode_step(params, cs1, ss1, tokens[-1], CFG, "fp32")
        np.testing.assert_allclose(
            np.asarray(lg2), np.asarray(lg_full[-1]), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(ss2), np.asarray(ss_f), rtol=2e-3, atol=2e-3)

    def test_prefill_then_decode_fastmamba(self, params, tokens):
        """fastmamba re-derives activation scales per call (dynamic
        quantization), so prefill/decode agree only to quantization noise —
        the functional contract is distribution-level agreement."""
        lg_full, _, ss_f = mamba2.prefill(params, tokens, CFG, "fastmamba")
        _, cs1, ss1 = mamba2.prefill(params, tokens[:-1], CFG, "fastmamba")
        lg2, _, ss2 = mamba2.decode_step(params, cs1, ss1, tokens[-1], CFG, "fastmamba")
        a, b = np.asarray(lg2), np.asarray(lg_full[-1])
        scale = np.abs(b).max()
        assert np.abs(a - b).max() < 0.1 * scale
        assert np.corrcoef(a, b)[0, 1] > 0.99
        assert np.argmax(a) == np.argmax(b)
        sd = np.abs(np.asarray(ss2) - np.asarray(ss_f)).max()
        assert sd < 0.1 * np.abs(np.asarray(ss_f)).max()

    def test_pure_decode_chain(self, params, tokens):
        """Decoding token-by-token from scratch == prefill logits."""
        lg_full, _, _ = mamba2.prefill(params, tokens[:8], CFG, "fp32")
        cs, ss = mamba2.init_decode_state(CFG)
        outs = []
        for t in np.asarray(tokens[:8]):
            lg, cs, ss = mamba2.decode_step(params, cs, ss, jnp.int32(t), CFG, "fp32")
            outs.append(np.asarray(lg))
        np.testing.assert_allclose(
            np.stack(outs), np.asarray(lg_full), rtol=2e-4, atol=2e-4)


class TestPallasParity:
    def test_fastmamba_pallas_equals_ref(self, params, tokens):
        lg_p, cs_p, ss_p = mamba2.prefill(params, tokens, CFG, "fastmamba",
                                          use_pallas=True)
        lg_r, cs_r, ss_r = mamba2.prefill(params, tokens, CFG, "fastmamba",
                                          use_pallas=False)
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_r),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ss_p), np.asarray(ss_r),
                                   rtol=1e-5, atol=1e-5)


class TestQuantOrdering:
    """Table II's qualitative result on outlier-bearing activations."""

    def test_fastmamba_lq_beats_normalq(self, params):
        # amplified norm gains -> per-channel activation outliers (Fig. 3)
        import copy

        p2 = {"embed": params["embed"], "norm_f_w": params["norm_f_w"],
              "layers": [dict(lp) for lp in params["layers"]]}
        rng = np.random.RandomState(1)
        for lp in p2["layers"]:
            w = np.array(lp["norm_w"])
            w[rng.choice(len(w), 10, replace=False)] *= 12.0
            lp["norm_w"] = jnp.asarray(w)
        toks = jnp.asarray(rng.randint(0, CFG.vocab_size, 32), jnp.int32)
        lg_fp, _, _ = mamba2.prefill(p2, toks, CFG, "fp32")
        fp = np.asarray(lg_fp)

        def err(variant):
            lg, _, _ = mamba2.prefill(p2, toks, CFG, variant)
            return float(np.sqrt(np.mean((np.asarray(lg) - fp) ** 2)))

        e_norm, e_lq, e_fm = err("normalq"), err("fastmamba_lq"), err("fastmamba")
        assert e_lq < e_norm, (e_lq, e_norm)
        # full FastMamba (PoT SSM+conv) stays close to LQ-only (paper: <1%)
        assert e_fm < e_norm
        assert e_fm < 3.0 * max(e_lq, 1e-6)


class TestParamPlumbing:
    def test_flatten_roundtrip(self, params):
        flat, names = mamba2.flatten_params(params)
        assert len(flat) == len(names) == 2 + 9 * CFG.n_layer
        p2 = mamba2.unflatten_params(flat, CFG.n_layer)
        for k in ("embed", "norm_f_w"):
            np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(p2[k]))
        for lp1, lp2 in zip(params["layers"], p2["layers"]):
            for k in lp1:
                np.testing.assert_array_equal(np.asarray(lp1[k]), np.asarray(lp2[k]))
