"""AOT compile path: lower every graph the Rust runtime needs to HLO text.

HLO *text* (not ``.serialize()``): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs under --out-dir (default ../artifacts):
  *.hlo.txt            one per lowered graph
  weights/<name>.bin   raw little-endian tensors in manifest order
  manifest.json        configs, parameter table, artifact table
  tiny_weights.npz     (from train_tiny, invoked if missing)

Python runs ONCE here; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import mamba2
from .config import CONFIGS, TINY, Mamba2Config
from .kernels import conv1d as k_conv
from .kernels import hadamard_matmul as k_had
from .kernels import nonlinear as k_nau
from .kernels import ssd_scan as k_ssd

#: sequence-length buckets the prefill scheduler pads into.
PREFILL_LENS = (32, 64, 128, 256)
#: decode batch sizes the batcher forms.
DECODE_BATCHES = (1, 2, 4, 8)
#: model variants shipped to the runtime (fp32 baseline + the paper's).
SERVE_VARIANTS = ("fp32", "fastmamba")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer ELIDES big literals as
    # `constant({...})`, which xla_extension 0.5.1's text parser silently
    # turns into zeros (discovered the hard way — the baked Hadamard matrix
    # became 0 and every quantized linear output vanished).
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "elided constant survived; old XLA would zero it"
    return text


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_meta(specs):
    return [
        {"shape": list(s.shape), "dtype": str(np.dtype(s.dtype).name)} for s in specs
    ]


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.artifacts = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, arg_specs, meta: dict):
        lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *arg_specs)
        entry = {
            "name": name,
            "file": fname,
            "inputs": _shape_meta(jax.tree.leaves(arg_specs)),
            "outputs": _shape_meta(jax.tree.leaves(out_specs)),
            **meta,
        }
        self.artifacts.append(entry)
        print(f"  emitted {fname} ({len(text) / 1e6:.2f} MB)")
        return entry


# ---------------------------------------------------------------------------
# Model graphs
# ---------------------------------------------------------------------------


def load_or_train_params(out_dir: str, cfg: Mamba2Config):
    npz_path = os.path.join(out_dir, "tiny_weights.npz")
    if not os.path.exists(npz_path):
        print("tiny weights missing; training (train_tiny.py)...")
        from . import train_tiny

        train_tiny.train(out_dir)
    data = np.load(npz_path)
    arrays = [jnp.asarray(data[k]) for k in data.files]
    names = list(data.files)
    # npz preserves insertion order == flatten order; sanity-check it.
    flat_names = mamba2.flatten_params(mamba2.init_params(cfg, 0))[1]
    assert names == flat_names, "weight manifest order mismatch"
    return mamba2.unflatten_params(arrays, cfg.n_layer), arrays, names


def param_specs(arrays):
    return [_spec(a.shape, a.dtype) for a in arrays]


def prepared_specs(cfg: Mamba2Config):
    """Input specs of the flattened prepared-weight list (Hadamard variants).

    The Rust runtime computes these tensors once at load
    (`quant::hadamard::prepare_weight`) — the serve-time graphs then skip
    the per-call weight transform+quantize (§Perf L2 optimization)."""
    params = mamba2.init_params(cfg, 0)
    prepared = mamba2.compute_prepared(params, cfg)
    arrays, names = mamba2.flatten_prepared(prepared)
    return [_spec(a.shape, a.dtype) for a in arrays], names


def emit_model_graphs(em: Emitter, cfg: Mamba2Config, arrays):
    n_flat = len(arrays)
    pspecs = param_specs(arrays)
    prep_specs, prep_names = prepared_specs(cfg)
    n_prep = len(prep_specs)

    for variant in SERVE_VARIANTS:
        # fastmamba prefill routes through the Pallas kernels (L1 in the HLO);
        # fp32 has no quantized hot path and lowers from the jnp reference.
        use_pallas = variant == "fastmamba"
        hadamard = variant in ("fastmamba", "fastmamba_lq")
        extra_prep = prep_specs if hadamard else []
        np_ = n_prep if hadamard else 0
        conv_s = (cfg.n_layer, cfg.d_conv - 1, cfg.conv_dim)
        ssm_s = (cfg.n_layer, cfg.nheads, cfg.headdim, cfg.d_state)
        for seqlen in PREFILL_LENS:
            def prefill_fn(*args, _v=variant, _p=use_pallas, _np=np_):
                params = mamba2.unflatten_params(list(args[:n_flat]), cfg.n_layer)
                prep = (mamba2.unflatten_prepared(
                    list(args[n_flat:n_flat + _np]), cfg.n_layer)
                    if _np else None)
                base = n_flat + _np
                return mamba2.prefill(
                    params, args[base + 2], cfg, _v, _p,
                    conv_states0=args[base], ssm_states0=args[base + 1],
                    prepared=prep)

            em.emit(
                f"{cfg.name}_prefill_{variant}_L{seqlen}",
                prefill_fn,
                pspecs + extra_prep
                + [_spec(conv_s), _spec(ssm_s), _spec((seqlen,), jnp.int32)],
                {"kind": "prefill", "variant": variant, "seq_len": seqlen,
                 "config": cfg.name, "n_params": n_flat, "n_prepared": np_},
            )

        for batch in DECODE_BATCHES:
            def decode_fn(*args, _v=variant, _np=np_):
                params = mamba2.unflatten_params(list(args[:n_flat]), cfg.n_layer)
                prep = (mamba2.unflatten_prepared(
                    list(args[n_flat:n_flat + _np]), cfg.n_layer)
                    if _np else None)
                base = n_flat + _np
                conv_s, ssm_s, tokens = args[base], args[base + 1], args[base + 2]
                return mamba2.decode_step_batched(
                    params, conv_s, ssm_s, tokens, cfg, _v, prepared=prep)

            conv_shape = (batch, cfg.n_layer, cfg.d_conv - 1, cfg.conv_dim)
            ssm_shape = (batch, cfg.n_layer, cfg.nheads, cfg.headdim, cfg.d_state)
            em.emit(
                f"{cfg.name}_decode_{variant}_B{batch}",
                decode_fn,
                pspecs + extra_prep
                + [_spec(conv_shape), _spec(ssm_shape), _spec((batch,), jnp.int32)],
                {"kind": "decode", "variant": variant, "batch": batch,
                 "config": cfg.name, "n_params": n_flat, "n_prepared": np_},
            )
    return prep_names


# ---------------------------------------------------------------------------
# Kernel micrographs (Pallas -> HLO -> PJRT composition proofs + benches)
# ---------------------------------------------------------------------------


def emit_kernel_graphs(em: Emitter, cfg: Mamba2Config):
    group = mamba2.HADAMARD_GROUP

    def hadamard_fn(x, w_q_t, s_w):
        return (k_had.hadamard_linear_pallas(x, w_q_t, s_w, group),)

    em.emit(
        "kernel_hadamard_linear",
        hadamard_fn,
        [_spec((64, cfg.d_model)), _spec((cfg.d_model, cfg.d_inner), jnp.int8),
         _spec((), jnp.float32)],
        {"kind": "kernel", "kernel": "hadamard_linear"},
    )

    def nau_fn(x):
        return (k_nau.softplus_fixed(x), k_nau.exp_fixed(jnp.minimum(x, 0)))

    em.emit(
        "kernel_nau",
        nau_fn,
        [_spec((1024,), jnp.int32)],
        {"kind": "kernel", "kernel": "nau"},
    )

    def conv_fn(x, w, b):
        return (k_conv.conv1d_pallas(x, w, b),)

    em.emit(
        "kernel_conv1d",
        conv_fn,
        [_spec((128, cfg.conv_dim)), _spec((cfg.conv_dim, cfg.d_conv)),
         _spec((cfg.conv_dim,))],
        {"kind": "kernel", "kernel": "conv1d"},
    )

    def ssd_fn(x, dt, abar, b, c, d, h0):
        return k_ssd.ssd_scan_pallas(x, dt, abar, b, c, d, h0)

    h_, l_, p_, n_ = cfg.nheads, 64, cfg.headdim, cfg.d_state
    em.emit(
        "kernel_ssd_scan",
        ssd_fn,
        [_spec((h_, l_, p_)), _spec((h_, l_)), _spec((h_, l_)), _spec((l_, n_)),
         _spec((l_, n_)), _spec((h_,)), _spec((h_, p_, n_))],
        {"kind": "kernel", "kernel": "ssd_scan"},
    )


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def write_weights(out_dir: str, arrays, names):
    wdir = os.path.join(out_dir, "weights")
    os.makedirs(wdir, exist_ok=True)
    table = []
    for i, (name, arr) in enumerate(zip(names, arrays)):
        arr_np = np.asarray(arr)
        fname = f"weights/p{i:03d}.bin"
        arr_np.astype("<f4").tofile(os.path.join(out_dir, fname))
        table.append(
            {"index": i, "name": name, "shape": list(arr_np.shape),
             "dtype": "float32", "file": fname}
        )
    return table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()
    out_dir = args.out_dir

    cfg = TINY
    em = Emitter(out_dir)
    params, arrays, names = load_or_train_params(out_dir, cfg)

    print("emitting model graphs (this lowers every serve-time executable)...")
    prep_names = emit_model_graphs(em, cfg, arrays)
    if not args.skip_kernels:
        print("emitting kernel micrographs...")
        emit_kernel_graphs(em, cfg)

    weight_table = write_weights(out_dir, arrays, names)
    prepared = mamba2.compute_prepared(params, cfg)
    prep_arrays, _ = mamba2.flatten_prepared(prepared)
    prep_table = [
        {"name": n, "shape": list(np.shape(a)),
         "dtype": str(np.asarray(a).dtype)}
        for n, a in zip(prep_names, prep_arrays)
    ]
    manifest = {
        "configs": {name: dataclasses.asdict(c) for name, c in CONFIGS.items()},
        "serve_config": cfg.name,
        "prefill_lens": list(PREFILL_LENS),
        "decode_batches": list(DECODE_BATCHES),
        "variants": list(SERVE_VARIANTS),
        "params": weight_table,
        "prepared": prep_table,
        "artifacts": em.artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(em.artifacts)} artifacts")


if __name__ == "__main__":
    main()
