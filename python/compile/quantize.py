"""Quantization algorithms from the paper, plus the two published baselines.

* `hadamard_*`     — Algorithm 1: Hadamard-based W8A8 linear quantization.
* `pot_*`          — fine-grained power-of-two quantization (SSM block, conv).
* `normalq_*`      — plain per-tensor absmax W8A8 (the paper's NormalQ).
* `smoothq_*`      — SmoothQuant-style activation/weight rebalancing W8A8.

All fake-quant helpers return float tensors that are *bit-identical* to the
values the integer datapath produces (quantize -> integer op -> dequantize),
so the model-quality numbers measured at L2 transfer to the fixed-point
hardware simulated at L3.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

INT8_MAX = 127.0


# ---------------------------------------------------------------------------
# Hadamard transform (Algorithm 1)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def hadamard_matrix(n: int) -> np.ndarray:
    """Sylvester-construction Hadamard matrix of order n = 2^k (unnormalized,
    entries +-1).  `FindHadamard` in Algorithm 1."""
    if n < 1 or (n & (n - 1)) != 0:
        raise ValueError(f"Hadamard order must be a power of two, got {n}")
    h = np.array([[1.0]], dtype=np.float64)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h.astype(np.float32)


def hadamard_transform(x: jnp.ndarray, group: int) -> jnp.ndarray:
    """Blocked Hadamard transform along the last axis (X[i] @ H[i], line 5).

    The last axis is split into d/group groups; each is multiplied by the
    unnormalized H_group.  Normalization by 1/group is folded into the final
    dequantization step (the `m/d` factor of Algorithm 1 line 13).
    """
    d = x.shape[-1]
    if d % group != 0:
        raise ValueError(f"dim {d} not divisible by group {group}")
    h = jnp.asarray(hadamard_matrix(group))
    xg = x.reshape(*x.shape[:-1], d // group, group)
    return (xg @ h).reshape(x.shape)


def _absmax_scale(x: jnp.ndarray) -> jnp.ndarray:
    """`FindScale`: symmetric int8 scale from the tensor absmax."""
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / INT8_MAX


def quantize_int8(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """`Quant`: symmetric round-to-nearest int8."""
    return jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)


def hadamard_prepare_weight(w: jnp.ndarray, group: int):
    """Offline half of Algorithm 1 for the (static) weight matrix.

    w has shape (q, d) as in the paper (output-major).  Returns the int8
    Hadamard-domain weight, already transposed to (d, q) for the activation
    @ weight product, plus its scale.
    """
    w_h = hadamard_transform(w, group)  # rows of W transformed: H^T W^T == (W H)^T
    s_w = _absmax_scale(w_h)
    return quantize_int8(w_h, s_w).T, s_w


def hadamard_linear_prepared(
    x: jnp.ndarray,
    w_q_t: jnp.ndarray,
    s_w: jnp.ndarray,
    group: int,
    bias: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Algorithm 1 forward with the weight half done offline
    (`hadamard_prepare_weight`) — the deployed configuration: the runtime
    prepares int8 Hadamard-domain weights once at load time, exactly like
    the FPGA's offline weight preprocessing."""
    x_h = hadamard_transform(x, group)
    s_x = _absmax_scale(x_h)
    x_q = quantize_int8(x_h, s_x)
    acc = jnp.matmul(
        x_q.astype(jnp.int32), w_q_t.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    y = acc.astype(jnp.float32) * (s_x * s_w / group)
    if bias is not None:
        y = y + bias
    return y


def hadamard_linear(
    x: jnp.ndarray, w: jnp.ndarray, group: int, bias: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Full Algorithm 1 (reference path, no Pallas): Y = X W^T with W8A8
    quantization in the Hadamard domain.

    x: (..., d) activations; w: (q, d) weight.  Equivalent integer math:
    Y = (X_H^int8 @ W_H^int8.T) * s_x * s_w / group.
    """
    w_q_t, s_w = hadamard_prepare_weight(w, group)
    return hadamard_linear_prepared(x, w_q_t, s_w, group, bias)


# ---------------------------------------------------------------------------
# NormalQ / SmoothQuant baselines (Table II comparisons)
# ---------------------------------------------------------------------------


def normalq_linear(
    x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Per-tensor absmax W8A8 with no outlier handling (NormalQ)."""
    s_x = _absmax_scale(x)
    s_w = _absmax_scale(w)
    x_q = quantize_int8(x, s_x).astype(jnp.int32)
    w_q = quantize_int8(w, s_w).astype(jnp.int32)
    y = jnp.matmul(x_q, w_q.T, preferred_element_type=jnp.int32).astype(jnp.float32)
    y = y * (s_x * s_w)
    if bias is not None:
        y = y + bias
    return y


def smoothq_factors(x_absmax: jnp.ndarray, w: jnp.ndarray, alpha: float = 0.5):
    """Per-input-channel smoothing factors s_j = max|X_j|^a / max|W_j|^(1-a).

    x_absmax: (d,) calibration statistics of per-channel activation absmax.
    """
    w_absmax = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-5)
    x_absmax = jnp.maximum(x_absmax, 1e-5)
    s = jnp.power(x_absmax, alpha) / jnp.power(w_absmax, 1.0 - alpha)
    return jnp.clip(s, 1e-5, 1e5)


def smoothq_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    alpha: float = 0.5,
    x_absmax: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """SmoothQuant W8A8: migrate activation outliers into the weights, then
    per-tensor int8 on both sides.  Without an offline calibration pass we
    use the batch's own per-channel absmax (favourable to the baseline)."""
    if x_absmax is None:
        x_absmax = jnp.max(jnp.abs(x.reshape(-1, x.shape[-1])), axis=0)
    s = smoothq_factors(x_absmax, w, alpha)
    return normalq_linear(x / s, w * s, bias)


# ---------------------------------------------------------------------------
# Power-of-two (PoT) quantization — SSM block & convolution layer
# ---------------------------------------------------------------------------


def pot_exponent(absmax: jnp.ndarray, bits: int = 16) -> jnp.ndarray:
    """Smallest p with absmax/2^p representable in `bits`-bit signed ints."""
    qmax = float((1 << (bits - 1)) - 1)
    p = jnp.ceil(jnp.log2(jnp.maximum(absmax, 1e-20) / qmax))
    return p.astype(jnp.int32)


def pot_fake_quant(
    x: jnp.ndarray, bits: int = 16, axis=None
) -> jnp.ndarray:
    """Quantize-dequantize with a power-of-two scale 2^p.

    `axis=None` gives per-tensor PoT; an int/tuple gives the paper's
    *fine-grained* variant (per-channel/per-group exponents).  The dequantized
    float values are exactly the fixed-point values (value = int * 2^p), so
    downstream float math matches the integer datapath wherever products stay
    in range.
    """
    qmax = float((1 << (bits - 1)) - 1)
    if axis is None:
        absmax = jnp.max(jnp.abs(x))
    else:
        absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    p = jnp.ceil(jnp.log2(jnp.maximum(absmax, 1e-20) / qmax))
    scale = jnp.exp2(p)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return q * scale


def pot_conv1d_prepare(w: jnp.ndarray, bits: int = 16) -> jnp.ndarray:
    """Fine-grained (per-channel) PoT fake-quant of the depthwise conv weight
    (conv_dim, K)."""
    return pot_fake_quant(w, bits=bits, axis=1)
