"""Build-time trainer for the tiny Mamba2 used by accuracy experiments.

We cannot download the pretrained Mamba2-130M/2.7B checkpoints, so Table II's
accuracy comparison runs on a tiny Mamba2 *trained here* on a synthetic
Markov corpus — real gradient descent, real weight statistics, real
perplexity gaps between quantizers.  The loss curve is recorded to
artifacts/train_log.json (surfaced in EXPERIMENTS.md).

After training we inject per-channel activation outliers (scaling a few
RMSNorm gain channels) to reproduce the heavy-tailed activation
distributions of Fig. 3 that large pretrained Mamba2 models exhibit and that
motivate the Hadamard transform; the modified model *is* the FP baseline all
quantizers are measured against, so the comparison stays fair.

Run: python -m compile.train_tiny --out ../artifacts  (invoked by `make artifacts`).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import mamba2
from .config import TINY


# ---------------------------------------------------------------------------
# Synthetic corpus: sparse order-1 Markov chain over the tiny vocab
# ---------------------------------------------------------------------------


def make_markov(vocab: int, branch: int = 8, seed: int = 0):
    """Transition table: from each state, `branch` successors with Zipf-ish
    probabilities.  Entropy is well below log(vocab), so a trained model
    separates clearly from a broken (badly quantized) one."""
    rng = np.random.RandomState(seed)
    succ = np.stack([rng.choice(vocab, branch, replace=False) for _ in range(vocab)])
    p = 1.0 / np.arange(1, branch + 1)
    p = p / p.sum()
    return succ, p


def sample_corpus(n_tokens: int, vocab: int, seed: int = 1, branch: int = 8):
    succ, p = make_markov(vocab, branch)
    rng = np.random.RandomState(seed)
    out = np.empty(n_tokens, dtype=np.int32)
    s = rng.randint(vocab)
    choices = rng.choice(branch, n_tokens, p=p)
    for i in range(n_tokens):
        out[i] = s
        s = succ[s, choices[i]]
    return out


def batches(corpus: np.ndarray, batch: int, seq: int, steps: int, seed: int = 2):
    rng = np.random.RandomState(seed)
    hi = len(corpus) - seq - 1
    for _ in range(steps):
        idx = rng.randint(0, hi, batch)
        x = np.stack([corpus[i : i + seq] for i in idx])
        y = np.stack([corpus[i + 1 : i + seq + 1] for i in idx])
        yield jnp.asarray(x), jnp.asarray(y)


# ---------------------------------------------------------------------------
# Training loop (hand-rolled Adam; optax is not in the image)
# ---------------------------------------------------------------------------


def loss_fn(params, x, y, cfg):
    logits, _, _ = jax.vmap(
        lambda t: mamba2.prefill(params, t, cfg, "fp32")
    )(x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)
    return jnp.mean(nll)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, opt, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return params, {"m": m, "v": v, "t": t}


def inject_outliers(params, n_channels: int = 12, gain: float = 8.0, seed: int = 7):
    """Scale a few RMSNorm gain channels per layer: creates the per-channel
    activation outliers of Fig. 3 at every linear-layer input."""
    rng = np.random.RandomState(seed)
    for lp in params["layers"]:
        idx = rng.choice(lp["norm_w"].shape[0], n_channels, replace=False)
        w = np.array(lp["norm_w"])
        w[idx] *= gain
        lp["norm_w"] = jnp.asarray(w)
    return params


def train(out_dir: str, steps: int = 200, batch: int = 8, seq: int = 64,
          lr: float = 3e-3, seed: int = 0, outliers: bool = True):
    cfg = TINY
    params = mamba2.init_params(cfg, seed)
    corpus = sample_corpus(200_000, cfg.vocab_size)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y, cfg)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    log = []
    t0 = time.time()
    for i, (x, y) in enumerate(batches(corpus, batch, seq, steps)):
        params, opt, loss = step_fn(params, opt, x, y)
        if i % 10 == 0 or i == steps - 1:
            loss_v = float(loss)
            log.append({"step": i, "loss": loss_v, "elapsed_s": time.time() - t0})
            print(f"step {i:4d}  loss {loss_v:.4f}  ({time.time() - t0:.1f}s)")

    if outliers:
        params = inject_outliers(params)

    os.makedirs(out_dir, exist_ok=True)
    flat, names = mamba2.flatten_params(params)
    np.savez(
        os.path.join(out_dir, "tiny_weights.npz"),
        **{n: np.asarray(a) for n, a in zip(names, flat)},
    )
    # held-out corpus for the eval harness (Table II)
    heldout = sample_corpus(40_000, cfg.vocab_size, seed=99)
    heldout.tofile(os.path.join(out_dir, "heldout_corpus.bin"))
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump({"config": cfg.name, "steps": steps, "batch": batch,
                   "seq": seq, "lr": lr, "curve": log}, f, indent=2)
    print(f"saved weights + log to {out_dir}")
    return params, log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--no-outliers", action="store_true")
    args = ap.parse_args()
    train(args.out, steps=args.steps, batch=args.batch, seq=args.seq,
          outliers=not args.no_outliers)


if __name__ == "__main__":
    main()
