"""Layer-2 JAX Mamba2 model with the paper's five quantization variants.

Variants (Table II rows):
  * ``fp32``          — full-precision baseline (stands in for the paper's FP16).
  * ``normalq``       — per-tensor absmax W8A8 on linear layers only.
  * ``smoothq``       — SmoothQuant W8A8 on linear layers only.
  * ``fastmamba_lq``  — Hadamard-based W8A8 (Algorithm 1) on linear layers only.
  * ``fastmamba``     — fastmamba_lq + PoT quantization of the convolution
                        layer and SSM block + PWL nonlinear approximations
                        (Eq. 3-6).  This is the configuration the accelerator
                        executes.

``use_pallas=True`` routes the heavy ops through the Layer-1 Pallas kernels
(hadamard_matmul / conv1d / ssd_scan / NAU) so they lower into the same HLO
that the Rust runtime loads; ``use_pallas=False`` uses the pure-jnp oracles,
which the test suite asserts are bit-identical.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize
from .config import Mamba2Config
from .kernels import conv1d as k_conv
from .kernels import hadamard_matmul as k_had
from .kernels import nonlinear as k_nau
from .kernels import ref
from .kernels import ssd_scan as k_ssd

VARIANTS = ("fp32", "normalq", "smoothq", "fastmamba_lq", "fastmamba")

#: Hadamard group size (d/m in Algorithm 1); 64 matches the module's 4x
#: 64-wide HAT trees and divides every projection width we use.
HADAMARD_GROUP = 64

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def init_params(cfg: Mamba2Config, seed: int = 0) -> Params:
    """Random-init parameters with Mamba2's published init scheme."""
    rng = np.random.RandomState(seed)

    def normal(*shape, std=0.02):
        return jnp.asarray(rng.normal(0.0, std, shape).astype(np.float32))

    layers = []
    for _ in range(cfg.n_layer):
        dt = np.exp(
            rng.uniform(np.log(1e-3), np.log(1e-1), cfg.nheads)
        ).astype(np.float32)
        dt_bias = dt + np.log(-np.expm1(-dt))  # inverse softplus
        a_init = rng.uniform(1.0, 16.0, cfg.nheads).astype(np.float32)
        layers.append(
            {
                "norm_w": jnp.ones((cfg.d_model,), jnp.float32),
                "in_proj_w": normal(cfg.d_in_proj, cfg.d_model),
                "conv_w": jnp.asarray(
                    rng.uniform(
                        -1.0 / np.sqrt(cfg.d_conv), 1.0 / np.sqrt(cfg.d_conv),
                        (cfg.conv_dim, cfg.d_conv),
                    ).astype(np.float32)
                ),
                "conv_b": jnp.zeros((cfg.conv_dim,), jnp.float32),
                "dt_bias": jnp.asarray(dt_bias),
                "a_log": jnp.asarray(np.log(a_init)),
                "d": jnp.ones((cfg.nheads,), jnp.float32),
                "norm_g_w": jnp.ones((cfg.d_inner,), jnp.float32),
                "out_proj_w": normal(cfg.d_model, cfg.d_inner),
            }
        )
    return {
        "embed": normal(cfg.vocab_size, cfg.d_model),
        "norm_f_w": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": layers,
    }


def flatten_params(params: Params) -> tuple[list[jnp.ndarray], list[str]]:
    """Deterministic flat ordering shared with the Rust runtime (manifest)."""
    arrays, names = [params["embed"], params["norm_f_w"]], ["embed", "norm_f_w"]
    keys = (
        "norm_w", "in_proj_w", "conv_w", "conv_b", "dt_bias",
        "a_log", "d", "norm_g_w", "out_proj_w",
    )
    for i, lp in enumerate(params["layers"]):
        for k in keys:
            arrays.append(lp[k])
            names.append(f"layers.{i}.{k}")
    return arrays, names


def unflatten_params(arrays: list[jnp.ndarray], n_layer: int) -> Params:
    keys = (
        "norm_w", "in_proj_w", "conv_w", "conv_b", "dt_bias",
        "a_log", "d", "norm_g_w", "out_proj_w",
    )
    params = {"embed": arrays[0], "norm_f_w": arrays[1], "layers": []}
    idx = 2
    for _ in range(n_layer):
        params["layers"].append({k: arrays[idx + j] for j, k in enumerate(keys)})
        idx += len(keys)
    return params


# ---------------------------------------------------------------------------
# Variant-dispatched primitives
# ---------------------------------------------------------------------------


def linear(x, w, variant: str, use_pallas: bool = False, bias=None, prepared=None):
    """Linear layer y = x @ w.T under the variant's quantizer.  w: (out, in).

    `prepared` optionally carries the offline Hadamard-domain int8 weight
    (w_q_t, s_w) so serve-time graphs skip the per-call weight transform —
    the deployed configuration (the FPGA preprocesses weights offline too).
    """
    if variant == "fp32":
        y = x @ w.T
        return y if bias is None else y + bias
    if variant == "normalq":
        return quantize.normalq_linear(x, w, bias)
    if variant == "smoothq":
        return quantize.smoothq_linear(x, w, bias)
    if variant in ("fastmamba_lq", "fastmamba"):
        if prepared is not None:
            w_q_t, s_w = prepared
        else:
            w_q_t, s_w = quantize.hadamard_prepare_weight(w, HADAMARD_GROUP)
        if use_pallas:
            return k_had.hadamard_linear_pallas(x, w_q_t, s_w, HADAMARD_GROUP, bias)
        return quantize.hadamard_linear_prepared(x, w_q_t, s_w, HADAMARD_GROUP, bias)
    raise ValueError(f"unknown variant {variant}")


def compute_prepared(params: Params, cfg: Mamba2Config):
    """Offline weight preparation for the Hadamard variants: per layer the
    in/out projections plus the tied lm head.  Returns a pytree mirrored by
    `flatten_prepared` (the Rust runtime computes identical tensors)."""
    layers = []
    for lp in params["layers"]:
        layers.append({
            "in_proj": quantize.hadamard_prepare_weight(lp["in_proj_w"], HADAMARD_GROUP),
            "out_proj": quantize.hadamard_prepare_weight(lp["out_proj_w"], HADAMARD_GROUP),
        })
    return {"layers": layers,
            "lm_head": quantize.hadamard_prepare_weight(params["embed"], HADAMARD_GROUP)}


def flatten_prepared(prepared) -> tuple[list, list[str]]:
    """Deterministic flat ordering of the prepared-weight pytree."""
    arrays, names = [], []
    for i, lp in enumerate(prepared["layers"]):
        for key in ("in_proj", "out_proj"):
            w_q_t, s_w = lp[key]
            arrays += [w_q_t, s_w]
            names += [f"layers.{i}.{key}.w_q_t", f"layers.{i}.{key}.s_w"]
    w_q_t, s_w = prepared["lm_head"]
    arrays += [w_q_t, s_w]
    names += ["lm_head.w_q_t", "lm_head.s_w"]
    return arrays, names


def unflatten_prepared(arrays: list, n_layer: int):
    layers = []
    idx = 0
    for _ in range(n_layer):
        layers.append({
            "in_proj": (arrays[idx], arrays[idx + 1]),
            "out_proj": (arrays[idx + 2], arrays[idx + 3]),
        })
        idx += 4
    return {"layers": layers, "lm_head": (arrays[idx], arrays[idx + 1])}


def softplus_v(x, variant: str, use_pallas: bool = False):
    if variant == "fastmamba":
        return k_nau.softplus_approx(x) if use_pallas else ref.softplus_approx_f32(x)
    return jax.nn.softplus(x)


def exp_v(x, variant: str, use_pallas: bool = False):
    """exp over non-positive arguments (dt * a with a < 0)."""
    if variant == "fastmamba":
        return k_nau.exp_approx(x) if use_pallas else ref.exp_approx_f32(x)
    return jnp.exp(x)


def conv_v(x, w, b, variant: str, use_pallas: bool = False):
    if variant == "fastmamba":
        w = quantize.pot_conv1d_prepare(w)
        x = quantize.pot_fake_quant(x, axis=0)  # fine-grained: per channel
    if use_pallas:
        return k_conv.conv1d_pallas(x, w, b)
    return ref.conv1d_ref(x, w, b)


def conv_v_stateful(x_ext, w, b, variant: str, use_pallas: bool, k: int):
    """Causal conv over a chunk with `k-1` rows of carried history prepended:
    the kernels zero-pad internally, so the first `k-1` outputs (which saw
    the synthetic zero padding) are dropped and the remaining L outputs have
    exactly the carried history in their receptive field."""
    y = conv_v(x_ext, w, b, variant, use_pallas)
    return y[k - 1:]


# ---------------------------------------------------------------------------
# Block forward (prefill) — Fig. 2 computational flow
# ---------------------------------------------------------------------------


def _split_zxbcdt(zxbcdt, cfg: Mamba2Config):
    d_in, d_st = cfg.d_inner, cfg.ngroups * cfg.d_state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : d_in + d_in + 2 * d_st]
    dt_raw = zxbcdt[..., d_in + d_in + 2 * d_st :]
    return z, xbc, dt_raw


def block_prefill(lp, u, cfg: Mamba2Config, variant: str, use_pallas: bool = False,
                  conv_state0=None, ssm_state0=None, lp_prepared=None):
    """One Mamba2 block over a sequence chunk u: (L, d_model).

    `conv_state0` (d_conv-1, conv_dim) and `ssm_state0` (H, P, N) carry the
    recurrent state from a previous chunk (zeros for a fresh sequence) — the
    serving scheduler relies on this to prefill long prompts in bucket-sized
    chunks.  Returns (residual output (L, d_model), conv_tail, ssm_state).
    """
    l = u.shape[0]
    res = u
    x = ref.rmsnorm(u, lp["norm_w"])
    zxbcdt = linear(x, lp["in_proj_w"], variant, use_pallas,
                    prepared=None if lp_prepared is None else lp_prepared["in_proj"])
    z, xbc_pre, dt_raw = _split_zxbcdt(zxbcdt, cfg)

    if conv_state0 is None:
        conv_state0 = jnp.zeros((cfg.d_conv - 1, cfg.conv_dim), jnp.float32)
    xbc_ext = jnp.concatenate([conv_state0, xbc_pre], axis=0)  # (K-1+L, C)
    conv_tail = xbc_ext[l:, :]
    xbc = ref.silu(conv_v_stateful(xbc_ext, lp["conv_w"], lp["conv_b"], variant,
                                   use_pallas, cfg.d_conv))

    x_ssm = xbc[:, : cfg.d_inner]
    b_mat = xbc[:, cfg.d_inner : cfg.d_inner + cfg.d_state]
    c_mat = xbc[:, cfg.d_inner + cfg.d_state :]

    # Step 1 (Fig. 7): dt preprocessing through the NAU in SoftPlus mode.
    dt = softplus_v(dt_raw + lp["dt_bias"], variant, use_pallas)  # (L, H)
    a = -jnp.exp(lp["a_log"])  # (H,)
    # Step 2: abar = exp(dt * a) through the NAU in exponential mode.
    abar = exp_v(dt * a[None, :], variant, use_pallas)  # (L, H)

    xh = x_ssm.reshape(l, cfg.nheads, cfg.headdim)
    if variant == "fastmamba":
        # Fine-grained PoT quantization of the SSM block operands.
        xh = quantize.pot_fake_quant(xh, axis=(0, 2))  # per head
        b_mat = quantize.pot_fake_quant(b_mat)
        c_mat = quantize.pot_fake_quant(c_mat)
        dt = quantize.pot_fake_quant(dt, axis=0)
        abar = quantize.pot_fake_quant(abar, axis=0)

    # Step 3: the recurrence.
    if ssm_state0 is None:
        ssm_state0 = jnp.zeros((cfg.nheads, cfg.headdim, cfg.d_state), jnp.float32)
    if use_pallas:
        y, h = k_ssd.ssd_scan_pallas(
            xh.transpose(1, 0, 2), dt.T, abar.T, b_mat, c_mat, lp["d"], ssm_state0
        )
        y = y.transpose(1, 0, 2)
    else:
        y, h = _ssd_ref_with_abar(xh, dt, abar, b_mat, c_mat, lp["d"], ssm_state0)

    y = y.reshape(l, cfg.d_inner)
    y = ref.gated_rmsnorm(y, z, lp["norm_g_w"])
    out = linear(y, lp["out_proj_w"], variant, use_pallas,
                 prepared=None if lp_prepared is None else lp_prepared["out_proj"])
    return res + out, conv_tail, h


def _ssd_ref_with_abar(xh, dt, abar, b_mat, c_mat, d_vec, h0):
    """Reference scan taking abar explicitly (matching the kernel contract)."""

    def one_head(x, dt_h, abar_h, d_h, h0_h):
        def step(h, inp):
            x_t, dt_t, ab_t, b_t, c_t = inp
            h = ab_t * h + (dt_t * x_t)[:, None] * b_t[None, :]
            return h, h @ c_t + d_h * x_t

        h, y = jax.lax.scan(step, h0_h, (x, dt_h, abar_h, b_mat, c_mat))
        return y, h

    fn = jax.vmap(one_head, in_axes=(1, 1, 1, 0, 0), out_axes=(1, 0))
    return fn(xh, dt, abar, d_vec, h0)


# ---------------------------------------------------------------------------
# Full-model prefill
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "variant", "use_pallas"))
def prefill(params: Params, tokens, cfg: Mamba2Config, variant: str = "fp32",
            use_pallas: bool = False, conv_states0=None, ssm_states0=None,
            prepared=None):
    """tokens: (L,) int32 -> (logits (L, vocab), conv_states, ssm_states).

    conv_states: (n_layer, d_conv-1, conv_dim); ssm_states: (n_layer, H, P, N).
    Optional `*_states0` carry recurrent state from a previous chunk so long
    prompts can be prefilled in bucket-sized chunks (chunked prefill).
    """
    x = params["embed"][tokens]
    conv_states, ssm_states = [], []
    for i, lp in enumerate(params["layers"]):
        cs0 = None if conv_states0 is None else conv_states0[i]
        ss0 = None if ssm_states0 is None else ssm_states0[i]
        lpp = None if prepared is None else prepared["layers"][i]
        x, ct, h = block_prefill(lp, x, cfg, variant, use_pallas, cs0, ss0, lpp)
        conv_states.append(ct)
        ssm_states.append(h)
    x = ref.rmsnorm(x, params["norm_f_w"])
    logits = linear(x, params["embed"], variant, use_pallas,
                    prepared=None if prepared is None else prepared["lm_head"])
    return logits, jnp.stack(conv_states), jnp.stack(ssm_states)


def prefill_batched(params, tokens_b, cfg, variant="fp32", use_pallas=False):
    """tokens_b: (B, L) — vmap of `prefill` (per-sequence quantizer scales)."""
    return jax.vmap(
        lambda t: prefill(params, t, cfg, variant, use_pallas)
    )(tokens_b)


# ---------------------------------------------------------------------------
# Decode step (recurrent, Eq. 2)
# ---------------------------------------------------------------------------


def block_decode(lp, u, conv_state, h, cfg: Mamba2Config, variant: str,
                 lp_prepared=None):
    """Single-token block step.  u: (d_model,); conv_state: (d_conv-1,
    conv_dim); h: (H, P, N).  Returns (out, conv_state', h')."""
    res = u
    x = ref.rmsnorm(u, lp["norm_w"])
    zxbcdt = linear(x[None, :], lp["in_proj_w"], variant,
                    prepared=None if lp_prepared is None else lp_prepared["in_proj"])[0]
    z, xbc_pre, dt_raw = _split_zxbcdt(zxbcdt, cfg)

    window = jnp.concatenate([conv_state, xbc_pre[None, :]], axis=0)  # (K, C)
    conv_w = lp["conv_w"]
    xbc_in = window
    if variant == "fastmamba":
        conv_w = quantize.pot_conv1d_prepare(conv_w)
        xbc_in = quantize.pot_fake_quant(window, axis=0)
    xbc = ref.silu(jnp.einsum("kc,ck->c", xbc_in, conv_w) + lp["conv_b"])
    new_conv_state = window[1:]

    x_ssm = xbc[: cfg.d_inner]
    b_t = xbc[cfg.d_inner : cfg.d_inner + cfg.d_state]
    c_t = xbc[cfg.d_inner + cfg.d_state :]

    dt = softplus_v(dt_raw + lp["dt_bias"], variant)  # (H,)
    a = -jnp.exp(lp["a_log"])
    abar = exp_v(dt * a, variant)  # (H,)

    xh = x_ssm.reshape(cfg.nheads, cfg.headdim)
    if variant == "fastmamba":
        xh = quantize.pot_fake_quant(xh, axis=1)
        b_t = quantize.pot_fake_quant(b_t)
        c_t = quantize.pot_fake_quant(c_t)
        dt = quantize.pot_fake_quant(dt)
        abar = quantize.pot_fake_quant(abar)

    h = abar[:, None, None] * h + (dt[:, None] * xh)[..., None] * b_t[None, None, :]
    y = h @ c_t + lp["d"][:, None] * xh  # (H, P)

    y = ref.gated_rmsnorm(y.reshape(cfg.d_inner), z, lp["norm_g_w"])
    out = linear(y[None, :], lp["out_proj_w"], variant,
                 prepared=None if lp_prepared is None else lp_prepared["out_proj"])[0]
    return res + out, new_conv_state, h


@functools.partial(jax.jit, static_argnames=("cfg", "variant"))
def decode_step(params: Params, conv_states, ssm_states, token, cfg: Mamba2Config,
                variant: str = "fp32", prepared=None):
    """One decode step.  token: () int32.  Returns (logits (vocab,), states')."""
    x = params["embed"][token]
    new_conv, new_ssm = [], []
    for i, lp in enumerate(params["layers"]):
        lpp = None if prepared is None else prepared["layers"][i]
        x, ct, h = block_decode(lp, x, conv_states[i], ssm_states[i], cfg, variant, lpp)
        new_conv.append(ct)
        new_ssm.append(h)
    x = ref.rmsnorm(x, params["norm_f_w"])
    logits = linear(x[None, :], params["embed"], variant,
                    prepared=None if prepared is None else prepared["lm_head"])[0]
    return logits, jnp.stack(new_conv), jnp.stack(new_ssm)


def decode_step_batched(params, conv_states_b, ssm_states_b, tokens_b, cfg,
                        variant="fp32", prepared=None):
    """Batched decode: tokens_b (B,), states with leading batch dim."""
    return jax.vmap(
        lambda cs, ss, t: decode_step(params, cs, ss, t, cfg, variant, prepared)
    )(conv_states_b, ssm_states_b, tokens_b)


def init_decode_state(cfg: Mamba2Config, batch: int | None = None):
    conv = jnp.zeros((cfg.n_layer, cfg.d_conv - 1, cfg.conv_dim), jnp.float32)
    ssm = jnp.zeros(
        (cfg.n_layer, cfg.nheads, cfg.headdim, cfg.d_state), jnp.float32
    )
    if batch is not None:
        conv = jnp.broadcast_to(conv[None], (batch, *conv.shape))
        ssm = jnp.broadcast_to(ssm[None], (batch, *ssm.shape))
    return conv, ssm
