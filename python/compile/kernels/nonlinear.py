"""Pallas kernel for the Nonlinear Approximation Unit (NAU, Fig. 8).

One multi-mode kernel computes either `exp` (Eq. 3) or `SoftPlus` (Eq. 6) on
16-bit fixed point (carried in int32 lanes).  The hardware's 8-segment PWL
of 2^v and the shift-by-|u| are expressed as branch-free integer ops so the
kernel lowers to plain HLO under interpret=True.

Hardware adaptation: the FPGA NAU is a 24-lane multiplexed pipeline (RPU
negate -> EXP-INT -> post-add).  On a TPU-style target the same structure is
a vectorized select tree over VMEM-resident tiles — the mode bit becomes a
broadcast select, the segment LUT a tiny constant table held in registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..config import FXP, FixedPointSpec
from . import ref

MODE_EXP = 0
MODE_SOFTPLUS = 1

#: lane width of the hardware NAU (Fig. 8: 24 x 16b).
NAU_LANES = 24


def _nau_kernel(x_ref, intercept_ref, slope_ref, o_ref, *, mode: int, spec: FixedPointSpec):
    """Fixed-point NAU over one VMEM tile.  Values are Q<spec> in int32.

    The PWL coefficient tables arrive as (tiny) kernel inputs — the hardware
    analogue is the EXP-INT segment LUT held in registers.
    """
    f = spec.frac_bits
    cf = spec.coeff_frac_bits
    intercept = intercept_ref[...]
    slope = slope_ref[...]
    seg_shift = f - int(np.log2(spec.pwl_segments))

    x = x_ref[...].astype(jnp.int32)

    # Preprocessing part: RPU negation for SoftPlus's positive branch.
    if mode == MODE_SOFTPLUS:
        x_neg = jnp.minimum(x, -x)  # == -|x|, the EXP-INT input
    else:
        x_neg = jnp.minimum(x, 0)

    # EXP-INT part (Eq. 3): t = x*log2e; u/v split; 8-seg PWL of 2^v; >>|u|.
    t = (x_neg * spec.log2e_fx) >> f
    neg = -t
    u_abs = neg >> f
    rem = neg & (spec.scale - 1)
    seg = rem >> seg_shift
    frac = rem - (seg << seg_shift)
    val_q = intercept[seg] + slope[seg] * frac  # Q1.cf
    u_clip = jnp.minimum(u_abs, 30)
    e = jnp.where(u_abs >= 30, 0, (val_q >> u_clip) >> (cf - f))

    # Postprocessing part: delay-unit add of x for the positive branch.
    if mode == MODE_SOFTPLUS:
        out = jnp.where(x > 0, x + e, e)
    else:
        out = e
    o_ref[...] = out.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("mode", "block"))
def nau_fixed(x_fx: jnp.ndarray, mode: int = MODE_EXP, block: int = 256) -> jnp.ndarray:
    """Run the NAU Pallas kernel over a 1-D int32 fixed-point tensor.

    The grid tiles the flat tensor into `block`-lane chunks — `block` is a
    multiple of the hardware's 24-lane width rounded to a TPU-friendly 256.
    """
    spec = FXP
    intercept_np, slope_np = ref.pwl_tables(spec)
    nseg = spec.pwl_segments
    flat = x_fx.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    out = pl.pallas_call(
        functools.partial(_nau_kernel, mode=mode, spec=spec),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.int32),
        grid=(flat.shape[0] // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((nseg,), lambda i: (0,)),
            pl.BlockSpec((nseg,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(flat, jnp.asarray(intercept_np), jnp.asarray(slope_np))
    return out[:n].reshape(x_fx.shape)


def exp_fixed(x_fx: jnp.ndarray) -> jnp.ndarray:
    """Eq. 3 exponential (x <= 0) on fixed point via the Pallas NAU."""
    return nau_fixed(x_fx, mode=MODE_EXP)


def softplus_fixed(x_fx: jnp.ndarray) -> jnp.ndarray:
    """Eq. 6 SoftPlus on fixed point via the Pallas NAU."""
    return nau_fixed(x_fx, mode=MODE_SOFTPLUS)


def exp_approx(x: jnp.ndarray) -> jnp.ndarray:
    """Float wrapper: quantize -> NAU exp -> dequantize."""
    return ref.from_fixed(exp_fixed(ref.to_fixed(x)))


def softplus_approx(x: jnp.ndarray) -> jnp.ndarray:
    """Float wrapper: quantize -> NAU SoftPlus -> dequantize."""
    return ref.from_fixed(softplus_fixed(ref.to_fixed(x)))
