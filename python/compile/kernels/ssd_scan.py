"""Pallas kernel for the SSM Module's recurrence (Fig. 7, Step 3).

The FPGA module iterates the SSD recurrence sequentially over the sequence —
Step 3's 32-parallel PMU/PMA/MAT array updates the hidden state H and reads
it out against C every cycle.  The kernel reproduces exactly that schedule:
grid over heads (the module time-multiplexes heads), an in-kernel `fori_loop`
over time, and the whole per-head state H (P x N) resident in VMEM for the
entire sequence — H never spills, mirroring the paper's on-chip H buffer.

Inputs take the *already preprocessed* abar = exp(dt * a) and dt so the same
kernel serves both the float path and the PoT/NAU-quantized path (the model
composes the NAU kernel upstream).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_scan_kernel(x_ref, dt_ref, abar_ref, b_ref, c_ref, d_ref, h0_ref, y_ref, h_ref):
    """One head: x (1,L,P), dt (1,L), abar (1,L), b/c (L,N), d (1,1), h0 (1,P,N)."""
    l = x_ref.shape[1]
    h0 = h0_ref[0]
    d_scalar = d_ref[0, 0]

    def step(t, h):
        x_t = x_ref[0, t, :]  # (P,)
        dtx = dt_ref[0, t] * x_t  # PMU: dt * x
        b_t = b_ref[t, :]
        c_t = c_ref[t, :]
        # PMU/PMA array: h = abar * h + (dt x) outer B
        h = abar_ref[0, t] * h + dtx[:, None] * b_t[None, :]
        # MAT array: y = h . C ; final PMA: + D * x
        y_t = h @ c_t + d_scalar * x_t
        pl.store(y_ref, (0, pl.dslice(t, 1), slice(None)), y_t[None, :])
        return h

    h = jax.lax.fori_loop(0, l, step, h0)
    h_ref[0] = h


@jax.jit
def ssd_scan_pallas(x, dt, abar, b_mat, c_mat, d_vec, h0):
    """Multi-head SSD scan.

    x: (H, L, P); dt, abar: (H, L); b_mat, c_mat: (L, N) (ngroups=1, shared);
    d_vec: (H,); h0: (H, P, N).  Returns (y: (H, L, P), h: (H, P, N)).
    """
    nh, l, p = x.shape
    n = b_mat.shape[1]
    d2 = d_vec.reshape(nh, 1)
    y, h = pl.pallas_call(
        _ssd_scan_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((nh, l, p), jnp.float32),
            jax.ShapeDtypeStruct((nh, p, n), jnp.float32),
        ),
        grid=(nh,),
        in_specs=[
            pl.BlockSpec((1, l, p), lambda h_: (h_, 0, 0)),
            pl.BlockSpec((1, l), lambda h_: (h_, 0)),
            pl.BlockSpec((1, l), lambda h_: (h_, 0)),
            pl.BlockSpec((l, n), lambda h_: (0, 0)),
            pl.BlockSpec((l, n), lambda h_: (0, 0)),
            pl.BlockSpec((1, 1), lambda h_: (h_, 0)),
            pl.BlockSpec((1, p, n), lambda h_: (h_, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, l, p), lambda h_: (h_, 0, 0)),
            pl.BlockSpec((1, p, n), lambda h_: (h_, 0, 0)),
        ),
        interpret=True,
    )(x, dt, abar, b_mat, c_mat, d2, h0)
    return y, h
