"""Pallas kernel for the Convolution Module (32 MAT units, kernel size 4).

The FPGA module assigns one 4-wide MAT unit per output element: a length-4
dot between the kernel taps and a sliding input window.  Here the grid tiles
the channel dimension (the module's 32-way channel parallelism) and each
grid step computes the full causal sequence for its channel block from a
VMEM-resident (L+K-1, block_c) input slab — one HBM read per channel block,
like the module's single pass through the on-chip line buffer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: channel parallelism of the hardware module.
CONV_MATS = 32


def _conv1d_kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, l: int):
    """x_ref: (l+k-1, bc) causally pre-padded; w_ref: (bc, k); b_ref: (1, bc)."""
    acc = jnp.zeros_like(o_ref)
    for tap in range(k):  # K is a static hardware constant (4)
        acc += x_ref[tap : tap + l, :] * w_ref[:, tap][None, :]
    o_ref[...] = acc + b_ref[0, :][None, :]


@functools.partial(jax.jit, static_argnames=("block_c",))
def conv1d_pallas(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, block_c: int = 64
) -> jnp.ndarray:
    """Depthwise causal conv1d.  x: (L, C); w: (C, K); b: (C,) -> (L, C)."""
    l, c = x.shape
    k = w.shape[1]
    pad_c = (-c) % block_c
    xp = jnp.pad(x, ((k - 1, 0), (0, pad_c)))
    wp = jnp.pad(w, ((0, pad_c), (0, 0)))
    bp = jnp.pad(b, (0, pad_c))[None, :]
    out = pl.pallas_call(
        functools.partial(_conv1d_kernel, k=k, l=l),
        out_shape=jax.ShapeDtypeStruct((l, c + pad_c), jnp.float32),
        grid=((c + pad_c) // block_c,),
        in_specs=[
            pl.BlockSpec((l + k - 1, block_c), lambda j: (0, j)),
            pl.BlockSpec((block_c, k), lambda j: (j, 0)),
            pl.BlockSpec((1, block_c), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((l, block_c), lambda j: (0, j)),
        interpret=True,
    )(xp, wp, bp)
    return out[:, :c]
