"""Pallas kernels for the Hadamard-based Linear Module (Algorithm 1, Fig. 6).

Two kernels mirror the module's two stages:

1. `hadamard_transform_pallas` — the HAT stage: blocked Hadamard transform of
   the activations (X[i] @ H[i] per group).  On the FPGA this is 4 parallel
   Hadamard Adder Trees; here it is a tile-local matmul against the +-1
   matrix held in VMEM.
2. `int8_matmul_pallas` — the 64-MAT stage: int8 x int8 -> int32 tiled matmul
   with the k-loop innermost in the grid, accumulating in the output tile
   (the MXU-shaped analogue of the MAT array's multiply-accumulate).

The activation scale s_X is found between the two stages (Algorithm 1 line
7), exactly like the hardware's x s_coe / >> s_shift requantization step.
Weights are transformed+quantized offline by `quantize.hadamard_prepare_weight`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import quantize

INT8_MAX = 127.0


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _hadamard_kernel(x_ref, h_ref, o_ref, *, group: int):
    """Transform one (bl, d) activation tile: each `group`-wide slice of the
    feature dim is multiplied by the shared Hadamard matrix."""
    x = x_ref[...]
    h = h_ref[...]
    bl, d = x.shape
    xg = x.reshape(bl * (d // group), group)
    o_ref[...] = (xg @ h).reshape(bl, d)


@functools.partial(jax.jit, static_argnames=("group", "block_l"))
def hadamard_transform_pallas(x: jnp.ndarray, group: int, block_l: int = 64):
    """Blocked Hadamard transform along the last axis via Pallas.

    x: (L, d) with d % group == 0.  Grid tiles the row dimension; the +-1
    Hadamard matrix (group x group) stays resident across grid steps.
    """
    l, d = x.shape
    assert d % group == 0, (d, group)
    h = jnp.asarray(quantize.hadamard_matrix(group))
    xp = _pad_to(x, 0, block_l)
    out = pl.pallas_call(
        functools.partial(_hadamard_kernel, group=group),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        grid=(xp.shape[0] // block_l,),
        in_specs=[
            pl.BlockSpec((block_l, d), lambda i: (i, 0)),
            pl.BlockSpec((group, group), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_l, d), lambda i: (i, 0)),
        interpret=True,
    )(xp, h)
    return out[:l]


def _int8_matmul_kernel(x_ref, w_ref, o_ref):
    """One (bl, bq) output tile; k innermost grid axis accumulates."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(jax.jit, static_argnames=("block_l", "block_q", "block_k"))
def int8_matmul_pallas(
    x_q: jnp.ndarray,
    w_q_t: jnp.ndarray,
    block_l: int = 64,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    """int8 x int8 -> int32 tiled matmul.  x_q: (L, d) int8; w_q_t: (d, q) int8."""
    l, d = x_q.shape
    d2, q = w_q_t.shape
    assert d == d2
    xp = _pad_to(_pad_to(x_q, 0, block_l), 1, block_k)
    wp = _pad_to(_pad_to(w_q_t, 0, block_k), 1, block_q)
    grid = (xp.shape[0] // block_l, wp.shape[1] // block_q, xp.shape[1] // block_k)
    out = pl.pallas_call(
        _int8_matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_l, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_q), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_l, block_q), lambda i, j, k: (i, j)),
        interpret=True,
    )(xp, wp)
    return out[:l, :q]


def hadamard_linear_pallas(
    x: jnp.ndarray,
    w_q_t: jnp.ndarray,
    s_w: jnp.ndarray,
    group: int,
    bias: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Full Algorithm 1 forward with Pallas kernels for both stages.

    x: (..., d) float activations; (w_q_t, s_w) from
    `quantize.hadamard_prepare_weight`.  Matches `ref.hadamard_linear_ref`
    bit-for-bit (same rounding, same scales).
    """
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    x_h = hadamard_transform_pallas(x2, group)
    s_x = jnp.maximum(jnp.max(jnp.abs(x_h)), 1e-8) / INT8_MAX
    x_q = jnp.clip(jnp.round(x_h / s_x), -128, 127).astype(jnp.int8)
    acc = int8_matmul_pallas(x_q, w_q_t)
    y = acc.astype(jnp.float32) * (s_x * s_w / group)
    if bias is not None:
        y = y + bias
    return y.reshape(*lead, -1)
