"""Pure-jnp oracles for every Pallas kernel.

These are the correctness contracts: pytest (python/tests/) asserts each
Pallas kernel matches its oracle to tight tolerances across
hypothesis-generated shapes and dtypes, and the Rust golden model
(rust/src/model) is in turn validated against HLO lowered from these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..config import FXP, FixedPointSpec


# ---------------------------------------------------------------------------
# Nonlinear approximations (Eq. 3 - 6), float reference of the exact bit math
# ---------------------------------------------------------------------------


def pwl_tables(spec: FixedPointSpec = FXP) -> tuple[np.ndarray, np.ndarray]:
    """Endpoint-interpolation PWL coefficients for g(rem) = 2^(-rem/2^F),
    rem in [0, 2^F), split into `spec.pwl_segments` equal segments.

    Returns (intercept_q, slope_q) in Q1.<coeff_frac_bits> fixed point; the
    approximation on segment i is  g ~= intercept[i] + slope[i]*(rem - rem0).
    """
    f = spec.frac_bits
    nseg = spec.pwl_segments
    seg_w = (1 << f) // nseg
    cs = 1 << spec.coeff_frac_bits
    rem0 = np.arange(nseg) * seg_w
    g0 = 2.0 ** (-rem0 / (1 << f))
    g1 = 2.0 ** (-(rem0 + seg_w) / (1 << f))
    intercept = np.round(g0 * cs).astype(np.int32)
    slope = np.round((g1 - g0) / seg_w * cs).astype(np.int32)
    return intercept, slope


def exp_fixed_ref(x_fx: jnp.ndarray, spec: FixedPointSpec = FXP) -> jnp.ndarray:
    """Bit-exact Eq. 3: e^x for x <= 0 on `spec` fixed point.

    x_fx: int32 tensor holding Q6.10 values (value = x_fx / 2^F), x_fx <= 0.
    Returns int32 Q6.10 exp values in [0, 2^F].

    Pipeline (all integer): t = (x * LOG2E) >> F;  split t = u + v with
    u integer <= 0 and v in (-1, 0];  2^v via 8-segment PWL;  >> |u|.
    """
    f = spec.frac_bits
    cf = spec.coeff_frac_bits
    intercept, slope = pwl_tables(spec)
    intercept = jnp.asarray(intercept)
    slope = jnp.asarray(slope)
    seg_shift = f - int(np.log2(spec.pwl_segments))

    x_fx = x_fx.astype(jnp.int32)
    t = (x_fx * spec.log2e_fx) >> f  # arithmetic shift == floor
    neg = -t  # >= 0
    u_abs = neg >> f
    rem = neg & (spec.scale - 1)
    seg = rem >> seg_shift
    frac = rem - (seg << seg_shift)
    val_q = intercept[seg] + slope[seg] * frac  # Q1.cf, in (0, 2^cf]
    u_clip = jnp.minimum(u_abs, 30)
    out = (val_q >> u_clip) >> (cf - f)
    return jnp.where(u_abs >= 30, 0, out).astype(jnp.int32)


def softplus_fixed_ref(x_fx: jnp.ndarray, spec: FixedPointSpec = FXP) -> jnp.ndarray:
    """Bit-exact Eq. 6 SoftPlus on fixed point (reusing the exp datapath).

    x <= 0 : e^x            (Eq. 5 approximation ln(1+e^x) ~= e^x)
    x >  0 : x + e^(-x)     (symmetry, Eq. 4)
    """
    x_fx = x_fx.astype(jnp.int32)
    neg_branch = exp_fixed_ref(jnp.minimum(x_fx, 0), spec)
    pos_branch = x_fx + exp_fixed_ref(jnp.minimum(-x_fx, 0), spec)
    return jnp.where(x_fx > 0, pos_branch, neg_branch)


def to_fixed(x: jnp.ndarray, spec: FixedPointSpec = FXP) -> jnp.ndarray:
    """Float -> saturating Q-format int32."""
    q = jnp.round(x * spec.scale)
    return jnp.clip(q, spec.qmin, spec.qmax).astype(jnp.int32)


def from_fixed(x_fx: jnp.ndarray, spec: FixedPointSpec = FXP) -> jnp.ndarray:
    return x_fx.astype(jnp.float32) / spec.scale


def exp_approx_f32(x: jnp.ndarray, spec: FixedPointSpec = FXP) -> jnp.ndarray:
    """Float-in/float-out wrapper of the fixed-point exp (x <= 0)."""
    return from_fixed(exp_fixed_ref(to_fixed(x, spec), spec), spec)


def softplus_approx_f32(x: jnp.ndarray, spec: FixedPointSpec = FXP) -> jnp.ndarray:
    """Float-in/float-out wrapper of the fixed-point SoftPlus."""
    return from_fixed(softplus_fixed_ref(to_fixed(x, spec), spec), spec)


# ---------------------------------------------------------------------------
# Hadamard int8 linear oracle (Algorithm 1)
# ---------------------------------------------------------------------------


def hadamard_linear_ref(x, w, group: int, bias=None):
    from .. import quantize

    return quantize.hadamard_linear(x, w, group, bias)


# ---------------------------------------------------------------------------
# Depthwise causal conv1d oracle (Convolution Module)
# ---------------------------------------------------------------------------


def conv1d_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Causal depthwise conv.  x: (L, C); w: (C, K); b: (C,).  y: (L, C).

    y[t, c] = b[c] + sum_k w[c, k] * x[t - (K-1) + k, c]   (zero padded)
    """
    k = w.shape[1]
    xp = jnp.pad(x, ((k - 1, 0), (0, 0)))
    cols = jnp.stack([xp[i : i + x.shape[0]] for i in range(k)], axis=-1)  # (L,C,K)
    return jnp.einsum("lck,ck->lc", cols, w) + b


# ---------------------------------------------------------------------------
# SSD scan oracle (SSM block, Eq. 2 over a sequence)
# ---------------------------------------------------------------------------


def ssd_scan_ref(x, dt, a, b_mat, c_mat, d_vec, h0=None):
    """Sequential reference of the Mamba2 SSD recurrence for one head.

    Shapes (single head):
      x:     (L, P)   head inputs
      dt:    (L,)     post-SoftPlus step sizes
      a:     ()       per-head A (negative scalar)
      b_mat: (L, N)   input matrix rows B_t
      c_mat: (L, N)   output matrix rows C_t
      d_vec: ()       per-head feedthrough D
      h0:    (P, N)   optional initial state
    Returns (y: (L, P), h: (P, N) final state).

      h_t = exp(dt_t * a) * h_{t-1} + dt_t * x_t (outer) B_t
      y_t = h_t @ C_t + D * x_t
    """
    l, p = x.shape
    n = b_mat.shape[1]
    h = jnp.zeros((p, n), jnp.float32) if h0 is None else h0

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        abar = jnp.exp(dt_t * a)
        h = abar * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = h @ c_t + d_vec * x_t
        return h, y_t

    h, y = jax.lax.scan(step, h, (x, dt, b_mat, c_mat))
    return y, h


def ssd_scan_multihead_ref(x, dt, a, b_mat, c_mat, d_vec, h0=None):
    """vmap of ssd_scan_ref over heads.

    x: (L, H, P); dt: (L, H); a: (H,); b_mat/c_mat: (L, N) shared (ngroups=1);
    d_vec: (H,); h0: (H, P, N).  Returns (y: (L, H, P), h: (H, P, N)).
    """
    nh = x.shape[1]
    if h0 is None:
        h0 = jnp.zeros((nh, x.shape[2], b_mat.shape[1]), jnp.float32)
    fn = jax.vmap(ssd_scan_ref, in_axes=(1, 1, 0, None, None, 0, 0), out_axes=(1, 0))
    return fn(x, dt, a, b_mat, c_mat, d_vec, h0)


# ---------------------------------------------------------------------------
# Float nonlinears kept in floating point by the accelerator
# ---------------------------------------------------------------------------


def silu(x):
    return x * jax.nn.sigmoid(x)


def rmsnorm(x, w, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def gated_rmsnorm(x, z, w, eps: float = 1e-5):
    """Mamba2's norm(y * silu(z)) gate before the output projection."""
    return rmsnorm(x * silu(z), w, eps)
