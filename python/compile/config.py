"""Model and fixed-point configuration shared across the compile path.

The dimensions mirror the published Mamba2 checkpoints the paper evaluates
(130M for prefill experiments, 2.7B for decode) plus a `tiny` configuration
that is trained at build time (see train_tiny.py) so accuracy experiments
(Table II) run against a model with real, non-random weight statistics.
"""

from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    """Dimensions of a Mamba2 model (SSD variant, ngroups=1)."""

    name: str
    d_model: int
    n_layer: int
    d_state: int
    headdim: int
    d_conv: int = 4
    expand: int = 2
    ngroups: int = 1
    vocab_size: int = 512

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def nheads(self) -> int:
        assert self.d_inner % self.headdim == 0
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:
        """Channels through the depthwise causal conv (x, B, C concatenated)."""
        return self.d_inner + 2 * self.ngroups * self.d_state

    @property
    def d_in_proj(self) -> int:
        """Output width of the input projection (z, xBC, dt)."""
        return 2 * self.d_inner + 2 * self.ngroups * self.d_state + self.nheads

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)


#: Mamba2-130M — the paper's prefill / accuracy model.
MAMBA2_130M = Mamba2Config(
    name="mamba2-130m",
    d_model=768,
    n_layer=24,
    d_state=128,
    headdim=64,
    vocab_size=50288,
)

#: Mamba2-2.7B — the paper's decode / energy-efficiency model.
MAMBA2_2_7B = Mamba2Config(
    name="mamba2-2.7b",
    d_model=2560,
    n_layer=64,
    d_state=128,
    headdim=64,
    vocab_size=50288,
)

#: Build-time-trained tiny model for accuracy-sensitive experiments.
TINY = Mamba2Config(
    name="mamba2-tiny",
    d_model=256,
    n_layer=4,
    d_state=64,
    headdim=32,
    vocab_size=512,
)

CONFIGS = {c.name: c for c in (MAMBA2_130M, MAMBA2_2_7B, TINY)}


@dataclasses.dataclass(frozen=True)
class FixedPointSpec:
    """Q-format used by the accelerator's fixed-point datapath.

    The paper's SSM module and NAU run on 16-bit fixed point; we use Q6.10
    (1 sign, 5 integer, 10 fraction bits).  `LOG2E` is the paper's 4-bit
    approximation log2(e) ~= (1.0111)_2 = 1.4375 (Eq. 3).
    """

    total_bits: int = 16
    frac_bits: int = 10
    #: number of PWL segments for 2^v, v in (-1, 0] (paper: 8).
    pwl_segments: int = 8
    #: internal PWL coefficient precision (Q1.14).
    coeff_frac_bits: int = 14

    @property
    def scale(self) -> int:
        return 1 << self.frac_bits

    @property
    def qmax(self) -> int:
        return (1 << (self.total_bits - 1)) - 1

    @property
    def qmin(self) -> int:
        return -(1 << (self.total_bits - 1))

    @property
    def log2e_fx(self) -> int:
        # exactly 1.4375 = (1.0111)_2 in the datapath's Q-format
        return int(1.4375 * self.scale)


FXP = FixedPointSpec()
