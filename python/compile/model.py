# L2 facade: the paper's jax model (fwd for prefill + recurrent decode),
# calling the Layer-1 kernels.  Implementation lives in mamba2.py; this
# module re-exports the public compile-path API.
from .config import CONFIGS, FXP, MAMBA2_130M, MAMBA2_2_7B, TINY, Mamba2Config
from .mamba2 import (
    VARIANTS,
    block_decode,
    block_prefill,
    decode_step,
    decode_step_batched,
    flatten_params,
    init_decode_state,
    init_params,
    prefill,
    prefill_batched,
    unflatten_params,
)

__all__ = [
    "CONFIGS", "FXP", "MAMBA2_130M", "MAMBA2_2_7B", "TINY", "Mamba2Config",
    "VARIANTS", "block_decode", "block_prefill", "decode_step",
    "decode_step_batched", "flatten_params", "init_decode_state",
    "init_params", "prefill", "prefill_batched", "unflatten_params",
]
