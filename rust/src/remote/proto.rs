//! Compact wire protocol between the pool dispatcher and remote workers.
//!
//! Framing is minimal and dependency-free: every message is
//!
//! ```text
//! [u32 len, little-endian] [u8 kind] [payload bytes]
//! ```
//!
//! where `len` counts the kind byte plus the payload, and is bounded by
//! [`MAX_FRAME`] so a corrupt or hostile peer can never make the reader
//! allocate unbounded memory.  All integers are little-endian; floats are
//! IEEE-754 bit patterns.  Decoding is bounds-checked at every read — a
//! truncated or malformed frame is an `InvalidData` error, never a panic
//! or an out-of-bounds read.
//!
//! The conversation is asymmetric:
//!
//! * dispatcher → worker: [`Frame::Hello`] (once), [`Frame::Submit`],
//!   [`Frame::Cancel`], [`Frame::Ping`]
//! * worker → dispatcher: [`Frame::HelloAck`] (once), then per-request
//!   event frames mirroring [`coordinator::request::Event`] 1:1 —
//!   [`Frame::FirstToken`], [`Frame::Token`], [`Frame::Finished`] — plus
//!   [`Frame::Pong`] health replies carrying live load/capacity.
//!
//! The handshake pins compatibility: `Hello` carries a magic and a
//! protocol version, and the worker answers `HelloAck` only when both
//! match ([`PROTO_VERSION`]); a mismatch closes the connection before any
//! request state exists.  `Submit` serializes the full request contract —
//! prompt, budget, variant, stop token, session id, remaining deadline,
//! priority, and every sampling field — so a remote worker reproduces
//! the exact token stream an in-process worker would (position-keyed
//! sampling makes the stream worker-invariant).  Deadlines cross the wire
//! as *remaining* milliseconds: `Instant`s are process-local, so the
//! sender computes what's left and the worker re-anchors on arrival.
//!
//! [`coordinator::request::Event`]: crate::coordinator::Event

use std::io::{self, Read, Write};
use std::time::Duration;

use crate::coordinator::sampler::SamplingParams;
use crate::coordinator::{FinishReason, FinishedRequest, Request, SpecStats};

/// `b"FMRW"` little-endian: FastMamba Remote Worker.
pub const MAGIC: u32 = u32::from_le_bytes(*b"FMRW");

/// Protocol version; bumped on any frame-layout change.  The handshake
/// rejects mismatches outright — no cross-version negotiation.
pub const PROTO_VERSION: u16 = 1;

/// Upper bound on one frame's body (kind + payload).  Large enough for a
/// long prompt or a long generation, small enough that a corrupt length
/// prefix cannot drive a giant allocation.
pub const MAX_FRAME: usize = 32 << 20;

const KIND_HELLO: u8 = 1;
const KIND_HELLO_ACK: u8 = 2;
const KIND_SUBMIT: u8 = 3;
const KIND_CANCEL: u8 = 4;
const KIND_PING: u8 = 5;
const KIND_PONG: u8 = 6;
const KIND_FIRST_TOKEN: u8 = 7;
const KIND_TOKEN: u8 = 8;
const KIND_FINISHED: u8 = 9;

/// A [`Request`] flattened for the wire.  Everything the serving contract
/// needs crosses; process-local plumbing (event channel, cancel flag,
/// resume state, `submitted_at`) never does — the worker re-creates its
/// own at admission.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: u64,
    pub variant: String,
    pub stop_token: Option<u32>,
    pub session_id: Option<u64>,
    /// deadline budget *remaining* at send time, in milliseconds
    pub deadline_ms: Option<u64>,
    pub priority: i32,
    pub sampling: SamplingParams,
}

impl WireRequest {
    /// Flatten a request for transmission.  The deadline is converted to
    /// remaining budget now, so queue time on the dispatcher side counts
    /// against it exactly as it would for a local worker.
    pub fn from_request(req: &Request) -> Self {
        let deadline_ms = req.deadline.map(|d| {
            d.saturating_sub(req.submitted_at.elapsed()).as_millis() as u64
        });
        Self {
            id: req.id,
            prompt: req.prompt.clone(),
            max_new_tokens: req.max_new_tokens as u64,
            variant: req.variant.clone(),
            stop_token: req.stop_token,
            session_id: req.session_id,
            deadline_ms,
            priority: req.priority,
            sampling: req.sampling.clone(),
        }
    }

    /// Rebuild a local [`Request`] on the worker side.  `submitted_at`
    /// re-anchors to now — TTFT/latency measured here cover the worker's
    /// own queue + serving time; the dispatcher keeps end-to-end numbers.
    pub fn into_request(self) -> Request {
        let mut req = Request::new(
            self.id,
            self.prompt,
            self.max_new_tokens as usize,
            &self.variant,
        );
        if let Some(t) = self.stop_token {
            req = req.with_stop_token(t);
        }
        if let Some(sid) = self.session_id {
            req = req.with_session(sid);
        }
        if let Some(ms) = self.deadline_ms {
            req = req.with_deadline(Duration::from_millis(ms));
        }
        req.with_priority(self.priority).with_sampling(self.sampling)
    }
}

/// One protocol message.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// dispatcher → worker, first frame on a connection
    Hello { magic: u32, version: u16 },
    /// worker → dispatcher, handshake accept; `capacity` is the worker's
    /// concurrent-slot count (its engine's `max_active`)
    HelloAck { version: u16, capacity: u32 },
    Submit(WireRequest),
    /// dispatcher → worker: cancel request `id` (maps onto the local
    /// cancel-flag path; the worker still answers with a terminal
    /// `Finished { finish_reason: Cancelled }`)
    Cancel { id: u64 },
    Ping { seq: u64 },
    /// health reply: `load` = requests pending+active on the worker
    Pong { seq: u64, load: u32, capacity: u32 },
    /// mirrors [`Event::FirstToken`](crate::coordinator::Event)
    FirstToken { id: u64 },
    /// mirrors [`Event::Token`](crate::coordinator::Event)
    Token { id: u64, tok: u32, index: u64 },
    /// mirrors [`Event::Finished`](crate::coordinator::Event) — terminal
    Finished { fin: FinishedRequest },
}

/// The dispatcher's opening frame.
pub fn hello() -> Frame {
    Frame::Hello { magic: MAGIC, version: PROTO_VERSION }
}

fn reason_byte(r: FinishReason) -> u8 {
    match r {
        FinishReason::Length => 0,
        FinishReason::StopToken => 1,
        FinishReason::StopSequence => 2,
        FinishReason::Cancelled => 3,
        FinishReason::Deadline => 4,
        FinishReason::WorkerDied => 5,
        FinishReason::Preempted => 6,
        FinishReason::Overloaded => 7,
    }
}

fn byte_reason(b: u8) -> Option<FinishReason> {
    Some(match b {
        0 => FinishReason::Length,
        1 => FinishReason::StopToken,
        2 => FinishReason::StopSequence,
        3 => FinishReason::Cancelled,
        4 => FinishReason::Deadline,
        5 => FinishReason::WorkerDied,
        6 => FinishReason::Preempted,
        7 => FinishReason::Overloaded,
        _ => return None,
    })
}

// ---------------------------------------------------------------------------
// payload writer

struct W(Vec<u8>);

impl W {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn opt_u32(&mut self, v: Option<u32>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u32(x);
            }
            None => self.u8(0),
        }
    }
    fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u64(x);
            }
            None => self.u8(0),
        }
    }
    fn str(&mut self, v: &str) {
        self.u32(v.len() as u32);
        self.0.extend_from_slice(v.as_bytes());
    }
    fn u32s(&mut self, vs: &[u32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.u32(v);
        }
    }
}

// ---------------------------------------------------------------------------
// bounds-checked payload reader

struct R<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> R<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Some(s)
    }
    fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|s| s[0])
    }
    fn u16(&mut self) -> Option<u16> {
        self.take(2).map(|s| u16::from_le_bytes(s.try_into().unwrap()))
    }
    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes(s.try_into().unwrap()))
    }
    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|s| u64::from_le_bytes(s.try_into().unwrap()))
    }
    fn i32(&mut self) -> Option<i32> {
        self.take(4).map(|s| i32::from_le_bytes(s.try_into().unwrap()))
    }
    fn f32(&mut self) -> Option<f32> {
        self.u32().map(f32::from_bits)
    }
    fn f64(&mut self) -> Option<f64> {
        self.u64().map(f64::from_bits)
    }
    fn opt_u32(&mut self) -> Option<Option<u32>> {
        match self.u8()? {
            0 => Some(None),
            1 => Some(Some(self.u32()?)),
            _ => None,
        }
    }
    fn opt_u64(&mut self) -> Option<Option<u64>> {
        match self.u8()? {
            0 => Some(None),
            1 => Some(Some(self.u64()?)),
            _ => None,
        }
    }
    fn str(&mut self) -> Option<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).ok()
    }
    fn u32s(&mut self) -> Option<Vec<u32>> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Some(v)
    }
    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

fn write_sampling(w: &mut W, s: &SamplingParams) {
    w.f32(s.temperature);
    w.u64(s.top_k as u64);
    w.f32(s.top_p);
    w.f32(s.repetition_penalty);
    w.f32(s.presence_penalty);
    w.f32(s.frequency_penalty);
    w.u32(s.logit_bias.len() as u32);
    for (tok, bias) in &s.logit_bias {
        w.u32(*tok);
        w.f32(*bias);
    }
    w.u32(s.stop_sequences.len() as u32);
    for seq in &s.stop_sequences {
        w.str(seq);
    }
    w.u64(s.seed);
}

fn read_sampling(r: &mut R<'_>) -> Option<SamplingParams> {
    let temperature = r.f32()?;
    let top_k = r.u64()? as usize;
    let top_p = r.f32()?;
    let repetition_penalty = r.f32()?;
    let presence_penalty = r.f32()?;
    let frequency_penalty = r.f32()?;
    let n_bias = r.u32()? as usize;
    let mut logit_bias = Vec::with_capacity(n_bias.min(1 << 16));
    for _ in 0..n_bias {
        let tok = r.u32()?;
        let bias = r.f32()?;
        logit_bias.push((tok, bias));
    }
    let n_stop = r.u32()? as usize;
    let mut stop_sequences = Vec::with_capacity(n_stop.min(1 << 10));
    for _ in 0..n_stop {
        stop_sequences.push(r.str()?);
    }
    let seed = r.u64()?;
    Some(SamplingParams {
        temperature,
        top_k,
        top_p,
        repetition_penalty,
        presence_penalty,
        frequency_penalty,
        logit_bias,
        stop_sequences,
        seed,
    })
}

/// Serialize a frame, header included, ready to write to a socket.
pub fn encode(f: &Frame) -> Vec<u8> {
    let mut w = W(Vec::new());
    let kind = match f {
        Frame::Hello { magic, version } => {
            w.u32(*magic);
            w.u16(*version);
            KIND_HELLO
        }
        Frame::HelloAck { version, capacity } => {
            w.u16(*version);
            w.u32(*capacity);
            KIND_HELLO_ACK
        }
        Frame::Submit(req) => {
            w.u64(req.id);
            w.u32s(&req.prompt);
            w.u64(req.max_new_tokens);
            w.str(&req.variant);
            w.opt_u32(req.stop_token);
            w.opt_u64(req.session_id);
            w.opt_u64(req.deadline_ms);
            w.i32(req.priority);
            write_sampling(&mut w, &req.sampling);
            KIND_SUBMIT
        }
        Frame::Cancel { id } => {
            w.u64(*id);
            KIND_CANCEL
        }
        Frame::Ping { seq } => {
            w.u64(*seq);
            KIND_PING
        }
        Frame::Pong { seq, load, capacity } => {
            w.u64(*seq);
            w.u32(*load);
            w.u32(*capacity);
            KIND_PONG
        }
        Frame::FirstToken { id } => {
            w.u64(*id);
            KIND_FIRST_TOKEN
        }
        Frame::Token { id, tok, index } => {
            w.u64(*id);
            w.u32(*tok);
            w.u64(*index);
            KIND_TOKEN
        }
        Frame::Finished { fin } => {
            w.u64(fin.id);
            w.u32s(&fin.generated);
            w.u8(reason_byte(fin.finish_reason));
            w.f64(fin.ttft_s);
            w.f64(fin.total_s);
            w.u64(fin.prompt_len as u64);
            match &fin.spec {
                Some(s) => {
                    w.u8(1);
                    w.u64(s.drafted);
                    w.u64(s.accepted);
                    w.u64(s.rounds);
                }
                None => w.u8(0),
            }
            KIND_FINISHED
        }
    };
    let body = w.0;
    let mut out = Vec::with_capacity(5 + body.len());
    out.extend_from_slice(&(body.len() as u32 + 1).to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&body);
    out
}

fn invalid(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("wire protocol: {what}"))
}

/// Decode one frame body (the bytes after the length prefix: kind +
/// payload).  Every length and enum byte is validated; trailing bytes are
/// rejected so a frame is exactly its declared content.
pub fn decode_body(body: &[u8]) -> io::Result<Frame> {
    let (&kind, payload) = body.split_first().ok_or_else(|| invalid("empty frame"))?;
    let mut r = R { buf: payload, pos: 0 };
    let frame = match kind {
        KIND_HELLO => {
            let magic = r.u32();
            let version = r.u16();
            match (magic, version) {
                (Some(magic), Some(version)) => Some(Frame::Hello { magic, version }),
                _ => None,
            }
        }
        KIND_HELLO_ACK => match (r.u16(), r.u32()) {
            (Some(version), Some(capacity)) => Some(Frame::HelloAck { version, capacity }),
            _ => None,
        },
        KIND_SUBMIT => (|| {
            let id = r.u64()?;
            let prompt = r.u32s()?;
            let max_new_tokens = r.u64()?;
            let variant = r.str()?;
            let stop_token = r.opt_u32()?;
            let session_id = r.opt_u64()?;
            let deadline_ms = r.opt_u64()?;
            let priority = r.i32()?;
            let sampling = read_sampling(&mut r)?;
            Some(Frame::Submit(WireRequest {
                id,
                prompt,
                max_new_tokens,
                variant,
                stop_token,
                session_id,
                deadline_ms,
                priority,
                sampling,
            }))
        })(),
        KIND_CANCEL => r.u64().map(|id| Frame::Cancel { id }),
        KIND_PING => r.u64().map(|seq| Frame::Ping { seq }),
        KIND_PONG => (|| {
            let seq = r.u64()?;
            let load = r.u32()?;
            let capacity = r.u32()?;
            Some(Frame::Pong { seq, load, capacity })
        })(),
        KIND_FIRST_TOKEN => r.u64().map(|id| Frame::FirstToken { id }),
        KIND_TOKEN => (|| {
            let id = r.u64()?;
            let tok = r.u32()?;
            let index = r.u64()?;
            Some(Frame::Token { id, tok, index })
        })(),
        KIND_FINISHED => (|| {
            let id = r.u64()?;
            let generated = r.u32s()?;
            let finish_reason = byte_reason(r.u8()?)?;
            let ttft_s = r.f64()?;
            let total_s = r.f64()?;
            let prompt_len = r.u64()? as usize;
            let spec = match r.u8()? {
                0 => None,
                1 => {
                    let drafted = r.u64()?;
                    let accepted = r.u64()?;
                    let rounds = r.u64()?;
                    Some(SpecStats { drafted, accepted, rounds })
                }
                _ => return None,
            };
            Some(Frame::Finished {
                fin: FinishedRequest {
                    id,
                    generated,
                    finish_reason,
                    ttft_s,
                    total_s,
                    prompt_len,
                    spec,
                },
            })
        })(),
        _ => return Err(invalid("unknown frame kind")),
    };
    match frame {
        Some(f) if r.done() => Ok(f),
        Some(_) => Err(invalid("trailing bytes in frame")),
        None => Err(invalid("truncated or malformed payload")),
    }
}

/// Read one complete frame (blocking).  `UnexpectedEof` on connection
/// close, `InvalidData` on a corrupt length prefix or payload.
pub fn read_frame(r: &mut impl Read) -> io::Result<Frame> {
    read_frame_counted(r).map(|(f, _)| f)
}

/// [`read_frame`] plus the framed byte count (for transport byte
/// counters).
pub fn read_frame_counted(r: &mut impl Read) -> io::Result<(Frame, usize)> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(invalid("frame length out of bounds"));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_body(&body).map(|f| (f, 4 + len))
}

/// Encode and write one frame; returns the bytes written (for transport
/// byte counters).
pub fn write_frame(w: &mut impl Write, f: &Frame) -> io::Result<usize> {
    let bytes = encode(f);
    w.write_all(&bytes)?;
    Ok(bytes.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frames() -> Vec<Frame> {
        let sampling = SamplingParams {
            temperature: 0.85,
            top_k: 40,
            top_p: 0.93,
            repetition_penalty: 1.1,
            presence_penalty: 0.25,
            frequency_penalty: -0.5,
            logit_bias: vec![(3, -100.0), (77, 2.5)],
            stop_sequences: vec!["1 2".to_string(), "stop".to_string()],
            seed: 0xDEAD_BEEF_CAFE,
        };
        vec![
            hello(),
            Frame::Hello { magic: 0x1234_5678, version: 9 },
            Frame::HelloAck { version: PROTO_VERSION, capacity: 64 },
            Frame::Submit(WireRequest {
                id: u64::MAX,
                prompt: vec![0, 1, u32::MAX, 42],
                max_new_tokens: 128,
                variant: "fastmamba".to_string(),
                stop_token: Some(7),
                session_id: Some(u64::MAX - 1),
                deadline_ms: Some(30_000),
                priority: -3,
                sampling,
            }),
            Frame::Submit(WireRequest {
                id: 0,
                prompt: vec![5],
                max_new_tokens: 1,
                variant: "fp32".to_string(),
                stop_token: None,
                session_id: None,
                deadline_ms: None,
                priority: 0,
                sampling: SamplingParams::default(),
            }),
            Frame::Cancel { id: 12 },
            Frame::Ping { seq: 3 },
            Frame::Pong { seq: 3, load: 17, capacity: 64 },
            Frame::FirstToken { id: 5 },
            Frame::Token { id: 5, tok: 1234, index: 0 },
            Frame::Finished {
                fin: FinishedRequest {
                    id: 5,
                    generated: (0..500).collect(),
                    finish_reason: FinishReason::StopSequence,
                    ttft_s: 0.0123,
                    total_s: 1.5,
                    prompt_len: 33,
                    spec: Some(SpecStats { drafted: 10, accepted: 8, rounds: 3 }),
                },
            },
            Frame::Finished {
                fin: FinishedRequest {
                    id: 6,
                    generated: Vec::new(),
                    finish_reason: FinishReason::WorkerDied,
                    ttft_s: 0.0,
                    total_s: 0.0,
                    prompt_len: 1,
                    spec: None,
                },
            },
        ]
    }

    #[test]
    fn remote_frame_roundtrip_all_kinds() {
        for f in sample_frames() {
            let bytes = encode(&f);
            let mut cursor = io::Cursor::new(&bytes);
            let back = read_frame(&mut cursor).unwrap_or_else(|e| panic!("{f:?}: {e}"));
            assert_eq!(back, f);
            assert_eq!(cursor.position() as usize, bytes.len(), "consumed exactly");
        }
        // every finish reason survives the byte mapping
        for r in [
            FinishReason::Length,
            FinishReason::StopToken,
            FinishReason::StopSequence,
            FinishReason::Cancelled,
            FinishReason::Deadline,
            FinishReason::WorkerDied,
            FinishReason::Preempted,
            FinishReason::Overloaded,
        ] {
            assert_eq!(byte_reason(reason_byte(r)), Some(r));
        }
        assert_eq!(byte_reason(200), None);
    }

    #[test]
    fn remote_frame_roundtrip_near_max_payload() {
        // a prompt near the frame bound round-trips; the length prefix and
        // element counts agree all the way up
        let prompt: Vec<u32> = (0..1_000_000u32).collect(); // ~4 MB payload
        let f = Frame::Submit(WireRequest {
            id: 1,
            prompt: prompt.clone(),
            max_new_tokens: 4,
            variant: "fp32".to_string(),
            stop_token: None,
            session_id: None,
            deadline_ms: None,
            priority: 0,
            sampling: SamplingParams::default(),
        });
        let bytes = encode(&f);
        assert!(bytes.len() < MAX_FRAME);
        let back = read_frame(&mut io::Cursor::new(&bytes)).unwrap();
        match back {
            Frame::Submit(wr) => assert_eq!(wr.prompt, prompt),
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn remote_truncated_frames_are_rejected_at_every_cut() {
        for f in sample_frames() {
            let bytes = encode(&f);
            // cut inside the header, at the body start, and through the body
            let cuts: Vec<usize> =
                [0, 1, 3, 4, 5, bytes.len() / 2, bytes.len() - 1].to_vec();
            for cut in cuts {
                if cut >= bytes.len() {
                    continue;
                }
                let err = read_frame(&mut io::Cursor::new(&bytes[..cut]))
                    .expect_err("truncated frame must fail");
                assert!(
                    matches!(
                        err.kind(),
                        io::ErrorKind::UnexpectedEof | io::ErrorKind::InvalidData
                    ),
                    "{f:?} cut at {cut}: {err}"
                );
            }
        }
    }

    #[test]
    fn remote_corrupt_frames_are_rejected() {
        // zero length
        assert!(read_frame(&mut io::Cursor::new(&0u32.to_le_bytes())).is_err());
        // length over the bound
        let huge = ((MAX_FRAME + 1) as u32).to_le_bytes();
        let err = read_frame(&mut io::Cursor::new(&huge)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // unknown kind byte
        let mut bad = encode(&Frame::Ping { seq: 1 });
        bad[4] = 0xEE;
        assert!(read_frame(&mut io::Cursor::new(&bad)).is_err());
        // trailing garbage inside a declared frame
        let mut padded = encode(&Frame::Cancel { id: 1 });
        let len = (padded.len() - 4 + 3) as u32;
        padded[..4].copy_from_slice(&len.to_le_bytes());
        padded.extend_from_slice(&[0, 0, 0]);
        let err = read_frame(&mut io::Cursor::new(&padded)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // invalid option tag inside Submit
        let f = Frame::Submit(WireRequest {
            id: 1,
            prompt: vec![1],
            max_new_tokens: 1,
            variant: "fp32".to_string(),
            stop_token: Some(3),
            session_id: None,
            deadline_ms: None,
            priority: 0,
            sampling: SamplingParams::default(),
        });
        let mut bytes = encode(&f);
        // stop_token option tag sits right after id/prompt/max_new/variant
        let tag_pos = 4 + 1 + 8 + (4 + 4) + 8 + (4 + 4);
        assert_eq!(bytes[tag_pos], 1, "locating the option tag");
        bytes[tag_pos] = 7;
        assert!(read_frame(&mut io::Cursor::new(&bytes)).is_err());
        // invalid finish-reason byte
        let fin = Frame::Finished {
            fin: FinishedRequest {
                id: 1,
                generated: vec![2],
                finish_reason: FinishReason::Length,
                ttft_s: 0.0,
                total_s: 0.0,
                prompt_len: 1,
                spec: None,
            },
        };
        let mut bytes = encode(&fin);
        let reason_pos = 4 + 1 + 8 + (4 + 4);
        assert_eq!(bytes[reason_pos], 0, "locating the reason byte");
        bytes[reason_pos] = 99;
        assert!(read_frame(&mut io::Cursor::new(&bytes)).is_err());
    }

    #[test]
    fn remote_wire_request_preserves_serving_contract() {
        use std::time::Duration;
        let sampling = SamplingParams {
            temperature: 0.7,
            seed: 42,
            stop_sequences: vec!["9 9".into()],
            ..SamplingParams::default()
        };
        let req = Request::new(31, vec![1, 2, 3], 16, "fastmamba")
            .with_stop_token(5)
            .with_session(1234)
            .with_priority(7)
            .with_deadline(Duration::from_secs(60))
            .with_sampling(sampling.clone());
        let wire = WireRequest::from_request(&req);
        assert_eq!(wire.id, 31);
        let remaining = wire.deadline_ms.expect("deadline crosses as remaining ms");
        assert!(remaining <= 60_000 && remaining > 59_000, "{remaining}");

        let back = wire.into_request();
        assert_eq!(back.id, req.id);
        assert_eq!(back.prompt, req.prompt);
        assert_eq!(back.max_new_tokens, req.max_new_tokens);
        assert_eq!(back.variant, req.variant);
        assert_eq!(back.stop_token, req.stop_token);
        assert_eq!(back.session_id, req.session_id);
        assert_eq!(back.priority, req.priority);
        assert_eq!(back.sampling, sampling);
        assert!(back.deadline.unwrap() <= Duration::from_secs(60));
    }

    #[test]
    fn remote_streamed_frames_interleave_on_one_pipe() {
        // several frames written back-to-back read out in order — the
        // framing self-delimits with no separators
        let frames = sample_frames();
        let mut pipe = Vec::new();
        for f in &frames {
            pipe.extend_from_slice(&encode(f));
        }
        let mut cursor = io::Cursor::new(&pipe);
        for want in &frames {
            let got = read_frame(&mut cursor).unwrap();
            assert_eq!(&got, want);
        }
        assert_eq!(cursor.position() as usize, pipe.len());
        // the next read reports a clean EOF
        let err = read_frame(&mut cursor).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }
}
