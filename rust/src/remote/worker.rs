//! The worker-process side of distributed serving (`serve --worker-mode`).
//!
//! [`serve_worker`] binds a TCP listener, builds one backend, and serves
//! dispatcher connections sequentially: handshake
//! ([`Frame::Hello`]/[`Frame::HelloAck`]), then an engine pump that turns
//! [`Frame::Submit`] into local [`Request`]s and streams every request
//! event back as [`Frame::FirstToken`]/[`Frame::Token`]/[`Frame::Finished`]
//! — the same [`WorkerEngine`] loop an in-process pool worker runs, with
//! the wire where the mpsc channels were.
//!
//! A dropped connection cancels whatever is in flight (the dispatcher
//! re-routes those requests to surviving workers and counts the loss on
//! its side), drains the engine, and returns to accepting — a worker
//! process outlives its dispatcher and serves the next one that connects.

use std::collections::HashMap;
use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::InferenceBackend;
use crate::coordinator::request::SubmitHandle;
use crate::coordinator::router::{PoolConfig, WorkerEngine};
use crate::coordinator::Event;

use super::proto::{self, Frame, MAGIC, PROTO_VERSION};

/// Handle to a running worker process loop.
///
/// [`WorkerServer::kill`] is deliberately abrupt — it severs the current
/// connection without any protocol goodbye, exactly what a crashed
/// process looks like from the dispatcher — so tests exercise the same
/// re-routing path a real `kill -9` does.
pub struct WorkerServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    current: Arc<Mutex<Option<TcpStream>>>,
    handle: Option<thread::JoinHandle<Result<()>>>,
}

impl WorkerServer {
    /// The bound address (resolves `:0` to the chosen port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Abruptly stop: sever the live connection mid-stream (the
    /// dispatcher sees a dead worker) and stop accepting.
    pub fn kill(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(s) = self.current.lock().unwrap().as_ref() {
            let _ = s.shutdown(Shutdown::Both);
        }
    }

    /// Wait for the accept loop to exit (after [`WorkerServer::kill`]).
    pub fn wait(mut self) -> Result<()> {
        match self.handle.take() {
            Some(h) => h.join().map_err(|_| anyhow!("worker loop panicked"))?,
            None => Ok(()),
        }
    }
}

impl Drop for WorkerServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(s) = self.current.lock().unwrap().as_ref() {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

/// Run one remote worker: bind `addr`, build the backend once, then serve
/// dispatcher connections until killed.  The engine configuration (plain
/// vs speculative, state cache, scheduling policy) comes from the same
/// [`PoolConfig`] an in-process worker would get.
pub fn serve_worker<F>(addr: &str, make_backend: F, cfg: PoolConfig) -> Result<WorkerServer>
where
    F: Fn() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
{
    let listener =
        TcpListener::bind(addr).with_context(|| format!("worker-mode bind {addr}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let current: Arc<Mutex<Option<TcpStream>>> = Arc::new(Mutex::new(None));
    let handle = {
        let stop = Arc::clone(&stop);
        let current = Arc::clone(&current);
        thread::spawn(move || accept_loop(listener, make_backend, cfg, stop, current))
    };
    Ok(WorkerServer { addr: local, stop, current, handle: Some(handle) })
}

fn accept_loop<F>(
    listener: TcpListener,
    make_backend: F,
    cfg: PoolConfig,
    stop: Arc<AtomicBool>,
    current: Arc<Mutex<Option<TcpStream>>>,
) -> Result<()>
where
    F: Fn() -> Result<Box<dyn InferenceBackend>>,
{
    // one backend for the process lifetime (construction is the expensive
    // part); each connection gets a fresh engine over it
    let be = make_backend().context("worker-mode backend construction")?;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                *current.lock().unwrap() = Some(
                    stream.try_clone().context("clone connection for kill handle")?,
                );
                // a failed connection (bad handshake, mid-stream drop) must
                // not take the worker down: log-free swallow, back to accept
                let _ = serve_conn(stream, be.as_ref(), &cfg, &stop);
                *current.lock().unwrap() = None;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).context("worker-mode accept"),
        }
    }
}

/// Commands the connection reader thread feeds the engine pump.
enum Cmd {
    Frame(Frame),
    /// the dispatcher hung up (EOF or read error)
    Eof,
}

fn serve_conn(
    stream: TcpStream,
    be: &dyn InferenceBackend,
    cfg: &PoolConfig,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // a bounded handshake window, so a silent connector can't wedge accept
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    match proto::read_frame(&mut &stream)? {
        Frame::Hello { magic, version } if magic == MAGIC && version == PROTO_VERSION => {}
        Frame::Hello { magic, version } => {
            // a version/magic mismatch closes before any request state
            // exists — the connecting dispatcher reads EOF instead of an
            // ack and reports the handshake failure
            bail!("handshake rejected: magic {magic:#x} version {version}");
        }
        other => bail!("expected Hello, got {other:?}"),
    }
    let capacity = cfg.capacity_per_worker();
    proto::write_frame(
        &mut &stream,
        &Frame::HelloAck { version: PROTO_VERSION, capacity: capacity as u32 },
    )?;
    stream.set_read_timeout(None)?;

    let (cmd_tx, cmds) = mpsc::channel::<Cmd>();
    let rstream = stream.try_clone()?;
    let reader = thread::spawn(move || loop {
        match proto::read_frame(&mut &rstream) {
            Ok(f) => {
                if cmd_tx.send(Cmd::Frame(f)).is_err() {
                    return;
                }
            }
            Err(_) => {
                let _ = cmd_tx.send(Cmd::Eof);
                return;
            }
        }
    });

    let result = pump(&stream, be, cfg, capacity, &cmds, stop);
    // sever our clone too, so the reader thread's blocking read returns
    let _ = stream.shutdown(Shutdown::Both);
    let _ = reader.join();
    result
}

/// The engine pump: the in-process worker loop with frames for channels.
fn pump(
    stream: &TcpStream,
    be: &dyn InferenceBackend,
    cfg: &PoolConfig,
    capacity: usize,
    cmds: &mpsc::Receiver<Cmd>,
    stop: &AtomicBool,
) -> Result<()> {
    let mut engine = WorkerEngine::build(be, cfg);
    engine.metrics_mut().start();
    let mut handles: HashMap<u64, SubmitHandle> = HashMap::new();
    let mut w = stream;
    let mut eof = false;
    let mut write_dead = false;

    loop {
        let stopping = stop.load(Ordering::SeqCst);
        // gather commands: block briefly only when the engine has nothing
        // to do, otherwise just drain what's queued
        let mut queued: Vec<Cmd> = Vec::new();
        if engine.idle() && !eof && !write_dead && !stopping {
            match cmds.recv_timeout(Duration::from_millis(50)) {
                Ok(c) => queued.push(c),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => eof = true,
            }
        }
        loop {
            match cmds.try_recv() {
                Ok(c) => queued.push(c),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    eof = true;
                    break;
                }
            }
        }
        for cmd in queued {
            match cmd {
                Cmd::Frame(Frame::Submit(wr)) => {
                    let mut req = wr.into_request();
                    let h = req.attach_events();
                    handles.insert(h.id(), h);
                    engine.submit(req);
                }
                Cmd::Frame(Frame::Cancel { id }) => {
                    if let Some(h) = handles.get(&id) {
                        h.cancel();
                    }
                }
                Cmd::Frame(Frame::Ping { seq }) => {
                    let pong = Frame::Pong {
                        seq,
                        load: engine.load() as u32,
                        capacity: capacity as u32,
                    };
                    if proto::write_frame(&mut w, &pong).is_err() {
                        write_dead = true;
                    }
                }
                // anything else is protocol misuse from the peer; dropping
                // it is safer than killing a connection mid-generation
                Cmd::Frame(_) => {}
                Cmd::Eof => eof = true,
            }
        }

        if eof || write_dead || stopping {
            // the dispatcher is gone (or we're shutting down): nobody will
            // read these streams again.  Cancel everything so the engine
            // retires it promptly and state slots free.
            for h in handles.values() {
                h.cancel();
            }
        }
        if engine.idle() && handles.is_empty() && (eof || write_dead || stopping) {
            break;
        }
        if !engine.idle() {
            engine.step()?;
        }

        // forward every event as a frame, in per-request order
        let mut done: Vec<u64> = Vec::new();
        for (&id, h) in handles.iter() {
            while let Some(ev) = h.try_event() {
                let frame = match ev {
                    Event::FirstToken => Frame::FirstToken { id },
                    Event::Token { tok, index } => {
                        Frame::Token { id, tok, index: index as u64 }
                    }
                    Event::Finished(fin) => {
                        done.push(id);
                        Frame::Finished { fin }
                    }
                };
                if !write_dead && proto::write_frame(&mut w, &frame).is_err() {
                    write_dead = true;
                }
            }
        }
        for id in done {
            handles.remove(&id);
        }
        // results already traveled as Finished frames; keep the engine's
        // finished buffer from growing without bound
        engine.drain_finished();
    }
    engine.metrics_mut().stop();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::coordinator::{Engine, EngineConfig, FinishReason, Request};
    use crate::remote::proto::WireRequest;
    use std::net::TcpStream;

    /// Same micro model the router tests use: same-seed construction means
    /// the worker process and a local engine hold identical weights.
    fn micro_backend() -> NativeBackend {
        let mut cfg = crate::config::ModelConfig::tiny();
        cfg.name = "mamba2-micro".into();
        cfg.d_model = 64;
        cfg.n_layer = 2;
        cfg.d_state = 16;
        cfg.headdim = 16;
        cfg.vocab_size = 128;
        NativeBackend::new(crate::model::ModelWeights::random(&cfg, 9))
            .with_buckets(vec![8, 16, 32], vec![1, 2, 4])
    }

    fn micro_cfg() -> PoolConfig {
        PoolConfig {
            engine: EngineConfig { max_active: 4, greedy_chunking: true },
            n_workers: 1,
            ..PoolConfig::default()
        }
    }

    fn start_worker() -> WorkerServer {
        serve_worker(
            "127.0.0.1:0",
            || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>),
            micro_cfg(),
        )
        .expect("bind worker")
    }

    fn req(i: u64) -> Request {
        let plen = [3usize, 9, 17, 33][i as usize % 4];
        let prompt: Vec<u32> =
            (0..plen).map(|j| ((i as usize * 131 + j * 17) % 128) as u32).collect();
        Request::new(i, prompt, 5, "fp32")
    }

    fn handshake(stream: &TcpStream) -> u32 {
        proto::write_frame(&mut &*stream, &proto::hello()).unwrap();
        match proto::read_frame(&mut &*stream).unwrap() {
            Frame::HelloAck { version, capacity } => {
                assert_eq!(version, PROTO_VERSION);
                capacity
            }
            other => panic!("expected HelloAck, got {other:?}"),
        }
    }

    /// Drive `n` requests through one raw connection and collect the
    /// streamed tokens per id, asserting event-order invariants.
    fn collect(stream: &TcpStream, n: usize) -> Vec<(u64, Vec<u32>)> {
        use std::collections::HashMap;
        let mut toks: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut first: HashMap<u64, bool> = HashMap::new();
        let mut out = Vec::new();
        while out.len() < n {
            match proto::read_frame(&mut &*stream).expect("event frame") {
                Frame::FirstToken { id } => {
                    assert!(!first.contains_key(&id), "duplicate FirstToken {id}");
                    first.insert(id, true);
                }
                Frame::Token { id, tok, index } => {
                    let v = toks.entry(id).or_default();
                    assert_eq!(index as usize, v.len(), "req {id} out of order");
                    v.push(tok);
                }
                Frame::Finished { fin } => {
                    assert!(first.get(&fin.id).copied().unwrap_or(false));
                    assert_eq!(
                        toks.get(&fin.id).cloned().unwrap_or_default(),
                        fin.generated,
                        "req {} stream != batch result",
                        fin.id
                    );
                    out.push((fin.id, fin.generated));
                }
                other => panic!("unexpected frame {other:?}"),
            }
        }
        out.sort();
        out
    }

    #[test]
    fn remote_worker_socket_roundtrip_matches_local_engine() {
        // what a local engine produces for these requests ...
        let be = micro_backend();
        let mut eng =
            Engine::new(&be, EngineConfig { max_active: 4, greedy_chunking: true });
        for i in 0..6 {
            eng.submit(req(i));
        }
        eng.run().unwrap();
        let mut want: Vec<(u64, Vec<u32>)> =
            eng.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        want.sort();

        // ... a worker process must reproduce over the wire, token-exact
        let server = start_worker();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let cap = handshake(&stream);
        assert_eq!(cap, 4, "worker advertises its engine capacity");
        for i in 0..6 {
            let wr = WireRequest::from_request(&req(i));
            proto::write_frame(&mut &stream, &Frame::Submit(wr)).unwrap();
        }
        let got = collect(&stream, 6);
        assert_eq!(want, got, "wire round-trip changed generated tokens");
        for (_, g) in &got {
            assert_eq!(g.len(), 5);
        }
        drop(stream);
        server.kill();
        server.wait().unwrap();
    }

    #[test]
    fn remote_handshake_version_mismatch_is_rejected() {
        let server = start_worker();
        let stream = TcpStream::connect(server.addr()).unwrap();
        proto::write_frame(
            &mut &stream,
            &Frame::Hello { magic: MAGIC, version: PROTO_VERSION + 1 },
        )
        .unwrap();
        // the worker closes without an ack: the next read is EOF, never a
        // HelloAck — exactly what client::connect reports as a version
        // mismatch
        match proto::read_frame(&mut &stream) {
            Err(e) => assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof, "{e}"),
            Ok(f) => panic!("worker acked a bad version with {f:?}"),
        }
        drop(stream);

        // and the worker is still healthy: a correct handshake succeeds
        let stream2 = TcpStream::connect(server.addr()).unwrap();
        handshake(&stream2);
        drop(stream2);
        server.kill();
        server.wait().unwrap();
    }

    #[test]
    fn remote_worker_outlives_dispatcher_and_serves_next_connection() {
        let server = start_worker();

        // first dispatcher hangs up abruptly with a request in flight
        {
            let stream = TcpStream::connect(server.addr()).unwrap();
            handshake(&stream);
            let mut r = req(0);
            r.max_new_tokens = 64; // long enough to still be running
            proto::write_frame(&mut &stream, &Frame::Submit(WireRequest::from_request(&r)))
                .unwrap();
            // wait for generation to visibly start, then vanish
            match proto::read_frame(&mut &stream).unwrap() {
                Frame::FirstToken { id } => assert_eq!(id, 0),
                other => panic!("expected FirstToken, got {other:?}"),
            }
            let _ = stream.shutdown(Shutdown::Both);
        }

        // the worker cancels the orphan, drains, and accepts the next
        // dispatcher; its output is unaffected by the earlier abort
        let stream = TcpStream::connect(server.addr()).unwrap();
        handshake(&stream);
        proto::write_frame(&mut &stream, &Frame::Submit(WireRequest::from_request(&req(1))))
            .unwrap();
        let got = collect(&stream, 1);
        assert_eq!(got[0].0, 1);
        assert_eq!(got[0].1.len(), 5);

        // pings answer with live load/capacity on the same pipe
        proto::write_frame(&mut &stream, &Frame::Ping { seq: 77 }).unwrap();
        match proto::read_frame(&mut &stream).unwrap() {
            Frame::Pong { seq, load, capacity } => {
                assert_eq!(seq, 77);
                assert_eq!(load, 0);
                assert_eq!(capacity, 4);
            }
            other => panic!("expected Pong, got {other:?}"),
        }
        drop(stream);
        server.kill();
        server.wait().unwrap();
    }

    #[test]
    fn remote_cancel_frame_finishes_request_as_cancelled() {
        let server = start_worker();
        let stream = TcpStream::connect(server.addr()).unwrap();
        handshake(&stream);
        let mut r = req(3);
        r.max_new_tokens = 512; // would run far longer than the test allows
        proto::write_frame(&mut &stream, &Frame::Submit(WireRequest::from_request(&r)))
            .unwrap();
        proto::write_frame(&mut &stream, &Frame::Cancel { id: 3 }).unwrap();
        loop {
            match proto::read_frame(&mut &stream).expect("frame") {
                Frame::Finished { fin } => {
                    assert_eq!(fin.id, 3);
                    assert_eq!(fin.finish_reason, FinishReason::Cancelled);
                    assert!(fin.generated.len() < 512);
                    break;
                }
                Frame::FirstToken { .. } | Frame::Token { .. } => {}
                other => panic!("unexpected frame {other:?}"),
            }
        }
        drop(stream);
        server.kill();
        server.wait().unwrap();
    }
}
