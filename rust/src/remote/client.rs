//! Dispatcher-side proxy for a remote worker process.
//!
//! [`connect`] performs the handshake and learns the worker's capacity;
//! [`run_remote`] then runs in place of a local worker thread: it takes
//! the same `mpsc::Receiver<Request>` the dispatcher feeds local workers,
//! ships each request over the wire, and replays the worker's event
//! frames into the request's own event channel — the [`SubmitHandle`]
//! held by the submitting client cannot tell a remote worker from a local
//! one.
//!
//! [`SubmitHandle`]: crate::coordinator::SubmitHandle
//!
//! Failure maps onto the pool's existing worker-death seam: the proxy's
//! reader thread owns the armed [`DeathNotice`], so a lost connection
//! (worker crash, network partition) sends `Msg::WorkerDead` *after* all
//! of that worker's `Msg::Done` results — exactly the invariant the
//! dispatcher's re-routing logic relies on for local threads.  The
//! dispatcher then re-routes every request the dead remote still held;
//! nothing is lost, nothing duplicates.

use std::collections::{HashMap, HashSet};
use std::io::{self};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{Event, Request};
use crate::coordinator::router::{DeathNotice, Msg};
use crate::obs::{Counter, Gauge, RemoteTransport, Telemetry};
use crate::util::json;

use super::proto::{self, Frame, WireRequest, PROTO_VERSION};

/// How often the proxy probes the link with a ping when otherwise idle.
const PING_EVERY: Duration = Duration::from_millis(500);

/// A handshaken connection to a remote worker.
pub(crate) struct RemoteConn {
    pub(crate) stream: TcpStream,
    /// concurrent state slots the worker advertised in its `HelloAck`
    pub(crate) capacity: usize,
    pub(crate) addr: String,
}

/// Connect to a `serve --worker-mode` process and complete the
/// `Hello`/`HelloAck` handshake.  A protocol-version mismatch (the worker
/// closes without acking, or acks a different version) is an error here,
/// before any request state exists.
pub(crate) fn connect(addr: &str, timeout: Duration) -> Result<RemoteConn> {
    let sockaddr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolve {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("{addr}: no address"))?;
    let stream = TcpStream::connect_timeout(&sockaddr, timeout)
        .with_context(|| format!("connect {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout))?;
    proto::write_frame(&mut &stream, &proto::hello())
        .with_context(|| format!("{addr}: handshake send"))?;
    match proto::read_frame(&mut &stream) {
        Ok(Frame::HelloAck { version, capacity }) => {
            if version != PROTO_VERSION {
                bail!(
                    "{addr}: protocol version mismatch (ours {PROTO_VERSION}, worker {version})"
                );
            }
            stream.set_read_timeout(None)?;
            Ok(RemoteConn { stream, capacity: capacity as usize, addr: addr.to_string() })
        }
        Ok(other) => bail!("{addr}: unexpected handshake reply {other:?}"),
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
            bail!("{addr}: worker rejected handshake (version mismatch?)")
        }
        Err(e) => Err(e).with_context(|| format!("{addr}: handshake read")),
    }
}

/// Proxy one remote worker for the pool dispatcher.  Runs on the thread
/// the dispatcher would have given a local worker; returns the
/// proxy-observed [`Metrics`] on clean drain, an error on connection
/// loss (with the `WorkerDead` notice already sent by the reader).
pub(crate) fn run_remote(
    id: usize,
    conn: RemoteConn,
    rx: mpsc::Receiver<Request>,
    pool_tx: mpsc::Sender<Msg>,
    tel: Option<Arc<Telemetry>>,
    transport: Option<Arc<RemoteTransport>>,
) -> Result<Metrics> {
    let RemoteConn { stream, capacity, addr } = conn;
    // requests currently on the worker, by id: the event-emission targets
    // (each entry shares its submitter's event channel and cancel flag)
    let in_flight: Arc<Mutex<HashMap<u64, Request>>> = Arc::new(Mutex::new(HashMap::new()));
    let closing = Arc::new(AtomicBool::new(false));
    // (seq, sent-at) of the ping awaiting its pong
    let pending_ping: Arc<Mutex<Option<(u64, Instant)>>> = Arc::new(Mutex::new(None));

    // Armed from the very start: any exit path that is not the clean
    // close below reports WorkerDead, including failures before the
    // reader thread spawns.
    let notice = DeathNotice {
        worker: id,
        pool_tx: pool_tx.clone(),
        error: format!("remote worker {addr}: proxy failed"),
        armed: true,
    };

    let rstream = stream.try_clone().context("clone remote stream")?;
    let reader = {
        let in_flight = Arc::clone(&in_flight);
        let closing = Arc::clone(&closing);
        let pending_ping = Arc::clone(&pending_ping);
        let transport = transport.clone();
        let addr = addr.clone();
        // the reader owns the death notice from here on: it sends this
        // worker's Done messages, so its WorkerDead is ordered after all
        // of them on the pool channel
        thread::spawn(move || {
            run_reader(
                id, rstream, notice, in_flight, closing, pending_ping, pool_tx, tel,
                transport, addr,
            )
        })
    };
    // writer: this thread.  Ships submits and cancels, probes with pings.
    // (`notice` has moved into the reader — the writer never touches it.)
    let mut w = &stream;
    let mut cancels_sent: HashSet<u64> = HashSet::new();
    let mut ping_seq = 0u64;
    let mut last_ping = Instant::now();
    let mut ingress_open = true;
    let mut write_failed = false;
    let mut send = |w: &mut &TcpStream, frame: &Frame, failed: &mut bool| {
        match proto::write_frame(w, frame) {
            Ok(n) => {
                if let Some(t) = &transport {
                    t.note_out(n);
                }
            }
            Err(_) => *failed = true, // reader fires the death path
        }
    };
    loop {
        if ingress_open {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(req) => {
                    let wire = WireRequest::from_request(&req);
                    in_flight.lock().unwrap().insert(req.id, req);
                    send(&mut w, &Frame::Submit(wire), &mut write_failed);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // end-of-input: no new requests will ever arrive.  Keep
                    // servicing cancels/pings until the worker finishes
                    // what it holds, then close the write side.
                    ingress_open = false;
                    closing.store(true, Ordering::SeqCst);
                }
            }
        } else {
            thread::sleep(Duration::from_millis(10));
        }

        // relay cancellations: the shared flag flips locally (the
        // submitter cancelled), the worker needs a frame to see it
        let to_cancel: Vec<u64> = {
            let inf = in_flight.lock().unwrap();
            inf.iter()
                .filter(|(id, r)| {
                    r.cancel_flag().is_cancelled() && !cancels_sent.contains(*id)
                })
                .map(|(id, _)| *id)
                .collect()
        };
        for cid in to_cancel {
            cancels_sent.insert(cid);
            send(&mut w, &Frame::Cancel { id: cid }, &mut write_failed);
        }

        // periodic health probe (also what feeds the RTT histogram)
        if !write_failed && last_ping.elapsed() >= PING_EVERY {
            ping_seq += 1;
            *pending_ping.lock().unwrap() = Some((ping_seq, Instant::now()));
            last_ping = Instant::now();
            send(&mut w, &Frame::Ping { seq: ping_seq }, &mut write_failed);
        }

        if write_failed {
            break; // connection died; the reader reports it
        }
        if !ingress_open && in_flight.lock().unwrap().is_empty() {
            // clean close: half-shutdown tells the worker we're done; the
            // reader sees EOF with nothing in flight and disarms
            let _ = stream.shutdown(Shutdown::Write);
            break;
        }
        if reader.is_finished() {
            break; // connection died; stop writing
        }
    }

    match reader.join() {
        Ok(m) => m,
        Err(_) => Err(anyhow!("remote worker {addr}: proxy reader panicked")),
    }
}

#[allow(clippy::too_many_arguments)]
fn run_reader(
    id: usize,
    stream: TcpStream,
    mut notice: DeathNotice,
    in_flight: Arc<Mutex<HashMap<u64, Request>>>,
    closing: Arc<AtomicBool>,
    pending_ping: Arc<Mutex<Option<(u64, Instant)>>>,
    pool_tx: mpsc::Sender<Msg>,
    tel: Option<Arc<Telemetry>>,
    transport: Option<Arc<RemoteTransport>>,
    addr: String,
) -> Result<Metrics> {
    // proxy-observed metrics: the remote engine keeps its own; this side
    // records what crossed back (completions, tokens, finish reasons,
    // worker-measured ttft/latency), which is what the pool report and
    // the hub aggregate over
    let mut m = Metrics::default();
    let publish_status = |tel: &Option<Arc<Telemetry>>, n_in_flight: usize| {
        if let Some(t) = tel {
            t.set_gauge(Gauge::ActiveSlots, n_in_flight as u64);
            t.set_gauge(Gauge::QueueDepth, n_in_flight as u64);
            t.set_status(json::obj(vec![
                ("role", json::s("remote_proxy")),
                ("addr", json::s(&addr)),
                ("active", json::num(n_in_flight as f64)),
                ("pending", json::num(0.0)),
            ]));
        }
    };
    if let Some(t) = &tel {
        m.attach_telemetry(Arc::clone(t));
    }
    m.start();
    publish_status(&tel, 0);
    loop {
        match proto::read_frame_counted(&mut &stream) {
            Ok((frame, n)) => {
                if let Some(t) = &transport {
                    t.note_in(n);
                }
                match frame {
                    Frame::FirstToken { id } => {
                        if let Some(r) = in_flight.lock().unwrap().get(&id) {
                            r.emit(Event::FirstToken);
                        }
                    }
                    Frame::Token { id, tok, index } => {
                        if let Some(r) = in_flight.lock().unwrap().get(&id) {
                            r.emit(Event::Token { tok, index: index as usize });
                        }
                    }
                    Frame::Finished { fin } => {
                        let req = in_flight.lock().unwrap().remove(&fin.id);
                        m.count(Counter::RequestsCompleted, 1);
                        m.count(Counter::TokensGenerated, fin.generated.len() as u64);
                        m.count(Counter::PromptTokens, fin.prompt_len as u64);
                        m.note_finish_reason(fin.finish_reason);
                        if fin.ttft_s > 0.0 {
                            m.note_ttft(fin.ttft_s);
                        }
                        m.note_latency(fin.total_s);
                        publish_status(&tel, in_flight.lock().unwrap().len());
                        if let Some(r) = req {
                            r.emit(Event::Finished(fin.clone()));
                        }
                        let _ = pool_tx.send(Msg::Done { worker: id, fin });
                    }
                    Frame::Pong { seq, .. } => {
                        let mut p = pending_ping.lock().unwrap();
                        if let Some((want, sent)) = *p {
                            if want == seq {
                                if let Some(t) = &transport {
                                    t.observe_rtt(sent.elapsed().as_secs_f64());
                                }
                                *p = None;
                            }
                        }
                    }
                    // Hello/HelloAck/Submit/Cancel/Ping are
                    // dispatcher→worker traffic; ignore if echoed
                    _ => {}
                }
            }
            Err(e) => {
                let n_lost = in_flight.lock().unwrap().len() as u64;
                m.stop();
                if closing.load(Ordering::SeqCst) && n_lost == 0 {
                    // expected EOF after our half-shutdown: clean drain
                    notice.armed = false;
                    publish_status(&tel, 0);
                    return Ok(m);
                }
                if let Some(t) = &transport {
                    t.note_disconnect(n_lost);
                }
                notice.error = format!(
                    "remote worker {addr}: connection lost ({e}); \
                     {n_lost} in-flight request(s) re-routing"
                );
                // the armed notice fires on return, after every Done this
                // thread already sent
                return Err(anyhow!("remote worker {addr} died: {e}"));
            }
        }
    }
}
