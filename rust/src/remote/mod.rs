//! Distributed serving: remote worker processes behind the pool router.
//!
//! The multi-worker pool ([`crate::coordinator::serve_pool`]) fans
//! requests out to worker threads in one process.  This module stretches
//! that seam across process (and machine) boundaries without changing it:
//!
//! * [`proto`] — a compact, dependency-free, length-prefixed wire
//!   protocol whose event frames mirror the in-process
//!   [`Event`](crate::coordinator::Event) stream 1:1;
//! * [`worker`] — the worker-process side (`serve --worker-mode
//!   HOST:PORT`): one listener, one backend, one engine pump per
//!   connection, speaking [`proto`];
//! * [`client`] — the dispatcher-side proxy that makes a connected remote
//!   worker look exactly like a local worker thread: same message
//!   channel in, same `Done`/`WorkerDead` messages out, so the router,
//!   re-routing on death, priority scheduling, and telemetry all apply
//!   unchanged.
//!
//! Because Mamba2 serving state is position-keyed and constant-size, a
//! remote worker's token stream is bit-identical to a local worker's for
//! the same request — mixing `--remote-worker` processes into a pool
//! changes capacity and placement, never tokens.

pub mod client;
pub mod proto;
pub mod worker;

pub use proto::{Frame, WireRequest, MAX_FRAME, PROTO_VERSION};
pub use worker::{serve_worker, WorkerServer};
