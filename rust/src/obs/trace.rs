//! Per-request span tracing, exported as Chrome `trace_event` JSON
//! (loadable in Perfetto / `chrome://tracing`).
//!
//! A [`TraceSink`] is shared by every engine (and the pool dispatcher)
//! through an `Arc`; recording is append-into-a-mutex with a hard event
//! cap, and a sampling knob (`--trace-sample N` keeps every Nth request)
//! bounds per-request overhead.  With no sink attached the engines pay a
//! single `Option` check per record point — the disabled path does no
//! clock reads and no allocation.
//!
//! Lane layout: request lifecycles live in pid 0 ("requests"), one thread
//! lane per request id, as a `B`("request") … instants … `E` pair — the
//! instants mark admission, the cache probe (hit/miss + tokens saved),
//! and the first token, and per-prefill-chunk / per-spec-round `X` spans
//! nest inside.  Engine-level batch work (decode steps over the whole
//! batch) lives in pid `1 + worker lane`, so a multi-worker pool shows one
//! process per worker next to the request swimlanes, reproducing the
//! paper's per-stage prefill/decode breakdown for the serving path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::util::json::{self, num, obj, s, Json};

/// Synthetic Chrome-trace process id that holds one lane per request.
pub const REQUEST_PID: u64 = 0;

/// Default hard cap on buffered events (~tens of MB of JSON at worst);
/// overflow increments a drop counter instead of growing without bound.
pub const DEFAULT_MAX_EVENTS: usize = 1 << 18;

#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: &'static str,
    /// Chrome phase: 'B' begin, 'E' end, 'i' instant, 'X' complete
    pub ph: char,
    pub pid: u64,
    pub tid: u64,
    /// microseconds since the sink's epoch
    pub ts_us: f64,
    /// duration in microseconds ('X' events only)
    pub dur_us: f64,
    pub args: Vec<(&'static str, Json)>,
}

#[derive(Debug)]
pub struct TraceSink {
    epoch: Instant,
    /// keep every Nth request id (1 = every request)
    sample_every: u64,
    max_events: usize,
    events: Mutex<Vec<TraceEvent>>,
    dropped: AtomicU64,
}

impl TraceSink {
    pub fn new(sample_every: u64) -> Self {
        Self::with_limits(sample_every, DEFAULT_MAX_EVENTS)
    }

    pub fn with_limits(sample_every: u64, max_events: usize) -> Self {
        Self {
            epoch: Instant::now(),
            sample_every: sample_every.max(1),
            max_events,
            events: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
        }
    }

    /// Whether this request id is in the sampled subset.
    #[inline]
    pub fn sampled(&self, req_id: u64) -> bool {
        self.sample_every == 1 || req_id % self.sample_every == 0
    }

    pub fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    fn push(&self, ev: TraceEvent) {
        let mut events = self.events.lock().unwrap();
        if events.len() >= self.max_events {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        events.push(ev);
    }

    /// Open a request's lifecycle span (the queued→retire envelope).
    pub fn begin_request(&self, req_id: u64, prompt_len: usize, priority: i32) {
        self.push(TraceEvent {
            name: "request",
            ph: 'B',
            pid: REQUEST_PID,
            tid: req_id,
            ts_us: self.now_us(),
            dur_us: 0.0,
            args: vec![
                ("prompt_len", num(prompt_len as f64)),
                ("priority", num(priority as f64)),
            ],
        });
    }

    /// Mark a point inside a request's lifecycle (admitted, cache_probe,
    /// first_token, …).
    pub fn instant(&self, req_id: u64, name: &'static str, args: Vec<(&'static str, Json)>) {
        self.push(TraceEvent {
            name,
            ph: 'i',
            pid: REQUEST_PID,
            tid: req_id,
            ts_us: self.now_us(),
            dur_us: 0.0,
            args,
        });
    }

    /// Close a request's lifecycle span with its terminal reason.
    pub fn end_request(&self, req_id: u64, reason: &str, generated: usize) {
        self.push(TraceEvent {
            name: "request",
            ph: 'E',
            pid: REQUEST_PID,
            tid: req_id,
            ts_us: self.now_us(),
            dur_us: 0.0,
            args: vec![
                ("finish_reason", s(reason)),
                ("generated", num(generated as f64)),
            ],
        });
    }

    /// A completed sub-span of one request (prefill chunk, spec round),
    /// recorded at its end: `dur_s` back-dates the start.
    pub fn span_request(
        &self,
        req_id: u64,
        name: &'static str,
        dur_s: f64,
        args: Vec<(&'static str, Json)>,
    ) {
        let dur_us = dur_s * 1e6;
        self.push(TraceEvent {
            name,
            ph: 'X',
            pid: REQUEST_PID,
            tid: req_id,
            ts_us: self.now_us() - dur_us,
            dur_us,
            args,
        });
    }

    /// A completed batch-level engine span (decode step over the whole
    /// decode batch) in the worker's own process lane.
    pub fn span_engine(
        &self,
        lane: u32,
        name: &'static str,
        dur_s: f64,
        args: Vec<(&'static str, Json)>,
    ) {
        let dur_us = dur_s * 1e6;
        self.push(TraceEvent {
            name,
            ph: 'X',
            pid: 1 + lane as u64,
            tid: 0,
            ts_us: self.now_us() - dur_us,
            dur_us,
            args,
        });
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events that hit the cap and were discarded.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Chrome trace JSON object: `{"traceEvents": [...], ...}`.
    pub fn to_chrome_json(&self) -> Json {
        let events = self.events.lock().unwrap();
        let arr: Vec<Json> = events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("name", s(e.name)),
                    ("ph", s(&e.ph.to_string())),
                    ("pid", num(e.pid as f64)),
                    ("tid", num(e.tid as f64)),
                    ("ts", num(e.ts_us)),
                ];
                if e.ph == 'X' {
                    fields.push(("dur", num(e.dur_us)));
                }
                if e.ph == 'i' {
                    // instant scope: thread
                    fields.push(("s", s("t")));
                }
                if !e.args.is_empty() {
                    fields.push(("args", obj(e.args.clone())));
                }
                obj(fields)
            })
            .collect();
        obj(vec![
            ("traceEvents", Json::Arr(arr)),
            ("displayTimeUnit", s("ms")),
            ("dropped_events", num(self.dropped() as f64)),
        ])
    }

    pub fn write(&self, path: &str) -> Result<()> {
        std::fs::write(path, json::to_string(&self.to_chrome_json()))?;
        Ok(())
    }
}

/// An engine's tracing attachment: the shared sink, the worker lane for
/// batch-level spans, and whether this engine opens the request envelope
/// at enqueue (false for pool workers — the dispatcher already opened it
/// when the request entered the ingress queue).
#[derive(Debug, Clone)]
pub struct TraceCtx {
    pub sink: Arc<TraceSink>,
    pub lane: u32,
    pub record_queued: bool,
}

impl TraceCtx {
    pub fn new(sink: Arc<TraceSink>, lane: u32) -> Self {
        Self { sink, lane, record_queued: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_events_round_trip_through_chrome_json() {
        let sink = TraceSink::new(1);
        sink.begin_request(3, 17, 0);
        sink.instant(3, "admitted", vec![]);
        sink.span_request(3, "prefill_chunk", 0.001, vec![("len", num(16.0))]);
        sink.end_request(3, "Length", 8);
        let text = json::to_string(&sink.to_chrome_json());
        let back = Json::parse(&text).unwrap();
        let events = back.arr_field("traceEvents").unwrap();
        assert_eq!(events.len(), 4);
        assert_eq!(events[0].str_field("ph").unwrap(), "B");
        assert_eq!(events[3].str_field("ph").unwrap(), "E");
        assert_eq!(
            events[3].get("args").unwrap().str_field("finish_reason").unwrap(),
            "Length"
        );
        let x = &events[2];
        assert_eq!(x.str_field("ph").unwrap(), "X");
        assert!(x.get("dur").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn trace_sampling_keeps_every_nth_request() {
        let sink = TraceSink::new(4);
        let kept: Vec<u64> = (0..12).filter(|&id| sink.sampled(id)).collect();
        assert_eq!(kept, vec![0, 4, 8]);
        let all = TraceSink::new(1);
        assert!((0..12).all(|id| all.sampled(id)));
    }

    #[test]
    fn trace_event_cap_drops_instead_of_growing() {
        let sink = TraceSink::with_limits(1, 8);
        for i in 0..20 {
            sink.instant(i, "tick", vec![]);
        }
        assert_eq!(sink.len(), 8);
        assert_eq!(sink.dropped(), 12);
        let back = Json::parse(&json::to_string(&sink.to_chrome_json())).unwrap();
        assert_eq!(back.usize_field("dropped_events").unwrap(), 12);
    }

    #[test]
    fn trace_timestamps_are_monotonic_in_record_order() {
        let sink = TraceSink::new(1);
        for i in 0..64 {
            sink.instant(1, "tick", vec![("i", num(i as f64))]);
        }
        let events = sink.events.lock().unwrap();
        for w in events.windows(2) {
            assert!(w[1].ts_us >= w[0].ts_us);
        }
    }
}
