//! Flight recorder: a bounded, lock-light ring buffer of structured
//! request-lifecycle events.
//!
//! Chrome traces (`obs::trace`) answer "what did this run look like" after
//! the fact; the flight recorder answers "what just happened" *while the
//! process is live* — the last few thousand lifecycle transitions
//! (enqueue, admit, cache probe, preempt/resume, shed, dispatch, worker
//! death, finish, stall) are always resident and dumpable as JSON on
//! demand (`GET /debug/flight?n=N`, or automatically when the stall
//! watchdog fires).  One recorder lives on the [`super::TelemetryHub`] and
//! is shared by every engine and the pool dispatcher.
//!
//! Concurrency: writers claim a slot with one `fetch_add` on a global
//! sequence counter, then fill `slots[seq % capacity]` under that slot's
//! own mutex — writers on different slots never contend, and a writer
//! lapping a reader simply overwrites the oldest event (that is the ring
//! contract).  The sequence number is strictly increasing across all
//! threads, so a dump sorted by `seq` is a globally consistent order even
//! when slot writes race.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{self, Json};

/// Default ring capacity: at ~100 bytes/event this is ≈400 KiB resident,
/// and deep enough to hold every transition of several hundred in-flight
/// requests.
pub const DEFAULT_FLIGHT_CAPACITY: usize = 4096;

/// Worker id the pool dispatcher records under (it is not a worker).
pub const DISPATCHER_LANE: u32 = u32::MAX;

/// What happened to a request (or worker) at one lifecycle transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlightKind {
    /// request entered an engine's pending queue
    Enqueue,
    /// request bound to a state slot and began prefill
    Admit,
    /// state-cache probe at admission (detail says hit/miss + tokens)
    CacheProbe,
    /// running request evicted from its slot by a higher-priority arrival
    Preempt,
    /// previously preempted request re-admitted from its snapshot
    Resume,
    /// request shed by admission control (queue full, `Overloaded`)
    Shed,
    /// dispatcher handed the request to a worker
    Dispatch,
    /// a pool worker died (req field is 0; detail names the worker)
    WorkerDeath,
    /// request retired (detail carries the finish reason)
    Finish,
    /// stall watchdog flagged this request/worker as wedged
    Stall,
}

impl FlightKind {
    pub fn name(self) -> &'static str {
        match self {
            FlightKind::Enqueue => "enqueue",
            FlightKind::Admit => "admit",
            FlightKind::CacheProbe => "cache_probe",
            FlightKind::Preempt => "preempt",
            FlightKind::Resume => "resume",
            FlightKind::Shed => "shed",
            FlightKind::Dispatch => "dispatch",
            FlightKind::WorkerDeath => "worker_death",
            FlightKind::Finish => "finish",
            FlightKind::Stall => "stall",
        }
    }
}

/// One recorded lifecycle transition.
#[derive(Debug, Clone)]
pub struct FlightEvent {
    /// global strictly-increasing sequence number (dump order)
    pub seq: u64,
    /// microseconds since the recorder was created
    pub t_us: u64,
    /// recording lane: worker index, or [`DISPATCHER_LANE`]
    pub worker: u32,
    /// request id (0 for worker-scoped events)
    pub req: u64,
    pub kind: FlightKind,
    /// small free-form detail, e.g. `"slot=2"` or `"reason=Length"`
    pub detail: String,
}

impl FlightEvent {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("seq", json::num(self.seq as f64)),
            ("t_us", json::num(self.t_us as f64)),
            (
                "worker",
                if self.worker == DISPATCHER_LANE {
                    json::s("dispatcher")
                } else {
                    json::num(self.worker as f64)
                },
            ),
            ("req", json::num(self.req as f64)),
            ("kind", json::s(self.kind.name())),
            ("detail", json::s(&self.detail)),
        ])
    }
}

/// The shared bounded event ring (see module docs for the concurrency
/// contract).
#[derive(Debug)]
pub struct FlightRecorder {
    epoch: Instant,
    seq: AtomicU64,
    slots: Vec<Mutex<Option<FlightEvent>>>,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_FLIGHT_CAPACITY)
    }
}

impl FlightRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (≥ events still resident).
    pub fn recorded(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Record one event: claim the next sequence number, overwrite the ring
    /// slot it maps to.  O(1), one atomic plus one uncontended slot lock.
    pub fn record(&self, worker: u32, req: u64, kind: FlightKind, detail: impl Into<String>) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let ev = FlightEvent {
            seq,
            t_us: self.epoch.elapsed().as_micros() as u64,
            worker,
            req,
            kind,
            detail: detail.into(),
        };
        *self.slots[(seq % self.slots.len() as u64) as usize].lock().unwrap() = Some(ev);
    }

    /// Snapshot the last `n` resident events in global sequence order.
    /// Events being overwritten concurrently may be missing or replaced by
    /// newer ones — the dump is always a consistent set of real events,
    /// sorted by `seq`, never a torn record.
    pub fn dump(&self, n: usize) -> Vec<FlightEvent> {
        let mut evs: Vec<FlightEvent> = self
            .slots
            .iter()
            .filter_map(|s| s.lock().unwrap().clone())
            .collect();
        evs.sort_by_key(|e| e.seq);
        if evs.len() > n {
            evs.drain(..evs.len() - n);
        }
        evs
    }

    /// JSON dump body for `/debug/flight` and the watchdog report.
    pub fn dump_json(&self, n: usize) -> Json {
        let evs = self.dump(n);
        json::obj(vec![
            ("capacity", json::num(self.capacity() as f64)),
            ("recorded", json::num(self.recorded() as f64)),
            ("returned", json::num(evs.len() as f64)),
            (
                "events",
                Json::Arr(evs.iter().map(FlightEvent::to_json).collect()),
            ),
        ])
    }
}

/// An engine's handle into the shared recorder: the recorder plus the
/// lane (worker index) this engine records under.
#[derive(Debug, Clone)]
pub struct FlightCtx {
    pub rec: Arc<FlightRecorder>,
    pub worker: u32,
}

impl FlightCtx {
    pub fn new(rec: Arc<FlightRecorder>, worker: u32) -> Self {
        Self { rec, worker }
    }

    #[inline]
    pub fn record(&self, req: u64, kind: FlightKind, detail: impl Into<String>) {
        self.rec.record(self.worker, req, kind, detail);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flight_ring_wraps_and_keeps_latest_events() {
        let rec = FlightRecorder::with_capacity(8);
        for i in 0..20u64 {
            rec.record(0, i, FlightKind::Enqueue, format!("i={i}"));
        }
        assert_eq!(rec.recorded(), 20);
        let evs = rec.dump(usize::MAX);
        assert_eq!(evs.len(), 8, "ring holds exactly its capacity");
        let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (12..20).collect::<Vec<_>>(), "oldest overwritten");
        // last-n trims from the front
        let last3 = rec.dump(3);
        assert_eq!(
            last3.iter().map(|e| e.seq).collect::<Vec<_>>(),
            vec![17, 18, 19]
        );
        assert_eq!(last3.last().unwrap().req, 19);
        assert_eq!(last3.last().unwrap().detail, "i=19");
        // the JSON dump parses back and reports the same shape
        let text = json::to_string(&rec.dump_json(3));
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.usize_field("capacity").unwrap(), 8);
        assert_eq!(v.usize_field("recorded").unwrap(), 20);
        assert_eq!(v.arr_field("events").unwrap().len(), 3);
        assert_eq!(
            v.arr_field("events").unwrap()[0].str_field("kind").unwrap(),
            "enqueue"
        );
    }

    #[test]
    fn flight_concurrent_writers_yield_distinct_ordered_seqs() {
        let rec = Arc::new(FlightRecorder::with_capacity(4096));
        let n_threads = 8;
        let per_thread = 400u64;
        let mut joins = Vec::new();
        for t in 0..n_threads {
            let r = Arc::clone(&rec);
            joins.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    r.record(t, i, FlightKind::Dispatch, "");
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let total = n_threads as u64 * per_thread;
        assert_eq!(rec.recorded(), total);
        let evs = rec.dump(usize::MAX);
        assert_eq!(evs.len(), total as usize, "capacity exceeds writes: none lost");
        // sequence numbers are globally unique and the dump is sorted
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.seq, i as u64, "dense, distinct, ordered seqs");
        }
        // every thread's events appear in its own program order
        for t in 0..n_threads {
            let mine: Vec<u64> = evs.iter().filter(|e| e.worker == t).map(|e| e.req).collect();
            assert_eq!(mine, (0..per_thread).collect::<Vec<_>>());
        }
    }
}
