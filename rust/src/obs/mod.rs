//! Live observability layer: telemetry registry, latency histograms,
//! request span tracing, and the Prometheus scrape endpoint.
//!
//! Dependency-free (std only), wired through every serving layer:
//!
//! * [`telemetry::Telemetry`] — `Arc`-shared atomic counters, gauges, and
//!   log-bucketed histograms that [`crate::coordinator::Engine`],
//!   [`crate::coordinator::SpecEngine`], and the pool dispatcher update
//!   *live*; `coordinator::Metrics` writes through to it, so mid-run
//!   scrapes and the end-of-run summary read the same cells.
//! * [`histogram::Histogram`] — fixed-memory log buckets with exact
//!   bucket-wise [`histogram::Histogram::merge`], replacing the unbounded
//!   per-request sample vectors and the concat-based cross-worker
//!   percentile merge.
//! * [`trace::TraceSink`] — per-request span tracing
//!   (queued → admitted → cache probe → prefill chunks → decode/spec
//!   rounds → retire), exported as Chrome `trace_event` JSON
//!   (`serve --trace-out FILE`, sampled by `--trace-sample N`).
//! * [`scrape::serve_metrics`] — the `/metrics` Prometheus-text endpoint
//!   (`serve --metrics-addr HOST:PORT`) over
//!   [`telemetry::TelemetryHub`], which aggregates per-worker telemetry
//!   and reads state-cache occupancy live.  The same listener serves the
//!   live introspection routes: `/statusz` (request/worker tables),
//!   `/readyz` (readiness distinct from `/healthz` liveness),
//!   `/debug/config`, and `/debug/flight?n=N`.
//! * [`flight::FlightRecorder`] — a bounded ring of structured lifecycle
//!   events (enqueue/admit/preempt/resume/shed/dispatch/finish/...),
//!   always resident, dumpable as JSON on demand.
//! * [`slo::SloMonitor`] / [`slo::StallWatchdog`] — burn-rate gauges +
//!   windowed `slo_violations_total` against configured TTFT/TPOT/
//!   availability objectives (`--slo-*`), and a watchdog that flags
//!   no-progress requests/workers and dumps the flight recorder.

pub mod flight;
pub mod histogram;
pub mod scrape;
pub mod slo;
pub mod telemetry;
pub mod trace;

pub use flight::{FlightCtx, FlightEvent, FlightKind, FlightRecorder};
pub use histogram::Histogram;
pub use scrape::{serve_metrics, MetricsServer};
pub use slo::{SloConfig, SloMonitor, StallWatchdog};
pub use telemetry::{Counter, Gauge, HistKind, RemoteTransport, Telemetry, TelemetryHub};
pub use trace::{TraceCtx, TraceSink};

/// Nearest-rank percentile of an ascending-sorted slice: the smallest
/// element with at least `⌈p·n⌉` elements ≤ it.  The previous
/// implementation indexed `(n as f64 * p) as usize`, which *truncates*
/// toward an off-by-one-high rank and biases small samples: for 100
/// sorted samples it returned the 51st value as the median.
pub fn nearest_rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Samples sorted once, queried many times — the snapshot-time view that
/// replaces re-sorting a cloned `Vec` on every percentile call.
#[derive(Debug, Clone)]
pub struct SortedSamples(Vec<f64>);

impl SortedSamples {
    pub fn new(mut samples: Vec<f64>) -> Self {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self(samples)
    }

    pub fn pct(&self, p: f64) -> f64 {
        nearest_rank(&self.0, p)
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_nearest_rank_matches_known_quantiles() {
        // 1..=100 sorted: nearest-rank p50 is the 50th value, p95 the
        // 95th, p99 the 99th.  The old truncating index returned 51/96/100.
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(nearest_rank(&v, 0.50), 50.0);
        assert_eq!(nearest_rank(&v, 0.95), 95.0);
        assert_eq!(nearest_rank(&v, 0.99), 99.0);
        assert_eq!(nearest_rank(&v, 1.00), 100.0);
        assert_eq!(nearest_rank(&v, 0.0), 1.0);
    }

    #[test]
    fn obs_nearest_rank_small_samples() {
        assert_eq!(nearest_rank(&[7.0], 0.5), 7.0);
        assert_eq!(nearest_rank(&[], 0.5), 0.0);
        // two samples: the median is the 1st (rank ⌈0.5·2⌉ = 1), the old
        // index (2·0.5 = 1 → second element) overshot
        assert_eq!(nearest_rank(&[1.0, 2.0], 0.5), 1.0);
        assert_eq!(nearest_rank(&[1.0, 2.0], 0.95), 2.0);
        // five samples, the seed repo's own doctest case
        let v = [0.1, 0.2, 0.3, 0.4, 1.0];
        assert_eq!(nearest_rank(&v, 0.50), 0.3);
        assert_eq!(nearest_rank(&v, 0.95), 1.0);
    }

    #[test]
    fn obs_sorted_samples_sorts_once_and_answers_many() {
        let s = SortedSamples::new(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.len(), 5);
        assert_eq!(s.pct(0.5), 3.0);
        assert_eq!(s.pct(0.95), 5.0);
        assert_eq!(s.pct(0.2), 1.0);
    }
}
