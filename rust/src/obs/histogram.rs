//! Fixed-memory log-bucketed latency histogram.
//!
//! The serving metrics used to keep one raw `f64` per request (TTFT,
//! end-to-end latency, acceptance rate), which grows without bound in a
//! long-lived process, and the multi-worker aggregate concatenated those
//! raw vectors — O(requests) memory and O(n log n) re-sorts per percentile.
//! This histogram replaces both: observation is O(1) into a fixed bucket
//! array, and [`Histogram::merge`] is an exact bucket-wise add, so the
//! merged quantiles are *identical* to the quantiles of the concatenated
//! sample streams (within one bucket's resolution of the true sample
//! quantile — buckets grow by 2^(1/8) ≈ 9% per step).
//!
//! Layout: bucket 0 covers `(0, MIN_VALUE]`, bucket `i` covers
//! `(MIN_VALUE·G^(i-1), MIN_VALUE·G^i]` with `G = 2^(1/8)`; values ≤ 0 are
//! counted separately (speculative decode legitimately records 0-second
//! inter-token gaps for tokens committed in one verify burst), and values
//! above the top bucket clamp into it.  With `MIN_VALUE = 1 µs` and 272
//! buckets the range tops out above 4½ hours — more than any latency this
//! stack can produce.

/// Lower bound of the first bucket, in the recorded unit (seconds for all
/// latency histograms in this crate): 1 µs.
pub const MIN_VALUE: f64 = 1e-6;

/// Buckets per factor-of-two; resolution is `2^(1/8) - 1 ≈ 9.05%`.
pub const BUCKETS_PER_OCTAVE: usize = 8;

/// Total buckets: 34 octaves above [`MIN_VALUE`] (top edge ≈ 17 180 s).
pub const N_BUCKETS: usize = 34 * BUCKETS_PER_OCTAVE;

#[derive(Debug, Clone, Default)]
pub struct Histogram {
    /// per-bucket counts; allocated lazily on the first observation so an
    /// empty histogram costs nothing
    counts: Vec<u64>,
    /// observations ≤ 0 (kept out of the log buckets)
    zero: u64,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

fn bucket_index(v: f64) -> usize {
    if v <= MIN_VALUE {
        return 0;
    }
    let i = ((v / MIN_VALUE).log2() * BUCKETS_PER_OCTAVE as f64).ceil() as usize;
    i.min(N_BUCKETS - 1)
}

/// Inclusive upper edge of bucket `i`.
fn bucket_upper(i: usize) -> f64 {
    MIN_VALUE * 2f64.powf(i as f64 / BUCKETS_PER_OCTAVE as f64)
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&mut self, v: f64) {
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
        if v <= 0.0 {
            self.zero += 1;
        } else {
            if self.counts.is_empty() {
                self.counts = vec![0; N_BUCKETS];
            }
            self.counts[bucket_index(v)] += 1;
        }
    }

    /// Exact bucket-wise merge: because every histogram shares one bucket
    /// layout, `a.merge(&b)` has bucket counts equal to observing both
    /// sample streams into one histogram — merged quantiles are identical
    /// to concatenated-stream quantiles, unlike raw-vector concatenation
    /// which was only as good as its unbounded memory.
    pub fn merge(&mut self, other: &Histogram) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        if !other.counts.is_empty() {
            if self.counts.is_empty() {
                self.counts = vec![0; N_BUCKETS];
            }
            for (a, b) in self.counts.iter_mut().zip(&other.counts) {
                *a += b;
            }
        }
        self.zero += other.zero;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Nearest-rank quantile over the bucket counts: the returned value is
    /// the upper edge of the bucket holding the rank-`⌈q·n⌉` observation,
    /// clamped to the exact observed `[min, max]` — within one bucket's
    /// resolution (≈9%) of the true sample quantile.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        if rank == self.n {
            return self.max;
        }
        let mut cum = self.zero;
        if rank <= cum {
            // rank falls in the ≤0 class; min is its only exact bound
            return self.min;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if rank <= cum {
                return bucket_upper(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Heap bytes held — constant once the bucket array is allocated, which
    /// is the whole point versus one `f64` per request.
    pub fn heap_bytes(&self) -> usize {
        self.counts.capacity() * std::mem::size_of::<u64>()
    }

    /// Observations whose value may exceed `threshold`, bucket-quantized:
    /// a bucket counts as "over" iff its inclusive upper edge exceeds the
    /// threshold, so an observation in the straddling bucket is counted as
    /// violating (the conservative direction for an SLO error fraction).
    /// The ≤0 class counts only for a negative threshold.  Because the
    /// answer is a pure function of the bucket counts, recomputing it
    /// offline from an exported `(bucket, count)` list reproduces the live
    /// value bit-for-bit.
    pub fn count_over(&self, threshold: f64) -> u64 {
        let mut over = if threshold < 0.0 { self.zero } else { 0 };
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 && bucket_upper(i) > threshold {
                over += c;
            }
        }
        over
    }

    /// Count of observations in the ≤0 class (kept out of the log buckets).
    pub fn zero_count(&self) -> u64 {
        self.zero
    }

    /// Sparse `(bucket_index, count)` pairs for every non-empty log bucket
    /// — the export shape for `fastmamba.metrics.v1` snapshots, from which
    /// [`Histogram::count_over`] is exactly recomputable.
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect()
    }

    /// Inclusive upper edge of log bucket `i` — public so offline snapshot
    /// consumers share the exact same edge arithmetic as the live path.
    pub fn bucket_upper_edge(i: usize) -> f64 {
        bucket_upper(i)
    }

    /// Cumulative `(le, count)` pairs for Prometheus exposition, keeping
    /// every `stride`-th bucket edge (34 edges at `stride = 8`) plus the
    /// implicit `+Inf` which callers render from [`Histogram::count`].
    pub fn cumulative_buckets(&self, stride: usize) -> Vec<(f64, u64)> {
        let stride = stride.max(1);
        let mut out = Vec::new();
        let mut cum = self.zero;
        for i in 0..N_BUCKETS {
            cum += self.counts.get(i).copied().unwrap_or(0);
            if (i + 1) % stride == 0 {
                out.push((bucket_upper(i), cum));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn samples(seed: u64, n: usize) -> Vec<f64> {
        // log-uniform latencies spanning 20 µs .. ~2 s
        let mut rng = Rng::new(seed);
        (0..n).map(|_| 2e-5 * (rng.uniform() * 11.5).exp()).collect()
    }

    fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
        let n = sorted.len();
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        sorted[rank - 1]
    }

    #[test]
    fn histogram_quantiles_track_exact_within_bucket_resolution() {
        let vals = samples(7, 4096);
        let mut h = Histogram::new();
        for &v in &vals {
            h.observe(v);
        }
        let mut sorted = vals.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.5, 0.95, 0.99] {
            let exact = exact_quantile(&sorted, q);
            let approx = h.quantile(q);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.10, "q={q}: exact={exact} approx={approx} rel={rel}");
        }
        assert_eq!(h.count(), 4096);
        assert!((h.min() - sorted[0]).abs() < 1e-15);
        assert!((h.max() - sorted[sorted.len() - 1]).abs() < 1e-15);
    }

    #[test]
    fn histogram_merge_is_exactly_bucketwise_concat() {
        let a = samples(11, 1500);
        let b = samples(12, 700);
        let (mut ha, mut hb, mut hc) = (Histogram::new(), Histogram::new(), Histogram::new());
        for &v in &a {
            ha.observe(v);
            hc.observe(v);
        }
        for &v in &b {
            hb.observe(v);
            hc.observe(v);
        }
        let mut merged = ha.clone();
        merged.merge(&hb);
        assert_eq!(merged.counts, hc.counts, "bucket-wise add ≡ concat");
        assert_eq!(merged.count(), hc.count());
        assert_eq!(merged.zero, hc.zero);
        assert!((merged.sum() - hc.sum()).abs() < 1e-9 * hc.sum().abs().max(1.0));
        assert_eq!(merged.min(), hc.min());
        assert_eq!(merged.max(), hc.max());
        // identical bucket counts and min/max ⇒ identical quantiles, the
        // property raw-vector concatenation needed unbounded memory for
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(merged.quantile(q), hc.quantile(q), "q={q}");
        }
    }

    #[test]
    fn histogram_merge_into_empty_and_with_empty() {
        let mut h = Histogram::new();
        h.observe(0.25);
        let mut e = Histogram::new();
        e.merge(&h);
        assert_eq!(e.count(), 1);
        assert_eq!(e.quantile(0.5), 0.25);
        let before = e.count();
        e.merge(&Histogram::new());
        assert_eq!(e.count(), before);
    }

    #[test]
    fn histogram_memory_is_constant_after_first_observation() {
        let mut h = Histogram::new();
        assert_eq!(h.heap_bytes(), 0, "empty histogram allocates nothing");
        h.observe(0.003);
        let fixed = h.heap_bytes();
        assert_eq!(fixed, N_BUCKETS * 8);
        for i in 0..200_000 {
            h.observe((i % 977) as f64 * 1e-5);
        }
        assert_eq!(h.heap_bytes(), fixed, "200k observations allocate nothing");
        assert_eq!(h.count(), 200_001);
    }

    #[test]
    fn histogram_handles_zero_and_huge_values() {
        let mut h = Histogram::new();
        h.observe(0.0);
        h.observe(-1.0);
        h.observe(1e9); // clamps into the top bucket
        assert_eq!(h.count(), 3);
        assert_eq!(h.quantile(0.01), -1.0, "low quantile lands in the ≤0 class");
        assert_eq!(h.quantile(1.0), 1e9, "top quantile clamps to observed max");
    }

    #[test]
    fn histogram_count_over_is_bucket_quantized_and_merge_consistent() {
        let mut h = Histogram::new();
        h.observe(0.0); // ≤0 class
        for &v in &[0.001, 0.010, 0.100, 1.0] {
            h.observe(v);
        }
        // threshold below every positive observation: all four are over
        assert_eq!(h.count_over(1e-9), 4);
        // negative threshold also sweeps in the ≤0 class
        assert_eq!(h.count_over(-1.0), 5);
        // threshold above the top observation's bucket edge: none are over
        assert_eq!(h.count_over(10.0), 0);
        // bucket-quantized boundary: an observation's own bucket upper edge
        // is ≥ the observation, so thresholding exactly at a recorded value
        // still counts it (the straddling bucket is "over")
        assert!(h.count_over(0.010) >= 2, "0.100 and 1.0 are over");
        assert!(h.count_over(0.009) >= 3);
        // count_over is a pure function of the bucket counts: recomputing
        // from the sparse export reproduces it exactly, and merge adds it
        let recompute = |h: &Histogram, t: f64| -> u64 {
            let mut over = if t < 0.0 { h.zero_count() } else { 0 };
            for (i, c) in h.nonzero_buckets() {
                if Histogram::bucket_upper_edge(i) > t {
                    over += c;
                }
            }
            over
        };
        for t in [-1.0, 1e-9, 0.009, 0.010, 0.05, 10.0] {
            assert_eq!(h.count_over(t), recompute(&h, t), "t={t}");
        }
        let mut other = Histogram::new();
        other.observe(0.5);
        let mut merged = h.clone();
        merged.merge(&other);
        assert_eq!(merged.count_over(0.009), h.count_over(0.009) + 1);
    }

    #[test]
    fn histogram_cumulative_buckets_are_monotone_and_end_at_count() {
        let mut h = Histogram::new();
        for &v in &samples(3, 512) {
            h.observe(v);
        }
        let edges = h.cumulative_buckets(BUCKETS_PER_OCTAVE);
        assert_eq!(edges.len(), N_BUCKETS / BUCKETS_PER_OCTAVE);
        let mut prev = 0;
        for &(le, c) in &edges {
            assert!(le > 0.0);
            assert!(c >= prev, "cumulative counts are monotone");
            prev = c;
        }
        assert_eq!(edges.last().unwrap().1, h.count());
    }
}
