//! Metrics + live-introspection endpoint: a minimal HTTP/1.1 responder on
//! a `std::net::TcpListener` thread (`serve --metrics-addr HOST:PORT`).
//!
//! Routes, all `GET`:
//!
//! * `/metrics` — the [`TelemetryHub`]'s Prometheus text exposition.
//! * `/statusz` — the live request/worker table as JSON (per-request id,
//!   state, worker, priorities, age, tokens; per-worker queue depth and
//!   utilization counters; dispatcher view; cache shard occupancy).
//! * `/readyz` — readiness: 200 only with at least one live worker and
//!   the ingress queue below its shed threshold (the load balancer's
//!   signal, distinct from `/healthz` liveness on the API port).
//! * `/debug/config` — the resolved serving configuration dump.
//! * `/debug/flight?n=N` — the last N flight-recorder events as JSON.
//!
//! Anything else is a 404.  The listener thread blocks in `accept`;
//! shutdown flips an atomic and self-connects to unblock it, so dropping
//! the [`MetricsServer`] never hangs.  Bind to port 0 to let the OS pick
//! — the bound address is available from [`MetricsServer::addr`] (which
//! is how the integration tests scrape a live pool without a fixed port).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::telemetry::TelemetryHub;
use crate::util::json;

pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

/// Start serving `hub`'s Prometheus exposition on `addr`
/// (e.g. `"127.0.0.1:9898"`, or `"127.0.0.1:0"` for an OS-picked port).
pub fn serve_metrics(addr: &str, hub: Arc<TelemetryHub>) -> Result<MetricsServer> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding metrics endpoint {addr}"))?;
    let bound = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop_in = Arc::clone(&stop);
    let handle = std::thread::Builder::new()
        .name("metrics-scrape".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop_in.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                // one scrape per connection; errors only drop that scrape
                let _ = handle_conn(stream, &hub);
            }
        })?;
    Ok(MetricsServer { addr: bound, stop, handle: Some(handle) })
}

fn handle_conn(mut stream: TcpStream, hub: &TelemetryHub) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf)?;
    let request_line = std::str::from_utf8(&buf[..n])
        .unwrap_or("")
        .lines()
        .next()
        .unwrap_or("");
    let path = request_line.split_whitespace().nth(1).unwrap_or("");
    let (route, query) = match path.split_once('?') {
        Some((r, q)) => (r, q),
        None => (path, ""),
    };
    const PROM: &str = "text/plain; version=0.0.4; charset=utf-8";
    const JSON: &str = "application/json";
    let (status, ctype, body) = match route {
        "/metrics" => ("200 OK", PROM, hub.render_prometheus()),
        "/statusz" => {
            let mut b = json::to_string(&hub.statusz_json());
            b.push('\n');
            ("200 OK", JSON, b)
        }
        "/readyz" => {
            let (ready, body) = hub.readiness();
            let mut b = json::to_string(&body);
            b.push('\n');
            let status = if ready { "200 OK" } else { "503 Service Unavailable" };
            (status, JSON, b)
        }
        "/debug/config" => {
            let mut b = json::to_string(&hub.config_json());
            b.push('\n');
            ("200 OK", JSON, b)
        }
        "/debug/flight" => {
            let n = query
                .split('&')
                .find_map(|kv| kv.strip_prefix("n="))
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(256);
            let mut b = json::to_string(&hub.flight().dump_json(n));
            b.push('\n');
            ("200 OK", JSON, b)
        }
        _ => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            String::from(
                "not found; try /metrics /statusz /readyz /debug/config /debug/flight?n=N\n",
            ),
        ),
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    Ok(())
}

impl MetricsServer {
    /// The bound address (resolves port 0 to the OS-picked port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener thread (idempotent).
    pub fn shutdown(&mut self) {
        if self.handle.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
pub(crate) fn http_get(addr: SocketAddr, path: &str) -> Result<(String, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n")?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .context("malformed HTTP response")?;
    Ok((head.to_string(), body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::super::telemetry::Counter;
    use super::*;

    /// Parse Prometheus text exposition into (series, value) pairs,
    /// failing on any malformed line.
    pub(crate) fn parse_prometheus(text: &str) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        for line in text.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (series, value) = line.rsplit_once(' ').expect("line has a value");
            let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value: {line}"));
            out.push((series.to_string(), v));
        }
        out
    }

    #[test]
    fn scrape_endpoint_serves_metrics_and_404s_elsewhere() {
        let hub = Arc::new(TelemetryHub::new());
        let tel = hub.register("0");
        tel.add(Counter::TokensGenerated, 42);
        let mut server = serve_metrics("127.0.0.1:0", Arc::clone(&hub)).unwrap();

        let (head, body) = http_get(server.addr(), "/metrics").unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(head.contains("text/plain"), "{head}");
        let series = parse_prometheus(&body);
        assert!(series
            .iter()
            .any(|(s, v)| s == "fastmamba_tokens_generated_total" && *v == 42.0));

        let (head, _) = http_get(server.addr(), "/other").unwrap();
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");

        server.shutdown();
        server.shutdown(); // idempotent
    }

    #[test]
    fn scrape_statusz_readyz_and_flight_routes_serve_json() {
        use crate::obs::flight::FlightKind;
        use crate::util::json::{self as j, Json};

        let hub = Arc::new(TelemetryHub::new());
        let mut server = serve_metrics("127.0.0.1:0", Arc::clone(&hub)).unwrap();

        // nothing registered yet: /readyz says not ready with a reason
        let (head, body) = http_get(server.addr(), "/readyz").unwrap();
        assert!(head.starts_with("HTTP/1.1 503"), "{head}");
        assert!(head.contains("application/json"), "{head}");
        let v = Json::parse(body.trim()).unwrap();
        assert_eq!(v.get("ready").unwrap(), &Json::Bool(false));

        // one worker with a published status flips readiness and fills
        // the /statusz tables
        let w = hub.register("0");
        w.set_status(j::obj(vec![
            (
                "requests",
                Json::Arr(vec![j::obj(vec![
                    ("id", j::num(5.0)),
                    ("state", j::s("active")),
                    ("tokens", j::num(2.0)),
                ])]),
            ),
            ("pending", j::num(0.0)),
            ("active", j::num(1.0)),
        ]));
        let (head, _) = http_get(server.addr(), "/readyz").unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");

        let (head, body) = http_get(server.addr(), "/statusz").unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        let v = Json::parse(body.trim()).unwrap();
        assert_eq!(v.arr_field("workers").unwrap().len(), 1);
        let reqs = v.arr_field("requests").unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].usize_field("id").unwrap(), 5);
        assert_eq!(reqs[0].str_field("worker").unwrap(), "0");

        // /debug/config serves whatever was attached at startup
        hub.attach_config(j::obj(vec![("workers", j::num(4.0))]));
        let (head, body) = http_get(server.addr(), "/debug/config").unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        let v = Json::parse(body.trim()).unwrap();
        assert_eq!(v.usize_field("workers").unwrap(), 4);

        // /debug/flight?n=N returns the last N events
        for i in 0..10u64 {
            hub.flight().record(0, i, FlightKind::Admit, "slot=0");
        }
        let (head, body) = http_get(server.addr(), "/debug/flight?n=4").unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        let v = Json::parse(body.trim()).unwrap();
        assert_eq!(v.usize_field("recorded").unwrap(), 10);
        let evs = v.arr_field("events").unwrap();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[3].usize_field("req").unwrap(), 9);

        server.shutdown();
    }

    #[test]
    fn scrape_counters_are_monotone_between_scrapes() {
        let hub = Arc::new(TelemetryHub::new());
        let tel = hub.register("0");
        tel.add(Counter::RequestsCompleted, 1);
        let mut server = serve_metrics("127.0.0.1:0", Arc::clone(&hub)).unwrap();
        let (_, b1) = http_get(server.addr(), "/metrics").unwrap();
        tel.add(Counter::RequestsCompleted, 5);
        let (_, b2) = http_get(server.addr(), "/metrics").unwrap();
        let v = |body: &str, name: &str| {
            parse_prometheus(body)
                .into_iter()
                .find(|(s, _)| s == name)
                .unwrap()
                .1
        };
        let name = "fastmamba_requests_completed_total";
        assert!(v(&b2, name) >= v(&b1, name));
        assert_eq!(v(&b2, name), 6.0);
        server.shutdown();
    }

    #[test]
    fn scrape_live_pool_mid_run_matches_final_report() {
        use crate::backend::{InferenceBackend, NativeBackend};
        use crate::coordinator::{serve_pool, EngineConfig, PoolConfig, Request};

        // the micro model the router stress tests use: small enough that
        // the 64-request trace finishes fast in debug builds
        let make = || -> Result<Box<dyn InferenceBackend>> {
            let mut cfg = crate::config::ModelConfig::tiny();
            cfg.name = "mamba2-micro".into();
            cfg.d_model = 64;
            cfg.n_layer = 2;
            cfg.d_state = 16;
            cfg.headdim = 16;
            cfg.vocab_size = 128;
            Ok(Box::new(
                NativeBackend::new(crate::model::ModelWeights::random(&cfg, 9))
                    .with_buckets(vec![8, 16, 32], vec![1, 2, 4]),
            ))
        };
        let hub = Arc::new(TelemetryHub::new());
        let mut server = serve_metrics("127.0.0.1:0", Arc::clone(&hub)).unwrap();
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 4, greedy_chunking: true },
                n_workers: 4,
                hub: Some(Arc::clone(&hub)),
                ..PoolConfig::default()
            },
        );
        let n = 64usize;
        for i in 0..n {
            let plen = [3usize, 9, 17, 33][i % 4];
            let prompt: Vec<u32> =
                (0..plen).map(|j| ((i * 131 + j * 17) % 128) as u32).collect();
            pool.submit(Request::new(i as u64, prompt, 2 + (i % 5), "fp32")).unwrap();
        }
        // mid-run scrape: once half the results arrived, the endpoint must
        // already account for at least that many completions
        for _ in 0..n / 2 {
            pool.results.recv().expect("pool result");
        }
        let (head, mid_body) = http_get(server.addr(), "/metrics").unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        let v = |body: &str, name: &str| -> f64 {
            parse_prometheus(body)
                .into_iter()
                .find(|(s, _)| s == name)
                .unwrap_or_else(|| panic!("missing series {name}"))
                .1
        };
        let name = "fastmamba_requests_completed_total";
        let mid = v(&mid_body, name);
        assert!(mid >= (n / 2) as f64, "mid-run scrape lagged: {mid}");
        assert!(mid <= n as f64);
        // per-worker labeled series render alongside the bare aggregate
        assert!(parse_prometheus(&mid_body)
            .iter()
            .any(|(s, _)| s.starts_with("fastmamba_requests_completed_total{worker=")));

        for _ in 0..n - n / 2 {
            pool.results.recv().expect("pool result");
        }
        let report = pool.finish().unwrap();
        assert!(report.errors.is_empty(), "{:?}", report.errors);

        // final scrape: monotone over the mid-run read, and the aggregate
        // equals the merged end-of-run snapshot exactly — the scrape and
        // the report are two reads of the same atomics
        let (_, final_body) = http_get(server.addr(), "/metrics").unwrap();
        let fin = v(&final_body, name);
        assert!(fin >= mid);
        assert_eq!(fin, report.merged.requests_completed as f64);
        assert_eq!(
            v(&final_body, "fastmamba_tokens_generated_total"),
            report.merged.tokens_generated as f64
        );
        assert_eq!(
            v(&final_body, "fastmamba_request_latency_seconds_count"),
            report.merged.requests_completed as f64
        );
        server.shutdown();
    }
}
