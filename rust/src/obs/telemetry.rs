//! Lock-light live telemetry registry.
//!
//! A [`Telemetry`] is an `Arc`-shared bundle of atomic counters, gauges,
//! and mutex-guarded [`Histogram`]s that an engine updates *while it
//! serves* — the write path for counters is one `fetch_add(Relaxed)`, so
//! the serving hot loop pays nanoseconds, not locks.  Each pool worker
//! registers its own `Telemetry` with the shared [`TelemetryHub`]; the hub
//! renders the Prometheus text exposition (per-worker series plus an
//! exact bucket-wise aggregate) and the periodic one-line stdout log, and
//! reads state-cache occupancy gauges straight from the attached
//! [`StateCache`] at scrape time.
//!
//! `coordinator::Metrics` writes through to an attached `Telemetry` on
//! every mutation, so the live view and the end-of-run snapshot are two
//! reads of the same cells — `Metrics::from_telemetry` reconstructs a full
//! snapshot from the atomics alone, and a scrape taken mid-run is always a
//! prefix (counter-monotone) of the final numbers.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::histogram::{Histogram, BUCKETS_PER_OCTAVE};
use crate::statecache::StateCache;

/// Monotone counters an engine maintains (mirrors the `u64` fields of
/// `coordinator::Metrics`, plus busy time in integer microseconds so it
/// can live in an atomic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    RequestsCompleted,
    TokensGenerated,
    PromptTokens,
    PrefillChunks,
    DecodeSteps,
    DecodePaddedSlots,
    DecodeBatchSlots,
    DraftTokens,
    DraftAccepted,
    SpecRounds,
    VerifyCalls,
    Rollbacks,
    ResyncSteps,
    DrafterReseeds,
    CacheHits,
    CacheMisses,
    CacheTokensSaved,
    CancelledRequests,
    DeadlineExpired,
    /// requests evicted from a state slot by a higher-priority arrival
    /// (each later resumes and finishes under its real reason)
    PreemptedRequests,
    /// requests shed by admission control (bounded queue full at
    /// submission; terminal reason `Overloaded`)
    RequestsShed,
    /// requests dropped undone at the dispatcher (cancel/deadline/worker
    /// death resolved from the backlog — no token was ever produced, so
    /// they stay out of the latency histograms)
    RequestsDropped,
    /// pending-queue re-orderings where priority aging promoted at least
    /// one request past a higher-static-priority one
    AgingReorders,
    BusyMicros,
}

pub const N_COUNTERS: usize = 24;

impl Counter {
    pub const ALL: [Counter; N_COUNTERS] = [
        Counter::RequestsCompleted,
        Counter::TokensGenerated,
        Counter::PromptTokens,
        Counter::PrefillChunks,
        Counter::DecodeSteps,
        Counter::DecodePaddedSlots,
        Counter::DecodeBatchSlots,
        Counter::DraftTokens,
        Counter::DraftAccepted,
        Counter::SpecRounds,
        Counter::VerifyCalls,
        Counter::Rollbacks,
        Counter::ResyncSteps,
        Counter::DrafterReseeds,
        Counter::CacheHits,
        Counter::CacheMisses,
        Counter::CacheTokensSaved,
        Counter::CancelledRequests,
        Counter::DeadlineExpired,
        Counter::PreemptedRequests,
        Counter::RequestsShed,
        Counter::RequestsDropped,
        Counter::AgingReorders,
        Counter::BusyMicros,
    ];

    pub fn index(self) -> usize {
        Self::ALL.iter().position(|c| *c == self).unwrap()
    }

    /// Prometheus series base name (rendered as `fastmamba_<name>_total`).
    pub fn name(self) -> &'static str {
        match self {
            Counter::RequestsCompleted => "requests_completed",
            Counter::TokensGenerated => "tokens_generated",
            Counter::PromptTokens => "prompt_tokens",
            Counter::PrefillChunks => "prefill_chunks",
            Counter::DecodeSteps => "decode_steps",
            Counter::DecodePaddedSlots => "decode_padded_slots",
            Counter::DecodeBatchSlots => "decode_batch_slots",
            Counter::DraftTokens => "draft_tokens",
            Counter::DraftAccepted => "draft_accepted",
            Counter::SpecRounds => "spec_rounds",
            Counter::VerifyCalls => "verify_calls",
            Counter::Rollbacks => "rollbacks",
            Counter::ResyncSteps => "resync_steps",
            Counter::DrafterReseeds => "drafter_reseeds",
            Counter::CacheHits => "cache_hits",
            Counter::CacheMisses => "cache_misses",
            Counter::CacheTokensSaved => "cache_tokens_saved",
            Counter::CancelledRequests => "cancelled_requests",
            Counter::DeadlineExpired => "deadline_expired",
            Counter::PreemptedRequests => "preempted_requests",
            Counter::RequestsShed => "requests_shed",
            Counter::RequestsDropped => "requests_dropped",
            Counter::AgingReorders => "aging_reorders",
            Counter::BusyMicros => "busy_microseconds",
        }
    }
}

/// Instantaneous values (each also keeps its observed peak).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    /// pending + active requests the engine currently holds
    QueueDepth,
    /// state slots currently bound to in-flight requests
    ActiveSlots,
}

pub const N_GAUGES: usize = 2;

impl Gauge {
    pub const ALL: [Gauge; N_GAUGES] = [Gauge::QueueDepth, Gauge::ActiveSlots];

    pub fn index(self) -> usize {
        Self::ALL.iter().position(|g| *g == self).unwrap()
    }

    pub fn name(self) -> &'static str {
        match self {
            Gauge::QueueDepth => "queue_depth",
            Gauge::ActiveSlots => "active_slots",
        }
    }
}

/// The latency/ratio distributions an engine records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HistKind {
    Ttft,
    Latency,
    Tpot,
    Acceptance,
    PrefillCall,
    DecodeCall,
}

pub const N_HISTS: usize = 6;

impl HistKind {
    pub const ALL: [HistKind; N_HISTS] = [
        HistKind::Ttft,
        HistKind::Latency,
        HistKind::Tpot,
        HistKind::Acceptance,
        HistKind::PrefillCall,
        HistKind::DecodeCall,
    ];

    pub fn index(self) -> usize {
        Self::ALL.iter().position(|h| *h == self).unwrap()
    }

    pub fn name(self) -> &'static str {
        match self {
            HistKind::Ttft => "ttft_seconds",
            HistKind::Latency => "request_latency_seconds",
            HistKind::Tpot => "tpot_seconds",
            HistKind::Acceptance => "draft_acceptance_ratio",
            HistKind::PrefillCall => "prefill_call_seconds",
            HistKind::DecodeCall => "decode_call_seconds",
        }
    }
}

/// One engine's live cells.  Counter/gauge writes are relaxed atomics;
/// histogram observes take a short uncontended mutex (only the owning
/// engine writes, scrapes clone).
#[derive(Debug)]
pub struct Telemetry {
    counters: [AtomicU64; N_COUNTERS],
    gauges: [AtomicU64; N_GAUGES],
    gauge_peaks: [AtomicU64; N_GAUGES],
    hists: [Mutex<Histogram>; N_HISTS],
}

impl Default for Telemetry {
    fn default() -> Self {
        Self::new()
    }
}

impl Telemetry {
    pub fn new() -> Self {
        Self {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            gauges: std::array::from_fn(|_| AtomicU64::new(0)),
            gauge_peaks: std::array::from_fn(|_| AtomicU64::new(0)),
            hists: std::array::from_fn(|_| Mutex::new(Histogram::new())),
        }
    }

    #[inline]
    pub fn add(&self, c: Counter, n: u64) {
        self.counters[c.index()].fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self, c: Counter) -> u64 {
        self.counters[c.index()].load(Ordering::Relaxed)
    }

    pub fn set_gauge(&self, g: Gauge, v: u64) {
        self.gauges[g.index()].store(v, Ordering::Relaxed);
        self.gauge_peaks[g.index()].fetch_max(v, Ordering::Relaxed);
    }

    pub fn gauge(&self, g: Gauge) -> u64 {
        self.gauges[g.index()].load(Ordering::Relaxed)
    }

    pub fn gauge_peak(&self, g: Gauge) -> u64 {
        self.gauge_peaks[g.index()].load(Ordering::Relaxed)
    }

    pub fn observe(&self, h: HistKind, v: f64) {
        self.hists[h.index()].lock().unwrap().observe(v);
    }

    /// Clone the named histogram (a scrape-time snapshot).
    pub fn hist(&self, h: HistKind) -> Histogram {
        self.hists[h.index()].lock().unwrap().clone()
    }
}

/// Shared registry over all per-worker [`Telemetry`] handles, plus the
/// optional [`StateCache`] whose occupancy it exposes as gauges.
#[derive(Debug, Default)]
pub struct TelemetryHub {
    workers: Mutex<Vec<(String, Arc<Telemetry>)>>,
    cache: Mutex<Option<Arc<StateCache>>>,
}

impl TelemetryHub {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new labeled telemetry handle (one per pool worker, plus
    /// `"dispatcher"` for backlog-resolved requests).
    pub fn register(&self, label: &str) -> Arc<Telemetry> {
        let tel = Arc::new(Telemetry::new());
        self.workers
            .lock()
            .unwrap()
            .push((label.to_string(), Arc::clone(&tel)));
        tel
    }

    pub fn attach_cache(&self, cache: Arc<StateCache>) {
        *self.cache.lock().unwrap() = Some(cache);
    }

    fn handles(&self) -> Vec<(String, Arc<Telemetry>)> {
        self.workers.lock().unwrap().clone()
    }

    /// Sum of one counter across every registered handle.
    pub fn total(&self, c: Counter) -> u64 {
        self.handles().iter().map(|(_, t)| t.get(c)).sum()
    }

    /// Sum of one gauge's current value across every registered handle.
    pub fn gauge_total(&self, g: Gauge) -> u64 {
        self.handles().iter().map(|(_, t)| t.gauge(g)).sum()
    }

    /// Exact bucket-wise aggregate of one histogram across workers — the
    /// merged quantiles equal the quantiles of the pooled sample stream.
    pub fn hist_aggregate(&self, h: HistKind) -> Histogram {
        let mut agg = Histogram::new();
        for (_, t) in self.handles() {
            agg.merge(&t.hist(h));
        }
        agg
    }

    /// Prometheus text exposition (format version 0.0.4): every counter
    /// and gauge per worker and aggregated, histogram `_bucket`/`_sum`/
    /// `_count` series per worker and aggregated, and the state-cache
    /// occupancy read live from the attached cache.
    pub fn render_prometheus(&self) -> String {
        let handles = self.handles();
        let mut out = String::new();
        for c in Counter::ALL {
            let full = format!("fastmamba_{}_total", c.name());
            out.push_str(&format!("# TYPE {full} counter\n"));
            for (label, t) in &handles {
                out.push_str(&format!("{full}{{worker=\"{label}\"}} {}\n", t.get(c)));
            }
            out.push_str(&format!("{full} {}\n", self.total(c)));
        }
        for g in Gauge::ALL {
            let full = format!("fastmamba_{}", g.name());
            out.push_str(&format!("# TYPE {full} gauge\n"));
            for (label, t) in &handles {
                out.push_str(&format!("{full}{{worker=\"{label}\"}} {}\n", t.gauge(g)));
            }
            out.push_str(&format!("{full} {}\n", self.gauge_total(g)));
            out.push_str(&format!("# TYPE {full}_peak gauge\n"));
            for (label, t) in &handles {
                out.push_str(&format!(
                    "{full}_peak{{worker=\"{label}\"}} {}\n",
                    t.gauge_peak(g)
                ));
            }
        }
        for h in HistKind::ALL {
            let full = format!("fastmamba_{}", h.name());
            out.push_str(&format!("# TYPE {full} histogram\n"));
            for (label, t) in &handles {
                render_histogram(&mut out, &full, &format!("worker=\"{label}\","), &t.hist(h));
            }
            render_histogram(&mut out, &full, "", &self.hist_aggregate(h));
        }
        if let Some(cache) = self.cache.lock().unwrap().as_ref() {
            let s = cache.stats();
            out.push_str("# TYPE fastmamba_cache_bytes_resident gauge\n");
            out.push_str(&format!("fastmamba_cache_bytes_resident {}\n", s.bytes_resident));
            out.push_str("# TYPE fastmamba_cache_bytes_max gauge\n");
            out.push_str(&format!("fastmamba_cache_bytes_max {}\n", cache.max_bytes()));
            out.push_str("# TYPE fastmamba_cache_entries gauge\n");
            out.push_str(&format!("fastmamba_cache_entries {}\n", s.entries));
            out.push_str("# TYPE fastmamba_cache_lookup_hits_total counter\n");
            out.push_str(&format!("fastmamba_cache_lookup_hits_total {}\n", s.hits));
            out.push_str("# TYPE fastmamba_cache_lookup_misses_total counter\n");
            out.push_str(&format!("fastmamba_cache_lookup_misses_total {}\n", s.misses));
            out.push_str("# TYPE fastmamba_cache_insertions_total counter\n");
            out.push_str(&format!("fastmamba_cache_insertions_total {}\n", s.insertions));
            out.push_str("# TYPE fastmamba_cache_evictions_total counter\n");
            out.push_str(&format!("fastmamba_cache_evictions_total {}\n", s.evictions));
        }
        out
    }

    /// One-line live status for the periodic stdout log
    /// (`serve --log-every-s`).
    pub fn one_line(&self) -> String {
        let ttft = self.hist_aggregate(HistKind::Ttft);
        let tpot = self.hist_aggregate(HistKind::Tpot);
        let cache = match self.cache.lock().unwrap().as_ref() {
            Some(c) => format!(
                " cache={:.1}MiB/{}ent",
                c.bytes_resident() as f64 / (1 << 20) as f64,
                c.entries()
            ),
            None => String::new(),
        };
        format!(
            "req={} gen_toks={} q={} active={} ttft_p50={:.1}ms tpot_p50={:.2}ms \
             cancelled={} deadline={}{}",
            self.total(Counter::RequestsCompleted),
            self.total(Counter::TokensGenerated),
            self.gauge_total(Gauge::QueueDepth),
            self.gauge_total(Gauge::ActiveSlots),
            ttft.quantile(0.5) * 1e3,
            tpot.quantile(0.5) * 1e3,
            self.total(Counter::CancelledRequests),
            self.total(Counter::DeadlineExpired),
            cache,
        )
    }
}

fn render_histogram(out: &mut String, full: &str, label_prefix: &str, h: &Histogram) {
    for (le, cum) in h.cumulative_buckets(BUCKETS_PER_OCTAVE) {
        out.push_str(&format!(
            "{full}_bucket{{{label_prefix}le=\"{le:.6e}\"}} {cum}\n"
        ));
    }
    out.push_str(&format!(
        "{full}_bucket{{{label_prefix}le=\"+Inf\"}} {}\n",
        h.count()
    ));
    let label_block = label_prefix.trim_end_matches(',');
    if label_block.is_empty() {
        out.push_str(&format!("{full}_sum {:.9}\n", h.sum()));
        out.push_str(&format!("{full}_count {}\n", h.count()));
    } else {
        out.push_str(&format!("{full}_sum{{{label_block}}} {:.9}\n", h.sum()));
        out.push_str(&format!("{full}_count{{{label_block}}} {}\n", h.count()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_counter_and_gauge_cells_are_shared_across_threads() {
        let tel = Arc::new(Telemetry::new());
        let mut joins = Vec::new();
        for _ in 0..4 {
            let t = Arc::clone(&tel);
            joins.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    t.add(Counter::TokensGenerated, 1);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(tel.get(Counter::TokensGenerated), 4000);

        tel.set_gauge(Gauge::QueueDepth, 7);
        tel.set_gauge(Gauge::QueueDepth, 2);
        assert_eq!(tel.gauge(Gauge::QueueDepth), 2, "gauge is instantaneous");
        assert_eq!(tel.gauge_peak(Gauge::QueueDepth), 7, "peak is sticky");
    }

    #[test]
    fn obs_hub_aggregates_counters_and_histograms_across_workers() {
        let hub = TelemetryHub::new();
        let w0 = hub.register("0");
        let w1 = hub.register("1");
        w0.add(Counter::RequestsCompleted, 3);
        w1.add(Counter::RequestsCompleted, 5);
        for v in [0.010, 0.020, 0.030] {
            w0.observe(HistKind::Ttft, v);
        }
        for v in [0.040, 0.050] {
            w1.observe(HistKind::Ttft, v);
        }
        assert_eq!(hub.total(Counter::RequestsCompleted), 8);
        let agg = hub.hist_aggregate(HistKind::Ttft);
        assert_eq!(agg.count(), 5);
        assert_eq!(agg.min(), 0.010);
        assert_eq!(agg.max(), 0.050);
    }

    #[test]
    fn obs_prometheus_exposition_has_per_worker_and_aggregate_series() {
        let hub = TelemetryHub::new();
        let w0 = hub.register("0");
        let w1 = hub.register("1");
        w0.add(Counter::TokensGenerated, 10);
        w1.add(Counter::TokensGenerated, 32);
        w0.observe(HistKind::Tpot, 0.002);
        let text = hub.render_prometheus();
        assert!(text.contains("# TYPE fastmamba_tokens_generated_total counter"));
        assert!(text.contains("fastmamba_tokens_generated_total{worker=\"0\"} 10"));
        assert!(text.contains("fastmamba_tokens_generated_total{worker=\"1\"} 32"));
        assert!(text.contains("fastmamba_tokens_generated_total 42"));
        assert!(text.contains("fastmamba_tpot_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("fastmamba_tpot_seconds_count 1"));
        assert!(text.contains("# TYPE fastmamba_queue_depth gauge"));
    }
}
