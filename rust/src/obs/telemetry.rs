//! Lock-light live telemetry registry.
//!
//! A [`Telemetry`] is an `Arc`-shared bundle of atomic counters, gauges,
//! and mutex-guarded [`Histogram`]s that an engine updates *while it
//! serves* — the write path for counters is one `fetch_add(Relaxed)`, so
//! the serving hot loop pays nanoseconds, not locks.  Each pool worker
//! registers its own `Telemetry` with the shared [`TelemetryHub`]; the hub
//! renders the Prometheus text exposition (per-worker series plus an
//! exact bucket-wise aggregate) and the periodic one-line stdout log, and
//! reads state-cache occupancy gauges straight from the attached
//! [`StateCache`] at scrape time.
//!
//! `coordinator::Metrics` writes through to an attached `Telemetry` on
//! every mutation, so the live view and the end-of-run snapshot are two
//! reads of the same cells — `Metrics::from_telemetry` reconstructs a full
//! snapshot from the atomics alone, and a scrape taken mid-run is always a
//! prefix (counter-monotone) of the final numbers.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::flight::FlightRecorder;
use super::histogram::{Histogram, BUCKETS_PER_OCTAVE};
use super::slo::{SloMonitor, StallWatchdog};
use crate::statecache::StateCache;
use crate::util::json::{self, Json};

/// Monotone counters an engine maintains (mirrors the `u64` fields of
/// `coordinator::Metrics`, plus busy time in integer microseconds so it
/// can live in an atomic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    RequestsCompleted,
    TokensGenerated,
    PromptTokens,
    PrefillChunks,
    DecodeSteps,
    DecodePaddedSlots,
    DecodeBatchSlots,
    DraftTokens,
    DraftAccepted,
    SpecRounds,
    VerifyCalls,
    Rollbacks,
    ResyncSteps,
    DrafterReseeds,
    CacheHits,
    CacheMisses,
    CacheTokensSaved,
    CancelledRequests,
    DeadlineExpired,
    /// requests evicted from a state slot by a higher-priority arrival
    /// (each later resumes and finishes under its real reason)
    PreemptedRequests,
    /// requests shed by admission control (bounded queue full at
    /// submission; terminal reason `Overloaded`)
    RequestsShed,
    /// requests dropped undone at the dispatcher (cancel/deadline/worker
    /// death resolved from the backlog — no token was ever produced, so
    /// they stay out of the latency histograms)
    RequestsDropped,
    /// pending-queue re-orderings where priority aging promoted at least
    /// one request past a higher-static-priority one
    AgingReorders,
    BusyMicros,
}

pub const N_COUNTERS: usize = 24;

impl Counter {
    pub const ALL: [Counter; N_COUNTERS] = [
        Counter::RequestsCompleted,
        Counter::TokensGenerated,
        Counter::PromptTokens,
        Counter::PrefillChunks,
        Counter::DecodeSteps,
        Counter::DecodePaddedSlots,
        Counter::DecodeBatchSlots,
        Counter::DraftTokens,
        Counter::DraftAccepted,
        Counter::SpecRounds,
        Counter::VerifyCalls,
        Counter::Rollbacks,
        Counter::ResyncSteps,
        Counter::DrafterReseeds,
        Counter::CacheHits,
        Counter::CacheMisses,
        Counter::CacheTokensSaved,
        Counter::CancelledRequests,
        Counter::DeadlineExpired,
        Counter::PreemptedRequests,
        Counter::RequestsShed,
        Counter::RequestsDropped,
        Counter::AgingReorders,
        Counter::BusyMicros,
    ];

    pub fn index(self) -> usize {
        Self::ALL.iter().position(|c| *c == self).unwrap()
    }

    /// Prometheus series base name (rendered as `fastmamba_<name>_total`).
    pub fn name(self) -> &'static str {
        match self {
            Counter::RequestsCompleted => "requests_completed",
            Counter::TokensGenerated => "tokens_generated",
            Counter::PromptTokens => "prompt_tokens",
            Counter::PrefillChunks => "prefill_chunks",
            Counter::DecodeSteps => "decode_steps",
            Counter::DecodePaddedSlots => "decode_padded_slots",
            Counter::DecodeBatchSlots => "decode_batch_slots",
            Counter::DraftTokens => "draft_tokens",
            Counter::DraftAccepted => "draft_accepted",
            Counter::SpecRounds => "spec_rounds",
            Counter::VerifyCalls => "verify_calls",
            Counter::Rollbacks => "rollbacks",
            Counter::ResyncSteps => "resync_steps",
            Counter::DrafterReseeds => "drafter_reseeds",
            Counter::CacheHits => "cache_hits",
            Counter::CacheMisses => "cache_misses",
            Counter::CacheTokensSaved => "cache_tokens_saved",
            Counter::CancelledRequests => "cancelled_requests",
            Counter::DeadlineExpired => "deadline_expired",
            Counter::PreemptedRequests => "preempted_requests",
            Counter::RequestsShed => "requests_shed",
            Counter::RequestsDropped => "requests_dropped",
            Counter::AgingReorders => "aging_reorders",
            Counter::BusyMicros => "busy_microseconds",
        }
    }
}

/// Instantaneous values (each also keeps its observed peak).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    /// pending + active requests the engine currently holds
    QueueDepth,
    /// state slots currently bound to in-flight requests
    ActiveSlots,
}

pub const N_GAUGES: usize = 2;

impl Gauge {
    pub const ALL: [Gauge; N_GAUGES] = [Gauge::QueueDepth, Gauge::ActiveSlots];

    pub fn index(self) -> usize {
        Self::ALL.iter().position(|g| *g == self).unwrap()
    }

    pub fn name(self) -> &'static str {
        match self {
            Gauge::QueueDepth => "queue_depth",
            Gauge::ActiveSlots => "active_slots",
        }
    }
}

/// The latency/ratio distributions an engine records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HistKind {
    Ttft,
    Latency,
    Tpot,
    Acceptance,
    PrefillCall,
    DecodeCall,
}

pub const N_HISTS: usize = 6;

impl HistKind {
    pub const ALL: [HistKind; N_HISTS] = [
        HistKind::Ttft,
        HistKind::Latency,
        HistKind::Tpot,
        HistKind::Acceptance,
        HistKind::PrefillCall,
        HistKind::DecodeCall,
    ];

    pub fn index(self) -> usize {
        Self::ALL.iter().position(|h| *h == self).unwrap()
    }

    pub fn name(self) -> &'static str {
        match self {
            HistKind::Ttft => "ttft_seconds",
            HistKind::Latency => "request_latency_seconds",
            HistKind::Tpot => "tpot_seconds",
            HistKind::Acceptance => "draft_acceptance_ratio",
            HistKind::PrefillCall => "prefill_call_seconds",
            HistKind::DecodeCall => "decode_call_seconds",
        }
    }
}

/// One engine's live cells.  Counter/gauge writes are relaxed atomics;
/// histogram observes take a short uncontended mutex (only the owning
/// engine writes, scrapes clone).
#[derive(Debug)]
pub struct Telemetry {
    counters: [AtomicU64; N_COUNTERS],
    gauges: [AtomicU64; N_GAUGES],
    gauge_peaks: [AtomicU64; N_GAUGES],
    hists: [Mutex<Histogram>; N_HISTS],
    /// live status slot: the owning engine (or dispatcher) republishes a
    /// small JSON object each step; `/statusz` reads the latest
    status: Mutex<Option<Json>>,
}

impl Default for Telemetry {
    fn default() -> Self {
        Self::new()
    }
}

impl Telemetry {
    pub fn new() -> Self {
        Self {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            gauges: std::array::from_fn(|_| AtomicU64::new(0)),
            gauge_peaks: std::array::from_fn(|_| AtomicU64::new(0)),
            hists: std::array::from_fn(|_| Mutex::new(Histogram::new())),
            status: Mutex::new(None),
        }
    }

    /// Publish this handle's live status object (overwrites the previous).
    pub fn set_status(&self, status: Json) {
        *self.status.lock().unwrap() = Some(status);
    }

    /// Latest published status object, if any.
    pub fn status(&self) -> Option<Json> {
        self.status.lock().unwrap().clone()
    }

    /// Heap bytes held by this handle's histogram bucket arrays.
    pub fn hist_heap_bytes(&self) -> usize {
        self.hists.iter().map(|h| h.lock().unwrap().heap_bytes()).sum()
    }

    #[inline]
    pub fn add(&self, c: Counter, n: u64) {
        self.counters[c.index()].fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self, c: Counter) -> u64 {
        self.counters[c.index()].load(Ordering::Relaxed)
    }

    pub fn set_gauge(&self, g: Gauge, v: u64) {
        self.gauges[g.index()].store(v, Ordering::Relaxed);
        self.gauge_peaks[g.index()].fetch_max(v, Ordering::Relaxed);
    }

    pub fn gauge(&self, g: Gauge) -> u64 {
        self.gauges[g.index()].load(Ordering::Relaxed)
    }

    pub fn gauge_peak(&self, g: Gauge) -> u64 {
        self.gauge_peaks[g.index()].load(Ordering::Relaxed)
    }

    pub fn observe(&self, h: HistKind, v: f64) {
        self.hists[h.index()].lock().unwrap().observe(v);
    }

    /// Clone the named histogram (a scrape-time snapshot).
    pub fn hist(&self, h: HistKind) -> Histogram {
        self.hists[h.index()].lock().unwrap().clone()
    }
}

/// Transport-level statistics for one remote worker connection: what the
/// dispatcher-side proxy sent/received, how often the link dropped (and
/// how many in-flight requests each drop re-queued), and the round-trip
/// time distribution of the protocol's ping/pong health probes.
///
/// Counters are relaxed atomics written by the proxy's reader/writer
/// threads; the RTT histogram takes a short uncontended mutex, exactly
/// like [`Telemetry`]'s histograms.  `/metrics` renders these as
/// `fastmamba_remote_*` series labeled by address, and `/statusz` carries
/// one `remote_workers` row per registered transport.
#[derive(Debug)]
pub struct RemoteTransport {
    addr: String,
    bytes_out: AtomicU64,
    bytes_in: AtomicU64,
    frames_out: AtomicU64,
    frames_in: AtomicU64,
    disconnects: AtomicU64,
    requeued: AtomicU64,
    rtt: Mutex<Histogram>,
}

impl RemoteTransport {
    pub fn new(addr: &str) -> Self {
        Self {
            addr: addr.to_string(),
            bytes_out: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            frames_out: AtomicU64::new(0),
            frames_in: AtomicU64::new(0),
            disconnects: AtomicU64::new(0),
            requeued: AtomicU64::new(0),
            rtt: Mutex::new(Histogram::new()),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One frame written to the socket (`bytes` = framed size).
    pub fn note_out(&self, bytes: usize) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// One frame read off the socket (`bytes` = framed size).
    pub fn note_in(&self, bytes: usize) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// The link dropped while `requeued` requests were in flight (each is
    /// re-routed to a surviving worker by the dispatcher).
    pub fn note_disconnect(&self, requeued: u64) {
        self.disconnects.fetch_add(1, Ordering::Relaxed);
        self.requeued.fetch_add(requeued, Ordering::Relaxed);
    }

    /// One ping/pong round trip, in seconds.
    pub fn observe_rtt(&self, seconds: f64) {
        self.rtt.lock().unwrap().observe(seconds);
    }

    pub fn bytes_out(&self) -> u64 {
        self.bytes_out.load(Ordering::Relaxed)
    }
    pub fn bytes_in(&self) -> u64 {
        self.bytes_in.load(Ordering::Relaxed)
    }
    pub fn frames_out(&self) -> u64 {
        self.frames_out.load(Ordering::Relaxed)
    }
    pub fn frames_in(&self) -> u64 {
        self.frames_in.load(Ordering::Relaxed)
    }
    pub fn disconnects(&self) -> u64 {
        self.disconnects.load(Ordering::Relaxed)
    }
    pub fn requeued(&self) -> u64 {
        self.requeued.load(Ordering::Relaxed)
    }

    /// Scrape-time snapshot of the RTT distribution.
    pub fn rtt(&self) -> Histogram {
        self.rtt.lock().unwrap().clone()
    }
}

/// Shared registry over all per-worker [`Telemetry`] handles, plus the
/// optional [`StateCache`] whose occupancy it exposes as gauges, the
/// always-on [`FlightRecorder`], per-connection [`RemoteTransport`]
/// stats, and the optional [`SloMonitor`] / [`StallWatchdog`] /
/// resolved-config attachments behind the live introspection endpoints
/// (`/statusz`, `/readyz`, `/debug/*`).
#[derive(Debug)]
pub struct TelemetryHub {
    workers: Mutex<Vec<(String, Arc<Telemetry>)>>,
    cache: Mutex<Option<Arc<StateCache>>>,
    flight: Arc<FlightRecorder>,
    remotes: Mutex<Vec<Arc<RemoteTransport>>>,
    slo: Mutex<Option<Arc<SloMonitor>>>,
    watchdog: Mutex<Option<Arc<StallWatchdog>>>,
    config: Mutex<Option<Json>>,
    started: Instant,
}

impl Default for TelemetryHub {
    fn default() -> Self {
        Self::new()
    }
}

impl TelemetryHub {
    pub fn new() -> Self {
        Self {
            workers: Mutex::new(Vec::new()),
            cache: Mutex::new(None),
            flight: Arc::new(FlightRecorder::new()),
            remotes: Mutex::new(Vec::new()),
            slo: Mutex::new(None),
            watchdog: Mutex::new(None),
            config: Mutex::new(None),
            started: Instant::now(),
        }
    }

    /// Register a new labeled telemetry handle (one per pool worker, plus
    /// `"dispatcher"` for backlog-resolved requests).
    pub fn register(&self, label: &str) -> Arc<Telemetry> {
        let tel = Arc::new(Telemetry::new());
        self.workers
            .lock()
            .unwrap()
            .push((label.to_string(), Arc::clone(&tel)));
        tel
    }

    pub fn attach_cache(&self, cache: Arc<StateCache>) {
        *self.cache.lock().unwrap() = Some(cache);
    }

    /// Register transport stats for one remote worker connection (one per
    /// `--remote-worker` address; the proxy writes, scrapes read).
    pub fn register_remote(&self, addr: &str) -> Arc<RemoteTransport> {
        let t = Arc::new(RemoteTransport::new(addr));
        self.remotes.lock().unwrap().push(Arc::clone(&t));
        t
    }

    /// Every registered remote transport, in registration order.
    pub fn remotes(&self) -> Vec<Arc<RemoteTransport>> {
        self.remotes.lock().unwrap().clone()
    }

    /// The hub's flight recorder (always present; engines record via a
    /// [`super::flight::FlightCtx`] built from this).
    pub fn flight(&self) -> &Arc<FlightRecorder> {
        &self.flight
    }

    pub fn attach_slo(&self, slo: Arc<SloMonitor>) {
        *self.slo.lock().unwrap() = Some(slo);
    }

    pub fn slo(&self) -> Option<Arc<SloMonitor>> {
        self.slo.lock().unwrap().clone()
    }

    pub fn attach_watchdog(&self, watchdog: Arc<StallWatchdog>) {
        *self.watchdog.lock().unwrap() = Some(watchdog);
    }

    pub fn watchdog(&self) -> Option<Arc<StallWatchdog>> {
        self.watchdog.lock().unwrap().clone()
    }

    /// Attach the resolved serving configuration dump (`/debug/config`).
    pub fn attach_config(&self, config: Json) {
        *self.config.lock().unwrap() = Some(config);
    }

    pub fn config_json(&self) -> Json {
        self.config
            .lock()
            .unwrap()
            .clone()
            .unwrap_or_else(|| json::obj(vec![("note", json::s("no config attached"))]))
    }

    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn handles(&self) -> Vec<(String, Arc<Telemetry>)> {
        self.workers.lock().unwrap().clone()
    }

    /// Whether a handle is the pool dispatcher's (by label, or by the
    /// `role` field of its published status).
    fn is_dispatcher(label: &str, status: Option<&Json>) -> bool {
        label == "dispatcher"
            || status.and_then(|s| s.get("role")).and_then(Json::as_str) == Some("dispatcher")
    }

    /// Pool liveness as the dispatcher last reported it: `Some(false)`
    /// when every worker is dead, `None` when no dispatcher status exists
    /// (single-engine topologies: process liveness is engine liveness).
    pub fn liveness(&self) -> Option<bool> {
        for (label, t) in self.handles() {
            let status = t.status();
            if Self::is_dispatcher(&label, status.as_ref()) {
                if let Some(alive) = status
                    .as_ref()
                    .and_then(|s| s.get("workers_alive"))
                    .and_then(Json::as_f64)
                {
                    return Some(alive > 0.0);
                }
            }
        }
        None
    }

    /// Readiness (`/readyz`): at least one live worker AND the ingress
    /// queue below its shed threshold — distinct from liveness, which only
    /// says the process is up.  Returns the verdict plus a JSON body
    /// naming the reason.
    pub fn readiness(&self) -> (bool, Json) {
        let handles = self.handles();
        let mut dispatcher = None;
        let mut n_workers = 0usize;
        let mut worker_overfull = false;
        for (label, t) in &handles {
            let status = t.status();
            if Self::is_dispatcher(label, status.as_ref()) {
                if status.is_some() {
                    dispatcher = status;
                }
                continue;
            }
            n_workers += 1;
            if let Some(s) = &status {
                let pending = s.get("pending").and_then(Json::as_f64).unwrap_or(0.0);
                let max_queue = s.get("max_queue").and_then(Json::as_f64).unwrap_or(0.0);
                if max_queue > 0.0 && pending >= max_queue {
                    worker_overfull = true;
                }
            }
        }
        let (ready, reason) = if let Some(d) = &dispatcher {
            let alive = d.get("workers_alive").and_then(Json::as_f64).unwrap_or(0.0);
            let backlog = d.get("backlog").and_then(Json::as_f64).unwrap_or(0.0);
            let max_queue = d.get("max_queue").and_then(Json::as_f64).unwrap_or(0.0);
            if alive <= 0.0 {
                (false, "no live workers".to_string())
            } else if max_queue > 0.0 && backlog >= max_queue {
                (false, format!("backlog {backlog} at shed threshold {max_queue}"))
            } else {
                (true, "ok".to_string())
            }
        } else if n_workers == 0 {
            (false, "no workers registered".to_string())
        } else if worker_overfull {
            (false, "queue at shed threshold".to_string())
        } else {
            (true, "ok".to_string())
        };
        let body = json::obj(vec![
            ("ready", Json::Bool(ready)),
            ("reason", json::s(&reason)),
        ]);
        (ready, body)
    }

    /// The live request/worker table (`/statusz`): every status row each
    /// engine published on its latest step, flattened into one request
    /// table (worker label attached per row), plus per-worker gauges, the
    /// dispatcher's view, and state-cache shard occupancy.
    pub fn statusz_json(&self) -> Json {
        let handles = self.handles();
        let mut workers = Vec::new();
        let mut requests = Vec::new();
        let mut dispatcher = None;
        for (label, t) in &handles {
            let status = t.status();
            if Self::is_dispatcher(label, status.as_ref()) {
                if status.is_some() {
                    dispatcher = status;
                }
                continue;
            }
            let (mut pending, mut active) = (0.0, 0.0);
            if let Some(s) = &status {
                pending = s.get("pending").and_then(Json::as_f64).unwrap_or(0.0);
                active = s.get("active").and_then(Json::as_f64).unwrap_or(0.0);
                if let Some(reqs) = s.get("requests").and_then(Json::as_arr) {
                    for r in reqs {
                        if let Json::Obj(fields) = r {
                            let mut row = fields.clone();
                            row.push(("worker".to_string(), json::s(label)));
                            requests.push(Json::Obj(row));
                        }
                    }
                }
            }
            workers.push(json::obj(vec![
                ("worker", json::s(label)),
                ("queue_depth", json::num(t.gauge(Gauge::QueueDepth) as f64)),
                ("active_slots", json::num(t.gauge(Gauge::ActiveSlots) as f64)),
                ("pending", json::num(pending)),
                ("active", json::num(active)),
                (
                    "requests_completed",
                    json::num(t.get(Counter::RequestsCompleted) as f64),
                ),
                (
                    "tokens_generated",
                    json::num(t.get(Counter::TokensGenerated) as f64),
                ),
                ("busy_us", json::num(t.get(Counter::BusyMicros) as f64)),
            ]));
        }
        let cache = self.cache.lock().unwrap().as_ref().map(|c| {
            let s = c.stats();
            json::obj(vec![
                ("bytes_resident", json::num(s.bytes_resident as f64)),
                ("bytes_max", json::num(c.max_bytes() as f64)),
                ("entries", json::num(s.entries as f64)),
                (
                    "shards",
                    Json::Arr(
                        c.shard_occupancy()
                            .iter()
                            .map(|&(entries, bytes)| {
                                json::obj(vec![
                                    ("entries", json::num(entries as f64)),
                                    ("bytes", json::num(bytes as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        });
        let remote_workers: Vec<Json> = self
            .remotes()
            .iter()
            .map(|t| {
                json::obj(vec![
                    ("addr", json::s(t.addr())),
                    ("bytes_out", json::num(t.bytes_out() as f64)),
                    ("bytes_in", json::num(t.bytes_in() as f64)),
                    ("frames_out", json::num(t.frames_out() as f64)),
                    ("frames_in", json::num(t.frames_in() as f64)),
                    ("disconnects", json::num(t.disconnects() as f64)),
                    ("requeued", json::num(t.requeued() as f64)),
                    ("rpc_p50_ms", json::num(t.rtt().quantile(0.5) * 1e3)),
                ])
            })
            .collect();
        json::obj(vec![
            ("uptime_s", json::num(self.uptime_s())),
            ("workers", Json::Arr(workers)),
            ("requests", Json::Arr(requests)),
            ("dispatcher", dispatcher.unwrap_or(Json::Null)),
            ("remote_workers", Json::Arr(remote_workers)),
            ("cache", cache.unwrap_or(Json::Null)),
        ])
    }

    /// Sum of one counter across every registered handle.
    pub fn total(&self, c: Counter) -> u64 {
        self.handles().iter().map(|(_, t)| t.get(c)).sum()
    }

    /// Sum of one gauge's current value across every registered handle.
    pub fn gauge_total(&self, g: Gauge) -> u64 {
        self.handles().iter().map(|(_, t)| t.gauge(g)).sum()
    }

    /// Exact bucket-wise aggregate of one histogram across workers — the
    /// merged quantiles equal the quantiles of the pooled sample stream.
    pub fn hist_aggregate(&self, h: HistKind) -> Histogram {
        let mut agg = Histogram::new();
        for (_, t) in self.handles() {
            agg.merge(&t.hist(h));
        }
        agg
    }

    /// Prometheus text exposition (format version 0.0.4): every counter
    /// and gauge per worker and aggregated, histogram `_bucket`/`_sum`/
    /// `_count` series per worker and aggregated, and the state-cache
    /// occupancy read live from the attached cache.
    pub fn render_prometheus(&self) -> String {
        let handles = self.handles();
        let mut out = String::new();
        for c in Counter::ALL {
            let full = format!("fastmamba_{}_total", c.name());
            out.push_str(&format!("# TYPE {full} counter\n"));
            for (label, t) in &handles {
                out.push_str(&format!("{full}{{worker=\"{label}\"}} {}\n", t.get(c)));
            }
            out.push_str(&format!("{full} {}\n", self.total(c)));
        }
        for g in Gauge::ALL {
            let full = format!("fastmamba_{}", g.name());
            out.push_str(&format!("# TYPE {full} gauge\n"));
            for (label, t) in &handles {
                out.push_str(&format!("{full}{{worker=\"{label}\"}} {}\n", t.gauge(g)));
            }
            out.push_str(&format!("{full} {}\n", self.gauge_total(g)));
            out.push_str(&format!("# TYPE {full}_peak gauge\n"));
            for (label, t) in &handles {
                out.push_str(&format!(
                    "{full}_peak{{worker=\"{label}\"}} {}\n",
                    t.gauge_peak(g)
                ));
            }
        }
        for h in HistKind::ALL {
            let full = format!("fastmamba_{}", h.name());
            out.push_str(&format!("# TYPE {full} histogram\n"));
            for (label, t) in &handles {
                render_histogram(&mut out, &full, &format!("worker=\"{label}\","), &t.hist(h));
            }
            render_histogram(&mut out, &full, "", &self.hist_aggregate(h));
        }
        if let Some(cache) = self.cache.lock().unwrap().as_ref() {
            let s = cache.stats();
            out.push_str("# TYPE fastmamba_cache_bytes_resident gauge\n");
            out.push_str(&format!("fastmamba_cache_bytes_resident {}\n", s.bytes_resident));
            out.push_str("# TYPE fastmamba_cache_bytes_max gauge\n");
            out.push_str(&format!("fastmamba_cache_bytes_max {}\n", cache.max_bytes()));
            out.push_str("# TYPE fastmamba_cache_entries gauge\n");
            out.push_str(&format!("fastmamba_cache_entries {}\n", s.entries));
            out.push_str("# TYPE fastmamba_cache_lookup_hits_total counter\n");
            out.push_str(&format!("fastmamba_cache_lookup_hits_total {}\n", s.hits));
            out.push_str("# TYPE fastmamba_cache_lookup_misses_total counter\n");
            out.push_str(&format!("fastmamba_cache_lookup_misses_total {}\n", s.misses));
            out.push_str("# TYPE fastmamba_cache_insertions_total counter\n");
            out.push_str(&format!("fastmamba_cache_insertions_total {}\n", s.insertions));
            out.push_str("# TYPE fastmamba_cache_evictions_total counter\n");
            out.push_str(&format!("fastmamba_cache_evictions_total {}\n", s.evictions));
        }
        // SLO burn rates: evaluating inside the scrape makes the scrape
        // interval the violation window, the usual Prometheus arrangement.
        // Burn gauges render via `{}` (shortest round-trip f64), so a
        // scraped value parses back bit-identical to the live f64.
        if let Some(slo) = self.slo() {
            let reports = slo.evaluate(self);
            if !reports.is_empty() {
                out.push_str("# TYPE fastmamba_slo_burn_rate gauge\n");
                for r in &reports {
                    out.push_str(&format!(
                        "fastmamba_slo_burn_rate{{objective=\"{}\"}} {}\n",
                        r.name, r.burn_rate
                    ));
                }
                out.push_str("# TYPE fastmamba_slo_window_burn_rate gauge\n");
                for r in &reports {
                    out.push_str(&format!(
                        "fastmamba_slo_window_burn_rate{{objective=\"{}\"}} {}\n",
                        r.name, r.window_burn
                    ));
                }
                out.push_str("# TYPE fastmamba_slo_violations_total counter\n");
                for r in &reports {
                    out.push_str(&format!(
                        "fastmamba_slo_violations_total{{objective=\"{}\"}} {}\n",
                        r.name, r.violations
                    ));
                }
            }
        }
        if let Some(wd) = self.watchdog() {
            out.push_str("# TYPE fastmamba_stalls_detected_total counter\n");
            out.push_str(&format!(
                "fastmamba_stalls_detected_total {}\n",
                wd.stalls_detected()
            ));
        }
        // per-remote-worker transport stats
        let remotes = self.remotes();
        if !remotes.is_empty() {
            out.push_str("# TYPE fastmamba_remote_bytes_total counter\n");
            for t in &remotes {
                let a = t.addr();
                out.push_str(&format!(
                    "fastmamba_remote_bytes_total{{addr=\"{a}\",dir=\"out\"}} {}\n",
                    t.bytes_out()
                ));
                out.push_str(&format!(
                    "fastmamba_remote_bytes_total{{addr=\"{a}\",dir=\"in\"}} {}\n",
                    t.bytes_in()
                ));
            }
            out.push_str("# TYPE fastmamba_remote_frames_total counter\n");
            for t in &remotes {
                let a = t.addr();
                out.push_str(&format!(
                    "fastmamba_remote_frames_total{{addr=\"{a}\",dir=\"out\"}} {}\n",
                    t.frames_out()
                ));
                out.push_str(&format!(
                    "fastmamba_remote_frames_total{{addr=\"{a}\",dir=\"in\"}} {}\n",
                    t.frames_in()
                ));
            }
            out.push_str("# TYPE fastmamba_remote_disconnects_total counter\n");
            for t in &remotes {
                out.push_str(&format!(
                    "fastmamba_remote_disconnects_total{{addr=\"{}\"}} {}\n",
                    t.addr(),
                    t.disconnects()
                ));
            }
            out.push_str("# TYPE fastmamba_remote_requeued_requests_total counter\n");
            for t in &remotes {
                out.push_str(&format!(
                    "fastmamba_remote_requeued_requests_total{{addr=\"{}\"}} {}\n",
                    t.addr(),
                    t.requeued()
                ));
            }
            out.push_str("# TYPE fastmamba_remote_rpc_seconds histogram\n");
            for t in &remotes {
                render_histogram(
                    &mut out,
                    "fastmamba_remote_rpc_seconds",
                    &format!("addr=\"{}\",", t.addr()),
                    &t.rtt(),
                );
            }
        }
        out.push_str("# TYPE fastmamba_flight_events_recorded_total counter\n");
        out.push_str(&format!(
            "fastmamba_flight_events_recorded_total {}\n",
            self.flight.recorded()
        ));
        // process self-metrics
        out.push_str("# TYPE fastmamba_process_uptime_seconds gauge\n");
        out.push_str(&format!(
            "fastmamba_process_uptime_seconds {:.3}\n",
            self.uptime_s()
        ));
        if let Some(rss) = rss_bytes() {
            out.push_str("# TYPE fastmamba_process_resident_bytes gauge\n");
            out.push_str(&format!("fastmamba_process_resident_bytes {rss}\n"));
        }
        let heap: usize = handles.iter().map(|(_, t)| t.hist_heap_bytes()).sum();
        out.push_str("# TYPE fastmamba_telemetry_heap_bytes gauge\n");
        out.push_str(&format!("fastmamba_telemetry_heap_bytes {heap}\n"));
        out
    }

    /// One-line live status for the periodic stdout log
    /// (`serve --log-every-s`).
    pub fn one_line(&self) -> String {
        let ttft = self.hist_aggregate(HistKind::Ttft);
        let tpot = self.hist_aggregate(HistKind::Tpot);
        let cache = match self.cache.lock().unwrap().as_ref() {
            Some(c) => format!(
                " cache={:.1}MiB/{}ent",
                c.bytes_resident() as f64 / (1 << 20) as f64,
                c.entries()
            ),
            None => String::new(),
        };
        let slo = match self.slo() {
            Some(s) => {
                let reports = s.evaluate(self);
                if reports.is_empty() {
                    String::new()
                } else {
                    let burns: Vec<String> = reports
                        .iter()
                        .map(|r| format!("{}={:.2}x", r.name, r.burn_rate))
                        .collect();
                    let viols: u64 = reports.iter().map(|r| r.violations).sum();
                    format!(" slo[{} viol={viols}]", burns.join(" "))
                }
            }
            None => String::new(),
        };
        format!(
            "req={} gen_toks={} q={} active={} ttft_p50={:.1}ms tpot_p50={:.2}ms \
             cancelled={} deadline={}{}{slo}",
            self.total(Counter::RequestsCompleted),
            self.total(Counter::TokensGenerated),
            self.gauge_total(Gauge::QueueDepth),
            self.gauge_total(Gauge::ActiveSlots),
            ttft.quantile(0.5) * 1e3,
            tpot.quantile(0.5) * 1e3,
            self.total(Counter::CancelledRequests),
            self.total(Counter::DeadlineExpired),
            cache,
        )
    }
}

/// Resident set size from `/proc/self/statm` (field 2, in pages; the
/// kernel's page size here is 4096 on every target this crate supports).
/// Off Linux there is no procfs — the gauge is simply not rendered.
#[cfg(target_os = "linux")]
fn rss_bytes() -> Option<u64> {
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let pages: u64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    Some(pages * 4096)
}

#[cfg(not(target_os = "linux"))]
fn rss_bytes() -> Option<u64> {
    None
}

fn render_histogram(out: &mut String, full: &str, label_prefix: &str, h: &Histogram) {
    for (le, cum) in h.cumulative_buckets(BUCKETS_PER_OCTAVE) {
        out.push_str(&format!(
            "{full}_bucket{{{label_prefix}le=\"{le:.6e}\"}} {cum}\n"
        ));
    }
    out.push_str(&format!(
        "{full}_bucket{{{label_prefix}le=\"+Inf\"}} {}\n",
        h.count()
    ));
    let label_block = label_prefix.trim_end_matches(',');
    if label_block.is_empty() {
        out.push_str(&format!("{full}_sum {:.9}\n", h.sum()));
        out.push_str(&format!("{full}_count {}\n", h.count()));
    } else {
        out.push_str(&format!("{full}_sum{{{label_block}}} {:.9}\n", h.sum()));
        out.push_str(&format!("{full}_count{{{label_block}}} {}\n", h.count()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_counter_and_gauge_cells_are_shared_across_threads() {
        let tel = Arc::new(Telemetry::new());
        let mut joins = Vec::new();
        for _ in 0..4 {
            let t = Arc::clone(&tel);
            joins.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    t.add(Counter::TokensGenerated, 1);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(tel.get(Counter::TokensGenerated), 4000);

        tel.set_gauge(Gauge::QueueDepth, 7);
        tel.set_gauge(Gauge::QueueDepth, 2);
        assert_eq!(tel.gauge(Gauge::QueueDepth), 2, "gauge is instantaneous");
        assert_eq!(tel.gauge_peak(Gauge::QueueDepth), 7, "peak is sticky");
    }

    #[test]
    fn obs_hub_aggregates_counters_and_histograms_across_workers() {
        let hub = TelemetryHub::new();
        let w0 = hub.register("0");
        let w1 = hub.register("1");
        w0.add(Counter::RequestsCompleted, 3);
        w1.add(Counter::RequestsCompleted, 5);
        for v in [0.010, 0.020, 0.030] {
            w0.observe(HistKind::Ttft, v);
        }
        for v in [0.040, 0.050] {
            w1.observe(HistKind::Ttft, v);
        }
        assert_eq!(hub.total(Counter::RequestsCompleted), 8);
        let agg = hub.hist_aggregate(HistKind::Ttft);
        assert_eq!(agg.count(), 5);
        assert_eq!(agg.min(), 0.010);
        assert_eq!(agg.max(), 0.050);
    }

    #[test]
    fn statusz_reports_live_requests_and_workers() {
        let hub = TelemetryHub::new();
        let w0 = hub.register("0");
        let w1 = hub.register("1");
        w0.add(Counter::RequestsCompleted, 2);
        w0.set_gauge(Gauge::QueueDepth, 3);
        w0.set_status(json::obj(vec![
            (
                "requests",
                Json::Arr(vec![
                    json::obj(vec![
                        ("id", json::num(11.0)),
                        ("state", json::s("active")),
                        ("tokens", json::num(5.0)),
                    ]),
                    json::obj(vec![
                        ("id", json::num(12.0)),
                        ("state", json::s("pending")),
                        ("tokens", json::num(0.0)),
                    ]),
                ]),
            ),
            ("pending", json::num(1.0)),
            ("active", json::num(1.0)),
        ]));
        w1.set_status(json::obj(vec![
            ("requests", Json::Arr(vec![])),
            ("pending", json::num(0.0)),
            ("active", json::num(0.0)),
        ]));
        let d = hub.register("dispatcher");
        d.set_status(json::obj(vec![
            ("role", json::s("dispatcher")),
            ("workers_alive", json::num(2.0)),
            ("backlog", json::num(0.0)),
        ]));

        let text = json::to_string(&hub.statusz_json());
        let v = Json::parse(&text).unwrap();
        assert!(v.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
        let workers = v.arr_field("workers").unwrap();
        assert_eq!(workers.len(), 2, "dispatcher is not a worker row");
        assert_eq!(workers[0].str_field("worker").unwrap(), "0");
        assert_eq!(workers[0].usize_field("queue_depth").unwrap(), 3);
        assert_eq!(workers[0].usize_field("requests_completed").unwrap(), 2);
        // requests flatten across workers, each row tagged with its worker
        let reqs = v.arr_field("requests").unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].usize_field("id").unwrap(), 11);
        assert_eq!(reqs[0].str_field("state").unwrap(), "active");
        assert_eq!(reqs[0].str_field("worker").unwrap(), "0");
        assert_eq!(reqs[1].usize_field("id").unwrap(), 12);
        // the dispatcher's own view rides along
        assert_eq!(
            v.get("dispatcher").unwrap().usize_field("workers_alive").unwrap(),
            2
        );
    }

    #[test]
    fn readyz_reflects_dispatcher_liveness_and_backlog() {
        // no workers registered: not ready (nothing can serve)
        let hub = TelemetryHub::new();
        assert!(!hub.readiness().0);
        assert_eq!(hub.liveness(), None, "no dispatcher: liveness unknown");

        // a single engine with no dispatcher: registered == ready
        let w = hub.register("0");
        let (ready, body) = hub.readiness();
        assert!(ready, "{body}");
        // ... until its own queue hits the shed threshold
        w.set_status(json::obj(vec![
            ("requests", Json::Arr(vec![])),
            ("pending", json::num(8.0)),
            ("active", json::num(2.0)),
            ("max_queue", json::num(8.0)),
        ]));
        assert!(!hub.readiness().0, "queue at shed threshold");
        w.set_status(json::obj(vec![
            ("requests", Json::Arr(vec![])),
            ("pending", json::num(2.0)),
            ("active", json::num(2.0)),
            ("max_queue", json::num(8.0)),
        ]));
        assert!(hub.readiness().0);

        // a dispatcher status takes over the verdict: backlog below the
        // shed threshold and at least one live worker
        let d = hub.register("dispatcher");
        d.set_status(json::obj(vec![
            ("role", json::s("dispatcher")),
            ("workers_alive", json::num(2.0)),
            ("backlog", json::num(3.0)),
            ("max_queue", json::num(16.0)),
            ("dispatched_total", json::num(40.0)),
        ]));
        assert!(hub.readiness().0);
        assert_eq!(hub.liveness(), Some(true));
        d.set_status(json::obj(vec![
            ("role", json::s("dispatcher")),
            ("workers_alive", json::num(2.0)),
            ("backlog", json::num(16.0)),
            ("max_queue", json::num(16.0)),
            ("dispatched_total", json::num(40.0)),
        ]));
        let (ready, body) = hub.readiness();
        assert!(!ready, "backlog at shed threshold");
        assert!(
            crate::util::json::to_string(&body).contains("shed threshold"),
            "{body}"
        );
        // all workers dead: not ready AND not live
        d.set_status(json::obj(vec![
            ("role", json::s("dispatcher")),
            ("workers_alive", json::num(0.0)),
            ("backlog", json::num(0.0)),
            ("max_queue", json::num(16.0)),
            ("dispatched_total", json::num(40.0)),
        ]));
        assert!(!hub.readiness().0);
        assert_eq!(hub.liveness(), Some(false));
    }

    #[test]
    fn remote_transport_stats_render_in_statusz_and_prometheus() {
        use crate::util::json::Json;
        let hub = TelemetryHub::new();
        let t = hub.register_remote("127.0.0.1:7070");
        t.note_out(100);
        t.note_out(50);
        t.note_in(700);
        t.note_disconnect(3);
        t.observe_rtt(0.002);
        t.observe_rtt(0.004);

        assert_eq!(t.bytes_out(), 150);
        assert_eq!(t.frames_out(), 2);
        assert_eq!(t.bytes_in(), 700);
        assert_eq!(t.frames_in(), 1);
        assert_eq!(t.disconnects(), 1);
        assert_eq!(t.requeued(), 3);
        assert_eq!(t.rtt().count(), 2);

        let status = hub.statusz_json();
        let rows = status.arr_field("remote_workers").unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].str_field("addr").unwrap(), "127.0.0.1:7070");
        assert_eq!(rows[0].usize_field("bytes_out").unwrap(), 150);
        assert_eq!(rows[0].usize_field("disconnects").unwrap(), 1);
        assert_eq!(rows[0].usize_field("requeued").unwrap(), 3);
        assert!(rows[0].get("rpc_p50_ms").and_then(Json::as_f64).unwrap() > 0.0);

        let text = hub.render_prometheus();
        assert!(text.contains(
            "fastmamba_remote_bytes_total{addr=\"127.0.0.1:7070\",dir=\"out\"} 150"
        ));
        assert!(text.contains(
            "fastmamba_remote_frames_total{addr=\"127.0.0.1:7070\",dir=\"in\"} 1"
        ));
        assert!(text
            .contains("fastmamba_remote_disconnects_total{addr=\"127.0.0.1:7070\"} 1"));
        assert!(text.contains(
            "fastmamba_remote_requeued_requests_total{addr=\"127.0.0.1:7070\"} 3"
        ));
        assert!(text.contains(
            "fastmamba_remote_rpc_seconds_count{addr=\"127.0.0.1:7070\"} 2"
        ));

        // a hub with no remotes renders none of the remote series
        let bare = TelemetryHub::new();
        assert!(!bare.render_prometheus().contains("fastmamba_remote_"));
        assert_eq!(bare.statusz_json().arr_field("remote_workers").unwrap().len(), 0);
    }

    #[test]
    fn obs_prometheus_exposition_has_per_worker_and_aggregate_series() {
        let hub = TelemetryHub::new();
        let w0 = hub.register("0");
        let w1 = hub.register("1");
        w0.add(Counter::TokensGenerated, 10);
        w1.add(Counter::TokensGenerated, 32);
        w0.observe(HistKind::Tpot, 0.002);
        let text = hub.render_prometheus();
        assert!(text.contains("# TYPE fastmamba_tokens_generated_total counter"));
        assert!(text.contains("fastmamba_tokens_generated_total{worker=\"0\"} 10"));
        assert!(text.contains("fastmamba_tokens_generated_total{worker=\"1\"} 32"));
        assert!(text.contains("fastmamba_tokens_generated_total 42"));
        assert!(text.contains("fastmamba_tpot_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("fastmamba_tpot_seconds_count 1"));
        assert!(text.contains("# TYPE fastmamba_queue_depth gauge"));
        // process self-metrics always render (RSS is Linux-only)
        assert!(text.contains("# TYPE fastmamba_process_uptime_seconds gauge"));
        assert!(text.contains("fastmamba_telemetry_heap_bytes"));
        assert!(text.contains("fastmamba_flight_events_recorded_total 0"));
        if cfg!(target_os = "linux") {
            assert!(text.contains("fastmamba_process_resident_bytes"), "{text}");
        }
        // telemetry heap reflects w0's one allocated histogram
        let heap_line = text
            .lines()
            .find(|l| l.starts_with("fastmamba_telemetry_heap_bytes"))
            .unwrap();
        let heap: usize = heap_line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!(heap >= crate::obs::histogram::N_BUCKETS * 8, "{heap_line}");
    }
}
