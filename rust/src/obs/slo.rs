//! SLO burn-rate monitoring and the stall watchdog.
//!
//! Objectives come from the CLI (`--slo-ttft-ms`, `--slo-tpot-ms`,
//! `--slo-availability`); each [`SloMonitor::evaluate`] call reads the
//! [`TelemetryHub`]'s aggregated cells and reports two views per
//! objective:
//!
//! * **burn rate** — the cumulative error fraction divided by the error
//!   budget, over the full process history.  Because it is a pure
//!   function of integer bucket counts (see
//!   [`crate::obs::Histogram::count_over`]), the exported
//!   `fastmamba.metrics.v1` snapshot reproduces the live gauge
//!   *bit-for-bit* offline via [`burn_from_buckets`] — latency
//!   attribution you can audit, not just trust.
//! * **windowed violations** — each `evaluate` call closes a rolling
//!   window over the delta since the previous call; a window whose own
//!   error fraction exceeds the budget increments
//!   `slo_violations_total{objective=...}` exactly once.  The scrape
//!   interval (or the `--log-every-s` ticker) is the window length, the
//!   usual Prometheus arrangement.
//!
//! The [`StallWatchdog`] is the liveness side: it watches the live
//! `/statusz` view for requests whose token count stops advancing and for
//! a dispatcher whose dispatch counter stops moving while a backlog
//! exists, and when it fires it counts `stalls_detected_total`, records a
//! [`FlightKind::Stall`] event, and dumps the flight recorder to stderr —
//! the post-mortem is captured at detection time, not reconstructed later.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::flight::{FlightKind, DISPATCHER_LANE};
use super::histogram::Histogram;
use super::telemetry::{Counter, HistKind, TelemetryHub};
use crate::util::json::{self, Json};

/// Configured objectives.  Latency thresholds are stored in seconds; a
/// `None` objective is not evaluated or exported.
#[derive(Debug, Clone)]
pub struct SloConfig {
    /// time-to-first-token objective: `latency_target` of requests must
    /// see their first token within this many seconds
    pub ttft_s: Option<f64>,
    /// inter-token latency objective, seconds
    pub tpot_s: Option<f64>,
    /// availability target in (0, 1): the allowed failure budget is
    /// `1 - availability`, burned by shed + dropped requests
    pub availability: Option<f64>,
    /// fraction of requests that must meet each latency threshold — the
    /// latency error budget is `1 - latency_target`
    pub latency_target: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        Self { ttft_s: None, tpot_s: None, availability: None, latency_target: 0.99 }
    }
}

impl SloConfig {
    pub fn is_enabled(&self) -> bool {
        self.ttft_s.is_some() || self.tpot_s.is_some() || self.availability.is_some()
    }

    /// The latency error budget, `1.0 - latency_target`, as the one
    /// expression both the live gauges and offline recomputes must share:
    /// `1.0 - 0.99` is *not* bit-identical to the literal `0.01` in f64,
    /// so consumers that hard-code the budget instead of deriving it from
    /// the exported `latency_target` lose the bit-for-bit guarantee.
    pub fn latency_budget(&self) -> f64 {
        1.0 - self.latency_target
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(json::num).unwrap_or(Json::Null);
        json::obj(vec![
            ("ttft_s", opt(self.ttft_s)),
            ("tpot_s", opt(self.tpot_s)),
            ("availability", opt(self.availability)),
            ("latency_target", json::num(self.latency_target)),
        ])
    }
}

/// Burn rate from an error/total pair: `(errors/total) / budget`.  Both
/// the live gauges and the offline recompute reduce to this one function,
/// which is what makes them bit-identical.
pub fn burn_from_counts(errors: u64, total: u64, budget: f64) -> f64 {
    if total == 0 || budget <= 0.0 {
        return 0.0;
    }
    (errors as f64 / total as f64) / budget
}

/// Recompute a latency burn rate from an exported sparse bucket list
/// (`[[bucket_index, count], ...]` plus the ≤0-class count and the total
/// count, as written by `Metrics::to_json`).  Uses the same bucket-edge
/// arithmetic as the live [`Histogram::count_over`] path, so the result
/// is bit-for-bit identical to the live gauge at the same snapshot.
pub fn burn_from_buckets(
    buckets: &[(usize, u64)],
    zero: u64,
    total: u64,
    threshold_s: f64,
    budget: f64,
) -> f64 {
    let mut errors = if threshold_s < 0.0 { zero } else { 0 };
    for &(i, c) in buckets {
        if Histogram::bucket_upper_edge(i) > threshold_s {
            errors += c;
        }
    }
    burn_from_counts(errors, total, budget)
}

/// One objective's evaluation result.
#[derive(Debug, Clone)]
pub struct ObjectiveReport {
    /// `"ttft"`, `"tpot"`, or `"availability"`
    pub name: &'static str,
    /// cumulative error-fraction / error-budget over the full history
    pub burn_rate: f64,
    /// burn rate of the window this evaluation closed
    pub window_burn: f64,
    /// true when this window burned past its budget (a violation)
    pub violated_now: bool,
    /// total violation windows since startup
    pub violations: u64,
}

/// Per-objective window anchor: cumulative (errors, total) at the last
/// window close.
#[derive(Debug, Default, Clone, Copy)]
struct Anchor {
    errors: u64,
    total: u64,
}

const OBJ_TTFT: usize = 0;
const OBJ_TPOT: usize = 1;
const OBJ_AVAIL: usize = 2;

/// Evaluates the configured objectives against a [`TelemetryHub`].
#[derive(Debug)]
pub struct SloMonitor {
    cfg: SloConfig,
    violations: [AtomicU64; 3],
    anchors: Mutex<[Anchor; 3]>,
}

impl SloMonitor {
    pub fn new(cfg: SloConfig) -> Self {
        Self {
            cfg,
            violations: std::array::from_fn(|_| AtomicU64::new(0)),
            anchors: Mutex::new([Anchor::default(); 3]),
        }
    }

    pub fn config(&self) -> &SloConfig {
        &self.cfg
    }

    /// Evaluate every configured objective: refresh the cumulative burn
    /// gauges and close one violation window per objective.
    pub fn evaluate(&self, hub: &TelemetryHub) -> Vec<ObjectiveReport> {
        let mut anchors = self.anchors.lock().unwrap();
        let mut out = Vec::new();
        if let Some(t) = self.cfg.ttft_s {
            let h = hub.hist_aggregate(HistKind::Ttft);
            let budget = self.cfg.latency_budget();
            out.push(self.close_window(
                OBJ_TTFT,
                "ttft",
                h.count_over(t),
                h.count(),
                budget,
                &mut anchors[OBJ_TTFT],
            ));
        }
        if let Some(t) = self.cfg.tpot_s {
            let h = hub.hist_aggregate(HistKind::Tpot);
            let budget = self.cfg.latency_budget();
            out.push(self.close_window(
                OBJ_TPOT,
                "tpot",
                h.count_over(t),
                h.count(),
                budget,
                &mut anchors[OBJ_TPOT],
            ));
        }
        if let Some(target) = self.cfg.availability {
            let errors = hub.total(Counter::RequestsShed) + hub.total(Counter::RequestsDropped);
            let total = hub.total(Counter::RequestsCompleted);
            out.push(self.close_window(
                OBJ_AVAIL,
                "availability",
                errors,
                total,
                1.0 - target,
                &mut anchors[OBJ_AVAIL],
            ));
        }
        out
    }

    fn close_window(
        &self,
        idx: usize,
        name: &'static str,
        errors: u64,
        total: u64,
        budget: f64,
        anchor: &mut Anchor,
    ) -> ObjectiveReport {
        let burn_rate = burn_from_counts(errors, total, budget);
        let d_errors = errors.saturating_sub(anchor.errors);
        let d_total = total.saturating_sub(anchor.total);
        let window_burn = burn_from_counts(d_errors, d_total, budget);
        let violated_now = d_total > 0 && window_burn > 1.0;
        if violated_now {
            self.violations[idx].fetch_add(1, Ordering::Relaxed);
        }
        *anchor = Anchor { errors, total };
        ObjectiveReport {
            name,
            burn_rate,
            window_burn,
            violated_now,
            violations: self.violations[idx].load(Ordering::Relaxed),
        }
    }
}

/// Flags requests with no token progress and a dispatcher with no
/// dispatch progress past `threshold`.  `check` is explicit (called by
/// the ticker thread, or directly in tests) so a wedged request is
/// detectable deterministically.
#[derive(Debug)]
pub struct StallWatchdog {
    threshold: Duration,
    stalls: AtomicU64,
    state: Mutex<WatchState>,
}

#[derive(Debug, Default)]
struct WatchState {
    /// request id → (last seen token count, unchanged since)
    reqs: HashMap<u64, (u64, Instant)>,
    /// (last seen dispatched_total, unchanged since)
    dispatch: Option<(u64, Instant)>,
}

impl StallWatchdog {
    pub fn new(threshold: Duration) -> Self {
        Self {
            threshold,
            stalls: AtomicU64::new(0),
            state: Mutex::new(WatchState::default()),
        }
    }

    pub fn threshold(&self) -> Duration {
        self.threshold
    }

    /// Total stalls detected since startup (`fastmamba_stalls_detected_total`).
    pub fn stalls_detected(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }

    /// One watchdog pass over the hub's live status view.  Returns how
    /// many stalls fired this pass; each firing records a `Stall` flight
    /// event, and any firing pass dumps the flight recorder to stderr.
    pub fn check(&self, hub: &TelemetryHub) -> usize {
        let status = hub.statusz_json();
        let now = Instant::now();
        let mut st = self.state.lock().unwrap();
        let mut fired = 0usize;
        let mut live_ids = Vec::new();
        if let Some(reqs) = status.get("requests").and_then(Json::as_arr) {
            for r in reqs {
                let (Some(id), Some(tokens)) = (
                    r.get("id").and_then(Json::as_f64),
                    r.get("tokens").and_then(Json::as_f64),
                ) else {
                    continue;
                };
                let (id, tokens) = (id as u64, tokens as u64);
                live_ids.push(id);
                match st.reqs.entry(id) {
                    Entry::Vacant(v) => {
                        v.insert((tokens, now));
                    }
                    Entry::Occupied(mut o) => {
                        let e = o.get_mut();
                        if e.0 != tokens {
                            *e = (tokens, now);
                        } else if now.duration_since(e.1) >= self.threshold {
                            fired += 1;
                            self.stalls.fetch_add(1, Ordering::Relaxed);
                            let worker = r
                                .get("worker")
                                .map(json::to_string)
                                .unwrap_or_default();
                            hub.flight().record(
                                DISPATCHER_LANE,
                                id,
                                FlightKind::Stall,
                                format!("no token progress (tokens={tokens} worker={worker})"),
                            );
                            e.1 = now; // re-arm instead of refiring every pass
                        }
                    }
                }
            }
        }
        st.reqs.retain(|id, _| live_ids.contains(id));
        if let Some(d) = status.get("dispatcher") {
            let dispatched = d
                .get("dispatched_total")
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as u64;
            let backlog = d.get("backlog").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            match st.dispatch {
                None => st.dispatch = Some((dispatched, now)),
                Some((prev, _)) if prev != dispatched => st.dispatch = Some((dispatched, now)),
                Some((_, since))
                    if backlog > 0 && now.duration_since(since) >= self.threshold =>
                {
                    fired += 1;
                    self.stalls.fetch_add(1, Ordering::Relaxed);
                    hub.flight().record(
                        DISPATCHER_LANE,
                        0,
                        FlightKind::Stall,
                        format!("no dispatch progress (backlog={backlog})"),
                    );
                    st.dispatch = Some((dispatched, now));
                }
                _ => {}
            }
        }
        drop(st);
        if fired > 0 {
            eprintln!(
                "[watchdog] {fired} stall(s) detected; flight dump: {}",
                json::to_string(&hub.flight().dump_json(64))
            );
        }
        fired
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::telemetry::TelemetryHub;
    use super::*;

    #[test]
    fn slo_violations_count_once_per_window() {
        let hub = TelemetryHub::new();
        let w = hub.register("0");
        let slo = SloMonitor::new(SloConfig {
            ttft_s: Some(0.001),
            ..SloConfig::default()
        });

        // window 1: ten requests, all blowing the 1 ms TTFT objective
        for _ in 0..10 {
            w.observe(HistKind::Ttft, 1.0);
        }
        let r = &slo.evaluate(&hub)[0];
        assert_eq!(r.name, "ttft");
        assert!(r.violated_now, "{r:?}");
        assert_eq!(r.violations, 1);
        assert!(r.burn_rate > 1.0);

        // window 2: no new observations — the same cumulative data must
        // not be double-counted as a fresh violation
        let r = &slo.evaluate(&hub)[0];
        assert!(!r.violated_now, "{r:?}");
        assert_eq!(r.violations, 1, "one violation per violating window");
        assert_eq!(r.window_burn, 0.0);

        // window 3: more bad data opens (and violates) a new window
        for _ in 0..5 {
            w.observe(HistKind::Ttft, 2.0);
        }
        let r = &slo.evaluate(&hub)[0];
        assert!(r.violated_now);
        assert_eq!(r.violations, 2);

        // window 4: only healthy observations — window burn stays within
        // budget even though the cumulative burn is still elevated
        for _ in 0..5 {
            w.observe(HistKind::Ttft, 1e-6);
        }
        let r = &slo.evaluate(&hub)[0];
        assert!(!r.violated_now, "{r:?}");
        assert_eq!(r.violations, 2);
        assert!(r.burn_rate > 1.0, "cumulative view still remembers");
    }

    #[test]
    fn slo_availability_burn_tracks_shed_and_dropped() {
        let hub = TelemetryHub::new();
        let w = hub.register("0");
        // 0.875 and 0.125 are exact in binary, so "exactly at budget" is
        // exactly at budget: 1.0 - 0.875 == 0.125 bit-for-bit (a target of
        // 0.90 would give a budget of 1.0 - 0.90 ≈ 0.09999999999999998,
        // which the literal 0.1 does NOT equal)
        let slo = SloMonitor::new(SloConfig {
            availability: Some(0.875),
            ..SloConfig::default()
        });
        // 40 completions, 2 shed + 3 dropped: error fraction 5/40 == budget
        w.add(Counter::RequestsCompleted, 40);
        w.add(Counter::RequestsShed, 2);
        w.add(Counter::RequestsDropped, 3);
        let r = &slo.evaluate(&hub)[0];
        assert_eq!(r.name, "availability");
        assert_eq!(r.burn_rate.to_bits(), burn_from_counts(5, 40, 0.125).to_bits());
        assert_eq!(r.burn_rate.to_bits(), 1.0f64.to_bits());
        assert!(!r.violated_now, "exactly at budget is not a violation");
        // five more sheds in the next window: 5/5 error fraction, burn 8×
        w.add(Counter::RequestsCompleted, 5);
        w.add(Counter::RequestsShed, 5);
        let r = &slo.evaluate(&hub)[0];
        assert!(r.violated_now);
        assert_eq!(r.violations, 1);
    }

    #[test]
    fn stall_watchdog_fires_on_wedged_request_and_dumps_flight() {
        let hub = TelemetryHub::new();
        let w = hub.register("0");
        // a worker whose status table shows request 7 frozen at 3 tokens
        let wedged = json::obj(vec![
            (
                "requests",
                Json::Arr(vec![json::obj(vec![
                    ("id", json::num(7.0)),
                    ("state", json::s("active")),
                    ("tokens", json::num(3.0)),
                ])]),
            ),
            ("pending", json::num(0.0)),
            ("active", json::num(1.0)),
        ]);
        w.set_status(wedged.clone());

        let wd = StallWatchdog::new(Duration::ZERO);
        assert_eq!(wd.check(&hub), 0, "first sighting arms, never fires");
        assert_eq!(wd.check(&hub), 1, "no progress past threshold fires");
        assert_eq!(wd.stalls_detected(), 1);
        let evs = hub.flight().dump(usize::MAX);
        let stall = evs.iter().find(|e| e.kind == FlightKind::Stall).unwrap();
        assert_eq!(stall.req, 7);
        assert!(stall.detail.contains("no token progress"), "{}", stall.detail);

        // progress re-arms: a new token count must not fire
        let moved = json::obj(vec![(
            "requests",
            Json::Arr(vec![json::obj(vec![
                ("id", json::num(7.0)),
                ("state", json::s("active")),
                ("tokens", json::num(4.0)),
            ])]),
        )]);
        w.set_status(moved);
        assert_eq!(wd.check(&hub), 0, "token progress resets the clock");

        // a wedged dispatcher (backlog, dispatch counter frozen) fires too
        let d = hub.register("dispatcher");
        d.set_status(json::obj(vec![
            ("role", json::s("dispatcher")),
            ("workers_alive", json::num(2.0)),
            ("backlog", json::num(4.0)),
            ("dispatched_total", json::num(9.0)),
        ]));
        wd.check(&hub); // arms the dispatch anchor (request 7 fires again here)
        let before = wd.stalls_detected();
        assert!(wd.check(&hub) >= 1);
        assert!(wd.stalls_detected() > before);
        let evs = hub.flight().dump(usize::MAX);
        assert!(
            evs.iter()
                .any(|e| e.kind == FlightKind::Stall && e.detail.contains("no dispatch progress")),
            "{evs:?}"
        );
    }

    #[test]
    fn slo_burn_rate_matches_offline_recompute_bit_for_bit() {
        use crate::backend::{InferenceBackend, NativeBackend};
        use crate::coordinator::{serve_pool, EngineConfig, PoolConfig, Request};
        use anyhow::Result;

        // deterministic 4-worker run on the micro model (the same recipe
        // as the live-scrape test)
        let make = || -> Result<Box<dyn InferenceBackend>> {
            let mut cfg = crate::config::ModelConfig::tiny();
            cfg.name = "mamba2-micro".into();
            cfg.d_model = 64;
            cfg.n_layer = 2;
            cfg.d_state = 16;
            cfg.headdim = 16;
            cfg.vocab_size = 128;
            Ok(Box::new(
                NativeBackend::new(crate::model::ModelWeights::random(&cfg, 9))
                    .with_buckets(vec![8, 16, 32], vec![1, 2, 4]),
            ))
        };
        let hub = Arc::new(TelemetryHub::new());
        let slo = Arc::new(SloMonitor::new(SloConfig {
            ttft_s: Some(0.005),
            tpot_s: Some(0.0005),
            availability: Some(0.99),
            latency_target: 0.99,
        }));
        hub.attach_slo(Arc::clone(&slo));
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 4, greedy_chunking: true },
                n_workers: 4,
                hub: Some(Arc::clone(&hub)),
                ..PoolConfig::default()
            },
        );
        let n = 64usize;
        for i in 0..n {
            let plen = [3usize, 9, 17, 33][i % 4];
            let prompt: Vec<u32> =
                (0..plen).map(|j| ((i * 131 + j * 17) % 128) as u32).collect();
            pool.submit(Request::new(i as u64, prompt, 2 + (i % 5), "fp32")).unwrap();
        }
        for _ in 0..n {
            pool.results.recv().expect("pool result");
        }
        let report = pool.finish().unwrap();
        assert!(report.errors.is_empty(), "{:?}", report.errors);

        // live gauges: rendered into the Prometheus exposition, parsed
        // back (Rust f64 Display round-trips exactly)
        let text = hub.render_prometheus();
        let gauge = |objective: &str| -> f64 {
            let prefix = format!("fastmamba_slo_burn_rate{{objective=\"{objective}\"}} ");
            text.lines()
                .find(|l| l.starts_with(&prefix))
                .unwrap_or_else(|| panic!("missing {prefix} in:\n{text}"))
                .rsplit(' ')
                .next()
                .unwrap()
                .parse()
                .unwrap()
        };

        // offline recompute: the exported fastmamba.metrics.v1 snapshot
        // carries the sparse bucket counts; the same pure function over
        // them must reproduce the live gauges bit-for-bit.  The budgets
        // are derived exactly as the live path derives them — a literal
        // 0.01 is NOT bit-identical to 1.0 - 0.99 in f64.
        let lat_budget = slo.config().latency_budget();
        let avail_budget = 1.0 - slo.config().availability.unwrap();
        let snapshot = json::to_string(&report.merged.to_json());
        let snap = Json::parse(&snapshot).unwrap();
        let recompute = |field: &str, threshold: f64| -> f64 {
            let h = snap.get(field).unwrap();
            let buckets: Vec<(usize, u64)> = h
                .arr_field("buckets")
                .unwrap()
                .iter()
                .map(|p| {
                    let p = p.as_arr().unwrap();
                    (p[0].as_usize().unwrap(), p[1].as_f64().unwrap() as u64)
                })
                .collect();
            burn_from_buckets(
                &buckets,
                h.usize_field("zero").unwrap() as u64,
                h.usize_field("count").unwrap() as u64,
                threshold,
                lat_budget,
            )
        };
        let off_ttft = recompute("ttft_s", 0.005);
        let off_tpot = recompute("tpot_s", 0.0005);
        assert!(off_ttft.is_finite() && off_tpot.is_finite());
        assert_eq!(gauge("ttft").to_bits(), off_ttft.to_bits(), "ttft burn");
        assert_eq!(gauge("tpot").to_bits(), off_tpot.to_bits(), "tpot burn");
        let off_avail = burn_from_counts(
            snap.usize_field("requests_shed").unwrap() as u64
                + snap.usize_field("requests_dropped").unwrap() as u64,
            snap.usize_field("requests_completed").unwrap() as u64,
            avail_budget,
        );
        assert_eq!(gauge("availability").to_bits(), off_avail.to_bits());

        // violations render as labeled counters alongside the gauges
        assert!(
            text.contains("fastmamba_slo_violations_total{objective=\"ttft\"}"),
            "{text}"
        );
    }

    #[test]
    fn slo_config_json_and_helpers() {
        let cfg = SloConfig {
            ttft_s: Some(0.25),
            tpot_s: None,
            availability: Some(0.999),
            latency_target: 0.95,
        };
        assert!(cfg.is_enabled());
        assert!(!SloConfig::default().is_enabled());
        let j = Json::parse(&json::to_string(&cfg.to_json())).unwrap();
        assert_eq!(j.get("ttft_s").unwrap().as_f64().unwrap(), 0.25);
        assert_eq!(j.get("tpot_s").unwrap(), &Json::Null);
        // burn helpers: empty data and zero budget are inert
        assert_eq!(burn_from_counts(0, 0, 0.01), 0.0);
        assert_eq!(burn_from_counts(5, 10, 0.0), 0.0);
        assert_eq!(burn_from_buckets(&[], 0, 0, 0.1, 0.01), 0.0);
    }
}
