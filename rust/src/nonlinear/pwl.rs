//! 8-segment first-order PWL coefficients for 2^v, v ∈ (−1, 0]
//! (the EXP-INT segment LUT of Fig. 8).
//!
//! Generated identically to `ref.pwl_tables` in Python: endpoint
//! interpolation of g(rem) = 2^(−rem/2^F) over `pwl_segments` equal
//! segments, coefficients in Q1.<coeff_frac_bits>.

use crate::config::FixedSpec;

/// Segment LUT: `g(rem) ≈ intercept[i] + slope[i]·(rem − rem0_i)`.
#[derive(Debug, Clone)]
pub struct PwlTable {
    pub intercept: Vec<i32>,
    pub slope: Vec<i32>,
}

impl PwlTable {
    pub fn new(spec: &FixedSpec) -> Self {
        let f = spec.frac_bits;
        let nseg = spec.pwl_segments as usize;
        let seg_w = (1usize << f) / nseg;
        let cs = (1i64 << spec.coeff_frac_bits) as f64;
        let mut intercept = Vec::with_capacity(nseg);
        let mut slope = Vec::with_capacity(nseg);
        for i in 0..nseg {
            let rem0 = (i * seg_w) as f64;
            let g0 = 2f64.powf(-rem0 / (1u64 << f) as f64);
            let g1 = 2f64.powf(-(rem0 + seg_w as f64) / (1u64 << f) as f64);
            // round in f64 to match numpy exactly
            intercept.push((g0 * cs).round_ties_even() as i32);
            slope.push(((g1 - g0) / seg_w as f64 * cs).round_ties_even() as i32);
        }
        Self { intercept, slope }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_segments_default() {
        let t = PwlTable::new(&FixedSpec::default());
        assert_eq!(t.intercept.len(), 8);
        assert_eq!(t.slope.len(), 8);
    }

    #[test]
    fn first_intercept_is_one() {
        let spec = FixedSpec::default();
        let t = PwlTable::new(&spec);
        assert_eq!(t.intercept[0], 1 << spec.coeff_frac_bits); // 2^0 = 1
    }

    #[test]
    fn intercepts_strictly_decreasing_slopes_negative() {
        let t = PwlTable::new(&FixedSpec::default());
        for w in t.intercept.windows(2) {
            assert!(w[1] < w[0]);
        }
        assert!(t.slope.iter().all(|s| *s < 0));
    }

    #[test]
    fn matches_python_generated_values() {
        // Golden values computed by python ref.pwl_tables(FXP) — pins the
        // cross-language bit-exactness contract.
        let t = PwlTable::new(&FixedSpec::default());
        let py_intercept = [16384, 15024, 13777, 12634, 11585, 10624, 9742, 8933];
        let py_slope = [-11, -10, -9, -8, -8, -7, -6, -6];
        assert_eq!(t.intercept, py_intercept);
        assert_eq!(t.slope, py_slope);
    }

    #[test]
    fn pwl_error_bound() {
        let spec = FixedSpec::default();
        let t = PwlTable::new(&spec);
        let f = spec.frac_bits;
        let seg_w = (1 << f) / spec.pwl_segments as i32;
        let cs = (1i64 << spec.coeff_frac_bits) as f64;
        let mut max_err = 0.0f64;
        for rem in 0..(1 << f) {
            let seg = (rem / seg_w) as usize;
            let approx = (t.intercept[seg] + t.slope[seg] * (rem - seg as i32 * seg_w))
                as f64
                / cs;
            let true_v = 2f64.powf(-rem as f64 / (1u64 << f) as f64);
            max_err = max_err.max((approx - true_v).abs());
        }
        assert!(max_err < 5e-3, "{max_err}");
    }
}
