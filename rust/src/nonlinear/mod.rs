//! Nonlinear approximation algorithms (paper §III-B, Eq. 3–6) and the float
//! nonlinears the accelerator keeps in floating point (RMSNorm, SiLU).
//!
//! [`exp_fixed`] / [`softplus_fixed`] are the *bit-exact* mirror of the
//! Python NAU datapath (`kernels/nonlinear.py` / `kernels/ref.py`): same
//! Q6.10 carry, same (1.0111)₂ log2(e), same 8-segment PWL coefficients,
//! same floor shifts.  Integration tests assert Rust == Pallas == reference
//! across the full 16-bit input range.

pub mod pwl;

use crate::config::FixedSpec;
use crate::quant::fixed::{from_fixed, to_fixed};
pub use pwl::PwlTable;

/// Eq. 3 — e^x for x ≤ 0 on the fixed-point datapath.
///
/// `t = (x · log2e) >> F`; split `t = u + v`, `u ∈ Z≤0`, `v ∈ (-1, 0]`;
/// `2^v` by 8-segment first-order PWL; result `= 2^v >> |u|`.
pub fn exp_fixed(x_fx: i32, table: &PwlTable, spec: &FixedSpec) -> i32 {
    let f = spec.frac_bits;
    let cf = spec.coeff_frac_bits;
    let t = (x_fx as i64 * spec.log2e_fx() as i64 >> f) as i32; // arithmetic
    let neg = -t; // ≥ 0 for x ≤ 0
    let u_abs = neg >> f;
    let rem = neg & (spec.scale() - 1);
    let seg_shift = f - spec.pwl_segments.trailing_zeros();
    let seg = (rem >> seg_shift) as usize;
    let frac = rem - ((seg as i32) << seg_shift);
    let val_q = table.intercept[seg] + table.slope[seg] * frac; // Q1.cf
    if u_abs >= 30 {
        0
    } else {
        (val_q >> u_abs) >> (cf - f)
    }
}

/// Eq. 6 — SoftPlus on fixed point, reusing the exp datapath (Fig. 8):
/// `x ≤ 0 → e^x`;  `x > 0 → x + e^(−x)` (RPU negate + delay + post-add).
pub fn softplus_fixed(x_fx: i32, table: &PwlTable, spec: &FixedSpec) -> i32 {
    if x_fx > 0 {
        x_fx + exp_fixed(-x_fx, table, spec)
    } else {
        exp_fixed(x_fx, table, spec)
    }
}

/// Float wrapper of [`exp_fixed`] (quantize → NAU → dequantize).
pub fn exp_approx(x: f32, table: &PwlTable, spec: &FixedSpec) -> f32 {
    from_fixed(exp_fixed(to_fixed(x.min(0.0), spec), table, spec), spec)
}

/// Float wrapper of [`softplus_fixed`].
pub fn softplus_approx(x: f32, table: &PwlTable, spec: &FixedSpec) -> f32 {
    from_fixed(softplus_fixed(to_fixed(x, spec), table, spec), spec)
}

// ---------------------------------------------------------------------------
// Floating-point nonlinears (the paper's "floating-point computing group")
// ---------------------------------------------------------------------------

/// SiLU activation x·σ(x).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// RMS normalization with gain `w`, in place over one feature vector.
pub fn rmsnorm(x: &mut [f32], w: &[f32], eps: f32) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for (v, g) in x.iter_mut().zip(w) {
        *v *= r * g;
    }
}

/// Mamba2's gated RMSNorm: `rmsnorm(y ⊙ silu(z)) ⊙ w`.
pub fn gated_rmsnorm(y: &mut [f32], z: &[f32], w: &[f32], eps: f32) {
    for (v, zi) in y.iter_mut().zip(z) {
        *v *= silu(*zi);
    }
    rmsnorm(y, w, eps);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (PwlTable, FixedSpec) {
        let spec = FixedSpec::default();
        (PwlTable::new(&spec), spec)
    }

    #[test]
    fn exp_of_zero_is_one() {
        let (t, s) = setup();
        assert_eq!(exp_fixed(0, &t, &s), s.scale());
    }

    #[test]
    fn exp_monotone_and_bounded() {
        let (t, s) = setup();
        let mut prev = i32::MAX;
        for k in 0..2000 {
            let v = exp_fixed(-k * 13, &t, &s);
            assert!(v <= prev);
            assert!((0..=s.scale()).contains(&v));
            prev = v;
        }
    }

    #[test]
    fn exp_accuracy_vs_true() {
        let (t, s) = setup();
        let mut max_err = 0.0f32;
        for i in 0..4000 {
            let x = -12.0 * i as f32 / 4000.0;
            let err = (exp_approx(x, &t, &s) - x.exp()).abs();
            max_err = max_err.max(err);
        }
        assert!(max_err < 4e-3, "max err {max_err}");
    }

    #[test]
    fn softplus_symmetry_exact() {
        // Eq. 4 holds exactly in fixed point: SP(x) - SP(-x) == x.
        let (t, s) = setup();
        for k in (-16000..16000).step_by(37) {
            assert_eq!(
                softplus_fixed(k, &t, &s) - softplus_fixed(-k, &t, &s),
                k
            );
        }
    }

    #[test]
    fn softplus_accuracy_within_paper_band() {
        // ln(1+e^x) ≈ e^x (Eq. 5) carries ≤ 1-ln2 ≈ 0.307 intrinsic error.
        let (t, s) = setup();
        for i in 0..2000 {
            let x = -10.0 + 20.0 * i as f32 / 2000.0;
            let err = (softplus_approx(x, &t, &s) - (1.0 + x.exp()).ln()).abs();
            assert!(err < 0.32, "x={x} err={err}");
        }
    }

    #[test]
    fn silu_known_values() {
        assert_eq!(silu(0.0), 0.0);
        assert!((silu(1.0) - 0.731_058_6).abs() < 1e-5);
        assert!(silu(-20.0).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_output_norm() {
        let mut x = vec![3.0f32, -4.0, 12.0, 0.5];
        let w = vec![1.0f32; 4];
        rmsnorm(&mut x, &w, 1e-5);
        let ms = x.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-3);
    }

    #[test]
    fn gated_rmsnorm_zero_gate_zeroes() {
        let mut y = vec![1.0f32, 2.0, 3.0, 4.0];
        let z = vec![0.0f32; 4]; // silu(0)=0
        gated_rmsnorm(&mut y, &z, &[1.0; 4], 1e-5);
        assert!(y.iter().all(|v| v.abs() < 1e-6));
    }
}
