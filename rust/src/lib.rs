//! FastMamba: reproduction of "FastMamba: A High-Speed and Efficient Mamba
//! Accelerator on FPGA with Accurate Quantization" (cs.AR 2025).
//!
//! The crate is the Layer-3 side of a three-layer stack:
//!
//! * **Layer 1** (build time): Pallas kernels — the quantized compute
//!   hot-spots (`python/compile/kernels/`).
//! * **Layer 2** (build time): the JAX Mamba2 model in five quantization
//!   variants, AOT-lowered to HLO text artifacts (`python/compile/`).
//! * **Layer 3** (this crate, serve time): a serving coordinator
//!   ([`coordinator`]) that executes the model through a single execution
//!   contract ([`backend::InferenceBackend`]) with two first-class
//!   implementations — the AOT artifacts through PJRT
//!   (`backend::PjrtBackend`, `pjrt` cargo feature) and the artifact-free
//!   in-process model ([`backend::NativeBackend`]) — plus the substrates
//!   the paper's evaluation needs —
//!   quantization ([`quant`]), the NAU nonlinear approximations
//!   ([`nonlinear`]), a native Mamba2 golden model / CPU baseline
//!   ([`model`]), a cycle-level simulator of the FastMamba FPGA
//!   microarchitecture ([`sim`]), analytical CPU/GPU baselines
//!   ([`baseline`]), the synthetic evaluation harness ([`eval`]), and the
//!   table/figure report generators ([`report`]).
//!
//! Two serving modes share those artifacts, and both fan out to N worker
//! threads — one backend each, behind the capacity-aware router — via
//! [`coordinator::router::serve_pool`] (`serve --workers N`).  The batched
//! greedy path
//! ([`coordinator::scheduler::Engine`]) packs active sequences into the
//! AOT decode buckets.  The speculative path
//! ([`coordinator::speculative::SpecEngine`], `serve --speculate K`)
//! drafts with the quantized `fastmamba` variant and verifies with
//! `fp32` in chunked-prefill-style calls, rolling rejected drafts back
//! through versioned SSM-state snapshots
//! ([`coordinator::state::StatePool`]) — token-exact with greedy fp32
//! decoding, and modeled on the accelerator by [`sim::speculative`].
//!
//! Because the recurrent state is constant-size, "prompt caching" costs
//! one O(state) snapshot copy per hit instead of O(tokens) of KV memory:
//! the [`statecache`] subsystem (`serve --state-cache-mb N`) stores
//! bucket-aligned prefix snapshots plus per-session end-of-turn states,
//! shared across all pool workers, so shared system prompts and
//! multi-turn conversations skip their redundant prefill entirely.
//!
//! Serving is observable while it runs: the [`obs`] layer threads
//! `Arc`-shared atomic telemetry through both engines and the pool
//! dispatcher (`serve --metrics-addr` exposes a Prometheus `/metrics`
//! scrape endpoint, `--log-every-s` a one-line status log), and
//! per-request span traces export as Chrome `trace_event` JSON
//! (`--trace-out`, Perfetto-loadable) — reproducing the paper's
//! per-stage prefill/decode breakdown for the serving path.  The same
//! listener serves live introspection: `/statusz` (per-request and
//! per-worker live tables), `/readyz` (load-balancer readiness, distinct
//! from `/healthz` liveness), `/debug/config` (the resolved serving
//! configuration), and `/debug/flight` — a bounded in-memory flight
//! recorder ([`obs::FlightRecorder`]) of request lifecycle events that a
//! stall watchdog (`--stall-ms`) dumps when progress wedges.  SLO
//! objectives (`--slo-ttft-ms`, `--slo-tpot-ms`, `--slo-availability`)
//! evaluate as error-budget burn rates ([`obs::SloMonitor`]) on the
//! exact histograms `/metrics` exports, so an offline recompute from a
//! snapshot reproduces the live gauges bit-for-bit.
//!
//! Python never runs on the request path: `make artifacts` lowers
//! everything once, and the `fastmamba` binary is self-contained.  Build
//! with `--no-default-features` on hosts without `xla_extension`: every
//! serving path then runs on [`backend::NativeBackend`].

pub mod backend;
pub mod baseline;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod model;
pub mod nonlinear;
pub mod obs;
pub mod quant;
pub mod remote;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod sim;
pub mod statecache;
pub mod util;

pub use config::{AcceleratorConfig, FixedSpec, ModelConfig};
