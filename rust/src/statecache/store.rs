//! Shard internals of the [`StateCache`](super::StateCache): entries, byte
//! accounting, and least-recently-used eviction.
//!
//! A shard owns two maps under one mutex — content-hashed prefix entries
//! (with a collision chain per hash, because a hit must *never* be decided
//! by the hash alone) and per-session end-of-turn entries — plus an
//! **ordered eviction index**: a `BTreeMap` from LRU tick to entry key.
//! Ticks come from the cache's global monotonic clock, so they are unique
//! and totally ordered; the LRU victim is `index.first_key_value()`, making
//! an eviction O(log n) instead of the former full-shard linear scan
//! (ROADMAP-flagged PR-4 follow-up).  Every mutation goes through the
//! shard's insert/touch/evict methods so the index, the maps, and the byte
//! total stay consistent.

use std::collections::{BTreeMap, HashMap};

/// Fixed per-entry overhead charged on top of the payload buffers
/// (map slots, Vec headers, LRU bookkeeping) so the byte budget tracks
/// real residency, not just float counts.
pub(crate) const ENTRY_OVERHEAD: usize = 64;

/// Bytes one cached snapshot is accounted at.
pub(crate) fn entry_bytes(
    n_tokens: usize,
    n_chunks: usize,
    conv_len: usize,
    ssm_len: usize,
) -> usize {
    4 * (conv_len + ssm_len) + 4 * n_tokens + 8 * n_chunks + ENTRY_OVERHEAD
}

/// One cached snapshot: the recurrent (conv, ssm) state after consuming
/// `tokens`, plus everything a hit must verify.
#[derive(Debug, Clone)]
pub(crate) struct Entry {
    /// quantization variant the state was computed under — quantized
    /// variants calibrate per chunk, so states are never variant-portable
    pub variant: String,
    /// exact prefill-chunk sequence that produced the state (prefix
    /// entries; empty for session entries, whose provenance is the
    /// previous turn's serving trajectory itself).  Verified on hit:
    /// a state reached through a different chunking is a different state
    /// for the quantized variants.
    pub chunks: Vec<usize>,
    /// the full token prefix the state has consumed — verified on every
    /// hit, so a hash collision can never seed another request's state
    pub tokens: Vec<u32>,
    pub conv: Vec<f32>,
    pub ssm: Vec<f32>,
    /// LRU clock value at last insert/hit (global monotonic tick — unique,
    /// which is what lets the eviction index key on it)
    pub last_used: u64,
    /// accounted size ([`entry_bytes`])
    pub bytes: usize,
}

impl Entry {
    /// Does this entry describe exactly this (variant, chunking, tokens)?
    pub fn matches(&self, variant: &str, chunks: &[usize], tokens: &[u32]) -> bool {
        self.variant == variant && self.chunks == chunks && self.tokens == tokens
    }
}

/// Where an eviction-index tick points.  Prefix entries are identified by
/// their hash; the position inside the (nearly always length-1) collision
/// chain is recovered by tick at eviction time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) enum IndexKey {
    Prefix { hash: u64 },
    Session { id: u64 },
}

/// One lock domain of the cache.
#[derive(Debug, Default)]
pub(crate) struct Shard {
    /// content hash -> collision chain of prefix entries
    prefix: HashMap<u64, Vec<Entry>>,
    /// session id -> latest end-of-turn entry
    sessions: HashMap<u64, Entry>,
    /// accounted bytes across both maps
    pub bytes: usize,
    /// ordered eviction index: LRU tick -> entry key (kept in lock-step
    /// with the maps by the methods below)
    index: BTreeMap<u64, IndexKey>,
    /// admission pins: refcounted keys the LRU must not evict because the
    /// scheduler is about to resume from them (queued session turns,
    /// preemption snapshots).  A pin may precede the entry it protects —
    /// it guards the *key*, so an insert-after-pin is covered too.
    pins: HashMap<IndexKey, u32>,
}

impl Shard {
    pub fn n_entries(&self) -> usize {
        self.prefix.values().map(|c| c.len()).sum::<usize>() + self.sessions.len()
    }

    /// The prefix entry chain stored under `hash` (read-only probing).
    pub fn prefix_chain(&self, hash: u64) -> Option<&[Entry]> {
        self.prefix.get(&hash).map(|c| c.as_slice())
    }

    /// The session entry stored under `id` (read-only probing).
    pub fn session(&self, id: u64) -> Option<&Entry> {
        self.sessions.get(&id)
    }

    /// Insert a prefix entry, updating bytes and the eviction index.
    pub fn insert_prefix_entry(&mut self, hash: u64, e: Entry) {
        debug_assert!(!self.index.contains_key(&e.last_used), "tick reuse");
        self.bytes += e.bytes;
        self.index.insert(e.last_used, IndexKey::Prefix { hash });
        self.prefix.entry(hash).or_default().push(e);
    }

    /// Insert (or overwrite) the session entry for `id`, swapping the byte
    /// accounting and the index slot of any previous entry.
    pub fn insert_session_entry(&mut self, id: u64, e: Entry) {
        debug_assert!(!self.index.contains_key(&e.last_used), "tick reuse");
        self.bytes += e.bytes;
        self.index.insert(e.last_used, IndexKey::Session { id });
        if let Some(old) = self.sessions.insert(id, e) {
            self.bytes -= old.bytes;
            self.index.remove(&old.last_used);
        }
    }

    /// Refresh the recency of the prefix entry at `pos` in `hash`'s chain.
    pub fn touch_prefix(&mut self, hash: u64, pos: usize, tick: u64) {
        if let Some(e) = self.prefix.get_mut(&hash).and_then(|c| c.get_mut(pos)) {
            self.index.remove(&e.last_used);
            e.last_used = tick;
            self.index.insert(tick, IndexKey::Prefix { hash });
        }
    }

    /// Refresh the recency of session `id`'s entry.
    pub fn touch_session(&mut self, id: u64, tick: u64) {
        if let Some(e) = self.sessions.get_mut(&id) {
            self.index.remove(&e.last_used);
            e.last_used = tick;
            self.index.insert(tick, IndexKey::Session { id });
        }
    }

    /// Pin `key` against eviction (refcounted: pin/unpin calls must
    /// balance).  Pinning a key with no resident entry is legal — the pin
    /// protects whatever lands under the key later.
    pub fn pin(&mut self, key: IndexKey) {
        *self.pins.entry(key).or_insert(0) += 1;
    }

    /// Drop one pin reference on `key`; the key becomes evictable again
    /// when the refcount reaches zero.  Unpinning a never-pinned key is a
    /// no-op (lifecycle paths may race a pin that was never taken).
    pub fn unpin(&mut self, key: IndexKey) {
        if let Some(c) = self.pins.get_mut(&key) {
            *c -= 1;
            if *c == 0 {
                self.pins.remove(&key);
            }
        }
    }

    #[cfg(test)]
    pub fn n_pins(&self) -> usize {
        self.pins.len()
    }

    /// Remove the least-recently-used **unpinned** entry (across both
    /// maps): the smallest index tick whose key holds no admission pin.
    /// Returns the evicted key + entry so the caller can spill it to a
    /// disk tier; `None` when the shard is empty or everything left is
    /// pinned (the byte budget is then temporarily exceeded — pins are
    /// bounded by queued-request count, so this resolves at admission).
    fn evict_one(&mut self) -> Option<(IndexKey, Entry)> {
        let (tick, key) = self
            .index
            .iter()
            .find(|(_, k)| !self.pins.contains_key(*k))
            .map(|(&t, &k)| (t, k))?;
        self.index.remove(&tick);
        let e = match key {
            IndexKey::Prefix { hash } => {
                let chain = self.prefix.get_mut(&hash).expect("indexed chain exists");
                let pos = chain
                    .iter()
                    .position(|e| e.last_used == tick)
                    .expect("indexed entry in chain");
                let e = chain.remove(pos);
                if chain.is_empty() {
                    self.prefix.remove(&hash);
                }
                e
            }
            IndexKey::Session { id } => {
                self.sessions.remove(&id).expect("indexed session exists")
            }
        };
        self.bytes -= e.bytes;
        Some((key, e))
    }

    /// Evict LRU entries until the shard holds at most `budget` bytes
    /// (pinned entries are skipped).  Returns the victims, oldest first,
    /// for the caller to count and optionally spill to disk.
    pub fn evict_to(&mut self, budget: usize) -> Vec<(IndexKey, Entry)> {
        debug_assert_eq!(self.index.len(), self.n_entries(), "index out of sync");
        let mut victims = Vec::new();
        while self.bytes > budget {
            match self.evict_one() {
                Some(v) => victims.push(v),
                None => break,
            }
        }
        victims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tag: u32, last_used: u64) -> Entry {
        let tokens = vec![tag; 4];
        let bytes = entry_bytes(4, 1, 8, 8);
        Entry {
            variant: "fp32".into(),
            chunks: vec![4],
            tokens,
            conv: vec![tag as f32; 8],
            ssm: vec![tag as f32; 8],
            last_used,
            bytes,
        }
    }

    #[test]
    fn evicts_oldest_first_across_maps() {
        let mut s = Shard::default();
        let e1 = entry(1, 10);
        let e2 = entry(2, 5); // oldest
        let e3 = entry(3, 20);
        let per = e1.bytes;
        s.insert_prefix_entry(101, e1);
        s.insert_prefix_entry(102, e2);
        s.insert_session_entry(7, e3);
        assert_eq!(s.n_entries(), 3);
        assert_eq!(s.bytes, 3 * per);

        let victims = s.evict_to(2 * per);
        assert_eq!(victims.len(), 1);
        assert_eq!(victims[0].0, IndexKey::Prefix { hash: 102 });
        assert!(s.prefix_chain(102).is_none(), "LRU prefix entry evicted first");
        assert!(s.session(7).is_some());

        let n = s.evict_to(per).len();
        assert_eq!(n, 1);
        assert!(s.prefix_chain(101).is_none(), "next-oldest evicted second");
        assert!(s.session(7).is_some(), "newest survives");
        assert_eq!(s.bytes, per);
    }

    #[test]
    fn evict_to_zero_empties_shard() {
        let mut s = Shard::default();
        s.insert_session_entry(1, entry(1, 1));
        assert_eq!(s.evict_to(0).len(), 1);
        assert_eq!(s.n_entries(), 0);
        assert_eq!(s.bytes, 0);
        assert!(s.evict_to(0).is_empty(), "empty shard evicts nothing");
    }

    #[test]
    fn eviction_order_matches_linear_lru_scan() {
        // the ordered index must reproduce the former linear scan's policy
        // exactly: strictly ascending last_used ticks, interleaved across
        // both maps and across collision chains
        let mut s = Shard::default();
        // (tick, where): shuffled insertion order, two entries sharing one
        // prefix hash (a collision chain), sessions mixed in
        s.insert_prefix_entry(200, entry(1, 14));
        s.insert_session_entry(40, entry(2, 3));
        s.insert_prefix_entry(201, entry(3, 9));
        s.insert_prefix_entry(200, entry(4, 1)); // same hash: chained
        s.insert_session_entry(41, entry(5, 22));
        s.insert_prefix_entry(202, entry(6, 6));
        assert_eq!(s.n_entries(), 6);

        // evict one at a time and record each victim's tick by diffing the
        // surviving ticks against the previous set
        let survivors = |s: &Shard| -> Vec<u64> {
            let mut t: Vec<u64> = [200u64, 201, 202]
                .iter()
                .filter_map(|h| s.prefix_chain(*h))
                .flatten()
                .map(|e| e.last_used)
                .chain([40u64, 41].iter().filter_map(|id| s.session(*id)).map(|e| e.last_used))
                .collect();
            t.sort_unstable();
            t
        };
        let mut order = Vec::new();
        while s.n_entries() > 0 {
            let before = survivors(&s);
            let target = s.bytes - 1; // force exactly one eviction
            assert_eq!(s.evict_to(target).len(), 1);
            let after = survivors(&s);
            let victim: Vec<u64> =
                before.iter().filter(|t| !after.contains(t)).copied().collect();
            assert_eq!(victim.len(), 1);
            order.push(victim[0]);
        }
        assert_eq!(order, vec![1, 3, 6, 9, 14, 22], "must evict in LRU-tick order");
        assert_eq!(s.bytes, 0);
    }

    #[test]
    fn touch_reorders_eviction() {
        let mut s = Shard::default();
        let per = entry(0, 0).bytes;
        s.insert_prefix_entry(1, entry(1, 1));
        s.insert_prefix_entry(2, entry(2, 2));
        // refresh the older entry: the other becomes the victim
        s.touch_prefix(1, 0, 3);
        assert_eq!(s.evict_to(per).len(), 1);
        assert!(s.prefix_chain(1).is_some(), "touched entry survives");
        assert!(s.prefix_chain(2).is_none(), "untouched entry evicted");

        s.insert_session_entry(9, entry(3, 4));
        s.touch_session(9, 5);
        assert_eq!(s.evict_to(per).len(), 1);
        assert!(s.session(9).is_some(), "touched session survives");
        assert!(s.prefix_chain(1).is_none());
    }

    #[test]
    fn session_overwrite_swaps_index_slot() {
        let mut s = Shard::default();
        s.insert_session_entry(9, entry(1, 1));
        s.insert_session_entry(9, entry(2, 2)); // overwrite: old tick 1 unindexed
        assert_eq!(s.n_entries(), 1);
        s.insert_prefix_entry(5, entry(3, 3));
        // the stale tick 1 must not be evictable; LRU is the session at 2
        assert_eq!(s.evict_to(s.bytes - 1).len(), 1);
        assert!(s.session(9).is_none(), "overwritten session is the LRU victim");
        assert!(s.prefix_chain(5).is_some());
    }

    #[test]
    fn pinned_entries_are_skipped_until_unpinned() {
        let mut s = Shard::default();
        let per = entry(0, 0).bytes;
        s.insert_session_entry(9, entry(1, 1)); // oldest — the natural victim
        s.insert_prefix_entry(5, entry(2, 2));
        s.insert_prefix_entry(6, entry(3, 3));

        // pin the LRU session: eviction must pass over it
        s.pin(IndexKey::Session { id: 9 });
        let victims = s.evict_to(2 * per);
        assert_eq!(victims.len(), 1);
        assert_eq!(victims[0].0, IndexKey::Prefix { hash: 5 }, "next-oldest unpinned evicted");
        assert!(s.session(9).is_some(), "pinned session survives LRU pressure");

        // everything pinned: eviction stalls rather than evicting a pin
        s.pin(IndexKey::Prefix { hash: 6 });
        assert!(s.evict_to(0).is_empty(), "all-pinned shard evicts nothing");
        assert_eq!(s.bytes, 2 * per, "budget temporarily exceeded while pinned");

        // refcounting: double-pin needs double-unpin
        s.pin(IndexKey::Session { id: 9 });
        s.unpin(IndexKey::Session { id: 9 });
        assert!(s.evict_to(per).is_empty(), "still one pin ref on each entry");
        s.unpin(IndexKey::Session { id: 9 });
        s.unpin(IndexKey::Prefix { hash: 6 });
        assert_eq!(s.n_pins(), 0);
        assert_eq!(s.evict_to(0).len(), 2, "unpinned entries evict normally");

        // unpinning a never-pinned key is a harmless no-op
        s.unpin(IndexKey::Session { id: 777 });
    }

    #[test]
    fn entry_bytes_accounts_payload_and_overhead() {
        assert_eq!(entry_bytes(0, 0, 0, 0), ENTRY_OVERHEAD);
        assert_eq!(
            entry_bytes(10, 2, 100, 200),
            4 * 300 + 4 * 10 + 8 * 2 + ENTRY_OVERHEAD
        );
    }
}
