//! Shard internals of the [`StateCache`](super::StateCache): entries, byte
//! accounting, and least-recently-used eviction.
//!
//! A shard owns two maps under one mutex — content-hashed prefix entries
//! (with a collision chain per hash, because a hit must *never* be decided
//! by the hash alone) and per-session end-of-turn entries — plus the
//! running byte total the eviction policy keeps under the shard's slice of
//! the global budget.

use std::collections::HashMap;

/// Fixed per-entry overhead charged on top of the payload buffers
/// (map slots, Vec headers, LRU bookkeeping) so the byte budget tracks
/// real residency, not just float counts.
pub(crate) const ENTRY_OVERHEAD: usize = 64;

/// Bytes one cached snapshot is accounted at.
pub(crate) fn entry_bytes(
    n_tokens: usize,
    n_chunks: usize,
    conv_len: usize,
    ssm_len: usize,
) -> usize {
    4 * (conv_len + ssm_len) + 4 * n_tokens + 8 * n_chunks + ENTRY_OVERHEAD
}

/// One cached snapshot: the recurrent (conv, ssm) state after consuming
/// `tokens`, plus everything a hit must verify.
#[derive(Debug, Clone)]
pub(crate) struct Entry {
    /// quantization variant the state was computed under — quantized
    /// variants calibrate per chunk, so states are never variant-portable
    pub variant: String,
    /// exact prefill-chunk sequence that produced the state (prefix
    /// entries; empty for session entries, whose provenance is the
    /// previous turn's serving trajectory itself).  Verified on hit:
    /// a state reached through a different chunking is a different state
    /// for the quantized variants.
    pub chunks: Vec<usize>,
    /// the full token prefix the state has consumed — verified on every
    /// hit, so a hash collision can never seed another request's state
    pub tokens: Vec<u32>,
    pub conv: Vec<f32>,
    pub ssm: Vec<f32>,
    /// LRU clock value at last insert/hit (global monotonic tick)
    pub last_used: u64,
    /// accounted size ([`entry_bytes`])
    pub bytes: usize,
}

impl Entry {
    /// Does this entry describe exactly this (variant, chunking, tokens)?
    pub fn matches(&self, variant: &str, chunks: &[usize], tokens: &[u32]) -> bool {
        self.variant == variant && self.chunks == chunks && self.tokens == tokens
    }
}

/// One lock domain of the cache.
#[derive(Debug, Default)]
pub(crate) struct Shard {
    /// content hash -> collision chain of prefix entries
    pub prefix: HashMap<u64, Vec<Entry>>,
    /// session id -> latest end-of-turn entry
    pub sessions: HashMap<u64, Entry>,
    /// accounted bytes across both maps
    pub bytes: usize,
}

/// What `evict_one` decided to remove.
enum Victim {
    Prefix { hash: u64, pos: usize },
    Session { id: u64 },
}

impl Shard {
    pub fn n_entries(&self) -> usize {
        self.prefix.values().map(|c| c.len()).sum::<usize>() + self.sessions.len()
    }

    /// Remove the least-recently-used entry (across both maps).  Returns
    /// false when the shard is already empty.
    fn evict_one(&mut self) -> bool {
        let mut best: Option<(u64, Victim)> = None;
        for (h, chain) in &self.prefix {
            for (i, e) in chain.iter().enumerate() {
                if best.as_ref().is_none_or(|(t, _)| e.last_used < *t) {
                    best = Some((e.last_used, Victim::Prefix { hash: *h, pos: i }));
                }
            }
        }
        for (id, e) in &self.sessions {
            if best.as_ref().is_none_or(|(t, _)| e.last_used < *t) {
                best = Some((e.last_used, Victim::Session { id: *id }));
            }
        }
        match best {
            None => false,
            Some((_, Victim::Prefix { hash, pos })) => {
                let chain = self.prefix.get_mut(&hash).expect("victim chain");
                let e = chain.remove(pos);
                self.bytes -= e.bytes;
                if chain.is_empty() {
                    self.prefix.remove(&hash);
                }
                true
            }
            Some((_, Victim::Session { id })) => {
                let e = self.sessions.remove(&id).expect("victim session");
                self.bytes -= e.bytes;
                true
            }
        }
    }

    /// Evict LRU entries until the shard holds at most `budget` bytes.
    /// Returns how many entries were evicted.
    pub fn evict_to(&mut self, budget: usize) -> u64 {
        let mut n = 0u64;
        while self.bytes > budget {
            if !self.evict_one() {
                break;
            }
            n += 1;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tag: u32, last_used: u64) -> Entry {
        let tokens = vec![tag; 4];
        let bytes = entry_bytes(4, 1, 8, 8);
        Entry {
            variant: "fp32".into(),
            chunks: vec![4],
            tokens,
            conv: vec![tag as f32; 8],
            ssm: vec![tag as f32; 8],
            last_used,
            bytes,
        }
    }

    #[test]
    fn evicts_oldest_first_across_maps() {
        let mut s = Shard::default();
        let e1 = entry(1, 10);
        let e2 = entry(2, 5); // oldest
        let e3 = entry(3, 20);
        let per = e1.bytes;
        s.bytes = 3 * per;
        s.prefix.insert(101, vec![e1]);
        s.prefix.insert(102, vec![e2]);
        s.sessions.insert(7, e3);
        assert_eq!(s.n_entries(), 3);

        let n = s.evict_to(2 * per);
        assert_eq!(n, 1);
        assert!(!s.prefix.contains_key(&102), "LRU prefix entry evicted first");
        assert!(s.sessions.contains_key(&7));

        let n = s.evict_to(per);
        assert_eq!(n, 1);
        assert!(!s.prefix.contains_key(&101), "next-oldest evicted second");
        assert!(s.sessions.contains_key(&7), "newest survives");
        assert_eq!(s.bytes, per);
    }

    #[test]
    fn evict_to_zero_empties_shard() {
        let mut s = Shard::default();
        let e = entry(1, 1);
        s.bytes = e.bytes;
        s.sessions.insert(1, e);
        assert_eq!(s.evict_to(0), 1);
        assert_eq!(s.n_entries(), 0);
        assert_eq!(s.bytes, 0);
        assert_eq!(s.evict_to(0), 0, "empty shard evicts nothing");
    }

    #[test]
    fn entry_bytes_accounts_payload_and_overhead() {
        assert_eq!(entry_bytes(0, 0, 0, 0), ENTRY_OVERHEAD);
        assert_eq!(
            entry_bytes(10, 2, 100, 200),
            4 * 300 + 4 * 10 + 8 * 2 + ENTRY_OVERHEAD
        );
    }
}
