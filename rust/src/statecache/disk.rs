//! Disk spill tier for the [`StateCache`](super::StateCache).
//!
//! Mamba2 snapshots are constant-size flat `f32` buffers, so persisting
//! one is a single sequential write — no serialization framework needed.
//! The tier is a directory of one file per entry:
//!
//! ```text
//! sess_<session id, 16 hex>.state     session end-of-turn snapshots
//! pfx_<content hash, 16 hex>.state    bucket-aligned prefix snapshots
//! ```
//!
//! Sessions are **written through** on every insert (a session snapshot
//! is the only copy of a conversation's state — losing it to process
//! death is exactly what `--state-cache-dir` exists to prevent).  Prefix
//! entries are written lazily, when the memory LRU evicts them: the
//! memory tier stays the hot path and disk absorbs the overflow.
//!
//! Reads fall through memory → disk in the cache's lookup methods.  A
//! disk hit runs the *same* verification as a memory hit (variant +
//! chunk plan + full token prefix; see the exactness contract in the
//! parent module) before any state is seeded — and is then re-admitted
//! to the memory tier so repeat hits stay off the filesystem.
//!
//! Every load error — missing file, short read, bad magic, wrong
//! version, truncated payload — degrades to a cache miss (corrupt files
//! are counted and deleted, never trusted).  Writes go to a temp file in
//! the same directory and `rename` into place, so a crash mid-write can
//! never leave a half-written `.state` file under a live key.

use std::fs::{self, File};
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::store::Entry;

/// `b"FMSC"` little-endian: FastMamba State Cache.
const MAGIC: u32 = u32::from_le_bytes(*b"FMSC");
const VERSION: u16 = 1;

const KIND_PREFIX: u8 = 0;
const KIND_SESSION: u8 = 1;

/// What a stored snapshot is keyed by — mirrors
/// [`store::IndexKey`](super::store::IndexKey) but is `pub(crate)` here
/// so the cache can spill eviction victims without exposing shard
/// internals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum DiskKey {
    Prefix { hash: u64 },
    Session { id: u64 },
}

impl DiskKey {
    fn file_name(self) -> String {
        match self {
            DiskKey::Prefix { hash } => format!("pfx_{hash:016x}.state"),
            DiskKey::Session { id } => format!("sess_{id:016x}.state"),
        }
    }

    fn kind_byte(self) -> u8 {
        match self {
            DiskKey::Prefix { .. } => KIND_PREFIX,
            DiskKey::Session { .. } => KIND_SESSION,
        }
    }
}

/// Counters for the tier, readable at any time (all relaxed atomics —
/// they feed `/statusz` and the stats summary, not control flow).
#[derive(Debug, Default)]
pub struct DiskStats {
    pub writes: AtomicU64,
    pub write_bytes: AtomicU64,
    pub reads: AtomicU64,
    pub read_hits: AtomicU64,
    pub read_bytes: AtomicU64,
    /// files rejected by validation (bad magic/version/truncation) and
    /// deleted; also counts files that failed mid-read
    pub corrupt: AtomicU64,
}

/// Snapshot of [`DiskStats`] as plain values.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiskStatsSnapshot {
    pub writes: u64,
    pub write_bytes: u64,
    pub reads: u64,
    pub read_hits: u64,
    pub read_bytes: u64,
    pub corrupt: u64,
}

/// The on-disk tier: a directory of snapshot files.
#[derive(Debug)]
pub struct DiskTier {
    dir: PathBuf,
    stats: DiskStats,
    /// monotonic discriminator for temp-file names, so two threads
    /// spilling the same key never write through each other's temp file
    temp_seq: AtomicU64,
}

impl DiskTier {
    /// Open (creating if needed) the tier rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> io::Result<Self> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        Ok(Self { dir, stats: DiskStats::default(), temp_seq: AtomicU64::new(0) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn stats(&self) -> DiskStatsSnapshot {
        DiskStatsSnapshot {
            writes: self.stats.writes.load(Ordering::Relaxed),
            write_bytes: self.stats.write_bytes.load(Ordering::Relaxed),
            reads: self.stats.reads.load(Ordering::Relaxed),
            read_hits: self.stats.read_hits.load(Ordering::Relaxed),
            read_bytes: self.stats.read_bytes.load(Ordering::Relaxed),
            corrupt: self.stats.corrupt.load(Ordering::Relaxed),
        }
    }

    /// Number of `.state` files currently in the directory (test/ops
    /// introspection; scans the directory).
    pub fn n_files(&self) -> usize {
        fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter(|e| {
                        e.path().extension().map(|x| x == "state").unwrap_or(false)
                    })
                    .count()
            })
            .unwrap_or(0)
    }

    /// Persist `entry` under `key`, replacing any previous file.  Errors
    /// are swallowed (the disk tier is best-effort — a failed spill just
    /// means the snapshot is gone, which is what would have happened with
    /// no disk tier at all).
    pub(crate) fn store(&self, key: DiskKey, entry: &Entry) {
        let payload = encode(key, entry);
        let n = self.temp_seq.fetch_add(1, Ordering::Relaxed);
        let tmp = self.dir.join(format!(".tmp_{n:x}_{}", key.file_name()));
        let fin = self.dir.join(key.file_name());
        let write = (|| -> io::Result<()> {
            let mut f = File::create(&tmp)?;
            f.write_all(&payload)?;
            f.sync_data()?;
            fs::rename(&tmp, &fin)
        })();
        match write {
            Ok(()) => {
                self.stats.writes.fetch_add(1, Ordering::Relaxed);
                self.stats.write_bytes.fetch_add(payload.len() as u64, Ordering::Relaxed);
            }
            Err(_) => {
                let _ = fs::remove_file(&tmp);
            }
        }
    }

    /// Load the snapshot stored under `key`.  Any failure (absent file,
    /// corruption, version mismatch) is a miss; corrupt files are deleted
    /// so they cannot fail the same way twice.
    pub(crate) fn load(&self, key: DiskKey) -> Option<Entry> {
        self.stats.reads.fetch_add(1, Ordering::Relaxed);
        let path = self.dir.join(key.file_name());
        let mut buf = Vec::new();
        match File::open(&path).and_then(|mut f| f.read_to_end(&mut buf)) {
            Ok(_) => {}
            Err(_) => return None, // absent (or unreadable): plain miss
        }
        match decode(key, &buf) {
            Some(e) => {
                self.stats.read_hits.fetch_add(1, Ordering::Relaxed);
                self.stats.read_bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
                Some(e)
            }
            None => {
                self.stats.corrupt.fetch_add(1, Ordering::Relaxed);
                let _ = fs::remove_file(&path);
                None
            }
        }
    }

    /// Remove the file for `key`, if present (session overwrite keeps
    /// only the latest turn; the write path renames over the old file,
    /// so this is only needed when a key is retired outright).
    #[allow(dead_code)]
    pub(crate) fn remove(&self, key: DiskKey) {
        let _ = fs::remove_file(self.dir.join(key.file_name()));
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    put_u32(out, vs.len() as u32);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn encode(key: DiskKey, e: &Entry) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        32 + e.variant.len() + 8 * e.chunks.len() + 4 * e.tokens.len()
            + 4 * (e.conv.len() + e.ssm.len()),
    );
    put_u32(&mut out, MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(key.kind_byte());
    put_u32(&mut out, e.variant.len() as u32);
    out.extend_from_slice(e.variant.as_bytes());
    put_u32(&mut out, e.chunks.len() as u32);
    for &c in &e.chunks {
        out.extend_from_slice(&(c as u64).to_le_bytes());
    }
    put_u32(&mut out, e.tokens.len() as u32);
    for &t in &e.tokens {
        out.extend_from_slice(&t.to_le_bytes());
    }
    put_f32s(&mut out, &e.conv);
    put_f32s(&mut out, &e.ssm);
    out
}

/// Bounds-checked little-endian reader over a loaded file.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Some(s)
    }

    fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|s| s[0])
    }

    fn u16(&mut self) -> Option<u16> {
        self.take(2).map(|s| u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|s| {
            u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]])
        })
    }

    fn f32(&mut self) -> Option<f32> {
        self.u32().map(f32::from_bits)
    }
}

fn decode(key: DiskKey, buf: &[u8]) -> Option<Entry> {
    let mut c = Cursor { buf, pos: 0 };
    if c.u32()? != MAGIC || c.u16()? != VERSION || c.u8()? != key.kind_byte() {
        return None;
    }
    let vlen = c.u32()? as usize;
    let variant = String::from_utf8(c.take(vlen)?.to_vec()).ok()?;
    let n_chunks = c.u32()? as usize;
    let mut chunks = Vec::with_capacity(n_chunks.min(1 << 16));
    for _ in 0..n_chunks {
        chunks.push(c.u64()? as usize);
    }
    let n_tokens = c.u32()? as usize;
    let mut tokens = Vec::with_capacity(n_tokens.min(1 << 20));
    for _ in 0..n_tokens {
        tokens.push(c.u32()?);
    }
    let conv_len = c.u32()? as usize;
    let mut conv = Vec::with_capacity(conv_len.min(1 << 24));
    for _ in 0..conv_len {
        conv.push(c.f32()?);
    }
    let ssm_len = c.u32()? as usize;
    let mut ssm = Vec::with_capacity(ssm_len.min(1 << 24));
    for _ in 0..ssm_len {
        ssm.push(c.f32()?);
    }
    if c.pos != buf.len() {
        return None; // trailing garbage: treat as corrupt
    }
    let bytes =
        super::store::entry_bytes(tokens.len(), chunks.len(), conv.len(), ssm.len());
    Some(Entry { variant, chunks, tokens, conv, ssm, last_used: 0, bytes })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fastmamba_disk_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn entry(tag: u32) -> Entry {
        let tokens: Vec<u32> = (0..6).map(|i| i * 7 + tag).collect();
        Entry {
            variant: "fastmamba".into(),
            chunks: vec![2, 4],
            tokens: tokens.clone(),
            conv: (0..8).map(|i| i as f32 + tag as f32 * 0.5).collect(),
            ssm: (0..8).map(|i| -(i as f32) - tag as f32).collect(),
            last_used: 99, // not persisted: recency restarts on reload
            bytes: super::super::store::entry_bytes(6, 2, 8, 8),
        }
    }

    #[test]
    fn disk_roundtrip_preserves_entry_exactly() {
        let dir = tmpdir("roundtrip");
        let tier = DiskTier::open(&dir).unwrap();
        let e = entry(1);
        let key = DiskKey::Session { id: 42 };
        tier.store(key, &e);
        assert_eq!(tier.n_files(), 1);

        let back = tier.load(key).expect("stored entry loads");
        assert_eq!(back.variant, e.variant);
        assert_eq!(back.chunks, e.chunks);
        assert_eq!(back.tokens, e.tokens);
        assert_eq!(back.conv, e.conv);
        assert_eq!(back.ssm, e.ssm);
        assert_eq!(back.bytes, e.bytes, "accounted size recomputed on load");
        assert_eq!(back.last_used, 0, "recency is a memory-tier concern");

        let s = tier.stats();
        assert_eq!(s.writes, 1);
        assert_eq!(s.read_hits, 1);
        assert!(s.write_bytes > 0 && s.read_bytes == s.write_bytes);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn absent_key_is_a_plain_miss() {
        let dir = tmpdir("absent");
        let tier = DiskTier::open(&dir).unwrap();
        assert!(tier.load(DiskKey::Prefix { hash: 7 }).is_none());
        let s = tier.stats();
        assert_eq!(s.reads, 1);
        assert_eq!(s.read_hits, 0);
        assert_eq!(s.corrupt, 0, "absence is not corruption");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_files_are_rejected_and_deleted() {
        let dir = tmpdir("corrupt");
        let tier = DiskTier::open(&dir).unwrap();
        let key = DiskKey::Prefix { hash: 0xAB };
        let good = encode(key, &entry(2));

        // every strict prefix of a valid file must be rejected (truncation
        // at any byte), as must bad magic and a flipped version
        for cut in [0, 4, 6, 7, 11, good.len() / 2, good.len() - 1] {
            assert!(decode(key, &good[..cut]).is_none(), "truncated at {cut}");
        }
        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        assert!(decode(key, &bad_magic).is_none());
        let mut bad_version = good.clone();
        bad_version[4] ^= 0xFF;
        assert!(decode(key, &bad_version).is_none());
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(decode(key, &trailing).is_none(), "trailing bytes rejected");
        // a session-kind file must not decode under a prefix key
        assert!(decode(DiskKey::Session { id: 0xAB }, &good).is_none());

        // a corrupt file on disk counts and is removed
        fs::write(dir.join(key.file_name()), &good[..good.len() - 3]).unwrap();
        assert!(tier.load(key).is_none());
        assert_eq!(tier.stats().corrupt, 1);
        assert_eq!(tier.n_files(), 0, "corrupt file deleted");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn store_overwrites_and_remove_deletes() {
        let dir = tmpdir("overwrite");
        let tier = DiskTier::open(&dir).unwrap();
        let key = DiskKey::Session { id: 9 };
        tier.store(key, &entry(1));
        tier.store(key, &entry(2)); // rename-over: still one file
        assert_eq!(tier.n_files(), 1);
        let back = tier.load(key).unwrap();
        assert_eq!(back.tokens, entry(2).tokens, "latest write wins");
        tier.remove(key);
        assert!(tier.load(key).is_none());
        assert_eq!(tier.n_files(), 0);
        fs::remove_dir_all(&dir).unwrap();
    }
}
