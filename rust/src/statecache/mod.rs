//! SSM state cache: O(state) prefix reuse and multi-turn sessions.
//!
//! Mamba2 serving has a caching advantage transformers can only
//! approximate: the per-request state is a **constant-size** recurrent
//! pair (conv window + SSM hidden state), so "prompt caching" costs one
//! O(state) snapshot copy per hit instead of O(tokens) of KV memory.
//! This module is that subsystem — a content-addressed store mapping
//!
//! ```text
//! (variant, prefill-chunk sequence, token prefix)  ->  (conv, ssm) snapshot
//! ```
//!
//! at **bucket-aligned chunk boundaries**, plus per-session end-of-turn
//! entries keyed by [`Request::session_id`], shared across all
//! [`serve_pool`] workers through one `Arc<StateCache>` with interior
//! sharded locking.
//!
//! ## Exactness contract
//!
//! A prefix hit is **bit-exact** with the uncached path: entries are keyed
//! by the exact chunk sequence that produced them (not just the token
//! prefix), because the quantized variants calibrate per prefill chunk —
//! a state reached through a different chunking is a different state.  A
//! request only hits entries whose chunk sequence is a prefix of its own
//! canonical chunk plan, so seeding from the snapshot and prefilling the
//! remaining chunks runs the *identical* call sequence the cache-off path
//! would (the property [`backend::conformance::check_state_reuse`]
//! certifies per backend).  Hits additionally verify the stored token
//! prefix — a hash collision can never seed another request's state.
//!
//! Session entries relax this: they capture the end-of-turn state of a
//! serving *trajectory* (prefill + decode steps), so a resumed turn
//! continues the exact conversation state with zero prefix recompute, but
//! the suffix is chunk-planned fresh — equivalent to the uncached path
//! for `fp32` (chunking-invariant argmax, see
//! `conformance::check_prefill_chunking_equivalence`), and a documented
//! trade for the per-chunk-calibrated quantized variants.
//!
//! ## Eviction
//!
//! [`CacheConfig::max_bytes`] bounds residency.  The budget is split
//! evenly over the lock shards; inserting past a shard's slice evicts
//! least-recently-used entries (hits refresh recency) until it fits.
//! Victim selection is O(log n) through an ordered tick index per shard
//! (`store::Shard`) — no per-eviction scan.  Entries larger than a shard's
//! whole slice are not cached in memory at all.
//!
//! Eviction is **admission-aware**: the scheduler pins the keys a queued
//! or preempted request will resume from ([`StateCache::pin_request`],
//! [`StateCache::pin_session`]), and the LRU skips pinned keys — so the
//! cache can never evict a snapshot the scheduler is committed to seeding
//! from.  Pins are refcounted and bounded by queue depth; an all-pinned
//! shard temporarily exceeds its budget rather than break a promise.
//!
//! ## Disk tier
//!
//! With [`StateCache::with_disk`] (`serve --state-cache-dir`), the cache
//! grows a persistence tier ([`disk::DiskTier`]): session entries are
//! written through on insert, prefix entries spill to disk when the
//! memory LRU evicts them, and lookups fall through memory → disk with
//! the same full verification (variant + chunk plan + token prefix) —
//! so a restarted process, or another process sharing the directory,
//! serves a session resume as a cache hit instead of a cold prefill.
//!
//! [`Request::session_id`]: crate::coordinator::Request::session_id
//! [`serve_pool`]: crate::coordinator::serve_pool
//! [`backend::conformance::check_state_reuse`]: crate::backend::conformance::check_state_reuse

pub mod disk;
mod store;

use std::collections::hash_map::DefaultHasher;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use disk::DiskKey;
pub use disk::{DiskStatsSnapshot, DiskTier};
use store::{entry_bytes, Entry, IndexKey, Shard};

/// Sizing of a [`StateCache`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheConfig {
    /// total byte budget across all shards (snapshot payload + accounted
    /// per-entry overhead); 0 disables caching entirely
    pub max_bytes: usize,
    /// lock shards (clamped to >= 1).  More shards = less contention
    /// between pool workers; each shard owns `max_bytes / shards` of the
    /// budget and evicts independently.
    pub shards: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self { max_bytes: 64 << 20, shards: 8 }
    }
}

impl CacheConfig {
    /// Budget in MiB with the default shard count — the CLI's
    /// `--state-cache-mb` flag.
    pub fn with_mb(mb: usize) -> Self {
        Self { max_bytes: mb << 20, ..Self::default() }
    }
}

/// Aggregate counters, readable at any time via [`StateCache::stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// lookups that returned a snapshot (prefix or session)
    pub hits: u64,
    /// lookups that probed at least one key and found nothing
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// entries currently resident
    pub entries: usize,
    /// bytes currently resident (accounted, across all shards)
    pub bytes_resident: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "hits={} misses={} hit_rate={:.0}% insertions={} evictions={} \
             entries={} resident={:.2}MiB",
            self.hits,
            self.misses,
            self.hit_rate() * 100.0,
            self.insertions,
            self.evictions,
            self.entries,
            self.bytes_resident as f64 / (1 << 20) as f64,
        )
    }
}

/// A prefix-cache hit: the snapshot covers `covered` prompt tokens,
/// produced by the first `chunks_used` chunks of the request's plan.
#[derive(Debug, Clone)]
pub struct PrefixHit {
    pub covered: usize,
    pub chunks_used: usize,
    pub conv: Vec<f32>,
    pub ssm: Vec<f32>,
}

/// A session-cache hit: the previous turn's end state covers `covered`
/// tokens of the new prompt (always leaving at least one token to feed).
#[derive(Debug, Clone)]
pub struct SessionHit {
    pub covered: usize,
    pub conv: Vec<f32>,
    pub ssm: Vec<f32>,
}

/// The shared, internally synchronized snapshot store.  All methods take
/// `&self`; clone an `Arc<StateCache>` into every worker/engine.
pub struct StateCache {
    shards: Vec<Mutex<Shard>>,
    /// per-shard slice of [`CacheConfig::max_bytes`]
    shard_budget: usize,
    max_bytes: usize,
    /// global LRU clock
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
    /// optional persistence tier (`--state-cache-dir`)
    disk: Option<DiskTier>,
}

impl fmt::Debug for StateCache {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("StateCache")
            .field("max_bytes", &self.max_bytes)
            .field("shards", &self.shards.len())
            .finish_non_exhaustive()
    }
}

impl StateCache {
    pub fn new(cfg: CacheConfig) -> Self {
        let n = cfg.shards.max(1);
        Self {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            shard_budget: cfg.max_bytes / n,
            max_bytes: cfg.max_bytes,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            disk: None,
        }
    }

    /// Attach a disk persistence tier: sessions write through, prefix
    /// eviction victims spill, lookups fall through memory → disk.
    pub fn with_disk(mut self, tier: DiskTier) -> Self {
        self.disk = Some(tier);
        self
    }

    /// The attached disk tier, if any.
    pub fn disk(&self) -> Option<&DiskTier> {
        self.disk.as_ref()
    }

    /// Disk-tier counters (`None` when no tier is attached).
    pub fn disk_stats(&self) -> Option<DiskStatsSnapshot> {
        self.disk.as_ref().map(|d| d.stats())
    }

    pub fn max_bytes(&self) -> usize {
        self.max_bytes
    }

    /// Per-shard `(entries, accounted bytes)` snapshot, in shard order.
    /// Each shard is locked briefly in turn, so the rows are individually
    /// consistent but the vector is not a single atomic cut — fine for the
    /// `/statusz` occupancy table this feeds.
    pub fn shard_occupancy(&self) -> Vec<(usize, usize)> {
        self.shards
            .iter()
            .map(|s| {
                let sh = s.lock().unwrap();
                (sh.n_entries(), sh.bytes)
            })
            .collect()
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Content hash of a prefix key — public so callers that pin/unpin by
    /// hash (the scheduler's admission pins) use the exact same keying as
    /// the lookups.
    pub fn prefix_hash(variant: &str, chunks: &[usize], tokens: &[u32]) -> u64 {
        let mut h = DefaultHasher::new();
        variant.hash(&mut h);
        chunks.hash(&mut h);
        tokens.hash(&mut h);
        h.finish()
    }

    /// The `(chunks used, token boundary)` pairs of `chunks` laid over
    /// `tokens`, shortest first — the probe points of a prefill plan.
    fn boundary_plan(tokens: &[u32], chunks: &[usize]) -> Vec<(usize, usize)> {
        let mut bounds = Vec::with_capacity(chunks.len());
        let mut boundary = 0usize;
        for (i, &c) in chunks.iter().enumerate() {
            boundary += c;
            if boundary > tokens.len() {
                break; // malformed plan; probe only what the prompt covers
            }
            bounds.push((i + 1, boundary));
        }
        bounds
    }

    fn session_shard(&self, id: u64) -> &Mutex<Shard> {
        let mut h = DefaultHasher::new();
        id.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    fn shard_for(&self, hash: u64) -> &Mutex<Shard> {
        &self.shards[(hash as usize) % self.shards.len()]
    }

    /// Pin session `id`'s entry against eviction (refcounted; a pin may
    /// precede the entry — it guards the key).  Used by the scheduler for
    /// preemption snapshots and queued session turns.
    pub fn pin_session(&self, id: u64) {
        self.session_shard(id).lock().unwrap().pin(IndexKey::Session { id });
    }

    /// Balance one [`pin_session`](Self::pin_session).
    pub fn unpin_session(&self, id: u64) {
        self.session_shard(id).lock().unwrap().unpin(IndexKey::Session { id });
    }

    /// Pin the prefix entry stored under `hash` (from
    /// [`prefix_hash`](Self::prefix_hash)) against eviction.
    pub fn pin_prefix_hashed(&self, hash: u64) {
        self.shard_for(hash).lock().unwrap().pin(IndexKey::Prefix { hash });
    }

    /// Balance one [`pin_prefix_hashed`](Self::pin_prefix_hashed).
    pub fn unpin_prefix_hashed(&self, hash: u64) {
        self.shard_for(hash).lock().unwrap().unpin(IndexKey::Prefix { hash });
    }

    /// Pin every snapshot a queued request could be admitted from — each
    /// bucket-boundary prefix of its prompt plus its session entry — so
    /// LRU pressure between enqueue and admission cannot evict a state
    /// the scheduler is about to seed from.  Must be balanced by
    /// [`unpin_request`](Self::unpin_request) with identical arguments
    /// when the request is admitted or terminated unadmitted.
    pub fn pin_request(
        &self,
        variant: &str,
        tokens: &[u32],
        chunks: &[usize],
        session: Option<u64>,
    ) {
        for (nc, b) in Self::boundary_plan(tokens, chunks) {
            self.pin_prefix_hashed(Self::prefix_hash(variant, &chunks[..nc], &tokens[..b]));
        }
        if let Some(id) = session {
            self.pin_session(id);
        }
    }

    /// Balance one [`pin_request`](Self::pin_request).
    pub fn unpin_request(
        &self,
        variant: &str,
        tokens: &[u32],
        chunks: &[usize],
        session: Option<u64>,
    ) {
        for (nc, b) in Self::boundary_plan(tokens, chunks) {
            self.unpin_prefix_hashed(Self::prefix_hash(
                variant,
                &chunks[..nc],
                &tokens[..b],
            ));
        }
        if let Some(id) = session {
            self.unpin_session(id);
        }
    }

    /// Spill eviction victims to the disk tier.  Called with no shard
    /// lock held (disk writes must never extend a lock hold).  Session
    /// victims are skipped: they were written through at insert, so the
    /// disk copy is already current.
    fn spill(&self, victims: Vec<(IndexKey, Entry)>) {
        let Some(disk) = &self.disk else { return };
        for (key, e) in &victims {
            if let IndexKey::Prefix { hash } = key {
                disk.store(DiskKey::Prefix { hash: *hash }, e);
            }
        }
    }

    /// Re-admit a disk-loaded entry to the memory tier so repeat hits
    /// stay off the filesystem.  Oversized entries stay disk-only.
    fn readmit(&self, key: IndexKey, mut e: Entry) {
        if e.bytes > self.shard_budget {
            return;
        }
        e.last_used = self.next_tick();
        let victims = {
            let mut shard = match key {
                IndexKey::Prefix { hash } => self.shard_for(hash).lock().unwrap(),
                IndexKey::Session { id } => self.session_shard(id).lock().unwrap(),
            };
            match key {
                IndexKey::Prefix { hash } => {
                    // a racing readmit may have beaten us: refresh, don't chain a dup
                    let existing = shard.prefix_chain(hash).and_then(|c| {
                        c.iter().position(|x| x.matches(&e.variant, &e.chunks, &e.tokens))
                    });
                    match existing {
                        Some(pos) => {
                            let t = e.last_used;
                            shard.touch_prefix(hash, pos, t);
                        }
                        None => shard.insert_prefix_entry(hash, e),
                    }
                }
                IndexKey::Session { id } => shard.insert_session_entry(id, e),
            }
            shard.evict_to(self.shard_budget)
        };
        self.evictions.fetch_add(victims.len() as u64, Ordering::Relaxed);
        self.spill(victims);
    }

    /// Longest cached prefix of `tokens` at the boundaries of `chunks`
    /// (the request's canonical prefill plan), probed longest-first.
    /// `variant`, the chunk-sequence prefix, and the token prefix must all
    /// match the stored entry exactly.
    pub fn lookup_prefix(
        &self,
        variant: &str,
        tokens: &[u32],
        chunks: &[usize],
    ) -> Option<PrefixHit> {
        let bounds = Self::boundary_plan(tokens, chunks);
        for &(nc, b) in bounds.iter().rev() {
            let h = Self::prefix_hash(variant, &chunks[..nc], &tokens[..b]);
            if let Some(hit) =
                self.lookup_prefix_hashed(h, variant, &chunks[..nc], &tokens[..b])
            {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(hit);
            }
        }
        if !bounds.is_empty() {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        None
    }

    /// One exact-key probe.  Split out (and hash-parameterized) so the
    /// collision-safety tests can force two keys onto one hash and prove
    /// the stored token prefix — not the hash — decides the hit.
    fn lookup_prefix_hashed(
        &self,
        hash: u64,
        variant: &str,
        chunks: &[usize],
        tokens: &[u32],
    ) -> Option<PrefixHit> {
        let tick = self.next_tick();
        {
            let mut shard = self.shard_for(hash).lock().unwrap();
            let found = shard.prefix_chain(hash).and_then(|chain| {
                chain
                    .iter()
                    .enumerate()
                    .find(|(_, e)| e.matches(variant, chunks, tokens))
                    .map(|(pos, e)| {
                        (
                            pos,
                            PrefixHit {
                                covered: tokens.len(),
                                chunks_used: chunks.len(),
                                conv: e.conv.clone(),
                                ssm: e.ssm.clone(),
                            },
                        )
                    })
            });
            if let Some((pos, hit)) = found {
                shard.touch_prefix(hash, pos, tick);
                return Some(hit);
            }
        }
        // memory miss: fall through to the disk tier.  A disk hit passes
        // the exact same verification as a memory hit before any state is
        // seeded, then re-admits so the next hit is in-memory.
        let disk = self.disk.as_ref()?;
        let e = disk.load(DiskKey::Prefix { hash })?;
        if !e.matches(variant, chunks, tokens) {
            return None;
        }
        let hit = PrefixHit {
            covered: tokens.len(),
            chunks_used: chunks.len(),
            conv: e.conv.clone(),
            ssm: e.ssm.clone(),
        };
        self.readmit(IndexKey::Prefix { hash }, e);
        Some(hit)
    }

    /// Insert a boundary snapshot: the state after prefilling exactly
    /// `chunks` over `tokens` (so `chunks` must sum to `tokens.len()`).
    /// Re-inserting an existing key only refreshes its recency.
    pub fn insert_prefix(
        &self,
        variant: &str,
        tokens: &[u32],
        chunks: &[usize],
        conv: &[f32],
        ssm: &[f32],
    ) {
        debug_assert_eq!(
            chunks.iter().sum::<usize>(),
            tokens.len(),
            "prefix snapshot chunks must cover the token prefix exactly"
        );
        let h = Self::prefix_hash(variant, chunks, tokens);
        self.insert_prefix_hashed(h, variant, tokens, chunks, conv, ssm);
    }

    fn insert_prefix_hashed(
        &self,
        hash: u64,
        variant: &str,
        tokens: &[u32],
        chunks: &[usize],
        conv: &[f32],
        ssm: &[f32],
    ) {
        let bytes = entry_bytes(tokens.len(), chunks.len(), conv.len(), ssm.len());
        if bytes > self.shard_budget {
            return; // would evict the whole shard and still not fit
        }
        let tick = self.next_tick();
        let mut shard = self.shard_for(hash).lock().unwrap();
        let existing = shard
            .prefix_chain(hash)
            .and_then(|c| c.iter().position(|e| e.matches(variant, chunks, tokens)));
        if let Some(pos) = existing {
            shard.touch_prefix(hash, pos, tick); // dedupe: refresh only
            return;
        }
        shard.insert_prefix_entry(
            hash,
            Entry {
                variant: variant.to_string(),
                chunks: chunks.to_vec(),
                tokens: tokens.to_vec(),
                conv: conv.to_vec(),
                ssm: ssm.to_vec(),
                last_used: tick,
                bytes,
            },
        );
        let victims = shard.evict_to(self.shard_budget);
        drop(shard);
        self.insertions.fetch_add(1, Ordering::Relaxed);
        self.evictions.fetch_add(victims.len() as u64, Ordering::Relaxed);
        self.spill(victims);
    }

    /// The previous turn of session `id` whose consumed tokens are a
    /// strict prefix of `tokens` (leaving at least one token to feed the
    /// decode path).  Variant and the full token prefix are verified.
    pub fn lookup_session(
        &self,
        id: u64,
        variant: &str,
        tokens: &[u32],
    ) -> Option<SessionHit> {
        let tick = self.next_tick();
        let hit = {
            let mut shard = self.session_shard(id).lock().unwrap();
            let found = match shard.session(id) {
                Some(e)
                    if e.variant == variant
                        && e.tokens.len() + 1 <= tokens.len()
                        && e.tokens[..] == tokens[..e.tokens.len()] =>
                {
                    Some(SessionHit {
                        covered: e.tokens.len(),
                        conv: e.conv.clone(),
                        ssm: e.ssm.clone(),
                    })
                }
                _ => None,
            };
            if found.is_some() {
                shard.touch_session(id, tick);
            }
            found
        };
        // memory miss: the disk tier may hold the turn (write-through at
        // insert — possibly from a previous process's lifetime).  Same
        // verification as the memory path, then re-admit.
        let hit = hit.or_else(|| {
            let disk = self.disk.as_ref()?;
            let e = disk.load(DiskKey::Session { id })?;
            let ok = e.variant == variant
                && e.tokens.len() + 1 <= tokens.len()
                && e.tokens[..] == tokens[..e.tokens.len()];
            if !ok {
                return None;
            }
            let hit = SessionHit {
                covered: e.tokens.len(),
                conv: e.conv.clone(),
                ssm: e.ssm.clone(),
            };
            self.readmit(IndexKey::Session { id }, e);
            Some(hit)
        });
        if hit.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Store (or replace) session `id`'s end-of-turn state: the snapshot
    /// after consuming exactly `tokens` of the conversation.
    pub fn insert_session(
        &self,
        id: u64,
        variant: &str,
        tokens: &[u32],
        conv: &[f32],
        ssm: &[f32],
    ) {
        if tokens.is_empty() {
            return;
        }
        let bytes = entry_bytes(tokens.len(), 0, conv.len(), ssm.len());
        let tick = self.next_tick();
        let e = Entry {
            variant: variant.to_string(),
            chunks: Vec::new(),
            tokens: tokens.to_vec(),
            conv: conv.to_vec(),
            ssm: ssm.to_vec(),
            last_used: tick,
            bytes,
        };
        // write through first: the disk copy is what survives process
        // death, so it updates even when the entry is too large for the
        // memory tier (an oversized session still serves via fallthrough)
        if let Some(disk) = &self.disk {
            disk.store(DiskKey::Session { id }, &e);
        }
        if bytes > self.shard_budget {
            return;
        }
        let victims = {
            let mut shard = self.session_shard(id).lock().unwrap();
            shard.insert_session_entry(id, e);
            shard.evict_to(self.shard_budget)
        };
        self.insertions.fetch_add(1, Ordering::Relaxed);
        self.evictions.fetch_add(victims.len() as u64, Ordering::Relaxed);
        self.spill(victims);
    }

    /// Bytes currently resident across all shards.
    pub fn bytes_resident(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().bytes).sum()
    }

    /// Entries currently resident across all shards.
    pub fn entries(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().n_entries()).sum()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.entries(),
            bytes_resident: self.bytes_resident(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 13 + seed * 131).collect()
    }

    fn state(tag: f32, len: usize) -> (Vec<f32>, Vec<f32>) {
        (vec![tag; len], vec![-tag; len])
    }

    #[test]
    fn prefix_roundtrip_prefers_longest_boundary() {
        let c = StateCache::new(CacheConfig::default());
        let t = toks(24, 1);
        let (cv8, sm8) = state(8.0, 6);
        let (cv16, sm16) = state(16.0, 6);
        c.insert_prefix("fp32", &t[..8], &[8], &cv8, &sm8);
        c.insert_prefix("fp32", &t[..16], &[8, 8], &cv16, &sm16);

        // request plan [8, 8, 8]: boundary 16 must win over boundary 8
        let hit = c.lookup_prefix("fp32", &t, &[8, 8, 8]).expect("hit");
        assert_eq!(hit.covered, 16);
        assert_eq!(hit.chunks_used, 2);
        assert_eq!(hit.conv, cv16);
        assert_eq!(hit.ssm, sm16);

        // a plan that only reaches boundary 8 gets the shorter entry
        let hit = c.lookup_prefix("fp32", &t[..13], &[8]).expect("hit");
        assert_eq!(hit.covered, 8);
        assert_eq!(hit.conv, cv8);

        // different variant: no hit (and a counted miss)
        assert!(c.lookup_prefix("fastmamba", &t, &[8, 8, 8]).is_none());
        let s = c.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert!(s.hit_rate() > 0.6 && s.hit_rate() < 0.7);
    }

    #[test]
    fn shard_occupancy_sums_to_aggregate_stats() {
        let c = StateCache::new(CacheConfig { max_bytes: 1 << 20, shards: 4 });
        for i in 0..12u32 {
            let t = toks(8, 100 + i);
            let (cv, sm) = state(i as f32, 4);
            c.insert_prefix("fp32", &t, &[8], &cv, &sm);
        }
        let occ = c.shard_occupancy();
        assert_eq!(occ.len(), 4, "one row per shard");
        let s = c.stats();
        assert_eq!(occ.iter().map(|(e, _)| e).sum::<usize>(), s.entries);
        assert_eq!(occ.iter().map(|(_, b)| b).sum::<usize>(), s.bytes_resident);
        assert!(s.entries == 12, "all distinct inserts resident");
    }

    #[test]
    fn chunk_plan_mismatch_is_a_miss() {
        // same tokens, same boundary, different chunking: quantized
        // variants calibrate per chunk, so this must never hit
        let c = StateCache::new(CacheConfig::default());
        let t = toks(16, 2);
        let (cv, sm) = state(1.0, 4);
        c.insert_prefix("fastmamba", &t, &[16], &cv, &sm);
        assert!(c.lookup_prefix("fastmamba", &t, &[8, 8]).is_none());
        assert!(c.lookup_prefix("fastmamba", &t, &[16]).is_some());
    }

    #[test]
    fn hash_collision_never_crosses_token_prefixes() {
        // force two different keys onto ONE hash: the chain plus the
        // stored-token verification must keep them apart
        let c = StateCache::new(CacheConfig::default());
        let ta = toks(8, 3);
        let tb = toks(8, 4);
        let (cva, sma) = state(3.0, 4);
        let (cvb, smb) = state(4.0, 4);
        let h = 0xDEAD_BEEF_u64;
        c.insert_prefix_hashed(h, "fp32", &ta, &[8], &cva, &sma);
        c.insert_prefix_hashed(h, "fp32", &tb, &[8], &cvb, &smb);

        let a = c.lookup_prefix_hashed(h, "fp32", &[8], &ta).expect("a");
        assert_eq!(a.conv, cva, "collision chain returned the wrong snapshot");
        let b = c.lookup_prefix_hashed(h, "fp32", &[8], &tb).expect("b");
        assert_eq!(b.conv, cvb);
        // same hash, tokens that match neither entry: must miss
        assert!(c.lookup_prefix_hashed(h, "fp32", &[8], &toks(8, 5)).is_none());
    }

    #[test]
    fn lru_eviction_under_byte_budget() {
        let per = entry_bytes(8, 1, 16, 16);
        // room for exactly two entries in one shard
        let c = StateCache::new(CacheConfig { max_bytes: 2 * per, shards: 1 });
        let (cv, sm) = state(1.0, 16);
        let (ta, tb, tc) = (toks(8, 1), toks(8, 2), toks(8, 3));
        c.insert_prefix("fp32", &ta, &[8], &cv, &sm);
        c.insert_prefix("fp32", &tb, &[8], &cv, &sm);
        assert_eq!(c.bytes_resident(), 2 * per);

        // touch A so B becomes the LRU victim
        assert!(c.lookup_prefix("fp32", &ta, &[8]).is_some());
        c.insert_prefix("fp32", &tc, &[8], &cv, &sm);

        assert!(c.lookup_prefix("fp32", &ta, &[8]).is_some(), "A survived");
        assert!(c.lookup_prefix("fp32", &tb, &[8]).is_none(), "B evicted");
        assert!(c.lookup_prefix("fp32", &tc, &[8]).is_some(), "C resident");
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        assert!(s.bytes_resident <= c.max_bytes());
    }

    #[test]
    fn byte_accounting_tracks_inserts_dedupe_and_overwrites() {
        let c = StateCache::new(CacheConfig { max_bytes: 1 << 20, shards: 1 });
        let (cv, sm) = state(1.0, 16);
        let t = toks(8, 1);
        c.insert_prefix("fp32", &t, &[8], &cv, &sm);
        let b1 = c.bytes_resident();
        assert_eq!(b1, entry_bytes(8, 1, 16, 16));
        // identical re-insert only refreshes recency
        c.insert_prefix("fp32", &t, &[8], &cv, &sm);
        assert_eq!(c.bytes_resident(), b1);
        assert_eq!(c.stats().insertions, 1);

        // session overwrite swaps byte accounting, never accumulates
        c.insert_session(9, "fp32", &t[..4], &cv, &sm);
        let b2 = c.bytes_resident();
        assert_eq!(b2 - b1, entry_bytes(4, 0, 16, 16));
        c.insert_session(9, "fp32", &t, &cv, &sm);
        let b3 = c.bytes_resident();
        assert_eq!(b3 - b1, entry_bytes(8, 0, 16, 16));
        assert_eq!(c.entries(), 2);
    }

    #[test]
    fn oversized_entry_is_not_cached() {
        let c = StateCache::new(CacheConfig { max_bytes: 256, shards: 1 });
        let (cv, sm) = state(1.0, 4096); // ~32 KiB payload >> 256 B budget
        let t = toks(8, 1);
        c.insert_prefix("fp32", &t, &[8], &cv, &sm);
        c.insert_session(1, "fp32", &t, &cv, &sm);
        assert_eq!(c.entries(), 0);
        assert_eq!(c.bytes_resident(), 0);
        assert_eq!(c.stats().insertions, 0);
    }

    #[test]
    fn session_resume_rules() {
        let c = StateCache::new(CacheConfig::default());
        let hist = toks(10, 7);
        let (cv, sm) = state(7.0, 8);
        c.insert_session(42, "fp32", &hist, &cv, &sm);

        // prompt extends the history: hit, covering exactly the history
        let mut prompt = hist.clone();
        prompt.extend_from_slice(&[1, 2, 3]);
        let hit = c.lookup_session(42, "fp32", &prompt).expect("hit");
        assert_eq!(hit.covered, 10);
        assert_eq!(hit.conv, cv);

        // prompt == history: no token left to feed -> miss
        assert!(c.lookup_session(42, "fp32", &hist).is_none());
        // diverging history -> miss
        let mut fork = hist.clone();
        fork[5] ^= 1;
        fork.extend_from_slice(&[1, 2, 3]);
        assert!(c.lookup_session(42, "fp32", &fork).is_none());
        // other variant, other session -> miss
        assert!(c.lookup_session(42, "fastmamba", &prompt).is_none());
        assert!(c.lookup_session(43, "fp32", &prompt).is_none());
    }

    #[test]
    fn empty_plan_probes_nothing() {
        let c = StateCache::new(CacheConfig::default());
        assert!(c.lookup_prefix("fp32", &[1, 2], &[]).is_none());
        assert_eq!(c.stats().misses, 0, "no boundary probed, no miss counted");
    }

    #[test]
    fn state_reuse_contract_holds_for_the_cached_backend() {
        // the cache's whole correctness story reduces to the backend
        // state-reuse contract: seed-from-snapshot + suffix prefill IS the
        // continuous run.  Certify it for the backend the tests cache.
        let be = crate::backend::NativeBackend::synthetic(3).with_buckets(
            vec![8, 16],
            vec![1, 2],
        );
        crate::backend::conformance::check_state_reuse(&be);
    }

    #[test]
    fn sharded_concurrent_access_is_safe() {
        let c = Arc::new(StateCache::new(CacheConfig { max_bytes: 1 << 20, shards: 4 }));
        let handles: Vec<_> = (0..4u32)
            .map(|w| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for i in 0..32u32 {
                        let t = toks(8, w * 100 + i);
                        let (cv, sm) = state(i as f32, 8);
                        c.insert_prefix("fp32", &t, &[8], &cv, &sm);
                        assert!(c.lookup_prefix("fp32", &t, &[8]).is_some());
                        c.insert_session((w * 100 + i) as u64, "fp32", &t, &cv, &sm);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = c.stats();
        assert_eq!(s.entries, 4 * 32 * 2);
        assert_eq!(s.insertions, 4 * 32 * 2);
        assert_eq!(s.hits, 4 * 32);
        assert!(s.summary().contains("hit_rate=100%"), "{}", s.summary());
    }

    fn disk_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fastmamba_cache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn disk_pinned_entries_survive_forced_pressure() {
        // regression: under forced LRU pressure, a pinned session snapshot
        // (what the scheduler holds for a preempted request) must survive
        // while unpinned neighbors are evicted around it
        let per = entry_bytes(8, 0, 16, 16);
        let c = StateCache::new(CacheConfig { max_bytes: 2 * per, shards: 1 });
        let (cv, sm) = state(1.0, 16);
        c.insert_session(1, "fp32", &toks(8, 1), &cv, &sm);
        c.pin_session(1);
        // hammer: each insert forces an eviction, which must never pick
        // session 1 even though it stays the least recently used
        for i in 0..6u32 {
            c.insert_session(100 + i as u64, "fp32", &toks(8, 10 + i), &cv, &sm);
        }
        let mut probe = toks(8, 1);
        probe.push(9999);
        assert!(
            c.lookup_session(1, "fp32", &probe).is_some(),
            "pinned session evicted under pressure"
        );
        c.unpin_session(1);
        // unpinned + least recently used (the probe refreshed it, so age
        // it below the hammer entries by touching them)... simplest: fill
        // past budget twice more and verify it can now be evicted
        for i in 0..4u32 {
            c.insert_session(200 + i as u64, "fp32", &toks(8, 40 + i), &cv, &sm);
        }
        assert!(
            c.lookup_session(1, "fp32", &probe).is_none(),
            "unpinned entry evicts normally"
        );
    }

    #[test]
    fn disk_pin_request_guards_prefix_and_session_keys() {
        let per = entry_bytes(8, 1, 16, 16);
        let c = StateCache::new(CacheConfig { max_bytes: 2 * per, shards: 1 });
        let (cv, sm) = state(2.0, 16);
        let prompt = toks(16, 3);
        c.insert_prefix("fp32", &prompt[..8], &[8], &cv, &sm);
        // pin as the scheduler would at enqueue: all boundary prefixes of
        // the queued prompt's plan plus its session id
        c.pin_request("fp32", &prompt, &[8, 8], Some(77));
        for i in 0..6u32 {
            c.insert_prefix("fp32", &toks(8, 50 + i), &[8], &cv, &sm);
        }
        assert!(
            c.lookup_prefix("fp32", &prompt, &[8, 8]).is_some(),
            "pinned boundary prefix evicted"
        );
        // the session pin guarded a key with no entry yet: inserting under
        // it now is still protected
        c.insert_session(77, "fp32", &prompt[..8], &cv, &sm);
        for i in 0..6u32 {
            c.insert_session(300 + i as u64, "fp32", &toks(8, 70 + i), &cv, &sm);
        }
        assert!(c.lookup_session(77, "fp32", &prompt).is_some());
        c.unpin_request("fp32", &prompt, &[8, 8], Some(77));
    }

    #[test]
    fn disk_spill_and_fallthrough_roundtrip() {
        let dir = disk_dir("spill");
        let per = entry_bytes(8, 1, 16, 16);
        let c = StateCache::new(CacheConfig { max_bytes: 2 * per, shards: 1 })
            .with_disk(DiskTier::open(&dir).unwrap());
        let (cva, sma) = state(1.0, 16);
        let (cvb, smb) = state(2.0, 16);
        let (cvc, smc) = state(3.0, 16);
        let (ta, tb, tc) = (toks(8, 1), toks(8, 2), toks(8, 3));
        c.insert_prefix("fp32", &ta, &[8], &cva, &sma);
        c.insert_prefix("fp32", &tb, &[8], &cvb, &smb);
        c.insert_prefix("fp32", &tc, &[8], &cvc, &smc); // evicts A -> spills

        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.disk_stats().unwrap().writes, 1, "victim spilled");

        // A is gone from memory but the lookup falls through to disk —
        // and the payload comes back bit-exact
        let hit = c.lookup_prefix("fp32", &ta, &[8]).expect("disk fallthrough hit");
        assert_eq!(hit.conv, cva);
        assert_eq!(hit.ssm, sma);
        assert_eq!(c.disk_stats().unwrap().read_hits, 1);

        // the hit re-admitted A to memory: a second lookup stays off disk
        let reads_before = c.disk_stats().unwrap().reads;
        assert!(c.lookup_prefix("fp32", &ta, &[8]).is_some());
        assert_eq!(c.disk_stats().unwrap().reads, reads_before, "served from memory");

        // stats count both as hits
        assert_eq!(c.stats().hits, 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn disk_sessions_warm_start_across_process_restart() {
        // two cache instances sharing one directory model a process
        // restart: the second serves the first's session as a hit
        let dir = disk_dir("warmstart");
        let hist = toks(10, 7);
        let (cv, sm) = state(7.0, 8);
        {
            let c = StateCache::new(CacheConfig::default())
                .with_disk(DiskTier::open(&dir).unwrap());
            c.insert_session(42, "fp32", &hist, &cv, &sm);
            assert_eq!(c.disk_stats().unwrap().writes, 1, "session written through");
        } // "process death"

        let c2 = StateCache::new(CacheConfig::default())
            .with_disk(DiskTier::open(&dir).unwrap());
        assert_eq!(c2.entries(), 0, "fresh memory tier");
        let mut prompt = hist.clone();
        prompt.extend_from_slice(&[1, 2, 3]);
        let hit = c2.lookup_session(42, "fp32", &prompt).expect("warm-start hit");
        assert_eq!(hit.covered, 10);
        assert_eq!(hit.conv, cv);
        assert_eq!(hit.ssm, sm);
        assert_eq!(c2.entries(), 1, "re-admitted to memory");

        // disk hits still verify: a diverging history is a miss
        let mut fork = hist.clone();
        fork[5] ^= 1;
        fork.extend_from_slice(&[1, 2, 3]);
        let c3 = StateCache::new(CacheConfig::default())
            .with_disk(DiskTier::open(&dir).unwrap());
        assert!(c3.lookup_session(42, "fp32", &fork).is_none());
        assert!(c3.lookup_session(42, "fastmamba", &prompt).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn disk_session_overwrite_keeps_latest_turn_on_disk() {
        let dir = disk_dir("turns");
        let (cv1, sm1) = state(1.0, 8);
        let (cv2, sm2) = state(2.0, 8);
        let t1 = toks(6, 1);
        let mut t2 = t1.clone();
        t2.extend_from_slice(&[8, 9]);
        {
            let c = StateCache::new(CacheConfig::default())
                .with_disk(DiskTier::open(&dir).unwrap());
            c.insert_session(5, "fp32", &t1, &cv1, &sm1);
            c.insert_session(5, "fp32", &t2, &cv2, &sm2); // next turn
            assert_eq!(c.disk().unwrap().n_files(), 1, "one file per session");
        }
        let c2 = StateCache::new(CacheConfig::default())
            .with_disk(DiskTier::open(&dir).unwrap());
        let mut prompt = t2.clone();
        prompt.push(99);
        let hit = c2.lookup_session(5, "fp32", &prompt).expect("latest turn");
        assert_eq!(hit.covered, t2.len());
        assert_eq!(hit.conv, cv2);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
