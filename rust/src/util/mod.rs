//! Dependency-free substrates: a JSON parser for the AOT manifest, a
//! deterministic RNG, a statistics-reporting micro-benchmark harness, and a
//! tiny CLI argument parser.  (The build environment is offline; everything
//! beyond the `xla` crate is implemented here.)

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
