//! Minimal recursive-descent JSON parser — enough for the AOT manifest and
//! the report files this crate writes/reads.  Numbers parse as f64; object
//! key order is preserved (the manifest relies on parameter order).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with preserved key order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: `obj.str_field("name")?` with a useful error.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing string field `{key}`"))
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("missing numeric field `{key}`"))
    }

    pub fn arr_field(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing array field `{key}`"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            fields.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                c => bail!("expected , or }} got `{}` at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected , or ] got `{}` at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                _ => {
                    // UTF-8 passthrough: collect continuation bytes
                    let start = self.i - 1;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

/// Serializer used by the report generators (stable key order).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, it) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(it, out);
            }
            out.push(']');
        }
        Json::Obj(fields) => {
            out.push('{');
            for (i, (k, val)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

/// Builder helpers for report emission.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", to_string(self))
    }
}

/// Map view when key lookup by hash is preferred.
pub fn to_map(v: &Json) -> BTreeMap<String, Json> {
    match v {
        Json::Obj(fields) => fields.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].str_field("b").unwrap(),
            "x"
        );
        assert!(v.get("c").is_some());
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        if let Json::Obj(fields) = v {
            let keys: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, vec!["z", "a", "m"]);
        } else {
            panic!();
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"x","vals":[1,2.5,-3],"flag":false,"n":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(to_string(&v), src);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\téß""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\té\u{df}");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = crate::model::weights::artifacts_dir();
        let p = dir.join("manifest.json");
        if p.exists() {
            let text = std::fs::read_to_string(p).unwrap();
            let v = Json::parse(&text).unwrap();
            assert!(v.get("artifacts").is_some());
            assert!(v.arr_field("params").unwrap().len() > 10);
        }
    }
}
