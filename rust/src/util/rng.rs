//! Deterministic RNG (SplitMix64 core) — workload generation, synthetic
//! weights, and property-test inputs all derive from explicit seeds so every
//! experiment is reproducible bit-for-bit.

/// SplitMix64: tiny, fast, good equidistribution for non-crypto use.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vec of N(0, std) f32s.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * std).collect()
    }

    /// Heavy-tailed vector: mostly N(0,1) with a few channels scaled up —
    /// the Fig. 3 activation distribution generator.
    pub fn outlier_vec(&mut self, n: usize, outlier_frac: f64, gain: f32) -> Vec<f32> {
        let mut v = self.normal_vec(n, 1.0);
        let n_out = ((n as f64 * outlier_frac).ceil() as usize).max(1);
        for _ in 0..n_out {
            let i = self.below(n);
            v[i] *= gain;
        }
        v
    }

    /// Exponential with rate lambda (Poisson inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.uniform().max(1e-300).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let v = r.normal_vec(50_000, 1.0);
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        let var: f32 = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / v.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn outliers_increase_kurtosis() {
        let mut r = Rng::new(5);
        let base = r.normal_vec(10_000, 1.0);
        let heavy = r.outlier_vec(10_000, 0.01, 30.0);
        let kurt = |v: &[f32]| {
            let m = v.iter().sum::<f32>() / v.len() as f32;
            let v2 = v.iter().map(|x| (x - m).powi(2)).sum::<f32>() / v.len() as f32;
            let v4 = v.iter().map(|x| (x - m).powi(4)).sum::<f32>() / v.len() as f32;
            v4 / (v2 * v2)
        };
        assert!(kurt(&heavy) > 3.0 * kurt(&base));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let mean: f64 =
            (0..20_000).map(|_| r.exponential(4.0)).sum::<f64>() / 20_000.0;
        assert!((mean - 0.25).abs() < 0.02, "{mean}");
    }
}
