//! Micro-benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations, robust statistics, and table-style reporting shared by
//! every `benches/` target.

use std::time::{Duration, Instant};

/// One benchmark measurement series.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub stddev_s: f64,
}

impl Stats {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.median_s
    }
}

/// Run `f` until `min_time` has elapsed (after `warmup` iterations) and at
/// least `min_iters` samples are collected.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize,
                         min_time: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= 100_000 {
            break;
        }
    }
    stats_from(name, &mut samples)
}

/// Quick preset: 2 warmups, ≥5 iters, ≥200 ms.
pub fn bench_quick<F: FnMut()>(name: &str, f: F) -> Stats {
    bench(name, 2, 5, Duration::from_millis(200), f)
}

fn stats_from(name: &str, samples: &mut [f64]) -> Stats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    Stats {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        median_s: samples[n / 2],
        p95_s: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        min_s: samples[0],
        stddev_s: var.sqrt(),
    }
}

/// Human-readable time.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} median {:>12}  mean {:>12}  p95 {:>12}  (n={})",
            self.name,
            fmt_time(self.median_s),
            fmt_time(self.mean_s),
            fmt_time(self.p95_s),
            self.iters
        )
    }
}

/// Markdown-ish table printer used by the table/figure regenerators.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let body: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", body.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0u64;
        let st = bench("noop", 1, 10, Duration::from_millis(5), || {
            n += 1;
            std::hint::black_box(n);
        });
        assert!(st.iters >= 10);
        assert!(st.median_s >= 0.0);
        assert!(st.min_s <= st.median_s && st.median_s <= st.p95_s);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // shouldn't panic
    }
}
