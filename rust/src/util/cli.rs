//! Tiny CLI argument parser (clap is unavailable offline): positional
//! subcommand + `--flag value` / `--flag` options.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut argv = argv.peekable();
        while let Some(a) = argv.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = if let Some(nxt) = argv.peek() {
                    if nxt.starts_with("--") {
                        "true".to_string()
                    } else {
                        argv.next().unwrap()
                    }
                } else {
                    "true".to_string()
                };
                out.flags.insert(name.to_string(), val);
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --batch 8 --variant fastmamba --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize_or("batch", 1), 8);
        assert_eq!(a.get("variant"), Some("fastmamba"));
        assert!(a.bool("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("report");
        assert_eq!(a.usize_or("batch", 4), 4);
        assert_eq!(a.get_or("variant", "fp32"), "fp32");
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn positionals() {
        let a = parse("run file1 file2 --x 1");
        assert_eq!(a.positionals, vec!["file1", "file2"]);
    }
}
