//! Synthetic evaluation harness — the Table II substitute.
//!
//! The paper evaluates W8A8 quantizers on Lambada + 6 zero-shot tasks via
//! lm-evaluation-harness.  Those datasets and the pretrained 130M checkpoint
//! are unavailable offline, so the harness measures the *same quantities*
//! on the build-time-trained tiny Mamba2: perplexity on a held-out slice of
//! its synthetic Markov corpus, and accuracy on seven synthetic cloze tasks
//! (rank the true continuation against distractors).  Table II's finding is
//! ordinal — NormalQ ≪ SmoothQ < FastMamba-LQ ≈ FP16, with full FastMamba
//! within ~1% of LQ — and that ordering is produced by the quantizers, not
//! the datasets.

use std::path::Path;

use anyhow::{Context, Result};

use crate::model::{Mamba2, Variant};
use crate::util::rng::Rng;

/// The seven synthetic stand-ins for the paper's task list.
pub const TASKS: [(&str, usize, u64); 7] = [
    ("lambada-syn", 24, 11),
    ("hellaswag-syn", 16, 22),
    ("piqa-syn", 12, 33),
    ("arc-easy-syn", 8, 44),
    ("arc-challenge-syn", 20, 55),
    ("winogrande-syn", 14, 66),
    ("openbookqa-syn", 10, 77),
];

/// One Table II row.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub method: String,
    pub ppl: f64,
    pub task_acc: Vec<(String, f64)>,
    pub avg_acc: f64,
    /// RMS logit error vs the FP32 baseline (0 for FP32 itself)
    pub logit_rmse: f64,
}

/// Load the held-out corpus written by train_tiny.py.
pub fn load_corpus(artifacts_dir: &Path) -> Result<Vec<u32>> {
    let bytes = std::fs::read(artifacts_dir.join("heldout_corpus.bin"))
        .context("heldout_corpus.bin missing (run `make artifacts`)")?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as u32)
        .collect())
}

fn log_softmax_at(logits: &[f32], idx: usize) -> f64 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b));
    let lse: f64 = logits.iter().map(|v| ((v - m) as f64).exp()).sum::<f64>().ln()
        + m as f64;
    logits[idx] as f64 - lse
}

/// Perplexity over sliding windows of the corpus.
pub fn perplexity(
    model: &Mamba2,
    variant: Variant,
    corpus: &[u32],
    window: usize,
    n_windows: usize,
) -> f64 {
    let vocab = model.w.cfg.vocab_size;
    let stride = (corpus.len() - window - 1) / n_windows.max(1);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for wi in 0..n_windows {
        let start = wi * stride;
        let toks = &corpus[start..start + window + 1];
        let (logits, _) = model.prefill(&toks[..window], variant);
        for t in 0..window {
            let target = toks[t + 1] as usize;
            nll -= log_softmax_at(&logits[t * vocab..(t + 1) * vocab], target);
            count += 1;
        }
    }
    (nll / count as f64).exp()
}

/// One synthetic cloze task: contexts drawn from the corpus, the true next
/// token must outscore 3 random distractors.
pub fn cloze_accuracy(
    model: &Mamba2,
    variant: Variant,
    corpus: &[u32],
    context_len: usize,
    n_items: usize,
    seed: u64,
) -> f64 {
    let vocab = model.w.cfg.vocab_size as u32;
    let mut rng = Rng::new(seed);
    let mut correct = 0usize;
    for _ in 0..n_items {
        let start = rng.below(corpus.len() - context_len - 1);
        let ctx = &corpus[start..start + context_len];
        let answer = corpus[start + context_len];
        let (logits, _) = model.prefill(ctx, variant);
        let last = &logits[(context_len - 1) * vocab as usize..];
        let mut best_is_answer = true;
        let answer_score = last[answer as usize];
        for k in 0..3 {
            // one unigram-plausible (corpus-sampled) distractor + two
            // uniform ones: hard enough to leave headroom, easy enough
            // that a trained model clears chance decisively
            let mut d = if k == 0 {
                corpus[rng.below(corpus.len())]
            } else {
                rng.below(vocab as usize) as u32
            };
            while d == answer {
                d = rng.below(vocab as usize) as u32;
            }
            if last[d as usize] >= answer_score {
                best_is_answer = false;
            }
        }
        if best_is_answer {
            correct += 1;
        }
    }
    correct as f64 / n_items as f64
}

/// RMS logit disagreement with FP32 on a probe window.
pub fn logit_rmse(model: &Mamba2, variant: Variant, corpus: &[u32], window: usize) -> f64 {
    let toks = &corpus[..window];
    let (fp, _) = model.prefill(toks, Variant::Fp32);
    let (qt, _) = model.prefill(toks, variant);
    let mse: f64 = fp
        .iter()
        .zip(&qt)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / fp.len() as f64;
    mse.sqrt()
}

/// Full Table II sweep.
pub fn table2(
    model: &Mamba2,
    corpus: &[u32],
    ppl_windows: usize,
    cloze_items: usize,
) -> Vec<EvalRow> {
    let mut rows = Vec::new();
    for variant in Variant::ALL {
        let ppl = perplexity(model, variant, corpus, 64, ppl_windows);
        let mut task_acc = Vec::new();
        let mut sum = 0.0;
        for (name, ctx_len, seed) in TASKS {
            let acc = cloze_accuracy(model, variant, corpus, ctx_len, cloze_items, seed);
            sum += acc;
            task_acc.push((name.to_string(), acc));
        }
        rows.push(EvalRow {
            method: variant.name().to_string(),
            ppl,
            avg_acc: sum / TASKS.len() as f64,
            task_acc,
            logit_rmse: logit_rmse(model, variant, corpus, 48),
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::weights::{artifacts_dir, ModelWeights};

    fn trained_model() -> Option<(Mamba2, Vec<u32>)> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let w = ModelWeights::load(&dir).ok()?;
        let corpus = load_corpus(&dir).ok()?;
        let mut m = Mamba2::new(w);
        m.prepare();
        Some((m, corpus))
    }

    #[test]
    fn corpus_tokens_in_vocab() {
        let Some((m, corpus)) = trained_model() else { return };
        assert!(corpus.len() > 10_000);
        assert!(corpus.iter().all(|t| (*t as usize) < m.w.cfg.vocab_size));
    }

    #[test]
    fn trained_ppl_beats_uniform() {
        let Some((m, corpus)) = trained_model() else { return };
        let ppl = perplexity(&m, Variant::Fp32, &corpus, 64, 4);
        // uniform over 512 tokens would be 512; the Markov floor is ~6.4
        assert!(ppl < 80.0, "trained fp32 ppl {ppl}");
        assert!(ppl > 3.0);
    }

    #[test]
    fn cloze_beats_chance() {
        let Some((m, corpus)) = trained_model() else { return };
        let acc = cloze_accuracy(&m, Variant::Fp32, &corpus, 16, 24, 1);
        assert!(acc > 0.4, "acc {acc} vs 0.25 chance"); // chance = 0.25
    }

    #[test]
    fn table2_ordering_holds() {
        // The paper's ordinal result on the trained, outlier-bearing model.
        let Some((m, corpus)) = trained_model() else { return };
        let rows = table2(&m, &corpus, 3, 10);
        let get = |name: &str| rows.iter().find(|r| r.method == name).unwrap();
        let fp = get("fp32");
        let normal = get("normalq");
        let lq = get("fastmamba_lq");
        let fm = get("fastmamba");
        // quantization noise ordering (the paper's core claim)
        assert!(lq.logit_rmse < normal.logit_rmse, "LQ {} vs NormalQ {}",
                lq.logit_rmse, normal.logit_rmse);
        // fastmamba close to fastmamba-lq (PoT costs little)
        assert!(fm.logit_rmse < 3.0 * lq.logit_rmse.max(1e-6));
        // ppl: fp32 best or near-best; normalq worst or near-worst
        assert!(fp.ppl <= lq.ppl * 1.05);
        assert!(normal.ppl >= lq.ppl * 0.95);
    }

    #[test]
    fn uniform_random_model_near_chance() {
        // sanity: an untrained model scores ~chance on cloze
        let cfg = ModelConfig::tiny();
        let m = Mamba2::new(ModelWeights::random(&cfg, 9));
        let mut rng = Rng::new(3);
        let corpus: Vec<u32> = (0..4000).map(|_| rng.below(512) as u32).collect();
        let acc = cloze_accuracy(&m, Variant::Fp32, &corpus, 8, 30, 2);
        assert!(acc < 0.6);
    }
}
