//! Synthetic evaluation harness — the Table II substitute.
//!
//! The paper evaluates W8A8 quantizers on Lambada + 6 zero-shot tasks via
//! lm-evaluation-harness.  Those datasets and the pretrained 130M checkpoint
//! are unavailable offline, so the harness measures the *same quantities*
//! on the build-time-trained tiny Mamba2: perplexity on a held-out slice of
//! its synthetic Markov corpus, and accuracy on seven synthetic cloze tasks
//! (rank the true continuation against distractors).  Table II's finding is
//! ordinal — NormalQ ≪ SmoothQ < FastMamba-LQ ≈ FP16, with full FastMamba
//! within ~1% of LQ — and that ordering is produced by the quantizers, not
//! the datasets.
//!
//! The harness is backend-generic: every metric runs through
//! [`InferenceBackend::forward_logits`], which chains exact prefill buckets
//! and decode steps, so the same sweep scores the native golden model or
//! the PJRT executables (arbitrary context lengths included).

use std::path::Path;

use anyhow::{Context, Result};

use crate::backend::InferenceBackend;
use crate::util::rng::Rng;

/// The seven synthetic stand-ins for the paper's task list.
pub const TASKS: [(&str, usize, u64); 7] = [
    ("lambada-syn", 24, 11),
    ("hellaswag-syn", 16, 22),
    ("piqa-syn", 12, 33),
    ("arc-easy-syn", 8, 44),
    ("arc-challenge-syn", 20, 55),
    ("winogrande-syn", 14, 66),
    ("openbookqa-syn", 10, 77),
];

/// One Table II row.
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub method: String,
    pub ppl: f64,
    pub task_acc: Vec<(String, f64)>,
    pub avg_acc: f64,
    /// RMS logit error vs the FP32 baseline (0 for FP32 itself)
    pub logit_rmse: f64,
}

/// Load the held-out corpus written by train_tiny.py.
pub fn load_corpus(artifacts_dir: &Path) -> Result<Vec<u32>> {
    let bytes = std::fs::read(artifacts_dir.join("heldout_corpus.bin"))
        .context("heldout_corpus.bin missing (run `make artifacts`)")?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as u32)
        .collect())
}

/// Deterministic synthetic corpus for artifact-free hosts: an order-1
/// drifting chain over the vocab (enough short-range structure that serve
/// traces are not pure noise, no training required).
pub fn synthetic_corpus(vocab: usize, len: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(len);
    let mut cur = rng.below(vocab);
    for _ in 0..len {
        // mostly local moves, occasional jumps
        cur = if rng.below(8) == 0 {
            rng.below(vocab)
        } else {
            (cur + 1 + rng.below(7)) % vocab
        };
        out.push(cur as u32);
    }
    out
}

/// The corpus a backend's workload should draw from: the trained held-out
/// corpus when the backend is serving the `artifacts/` checkpoint, a
/// synthetic one otherwise.
pub fn corpus_for(be: &dyn InferenceBackend) -> Vec<u32> {
    if let Some(dir) = be.artifacts_dir() {
        match load_corpus(dir) {
            Ok(c) => return c,
            // backend serves the trained checkpoint but its corpus is
            // missing/corrupt: don't silently score it on synthetic data
            Err(e) => eprintln!(
                "warning: held-out corpus unavailable ({e:#}); \
                 falling back to a synthetic corpus"
            ),
        }
    }
    synthetic_corpus(be.cfg().vocab_size, 20_000, 17)
}

fn log_softmax_at(logits: &[f32], idx: usize) -> f64 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b));
    let lse: f64 = logits.iter().map(|v| ((v - m) as f64).exp()).sum::<f64>().ln()
        + m as f64;
    logits[idx] as f64 - lse
}

fn to_i32(tokens: &[u32]) -> Vec<i32> {
    tokens.iter().map(|t| *t as i32).collect()
}

/// Perplexity over sliding windows of the corpus.
pub fn perplexity(
    be: &dyn InferenceBackend,
    variant: &str,
    corpus: &[u32],
    window: usize,
    n_windows: usize,
) -> Result<f64> {
    let vocab = be.cfg().vocab_size;
    let stride = (corpus.len() - window - 1) / n_windows.max(1);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for wi in 0..n_windows {
        let start = wi * stride;
        let toks = &corpus[start..start + window + 1];
        let logits = be.forward_logits(variant, &to_i32(&toks[..window]))?;
        for t in 0..window {
            let target = toks[t + 1] as usize;
            nll -= log_softmax_at(&logits[t * vocab..(t + 1) * vocab], target);
            count += 1;
        }
    }
    Ok((nll / count as f64).exp())
}

/// One synthetic cloze task: contexts drawn from the corpus, the true next
/// token must outscore 3 random distractors.
pub fn cloze_accuracy(
    be: &dyn InferenceBackend,
    variant: &str,
    corpus: &[u32],
    context_len: usize,
    n_items: usize,
    seed: u64,
) -> Result<f64> {
    let vocab = be.cfg().vocab_size as u32;
    let mut rng = Rng::new(seed);
    let mut correct = 0usize;
    for _ in 0..n_items {
        let start = rng.below(corpus.len() - context_len - 1);
        let ctx = &corpus[start..start + context_len];
        let answer = corpus[start + context_len];
        let logits = be.forward_logits(variant, &to_i32(ctx))?;
        let last = &logits[(context_len - 1) * vocab as usize..];
        let mut best_is_answer = true;
        let answer_score = last[answer as usize];
        for k in 0..3 {
            // one unigram-plausible (corpus-sampled) distractor + two
            // uniform ones: hard enough to leave headroom, easy enough
            // that a trained model clears chance decisively
            let mut d = if k == 0 {
                corpus[rng.below(corpus.len())]
            } else {
                rng.below(vocab as usize) as u32
            };
            while d == answer {
                d = rng.below(vocab as usize) as u32;
            }
            if last[d as usize] >= answer_score {
                best_is_answer = false;
            }
        }
        if best_is_answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / n_items as f64)
}

/// RMS logit disagreement with FP32 on a probe window.
pub fn logit_rmse(
    be: &dyn InferenceBackend,
    variant: &str,
    corpus: &[u32],
    window: usize,
) -> Result<f64> {
    let toks = to_i32(&corpus[..window]);
    let fp = be.forward_logits("fp32", &toks)?;
    let qt = be.forward_logits(variant, &toks)?;
    let mse: f64 = fp
        .iter()
        .zip(&qt)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / fp.len() as f64;
    Ok(mse.sqrt())
}

/// Full Table II sweep over every variant the backend executes.
pub fn table2(
    be: &dyn InferenceBackend,
    corpus: &[u32],
    ppl_windows: usize,
    cloze_items: usize,
) -> Result<Vec<EvalRow>> {
    let mut rows = Vec::new();
    for variant in be.variants() {
        let ppl = perplexity(be, &variant, corpus, 64, ppl_windows)?;
        let mut task_acc = Vec::new();
        let mut sum = 0.0;
        for (name, ctx_len, seed) in TASKS {
            let acc = cloze_accuracy(be, &variant, corpus, ctx_len, cloze_items, seed)?;
            sum += acc;
            task_acc.push((name.to_string(), acc));
        }
        rows.push(EvalRow {
            logit_rmse: logit_rmse(be, &variant, corpus, 48)?,
            method: variant,
            avg_acc: sum / TASKS.len() as f64,
            task_acc,
            ppl,
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::config::ModelConfig;
    use crate::model::weights::{artifacts_dir, ModelWeights};

    fn trained_backend() -> Option<(NativeBackend, Vec<u32>)> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let be = NativeBackend::load_default().ok()?;
        let corpus = load_corpus(&dir).ok()?;
        Some((be, corpus))
    }

    #[test]
    fn corpus_tokens_in_vocab() {
        let Some((be, corpus)) = trained_backend() else { return };
        assert!(corpus.len() > 10_000);
        assert!(corpus.iter().all(|t| (*t as usize) < be.cfg().vocab_size));
    }

    #[test]
    fn synthetic_corpus_always_available() {
        let be = NativeBackend::synthetic(3);
        let c = corpus_for(&be);
        assert!(c.len() >= 10_000);
        assert!(c.iter().all(|t| (*t as usize) < be.cfg().vocab_size));
        // deterministic
        assert_eq!(synthetic_corpus(512, 100, 17), synthetic_corpus(512, 100, 17));
    }

    #[test]
    fn trained_ppl_beats_uniform() {
        let Some((be, corpus)) = trained_backend() else { return };
        let ppl = perplexity(&be, "fp32", &corpus, 64, 4).unwrap();
        // uniform over 512 tokens would be 512; the Markov floor is ~6.4
        assert!(ppl < 80.0, "trained fp32 ppl {ppl}");
        assert!(ppl > 3.0);
    }

    #[test]
    fn cloze_beats_chance() {
        let Some((be, corpus)) = trained_backend() else { return };
        let acc = cloze_accuracy(&be, "fp32", &corpus, 16, 24, 1).unwrap();
        assert!(acc > 0.4, "acc {acc} vs 0.25 chance"); // chance = 0.25
    }

    #[test]
    fn table2_ordering_holds() {
        // The paper's ordinal result on the trained, outlier-bearing model.
        let Some((be, corpus)) = trained_backend() else { return };
        let rows = table2(&be, &corpus, 3, 10).unwrap();
        let get = |name: &str| rows.iter().find(|r| r.method == name).unwrap();
        let fp = get("fp32");
        let normal = get("normalq");
        let lq = get("fastmamba_lq");
        let fm = get("fastmamba");
        // quantization noise ordering (the paper's core claim)
        assert!(lq.logit_rmse < normal.logit_rmse, "LQ {} vs NormalQ {}",
                lq.logit_rmse, normal.logit_rmse);
        // fastmamba close to fastmamba-lq (PoT costs little)
        assert!(fm.logit_rmse < 3.0 * lq.logit_rmse.max(1e-6));
        // ppl: fp32 best or near-best; normalq worst or near-worst
        assert!(fp.ppl <= lq.ppl * 1.05);
        assert!(normal.ppl >= lq.ppl * 0.95);
    }

    #[test]
    fn eval_runs_on_artifact_free_backend() {
        // the whole harness must execute end-to-end with no artifacts:
        // synthetic weights, synthetic corpus, every variant
        let be = NativeBackend::synthetic(3);
        let corpus = synthetic_corpus(be.cfg().vocab_size, 4000, 5);
        let rows = table2(&be, &corpus, 1, 3).unwrap();
        assert_eq!(rows.len(), be.variants().len());
        for r in &rows {
            assert!(r.ppl.is_finite() && r.ppl > 1.0, "{}: ppl {}", r.method, r.ppl);
            assert!((0.0..=1.0).contains(&r.avg_acc), "{}", r.method);
            assert!(r.logit_rmse.is_finite());
        }
        let fp = rows.iter().find(|r| r.method == "fp32").unwrap();
        assert_eq!(fp.logit_rmse, 0.0, "fp32 rmse vs itself");
    }

    #[test]
    fn uniform_random_model_near_chance() {
        // sanity: an untrained model scores ~chance on cloze
        let cfg = ModelConfig::tiny();
        let be = NativeBackend::new(ModelWeights::random(&cfg, 9));
        let mut rng = Rng::new(3);
        let corpus: Vec<u32> = (0..4000).map(|_| rng.below(512) as u32).collect();
        let acc = cloze_accuracy(&be, "fp32", &corpus, 8, 30, 2).unwrap();
        assert!(acc < 0.6);
    }
}
