//! PJRT runtime: loads the AOT-lowered HLO text artifacts and executes them
//! on the CPU PJRT client — the only compute path the coordinator uses at
//! serve time (Python never runs here).
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and `python/compile/aot.py`).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::model::weights::{artifacts_dir, Manifest, ModelWeights};

// Interchange types live with the execution trait now; re-exported here so
// `crate::runtime::{PrefillOut, DecodeOut}` paths keep working.
pub use crate::backend::{DecodeOut, PrefillOut};

/// A compiled executable cache keyed by artifact name, plus the weight
/// literals shared by every model executable.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    pub dir: PathBuf,
    executables: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// flat weight literals in manifest order
    weights: Vec<xla::Literal>,
    /// offline Hadamard-prepared int8 weights + scales (flatten_prepared
    /// order) — computed once here so the quantized executables skip the
    /// per-call weight transform (§Perf L2)
    prepared: Vec<xla::Literal>,
    pub weights_host: ModelWeights,
}

fn f32_literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
    Ok(lit.reshape(&dims_i64)?)
}

fn i32_literal(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
    Ok(lit.reshape(&dims_i64)?)
}

fn i8_literal(data: &[i8], dims: &[usize]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8,
        dims,
        bytes,
    )?)
}

/// Hadamard group size — must match `mamba2.HADAMARD_GROUP` in Python.
const HADAMARD_GROUP: usize = 64;

/// Build the prepared-weight literals in `flatten_prepared` order:
/// per layer [in_proj.w_q_t, in_proj.s_w, out_proj.w_q_t, out_proj.s_w],
/// then [lm_head.w_q_t, lm_head.s_w].
fn build_prepared(w: &ModelWeights) -> Result<Vec<xla::Literal>> {
    use crate::quant::hadamard::prepare_weight;
    let cfg = &w.cfg;
    let mut out = Vec::new();
    let mut push = |raw: &[f32], q: usize, d: usize| -> Result<()> {
        let pw = prepare_weight(raw, q, d, HADAMARD_GROUP);
        out.push(i8_literal(&pw.w_q_t, &[d, q])?);
        out.push(xla::Literal::from(pw.scale));
        Ok(())
    };
    for lw in &w.layers {
        push(&lw.in_proj_w, cfg.d_in_proj(), cfg.d_model)?;
        push(&lw.out_proj_w, cfg.d_model, cfg.d_inner())?;
    }
    push(&w.embed, cfg.vocab_size, cfg.d_model)?;
    Ok(out)
}

impl Runtime {
    /// Create a runtime over the default artifacts directory.
    pub fn load_default() -> Result<Self> {
        Self::load(artifacts_dir())
    }

    pub fn load(dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        let weights_host = ModelWeights::load(&dir)?;
        let mut weights = Vec::new();
        // manifest order == flatten order: build literals with true shapes
        let flat = weights_host.flat();
        for p in &manifest.params {
            let (_, data) = flat[p.index];
            let dims = if p.shape.is_empty() { vec![1] } else { p.shape.clone() };
            weights.push(f32_literal(data, &dims)?);
        }
        let prepared = build_prepared(&weights_host)?;
        Ok(Self {
            client,
            manifest,
            dir,
            executables: Mutex::new(HashMap::new()),
            weights,
            prepared,
            weights_host,
        })
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        let mut cache = self.executables.lock().unwrap();
        if cache.contains_key(name) {
            return Ok(());
        }
        let art = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?;
        let path = self.dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.executables.lock().unwrap().len()
    }

    /// Warm the cache for a set of artifacts (done at server startup so the
    /// request path never compiles).
    pub fn warmup(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    fn run_tuple3(
        &self,
        name: &str,
        extra: Vec<xla::Literal>,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.ensure_compiled(name)?;
        let n_prepared = self
            .manifest
            .artifact(name)
            .map(|a| a.n_prepared)
            .unwrap_or(0);
        let cache = self.executables.lock().unwrap();
        let exe = cache.get(name).unwrap();
        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        if n_prepared > 0 {
            debug_assert_eq!(n_prepared, self.prepared.len());
            args.extend(self.prepared.iter());
        }
        args.extend(extra.iter());
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (a, b, c) = result.to_tuple3()?;
        Ok((a.to_vec::<f32>()?, b.to_vec::<f32>()?, c.to_vec::<f32>()?))
    }

    /// Zero-initialized (conv, ssm) state pair for a fresh sequence.
    pub fn zero_state(&self) -> (Vec<f32>, Vec<f32>) {
        let cfg = &self.weights_host.cfg;
        (
            vec![0.0; cfg.conv_state_len()],
            vec![0.0; cfg.ssm_state_len()],
        )
    }

    /// Run a prefill executable over one chunk.  `tokens.len()` must equal
    /// the artifact's bucket length; `conv/ssm_state` carry the recurrent
    /// state from earlier chunks (chunked prefill), zeros for a fresh start.
    pub fn prefill(
        &self,
        variant: &str,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<PrefillOut> {
        let cfg = &self.weights_host.cfg;
        let name = format!("{}_prefill_{}_L{}", cfg.name, variant, tokens.len());
        let conv_dims = [cfg.n_layer, cfg.d_conv - 1, cfg.conv_dim()];
        let ssm_dims = [cfg.n_layer, cfg.nheads(), cfg.headdim, cfg.d_state];
        let extra = vec![
            f32_literal(conv_state, &conv_dims)?,
            f32_literal(ssm_state, &ssm_dims)?,
            i32_literal(tokens, &[tokens.len()])?,
        ];
        let (logits, conv_state, ssm_state) = self.run_tuple3(&name, extra)?;
        Ok(PrefillOut { logits, conv_state, ssm_state })
    }

    /// Prefill a fresh sequence (zero state).
    pub fn prefill_fresh(&self, variant: &str, tokens: &[i32]) -> Result<PrefillOut> {
        let (c, s) = self.zero_state();
        self.prefill(variant, tokens, &c, &s)
    }

    /// Run a batched decode executable.  All state slices are batch-major.
    pub fn decode(
        &self,
        variant: &str,
        batch: usize,
        conv_state: &[f32],
        ssm_state: &[f32],
        tokens: &[i32],
    ) -> Result<DecodeOut> {
        let cfg = &self.weights_host.cfg;
        assert_eq!(tokens.len(), batch);
        let name = format!("{}_decode_{}_B{}", cfg.name, variant, batch);
        let conv_dims = [batch, cfg.n_layer, cfg.d_conv - 1, cfg.conv_dim()];
        let ssm_dims = [batch, cfg.n_layer, cfg.nheads(), cfg.headdim, cfg.d_state];
        let extra = vec![
            f32_literal(conv_state, &conv_dims)?,
            f32_literal(ssm_state, &ssm_dims)?,
            i32_literal(tokens, &[batch])?,
        ];
        let (logits, conv_state, ssm_state) = self.run_tuple3(&name, extra)?;
        Ok(DecodeOut { logits, conv_state, ssm_state })
    }

    /// Prefill bucket lengths available in the manifest (ascending).
    pub fn prefill_buckets(&self) -> Vec<usize> {
        let mut v = self.manifest.prefill_lens.clone();
        v.sort_unstable();
        v
    }

    pub fn decode_batches(&self) -> Vec<usize> {
        let mut v = self.manifest.decode_batches.clone();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Mamba2, Variant};

    fn runtime() -> Option<Runtime> {
        let dir = artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Runtime::load(dir).expect("runtime load"))
        } else {
            None
        }
    }

    #[test]
    fn prefill_executes_and_matches_golden_model() {
        let Some(rt) = runtime() else { return };
        let tokens: Vec<i32> = (0..32).map(|i| (i * 7) % 512).collect();
        let out = rt.prefill_fresh("fp32", &tokens).expect("prefill");
        let cfg = &rt.weights_host.cfg;
        assert_eq!(out.logits.len(), 32 * cfg.vocab_size);

        // golden model parity (same weights, same tokens)
        let golden = Mamba2::new(rt.weights_host.clone());
        let t_u32: Vec<u32> = tokens.iter().map(|t| *t as u32).collect();
        let (want, state) = golden.prefill(&t_u32, Variant::Fp32);
        let mut max_err = 0.0f32;
        for (a, b) in out.logits.iter().zip(&want) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 2e-2, "PJRT vs golden max err {max_err}");
        let mut s_err = 0.0f32;
        for (a, b) in out.ssm_state.iter().zip(&state.ssm) {
            s_err = s_err.max((a - b).abs());
        }
        assert!(s_err < 2e-2, "state err {s_err}");
    }

    #[test]
    fn decode_step_continues_prefill() {
        let Some(rt) = runtime() else { return };
        let cfg = rt.weights_host.cfg.clone();
        let tokens: Vec<i32> = (0..32).map(|i| (i * 5) % 512).collect();
        let pre = rt.prefill_fresh("fp32", &tokens).unwrap();
        let out = rt
            .decode("fp32", 1, &pre.conv_state, &pre.ssm_state, &[tokens[31]])
            .unwrap();
        assert_eq!(out.logits.len(), cfg.vocab_size);
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fastmamba_variant_runs() {
        let Some(rt) = runtime() else { return };
        let tokens: Vec<i32> = (0..32).map(|i| (i * 3) % 512).collect();
        let out = rt.prefill_fresh("fastmamba", &tokens).expect("fastmamba prefill");
        assert!(out.logits.iter().all(|v| v.is_finite()));
        // quantized logits close to fp32 logits (Table II premise)
        let fp = rt.prefill_fresh("fp32", &tokens).unwrap();
        let rms_fp = (fp.logits.iter().map(|v| v * v).sum::<f32>()
            / fp.logits.len() as f32)
            .sqrt();
        let rms_e = (out
            .logits
            .iter()
            .zip(&fp.logits)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / fp.logits.len() as f32)
            .sqrt();
        assert!(rms_e < 0.3 * rms_fp, "rel {}", rms_e / rms_fp);
    }

    #[test]
    fn batched_decode_shapes() {
        let Some(rt) = runtime() else { return };
        let cfg = rt.weights_host.cfg.clone();
        let b = 4;
        let conv = vec![0.0f32; b * cfg.conv_state_len()];
        let ssm = vec![0.0f32; b * cfg.ssm_state_len()];
        let out = rt.decode("fp32", b, &conv, &ssm, &[1, 2, 3, 4]).unwrap();
        assert_eq!(out.logits.len(), b * cfg.vocab_size);
        assert_eq!(out.conv_state.len(), conv.len());
        assert_eq!(out.ssm_state.len(), ssm.len());
    }

    #[test]
    fn executable_cache_reuses() {
        let Some(rt) = runtime() else { return };
        let tokens: Vec<i32> = vec![0; 32];
        rt.prefill_fresh("fp32", &tokens).unwrap();
        let n1 = rt.compiled_count();
        rt.prefill_fresh("fp32", &tokens).unwrap();
        assert_eq!(rt.compiled_count(), n1);
    }
}
