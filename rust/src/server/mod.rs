//! OpenAI-style HTTP/SSE serving frontend over the streaming request
//! lifecycle.
//!
//! The server is the same dependency-free `std::net` construction as the
//! Prometheus scrape endpoint ([`crate::obs::scrape`]): one
//! `TcpListener` accept thread, one short-lived thread per connection,
//! shutdown by flipping an atomic and self-connecting.  What it serves is
//! the full request lifecycle instead of a metrics snapshot:
//!
//! - `POST /v1/completions` maps the body onto a [`Request`] (sampling
//!   params, session id, deadline, priority — see [`api::parse_completion`])
//!   and submits it through a [`Submitter`].  Non-streaming requests block
//!   for the terminal event and answer with one JSON completion;
//!   `"stream": true` answers as SSE where each lifecycle [`Event`] is one
//!   frame ([`Event::FirstToken`] → the TTFT marker frame, each
//!   [`Event::Token`] → one chunk, [`Event::Finished`] → the
//!   `finish_reason` + usage frame) followed by the `data: [DONE]`
//!   terminator.
//! - A client that disappears mid-stream is detected (write failure or
//!   idle-tick EOF probe) and turns into [`SubmitHandle::cancel`], so the
//!   engine frees the state slot instead of decoding to `max_new_tokens`
//!   for nobody.
//! - A request shed by admission control (`--max-queue`, see
//!   [`crate::coordinator::request::SchedPolicy`]) answers `429 Too Many
//!   Requests` with a `Retry-After` header on both response shapes — the
//!   SSE headers are held back until the first lifecycle event so a shed
//!   streaming request still gets the plain retriable status code.
//! - `GET /healthz` reports the served variants.
//!
//! [`Submitter`] decouples the frontend from the serving topology: the
//! worker pool, the single-threaded [`Engine`], and the [`SpecEngine`] all
//! feed through [`ChannelSubmitter`] (an `mpsc::Sender<Request>` that
//! attaches the event channel before sending), so every CLI serve path —
//! single/pool × plain/speculative — exposes the same HTTP surface.
//!
//! [`Engine`]: crate::coordinator::scheduler::Engine
//! [`SpecEngine`]: crate::coordinator::speculative::SpecEngine

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::request::{Event, FinishReason, Request, SubmitHandle};

pub mod api;
pub mod http;

pub use api::ApiConfig;

/// How the HTTP frontend hands a parsed [`Request`] to a serving backend.
///
/// Implementations must attach the event channel (the returned
/// [`SubmitHandle`] is how the connection thread streams tokens back and
/// propagates cancellation).
pub trait Submitter: Send + Sync {
    fn submit(&self, req: Request) -> Result<SubmitHandle>;
}

/// [`Submitter`] over a raw `mpsc::Sender<Request>` — the pool's
/// [`ServePool::sender`] ingress clone, or the feed channel of a
/// single-engine pump loop (see `serve_over_http` in the CLI).  Attaches
/// the per-request event channel before sending, which is what a raw
/// sender clone does not do on its own.
///
/// The sender sits behind a `Mutex` because `mpsc::Sender` is `!Sync`;
/// submission is one short `send` per request, so the lock is uncontended
/// in practice.
///
/// [`ServePool::sender`]: crate::coordinator::router::ServePool::sender
pub struct ChannelSubmitter {
    tx: Mutex<mpsc::Sender<Request>>,
}

impl ChannelSubmitter {
    pub fn new(tx: mpsc::Sender<Request>) -> Self {
        Self { tx: Mutex::new(tx) }
    }
}

impl Submitter for ChannelSubmitter {
    fn submit(&self, mut req: Request) -> Result<SubmitHandle> {
        let handle = req.attach_events();
        self.tx
            .lock()
            .map_err(|_| anyhow!("submitter lock poisoned"))?
            .send(req)
            .map_err(|_| anyhow!("serving side is gone"))?;
        Ok(handle)
    }
}

/// Frontend configuration: the API mapping knobs plus wire-level bounds.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    pub api: ApiConfig,
    /// request-body size cap (413-class rejection above this)
    pub max_body_bytes: usize,
    /// pool telemetry, when serving over a topology that registers one:
    /// `/healthz` consults [`crate::obs::TelemetryHub::liveness`] so a
    /// pool whose workers have all died answers `503` instead of `200`
    /// (the process being up is not the service being alive)
    pub hub: Option<Arc<crate::obs::TelemetryHub>>,
}

impl HttpConfig {
    pub fn new(api: ApiConfig) -> Self {
        Self { api, max_body_bytes: 1024 * 1024, hub: None }
    }

    /// Attach the serving topology's telemetry hub (pool liveness on
    /// `/healthz`).
    pub fn with_hub(mut self, hub: Arc<crate::obs::TelemetryHub>) -> Self {
        self.hub = Some(hub);
        self
    }
}

/// A running HTTP frontend (see [`serve_http`]).
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    served: Arc<AtomicU64>,
}

impl HttpServer {
    /// The bound address (resolves port 0 to the OS-picked port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Completion requests that reached a terminal outcome (finished,
    /// cancelled, or abandoned by the client) — the CLI's
    /// `--http-requests N` exit condition reads this.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::SeqCst)
    }

    /// Stop accepting, join every connection thread, and release the
    /// submitter (idempotent).  Joining matters: the accept thread owns
    /// the `Arc<dyn Submitter>`, so for a [`ChannelSubmitter`] over a pool
    /// ingress clone, shutdown is what lets `ServePool::finish()` observe
    /// end-of-input and unblock.
    pub fn shutdown(&mut self) {
        if self.accept.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve the OpenAI-style completion API on `addr`
/// (e.g. `"127.0.0.1:8080"`, or `"127.0.0.1:0"` for an OS-picked port).
///
/// The accept thread holds the only long-lived clone of `submitter`; each
/// connection runs on its own thread and streams straight from its
/// request's [`SubmitHandle`], so slow clients only ever stall their own
/// request.
pub fn serve_http(
    addr: &str,
    submitter: Arc<dyn Submitter>,
    cfg: HttpConfig,
) -> Result<HttpServer> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding http frontend {addr}"))?;
    let bound = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let served = Arc::new(AtomicU64::new(0));
    let stop_in = Arc::clone(&stop);
    let served_in = Arc::clone(&served);
    let cfg = Arc::new(cfg);
    let accept = std::thread::Builder::new()
        .name("http-accept".into())
        .spawn(move || {
            let next_id = AtomicU64::new(1);
            let mut conns: Vec<JoinHandle<()>> = Vec::new();
            for conn in listener.incoming() {
                if stop_in.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let submitter = Arc::clone(&submitter);
                let cfg = Arc::clone(&cfg);
                let served = Arc::clone(&served_in);
                let stop = Arc::clone(&stop_in);
                let id = next_id.fetch_add(1, Ordering::SeqCst);
                let spawned = std::thread::Builder::new().name("http-conn".into()).spawn(
                    move || {
                        // connection errors only drop that connection
                        let _ = handle_conn(stream, id, &*submitter, &cfg, &served, &stop);
                    },
                );
                if let Ok(h) = spawned {
                    conns.push(h);
                }
                conns.retain(|h| !h.is_finished());
            }
            // join every in-flight connection before dropping `submitter`:
            // streams get to retire their requests, and the pool-ingress
            // sender clone drops only once nothing can submit through it
            for h in conns {
                let _ = h.join();
            }
        })?;
    Ok(HttpServer { addr: bound, stop, accept: Some(accept), served })
}

/// Requests one connection may serve before the server forces a close —
/// bounds how long a single client can pin a connection thread while
/// still amortizing the TCP handshake for well-behaved keep-alive
/// clients.
const MAX_REQUESTS_PER_CONN: usize = 32;

fn handle_conn(
    mut stream: TcpStream,
    id: u64,
    submitter: &dyn Submitter,
    cfg: &HttpConfig,
    served: &AtomicU64,
    stop: &AtomicBool,
) -> Result<()> {
    // bytes read past one request's body belong to the next pipelined
    // request on the same connection
    let mut carry: Vec<u8> = Vec::new();
    for served_n in 0..MAX_REQUESTS_PER_CONN {
        let req = match http::read_request(&mut stream, cfg.max_body_bytes, &mut carry) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // client was done with the connection
            Err(e) => {
                if served_n > 0 {
                    // an idle keep-alive connection timing out (or a
                    // half-sent followup) is a normal end, not a protocol
                    // error worth answering
                    return Ok(());
                }
                let body = api::error_json(&format!("{e:#}"), "invalid_request_error");
                return http::write_response(
                    &mut stream,
                    "400 Bad Request",
                    "application/json",
                    &body,
                    false,
                );
            }
        };
        // honor the client's choice, the per-connection budget, and server
        // shutdown; SSE responses are always terminal (their headers
        // commit to `Connection: close`)
        let ka = req.keep_alive
            && served_n + 1 < MAX_REQUESTS_PER_CONN
            && !stop.load(Ordering::SeqCst);
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => {
                use crate::util::json::{obj, s, Json};
                // liveness, not readiness: the process answering is not the
                // service being alive — a pool whose workers all died can
                // still accept this connection, and must say so
                let dead = cfg
                    .hub
                    .as_ref()
                    .and_then(|h| h.liveness())
                    .map(|alive| !alive)
                    .unwrap_or(false);
                let body = crate::util::json::to_string(&obj(vec![
                    ("status", s(if dead { "unhealthy" } else { "ok" })),
                    ("model", s(&cfg.api.variant)),
                    (
                        "variants",
                        Json::Arr(cfg.api.variants.iter().map(|v| s(v)).collect()),
                    ),
                ]));
                let status = if dead { "503 Service Unavailable" } else { "200 OK" };
                http::write_response(&mut stream, status, "application/json", &body, ka)?;
            }
            ("POST", "/v1/completions") => {
                let parsed = match api::parse_completion(&req.body, id, &cfg.api) {
                    Ok(p) => p,
                    Err(msg) => {
                        // the body was fully consumed, so framing survives a
                        // rejection — the connection stays usable
                        let body = api::error_json(&msg, "invalid_request_error");
                        http::write_response(
                            &mut stream,
                            "400 Bad Request",
                            "application/json",
                            &body,
                            ka,
                        )?;
                        if ka {
                            continue;
                        }
                        return Ok(());
                    }
                };
                let model = parsed.req.variant.clone();
                let handle = match submitter.submit(parsed.req) {
                    Ok(h) => h,
                    Err(e) => {
                        // the serving side is gone for good: answer and close
                        let body = api::error_json(&format!("{e:#}"), "server_error");
                        return http::write_response(
                            &mut stream,
                            "503 Service Unavailable",
                            "application/json",
                            &body,
                            false,
                        );
                    }
                };
                if parsed.stream {
                    let out = stream_completion(stream, id, &model, &handle, stop);
                    served.fetch_add(1, Ordering::SeqCst);
                    return out;
                }
                let out = match handle.wait_finished() {
                    Some(fin) if fin.finish_reason == FinishReason::Overloaded => {
                        write_overloaded(&mut stream, ka)
                    }
                    Some(fin) => http::write_response(
                        &mut stream,
                        "200 OK",
                        "application/json",
                        &api::completion_json(id, &model, &fin),
                        ka,
                    ),
                    None => http::write_response(
                        &mut stream,
                        "500 Internal Server Error",
                        "application/json",
                        &api::error_json("serving side shut down mid-request", "server_error"),
                        ka,
                    ),
                };
                served.fetch_add(1, Ordering::SeqCst);
                out?;
            }
            _ => {
                http::write_response(
                    &mut stream,
                    "404 Not Found",
                    "application/json",
                    &api::error_json(
                        "unknown route; POST /v1/completions or GET /healthz",
                        "not_found",
                    ),
                    ka,
                )?;
            }
        }
        if !ka {
            return Ok(());
        }
    }
    Ok(())
}

/// `429 Too Many Requests` + `Retry-After` for a request shed by
/// admission control: it consumed no slot and generated nothing, so the
/// client can retry verbatim after backing off.
fn write_overloaded(stream: &mut TcpStream, keep_alive: bool) -> Result<()> {
    http::write_response_extra(
        stream,
        "429 Too Many Requests",
        "application/json",
        &[("Retry-After", "1")],
        &api::error_json(
            "server overloaded: request shed by admission control; retry after backoff",
            "overloaded_error",
        ),
        keep_alive,
    )
}

/// Stream one request as SSE: every lifecycle event is one frame, the
/// terminal frame is followed by `data: [DONE]`.  A vanished client — a
/// failed frame write, or EOF on the idle-tick probe — becomes
/// [`SubmitHandle::cancel`] so the engine frees the slot; the handle is
/// then drained to the terminal event so the retire is observed before
/// the connection thread exits.
///
/// The SSE headers are deferred until the first lifecycle event arrives:
/// a request shed by admission control terminates without producing any
/// stream, and it must answer with a plain `429` + `Retry-After` (the
/// retriable status code) instead of committing to a `200` SSE response
/// whose only frame is an `overloaded` finish.
fn stream_completion(
    mut stream: TcpStream,
    id: u64,
    model: &str,
    handle: &SubmitHandle,
    stop: &AtomicBool,
) -> Result<()> {
    let first = loop {
        match handle.poll_event(Duration::from_millis(100)) {
            Ok(ev) => break ev,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // serving side alive but quiet: probe the client and honor
                // server shutdown so a stalled stream cannot pin a slot
                if stop.load(Ordering::SeqCst) || client_gone(&stream) {
                    handle.cancel();
                    drain_until_finished(handle);
                    return Ok(());
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // engine/pool dropped before any event: still pre-headers,
                // so a proper status line goes out instead of a dead stream
                return http::write_response(
                    &mut stream,
                    "500 Internal Server Error",
                    "application/json",
                    &api::error_json("serving side shut down mid-request", "server_error"),
                    false,
                );
            }
        }
    };
    if let Event::Finished(fin) = &first {
        if fin.finish_reason == FinishReason::Overloaded {
            return write_overloaded(&mut stream, false);
        }
    }
    http::write_sse_headers(&mut stream)?;
    let mut next = Some(first);
    loop {
        let ev = match next.take() {
            Some(ev) => ev,
            None => match handle.poll_event(Duration::from_millis(100)) {
                Ok(ev) => ev,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if stop.load(Ordering::SeqCst) || client_gone(&stream) {
                        handle.cancel();
                        drain_until_finished(handle);
                        return Ok(());
                    }
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // engine/pool dropped without a terminal event
                    return Ok(());
                }
            },
        };
        let frame = api::chunk_json(id, model, &ev);
        let wrote = http::write_sse_data(&mut stream, &frame).is_ok();
        if matches!(ev, Event::Finished(_)) {
            if wrote {
                let _ = http::write_sse_data(&mut stream, "[DONE]");
            }
            return Ok(());
        }
        if !wrote {
            handle.cancel();
            drain_until_finished(handle);
            return Ok(());
        }
    }
}

/// After a cancel, wait (bounded) for the terminal event so the request
/// is known-retired — its slot freed — before this connection thread
/// exits.
fn drain_until_finished(handle: &SubmitHandle) {
    for _ in 0..50 {
        match handle.poll_event(Duration::from_millis(100)) {
            Ok(Event::Finished(_)) | Err(mpsc::RecvTimeoutError::Disconnected) => return,
            Ok(_) | Err(mpsc::RecvTimeoutError::Timeout) => {}
        }
    }
}

/// Has the client closed its end?  A completions client sends nothing
/// after the request body, so a successful zero-byte read is EOF; a
/// `WouldBlock` means the socket is open with nothing to read (the normal
/// mid-stream state).
fn client_gone(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut probe = [0u8; 16];
    let gone = match (&*stream).read(&mut probe) {
        Ok(0) => true,
        Ok(_) => false, // stray bytes: not EOF, keep serving
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    gone
}

#[cfg(test)]
mod tests {
    use std::io::Write;

    use super::*;
    use crate::backend::{InferenceBackend, NativeBackend};
    use crate::coordinator::request::{FinishReason, FinishedRequest};
    use crate::coordinator::sampler::SamplingParams;
    use crate::coordinator::{serve_pool, EngineConfig, PoolConfig, SchedPolicy, ServePool};
    use crate::util::json::Json;

    fn micro_backend() -> NativeBackend {
        let mut cfg = crate::config::ModelConfig::tiny();
        cfg.name = "mamba2-micro".into();
        cfg.d_model = 64;
        cfg.n_layer = 2;
        cfg.d_state = 16;
        cfg.headdim = 16;
        cfg.vocab_size = 128;
        NativeBackend::new(crate::model::ModelWeights::random(&cfg, 9))
            .with_buckets(vec![8, 16, 32], vec![1, 2, 4])
    }

    fn micro_pool(n_workers: usize, max_active: usize) -> ServePool {
        serve_pool(
            || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>),
            PoolConfig {
                engine: EngineConfig { max_active, greedy_chunking: true },
                n_workers,
                ..PoolConfig::default()
            },
        )
    }

    fn test_cfg() -> HttpConfig {
        HttpConfig::new(ApiConfig {
            variant: "fp32".into(),
            variants: vec!["fp32".into(), "fastmamba".into()],
            vocab_size: 128,
            default_max_tokens: 8,
        })
    }

    fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut stream = TcpStream::connect(addr).unwrap();
        write!(stream, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
        read_split(stream)
    }

    fn http_post(addr: SocketAddr, path: &str, body: &str) -> (String, String) {
        let mut stream = TcpStream::connect(addr).unwrap();
        write!(
            stream,
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        read_split(stream)
    }

    fn read_split(mut stream: TcpStream) -> (String, String) {
        let mut raw = String::new();
        stream.read_to_string(&mut raw).unwrap();
        let (head, body) = raw.split_once("\r\n\r\n").expect("malformed response");
        (head.to_string(), body.to_string())
    }

    /// SSE body → frame payloads (strips `data: `, keeps order).
    fn sse_payloads(body: &str) -> Vec<String> {
        body.split("\n\n")
            .filter(|f| !f.is_empty())
            .map(|f| f.strip_prefix("data: ").expect("frame prefix").to_string())
            .collect()
    }

    #[test]
    fn server_healthz_routes_and_rejects() {
        let pool = micro_pool(1, 2);
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server = serve_http("127.0.0.1:0", submitter, test_cfg()).unwrap();

        let (head, body) = http_get(server.addr(), "/healthz");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.str_field("status").unwrap(), "ok");
        assert_eq!(v.str_field("model").unwrap(), "fp32");
        assert_eq!(v.arr_field("variants").unwrap().len(), 2);

        let (head, _) = http_get(server.addr(), "/nope");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");

        let (head, body) = http_post(server.addr(), "/v1/completions", r#"{"prompt": []}"#);
        assert!(head.starts_with("HTTP/1.1 400"), "{head}");
        let v = Json::parse(&body).unwrap();
        assert!(v.get("error").unwrap().str_field("message").unwrap().contains("empty"));

        let (head, _) = http_post(server.addr(), "/v1/completions", "{not json");
        assert!(head.starts_with("HTTP/1.1 400"), "{head}");

        server.shutdown();
        server.shutdown(); // idempotent
        pool.finish().unwrap();
    }

    #[test]
    fn server_healthz_reflects_pool_liveness_503_on_all_dead() {
        use crate::util::json::{num, obj, s};

        // fabricate a pool's telemetry state directly: the dispatcher
        // status slot is the single source of truth for pool liveness,
        // so the socket-level contract is testable without killing real
        // worker threads
        let hub = Arc::new(crate::obs::TelemetryHub::new());
        let dtel = hub.register("dispatcher");
        let pool = micro_pool(1, 2);
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server =
            serve_http("127.0.0.1:0", submitter, test_cfg().with_hub(Arc::clone(&hub)))
                .unwrap();

        // workers alive → 200, same body shape as the hub-less route
        dtel.set_status(obj(vec![
            ("role", s("dispatcher")),
            ("workers_alive", num(2.0)),
            ("backlog", num(0.0)),
            ("max_queue", num(0.0)),
            ("dispatched_total", num(0.0)),
        ]));
        let (head, body) = http_get(server.addr(), "/healthz");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert_eq!(Json::parse(&body).unwrap().str_field("status").unwrap(), "ok");

        // every worker dead → 503 with an explicit "unhealthy" status
        dtel.set_status(obj(vec![
            ("role", s("dispatcher")),
            ("workers_alive", num(0.0)),
            ("backlog", num(0.0)),
            ("max_queue", num(0.0)),
            ("dispatched_total", num(0.0)),
        ]));
        let (head, body) = http_get(server.addr(), "/healthz");
        assert!(head.starts_with("HTTP/1.1 503"), "{head}");
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.str_field("status").unwrap(), "unhealthy");
        assert_eq!(v.str_field("model").unwrap(), "fp32");

        server.shutdown();
        pool.finish().unwrap();
    }

    #[test]
    fn server_completion_over_pool_matches_direct_submit() {
        let pool = micro_pool(2, 2);
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server = serve_http("127.0.0.1:0", submitter, test_cfg()).unwrap();

        // one greedy, one sampled — both must match an in-process submit
        // of the same prompt + params (sampling is position-keyed, so the
        // draws don't depend on request id or worker)
        for (i, (body, direct_sampling)) in [
            (
                r#"{"prompt": [1, 2, 3], "max_tokens": 6}"#.to_string(),
                SamplingParams::default(),
            ),
            (
                r#"{"prompt": [5, 9, 2, 44], "max_tokens": 6,
                    "temperature": 1.0, "seed": 77}"#
                    .to_string(),
                SamplingParams { temperature: 1.0, seed: 77, ..Default::default() },
            ),
        ]
        .into_iter()
        .enumerate()
        {
            let parsed = Json::parse(&body).unwrap();
            let prompt: Vec<u32> = parsed
                .arr_field("prompt")
                .unwrap()
                .iter()
                .map(|t| t.as_usize().unwrap() as u32)
                .collect();
            let direct = pool
                .submit(
                    Request::new(1000 + i as u64, prompt, 6, "fp32")
                        .with_sampling(direct_sampling),
                )
                .unwrap()
                .wait_finished()
                .unwrap();

            let (head, resp) = http_post(server.addr(), "/v1/completions", &body);
            assert!(head.starts_with("HTTP/1.1 200"), "{head}");
            let v = Json::parse(&resp).unwrap();
            let choice = &v.arr_field("choices").unwrap()[0];
            let toks: Vec<u32> = choice
                .arr_field("tokens")
                .unwrap()
                .iter()
                .map(|t| t.as_usize().unwrap() as u32)
                .collect();
            assert_eq!(toks, direct.generated, "HTTP tokens != direct submit");
            assert_eq!(choice.str_field("text").unwrap(), api::render_text(&direct.generated));
            assert_eq!(choice.str_field("finish_reason").unwrap(), "length");
            let u = v.get("usage").unwrap();
            assert_eq!(u.usize_field("completion_tokens").unwrap(), direct.generated.len());
        }

        assert_eq!(server.served(), 2);
        server.shutdown();
        pool.finish().unwrap();
    }

    #[test]
    fn sse_stream_matches_in_process_submit_handle() {
        let pool = micro_pool(1, 2);
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server = serve_http("127.0.0.1:0", submitter, test_cfg()).unwrap();

        // in-process reference: the exact event stream off a SubmitHandle
        let sampling = SamplingParams { temperature: 1.0, seed: 42, ..Default::default() };
        let h = pool
            .submit(
                Request::new(2000, vec![3, 1, 4, 1, 5], 5, "fp32")
                    .with_sampling(sampling),
            )
            .unwrap();
        let mut direct_tokens: Vec<(u32, usize)> = Vec::new();
        let mut saw_first = false;
        let direct_fin: FinishedRequest = loop {
            match h.next_event().expect("event stream ended early") {
                Event::FirstToken => saw_first = true,
                Event::Token { tok, index } => direct_tokens.push((tok, index)),
                Event::Finished(f) => break f,
            }
        };
        assert!(saw_first);

        let body = r#"{"prompt": [3, 1, 4, 1, 5], "max_tokens": 5, "stream": true,
                       "temperature": 1.0, "seed": 42}"#;
        let (head, resp) = http_post(server.addr(), "/v1/completions", body);
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(head.contains("text/event-stream"), "{head}");

        let payloads = sse_payloads(&resp);
        assert_eq!(payloads.last().map(String::as_str), Some("[DONE]"));
        let frames: Vec<Json> = payloads[..payloads.len() - 1]
            .iter()
            .map(|p| Json::parse(p).unwrap())
            .collect();
        // frame 0: TTFT marker; frames 1..=n: tokens; last: finish_reason
        let choice = |f: &Json| f.arr_field("choices").unwrap()[0].clone();
        assert!(matches!(choice(&frames[0]).get("first_token"), Some(Json::Bool(true))));
        let sse_tokens: Vec<(u32, usize)> = frames[1..frames.len() - 1]
            .iter()
            .map(|f| {
                let c = choice(f);
                (c.usize_field("token").unwrap() as u32, c.usize_field("token_index").unwrap())
            })
            .collect();
        assert_eq!(sse_tokens, direct_tokens, "SSE stream != in-process event stream");
        let last = choice(frames.last().unwrap());
        assert_eq!(last.str_field("finish_reason").unwrap(), "length");
        assert_eq!(direct_fin.finish_reason, FinishReason::Length);
        // concatenated chunk text reproduces the canonical rendering
        let text: String = frames[..frames.len() - 1]
            .iter()
            .map(|f| choice(f).str_field("text").unwrap().to_string())
            .collect();
        assert_eq!(text, api::render_text(&direct_fin.generated));

        server.shutdown();
        pool.finish().unwrap();
    }

    #[test]
    fn sse_client_disconnect_cancels_and_frees_slot() {
        // single worker, single slot: a huge streamed request owns the only
        // slot; dropping its connection must cancel it (freeing the slot)
        // so a queued follow-up request can complete
        let pool = micro_pool(1, 1);
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server = serve_http("127.0.0.1:0", submitter, test_cfg()).unwrap();

        let body = r#"{"prompt": [1, 2, 3], "max_tokens": 100000, "stream": true}"#;
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        write!(
            stream,
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        // read until a couple of SSE frames arrived (the response head's
        // \r\n\r\n contains no \n\n, so every \n\n is a frame terminator),
        // then vanish without closing cleanly at a frame boundary
        let mut seen = String::new();
        let mut byte = [0u8; 1];
        while seen.matches("\n\n").count() < 3 {
            let n = stream.read(&mut byte).unwrap();
            assert!(n > 0, "server closed early: {seen}");
            seen.push(byte[0] as char);
        }
        drop(stream);

        // the freed slot serves a follow-up to completion
        let follow = r#"{"prompt": [7, 8], "max_tokens": 3}"#;
        let (head, resp) = http_post(server.addr(), "/v1/completions", follow);
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        let v = Json::parse(&resp).unwrap();
        let choice = &v.arr_field("choices").unwrap()[0];
        assert_eq!(choice.str_field("finish_reason").unwrap(), "length");
        assert_eq!(choice.arr_field("tokens").unwrap().len(), 3);
        assert_eq!(server.served(), 2);
        server.shutdown();
        let report = pool.finish().unwrap();
        assert_eq!(report.merged.cancelled_requests, 1, "disconnect did not cancel");
        assert_eq!(report.merged.requests_completed, 2);
    }

    #[test]
    fn server_rejects_request_smuggling_headers() {
        let pool = micro_pool(1, 2);
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server = serve_http("127.0.0.1:0", submitter, test_cfg()).unwrap();
        let body = r#"{"prompt": [1]}"#;

        // duplicate Content-Length headers that disagree: reject instead of
        // letting the last one silently win (request-smuggling vector)
        let mut s1 = TcpStream::connect(server.addr()).unwrap();
        write!(
            s1,
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len(),
            body.len() + 2,
        )
        .unwrap();
        let (head, resp) = read_split(s1);
        assert!(head.starts_with("HTTP/1.1 400"), "{head}");
        assert!(resp.contains("conflicting Content-Length"), "{resp}");

        // chunked transfer coding is unsupported — reject, never misparse
        let mut s2 = TcpStream::connect(server.addr()).unwrap();
        write!(
            s2,
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nTransfer-Encoding: chunked\r\n\
             Connection: close\r\n\r\n0\r\n\r\n"
        )
        .unwrap();
        let (head, resp) = read_split(s2);
        assert!(head.starts_with("HTTP/1.1 400"), "{head}");
        assert!(resp.contains("Transfer-Encoding"), "{resp}");

        // repeated Content-Length headers that agree stay valid
        let mut s3 = TcpStream::connect(server.addr()).unwrap();
        write!(
            s3,
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {len}\r\n\
             Content-Length: {len}\r\nConnection: close\r\n\r\n{body}",
            len = body.len(),
        )
        .unwrap();
        let (head, _) = read_split(s3);
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");

        server.shutdown();
        pool.finish().unwrap();
    }

    #[test]
    fn server_overload_returns_429_with_retry_after_and_retry_succeeds() {
        // 1 worker × 1 slot with a 1-deep dispatcher backlog: a streaming
        // victim owns the slot and one queued request fills the backlog, so
        // the next submission sheds → HTTP 429 + Retry-After.  Dropping the
        // victim frees everything and the retried request completes.
        let pool = serve_pool(
            || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>),
            PoolConfig {
                engine: EngineConfig { max_active: 1, greedy_chunking: true },
                n_workers: 1,
                sched: SchedPolicy { max_queue: 1, ..SchedPolicy::default() },
                ..PoolConfig::default()
            },
        );
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server = serve_http("127.0.0.1:0", submitter, test_cfg()).unwrap();

        // victim: read until SSE frames flow, so it is placed on the worker
        let body = r#"{"prompt": [1, 2, 3], "max_tokens": 100000, "stream": true}"#;
        let mut victim = TcpStream::connect(server.addr()).unwrap();
        victim.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        write!(
            victim,
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut seen = String::new();
        let mut byte = [0u8; 1];
        while seen.matches("\n\n").count() < 2 {
            let n = victim.read(&mut byte).unwrap();
            assert!(n > 0, "server closed early: {seen}");
            seen.push(byte[0] as char);
        }

        // q1 fills the one-deep backlog (no slot free → no frames yet,
        // because SSE headers wait for the first event)
        let q1body = r#"{"prompt": [4, 5], "max_tokens": 2, "stream": true}"#;
        let mut q1 = TcpStream::connect(server.addr()).unwrap();
        q1.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        write!(
            q1,
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{q1body}",
            q1body.len()
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(400)); // q1 → dispatcher backlog

        // q2 sheds: a plain retriable 429, not a 200 SSE stream
        let q2body = r#"{"prompt": [6], "max_tokens": 2}"#;
        let (head, resp) = http_post(server.addr(), "/v1/completions", q2body);
        assert!(head.starts_with("HTTP/1.1 429"), "{head}");
        assert!(head.contains("Retry-After: 1"), "{head}");
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("error").unwrap().str_field("type").unwrap(), "overloaded_error");

        // the vanished victim cancels → slot frees → q1 completes, and the
        // shed request succeeds verbatim on retry: zero requests lost
        drop(victim);
        let (h1, b1) = read_split(q1);
        assert!(h1.starts_with("HTTP/1.1 200"), "{h1}");
        assert_eq!(sse_payloads(&b1).last().map(String::as_str), Some("[DONE]"));

        let (head, resp) = http_post(server.addr(), "/v1/completions", q2body);
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        let v = Json::parse(&resp).unwrap();
        let choice = &v.arr_field("choices").unwrap()[0];
        assert_eq!(choice.str_field("finish_reason").unwrap(), "length");
        assert_eq!(choice.arr_field("tokens").unwrap().len(), 2);

        assert_eq!(server.served(), 4);
        server.shutdown();
        let report = pool.finish().unwrap();
        assert_eq!(report.merged.requests_shed, 1, "q2 was not shed");
        assert_eq!(report.merged.cancelled_requests, 1, "victim was not cancelled");
        assert_eq!(report.merged.requests_completed, 4);
    }

    /// Read exactly one `Content-Length`-framed response off a keep-alive
    /// connection (the stream stays open for the next one).
    fn read_one_response(stream: &mut TcpStream) -> (String, String) {
        let mut buf: Vec<u8> = Vec::new();
        let mut byte = [0u8; 1];
        let head_end = loop {
            if let Some(pos) = http::find_subslice(&buf, b"\r\n\r\n") {
                break pos;
            }
            let n = stream.read(&mut byte).expect("response head");
            assert!(n > 0, "EOF mid-head: {}", String::from_utf8_lossy(&buf));
            buf.push(byte[0]);
        };
        let head = String::from_utf8(buf[..head_end].to_vec()).unwrap();
        let clen: usize = head
            .lines()
            .find_map(|l| {
                let (name, v) = l.split_once(':')?;
                name.eq_ignore_ascii_case("content-length").then(|| v.trim().parse().ok())?
            })
            .expect("Content-Length header");
        let mut body = buf[head_end + 4..].to_vec();
        while body.len() < clen {
            let n = stream.read(&mut byte).expect("response body");
            assert!(n > 0, "EOF mid-body");
            body.push(byte[0]);
        }
        (head, String::from_utf8(body).unwrap())
    }

    #[test]
    fn server_keep_alive_serves_many_requests_on_one_connection() {
        let pool = micro_pool(1, 2);
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server = serve_http("127.0.0.1:0", submitter, test_cfg()).unwrap();

        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let body = r#"{"prompt": [1, 2, 3], "max_tokens": 3}"#;
        // three completions on the same socket: HTTP/1.1 defaults to
        // keep-alive, so no Connection header is sent at all
        let mut want_tokens: Option<String> = None;
        for i in 0..3 {
            write!(
                stream,
                "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
                 Content-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .unwrap();
            let (head, resp) = read_one_response(&mut stream);
            assert!(head.starts_with("HTTP/1.1 200"), "request {i}: {head}");
            assert!(head.contains("Connection: keep-alive"), "request {i}: {head}");
            let v = Json::parse(&resp).unwrap();
            let toks = crate::util::json::to_string(
                &v.arr_field("choices").unwrap()[0].get("tokens").unwrap().clone(),
            );
            match &want_tokens {
                None => want_tokens = Some(toks),
                Some(w) => assert_eq!(&toks, w, "same prompt, different tokens"),
            }
        }
        // a 404 and a parse-rejected request keep the connection usable too
        write!(stream, "GET /nope HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let (head, _) = read_one_response(&mut stream);
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");
        assert!(head.contains("Connection: keep-alive"), "{head}");
        let bad = r#"{"prompt": []}"#;
        write!(
            stream,
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{bad}",
            bad.len()
        )
        .unwrap();
        let (head, _) = read_one_response(&mut stream);
        assert!(head.starts_with("HTTP/1.1 400"), "{head}");
        assert!(head.contains("Connection: keep-alive"), "{head}");

        // Connection: close is honored: the response says close and the
        // server actually closes (EOF after the body)
        write!(
            stream,
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let (head, _) = read_one_response(&mut stream);
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(head.contains("Connection: close"), "{head}");
        let mut probe = [0u8; 1];
        assert_eq!(stream.read(&mut probe).unwrap(), 0, "server did not close");

        assert_eq!(server.served(), 4);
        server.shutdown();
        pool.finish().unwrap();
    }

    #[test]
    fn server_pipelined_requests_share_the_carry_buffer() {
        // both requests land in one TCP write: the bytes of the second
        // arrive while the server reads the first's body, and must be
        // carried over instead of dropped
        let pool = micro_pool(1, 2);
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server = serve_http("127.0.0.1:0", submitter, test_cfg()).unwrap();

        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let b1 = r#"{"prompt": [1, 2, 3], "max_tokens": 2}"#;
        let b2 = r#"{"prompt": [4, 5], "max_tokens": 3}"#;
        let mut batch = String::new();
        for b in [b1, b2] {
            batch.push_str(&format!(
                "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{b}",
                b.len()
            ));
        }
        stream.write_all(batch.as_bytes()).unwrap();
        let (h1, r1) = read_one_response(&mut stream);
        assert!(h1.starts_with("HTTP/1.1 200"), "{h1}");
        let v1 = Json::parse(&r1).unwrap();
        assert_eq!(
            v1.arr_field("choices").unwrap()[0].arr_field("tokens").unwrap().len(),
            2
        );
        let (h2, r2) = read_one_response(&mut stream);
        assert!(h2.starts_with("HTTP/1.1 200"), "{h2}");
        let v2 = Json::parse(&r2).unwrap();
        assert_eq!(
            v2.arr_field("choices").unwrap()[0].arr_field("tokens").unwrap().len(),
            3
        );
        assert_eq!(server.served(), 2);
        server.shutdown();
        pool.finish().unwrap();
    }

    #[test]
    fn server_http10_defaults_to_close_and_sse_is_terminal() {
        let pool = micro_pool(1, 2);
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server = serve_http("127.0.0.1:0", submitter, test_cfg()).unwrap();

        // HTTP/1.0 without an explicit keep-alive: one request, then close
        let mut s10 = TcpStream::connect(server.addr()).unwrap();
        s10.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        write!(s10, "GET /healthz HTTP/1.0\r\nHost: t\r\n\r\n").unwrap();
        let (head, _) = read_one_response(&mut s10);
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(head.contains("Connection: close"), "{head}");
        let mut probe = [0u8; 1];
        assert_eq!(s10.read(&mut probe).unwrap(), 0, "HTTP/1.0 must close");

        // an SSE response commits to close even on an HTTP/1.1 keep-alive
        // connection: frames end at [DONE] and then the socket ends
        let body = r#"{"prompt": [1, 2], "max_tokens": 2, "stream": true}"#;
        let mut sse = TcpStream::connect(server.addr()).unwrap();
        sse.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        write!(
            sse,
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut raw = String::new();
        sse.read_to_string(&mut raw).unwrap(); // EOF-terminated: server closed
        let (head, resp) = raw.split_once("\r\n\r\n").expect("response head");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(head.contains("Connection: close"), "{head}");
        assert_eq!(sse_payloads(resp).last().map(String::as_str), Some("[DONE]"));

        server.shutdown();
        pool.finish().unwrap();
    }
}
