//! OpenAI-style completion API: request-body → [`Request`] mapping and
//! JSON rendering for both response shapes (non-streaming completion,
//! SSE chunks).
//!
//! The crate serves token ids, not text (there is no tokenizer on the
//! serving path), so `prompt` is an array of integer token ids and
//! `choices[0].text` renders tokens as decimal ids joined by single
//! spaces — the same canonical rendering the string stop-sequence matcher
//! ([`crate::coordinator::sampler::StopMatcher`]) runs on, which keeps
//! `stop` semantics consistent between the API surface and the engine.

use std::time::Duration;

use crate::coordinator::request::{Event, FinishReason, FinishedRequest, Request};
use crate::coordinator::sampler::SamplingParams;
use crate::util::json::{self, num, obj, s, Json};

/// Server-side knobs the API mapping needs (derived from the backend).
#[derive(Debug, Clone)]
pub struct ApiConfig {
    /// default model variant when the body omits `model`
    pub variant: String,
    /// every variant the backend serves (the `model` whitelist)
    pub variants: Vec<String>,
    pub vocab_size: usize,
    /// `max_tokens` default when the body omits it
    pub default_max_tokens: usize,
}

/// A parsed `POST /v1/completions` body.
#[derive(Debug)]
pub struct ParsedCompletion {
    pub req: Request,
    pub stream: bool,
}

fn opt_f32(body: &Json, key: &str) -> Result<Option<f32>, String> {
    match body.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_f64()
            .map(|n| Some(n as f32))
            .ok_or_else(|| format!("`{key}` must be a number")),
    }
}

fn opt_u64(body: &Json, key: &str) -> Result<Option<u64>, String> {
    match body.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => match v.as_f64() {
            Some(n) if n >= 0.0 && n.fract() == 0.0 => Ok(Some(n as u64)),
            _ => Err(format!("`{key}` must be a non-negative integer")),
        },
    }
}

/// Map a completion request body onto a [`Request`] with id `id`.
/// Errors are client errors (HTTP 400) phrased for the response body.
pub fn parse_completion(
    body: &[u8],
    id: u64,
    cfg: &ApiConfig,
) -> Result<ParsedCompletion, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let v = Json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    if !matches!(v, Json::Obj(_)) {
        return Err("body must be a JSON object".into());
    }

    let prompt_json = v.get("prompt").ok_or("missing `prompt` (array of token ids)")?;
    let prompt_arr = prompt_json
        .as_arr()
        .ok_or("`prompt` must be an array of integer token ids")?;
    if prompt_arr.is_empty() {
        return Err("`prompt` must not be empty".into());
    }
    let mut prompt = Vec::with_capacity(prompt_arr.len());
    for t in prompt_arr {
        let n = t.as_f64().ok_or("`prompt` entries must be numbers")?;
        if n < 0.0 || n.fract() != 0.0 || n >= cfg.vocab_size as f64 {
            return Err(format!(
                "prompt token {n} out of range (vocab size {})",
                cfg.vocab_size
            ));
        }
        prompt.push(n as u32);
    }

    let variant = match v.get("model") {
        None | Some(Json::Null) => cfg.variant.clone(),
        Some(m) => {
            let name = m.as_str().ok_or("`model` must be a string")?;
            if !cfg.variants.iter().any(|v| v == name) {
                return Err(format!(
                    "unknown model {name:?}; served variants: {}",
                    cfg.variants.join(", ")
                ));
            }
            name.to_string()
        }
    };

    let max_tokens = match opt_u64(&v, "max_tokens")? {
        None => cfg.default_max_tokens,
        Some(0) => return Err("`max_tokens` must be >= 1".into()),
        Some(n) => n as usize,
    };

    let mut sampling = SamplingParams::default();
    if let Some(t) = opt_f32(&v, "temperature")? {
        if !(0.0..=100.0).contains(&t) {
            return Err("`temperature` must be in [0, 100]".into());
        }
        sampling.temperature = t;
    }
    if let Some(k) = opt_u64(&v, "top_k")? {
        sampling.top_k = k as usize;
    }
    if let Some(p) = opt_f32(&v, "top_p")? {
        if !(0.0..=1.0).contains(&p) {
            return Err("`top_p` must be in [0, 1]".into());
        }
        sampling.top_p = p;
    }
    if let Some(rp) = opt_f32(&v, "repetition_penalty")? {
        if rp <= 0.0 {
            return Err("`repetition_penalty` must be > 0".into());
        }
        sampling.repetition_penalty = rp;
    }
    if let Some(p) = opt_f32(&v, "presence_penalty")? {
        sampling.presence_penalty = p;
    }
    if let Some(p) = opt_f32(&v, "frequency_penalty")? {
        sampling.frequency_penalty = p;
    }
    if let Some(seed) = opt_u64(&v, "seed")? {
        sampling.seed = seed;
    }
    if let Some(bias) = v.get("logit_bias") {
        let Json::Obj(fields) = bias else {
            return Err("`logit_bias` must be an object of token-id -> bias".into());
        };
        for (k, b) in fields {
            let tok: u32 = k
                .parse()
                .map_err(|_| format!("logit_bias key {k:?} is not a token id"))?;
            if tok as usize >= cfg.vocab_size {
                return Err(format!("logit_bias token {tok} out of range"));
            }
            let b = b.as_f64().ok_or("logit_bias values must be numbers")?;
            sampling.logit_bias.push((tok, b as f32));
        }
    }
    match v.get("stop") {
        None | Some(Json::Null) => {}
        Some(Json::Str(one)) => sampling.stop_sequences.push(one.clone()),
        Some(Json::Arr(many)) => {
            for e in many {
                let e = e.as_str().ok_or("`stop` entries must be strings")?;
                sampling.stop_sequences.push(e.to_string());
            }
        }
        Some(_) => return Err("`stop` must be a string or an array of strings".into()),
    }

    let mut req = Request::new(id, prompt, max_tokens, &variant).with_sampling(sampling);
    if let Some(tok) = opt_u64(&v, "stop_token_id")? {
        if tok as usize >= cfg.vocab_size {
            return Err(format!("stop_token_id {tok} out of range"));
        }
        req = req.with_stop_token(tok as u32);
    }
    if let Some(sid) = opt_u64(&v, "session_id")? {
        req = req.with_session(sid);
    }
    if let Some(ms) = opt_u64(&v, "deadline_ms")? {
        req = req.with_deadline(Duration::from_millis(ms));
    }
    if let Some(p) = v.get("priority") {
        let n = p.as_f64().ok_or("`priority` must be a number")?;
        req = req.with_priority(n as i32);
    }

    let stream = match v.get("stream") {
        None | Some(Json::Null) => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => return Err("`stream` must be a boolean".into()),
    };
    Ok(ParsedCompletion { req, stream })
}

/// The API string for a [`FinishReason`] (`finish_reason` in responses).
pub fn finish_reason_str(reason: FinishReason) -> &'static str {
    match reason {
        FinishReason::Length => "length",
        FinishReason::StopToken | FinishReason::StopSequence => "stop",
        FinishReason::Cancelled => "cancelled",
        FinishReason::Deadline => "deadline",
        FinishReason::WorkerDied => "worker_died",
        // internal marker — a preempted request resumes and retires with a
        // real terminal reason, so this never reaches a client response
        FinishReason::Preempted => "preempted",
        FinishReason::Overloaded => "overloaded",
    }
}

/// Canonical text rendering of a token sequence: decimal ids joined by
/// single spaces (matches [`StopMatcher::render`]).
///
/// [`StopMatcher::render`]: crate::coordinator::sampler::StopMatcher::render
pub fn render_text(toks: &[u32]) -> String {
    toks.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ")
}

fn usage_json(fin: &FinishedRequest) -> Json {
    obj(vec![
        ("prompt_tokens", num(fin.prompt_len as f64)),
        ("completion_tokens", num(fin.generated.len() as f64)),
        ("total_tokens", num((fin.prompt_len + fin.generated.len()) as f64)),
    ])
}

/// Non-streaming completion response body.
pub fn completion_json(id: u64, model: &str, fin: &FinishedRequest) -> String {
    let choice = obj(vec![
        ("index", num(0.0)),
        ("text", s(&render_text(&fin.generated))),
        ("tokens", Json::Arr(fin.generated.iter().map(|t| num(*t as f64)).collect())),
        ("finish_reason", s(finish_reason_str(fin.finish_reason))),
    ]);
    json::to_string(&obj(vec![
        ("id", s(&format!("cmpl-{id}"))),
        ("object", s("text_completion")),
        ("model", s(model)),
        ("choices", Json::Arr(vec![choice])),
        ("usage", usage_json(fin)),
    ]))
}

/// One SSE chunk for one lifecycle [`Event`] — the 1:1 event→frame
/// mapping (`FirstToken` announces TTFT, each `Token` carries one token,
/// `Finished` carries `finish_reason` + usage; the `[DONE]` sentinel
/// follows separately).
pub fn chunk_json(id: u64, model: &str, ev: &Event) -> String {
    let choice = match ev {
        Event::FirstToken => obj(vec![
            ("index", num(0.0)),
            ("text", s("")),
            ("first_token", Json::Bool(true)),
            ("finish_reason", Json::Null),
        ]),
        Event::Token { tok, index } => {
            // token at stream index 0 renders bare, later ones carry the
            // joining space — concatenating `text` fields reproduces
            // render_text() exactly
            let text =
                if *index == 0 { tok.to_string() } else { format!(" {tok}") };
            obj(vec![
                ("index", num(0.0)),
                ("text", s(&text)),
                ("token", num(*tok as f64)),
                ("token_index", num(*index as f64)),
                ("finish_reason", Json::Null),
            ])
        }
        Event::Finished(fin) => obj(vec![
            ("index", num(0.0)),
            ("text", s("")),
            ("finish_reason", s(finish_reason_str(fin.finish_reason))),
        ]),
    };
    let mut fields = vec![
        ("id", s(&format!("cmpl-{id}"))),
        ("object", s("text_completion.chunk")),
        ("model", s(model)),
        ("choices", Json::Arr(vec![choice])),
    ];
    if let Event::Finished(fin) = ev {
        fields.push(("usage", usage_json(fin)));
    }
    json::to_string(&obj(fields))
}

/// Error response body.
pub fn error_json(message: &str, kind: &str) -> String {
    json::to_string(&obj(vec![(
        "error",
        obj(vec![("message", s(message)), ("type", s(kind))]),
    )]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ApiConfig {
        ApiConfig {
            variant: "fp32".into(),
            variants: vec!["fp32".into(), "fastmamba".into()],
            vocab_size: 128,
            default_max_tokens: 16,
        }
    }

    #[test]
    fn server_parse_completion_full_surface() {
        let body = br#"{
            "prompt": [1, 2, 3],
            "model": "fastmamba",
            "max_tokens": 8,
            "stream": true,
            "temperature": 0.9,
            "top_k": 40,
            "top_p": 0.95,
            "repetition_penalty": 1.1,
            "presence_penalty": 0.2,
            "frequency_penalty": 0.3,
            "seed": 7,
            "logit_bias": {"5": -10.0},
            "stop": ["9 12", "44"],
            "stop_token_id": 99,
            "session_id": 123,
            "deadline_ms": 5000,
            "priority": 2
        }"#;
        let p = parse_completion(body, 42, &cfg()).unwrap();
        assert!(p.stream);
        assert_eq!(p.req.id, 42);
        assert_eq!(p.req.prompt, vec![1, 2, 3]);
        assert_eq!(p.req.variant, "fastmamba");
        assert_eq!(p.req.max_new_tokens, 8);
        assert_eq!(p.req.stop_token, Some(99));
        assert_eq!(p.req.session_id, Some(123));
        assert_eq!(p.req.deadline, Some(Duration::from_millis(5000)));
        assert_eq!(p.req.priority, 2);
        let sp = &p.req.sampling;
        assert_eq!(sp.temperature, 0.9);
        assert_eq!(sp.top_k, 40);
        assert_eq!(sp.top_p, 0.95);
        assert_eq!(sp.repetition_penalty, 1.1);
        assert_eq!(sp.seed, 7);
        assert_eq!(sp.logit_bias, vec![(5, -10.0)]);
        assert_eq!(sp.stop_sequences, vec!["9 12".to_string(), "44".to_string()]);
    }

    #[test]
    fn server_parse_completion_defaults_are_pure_greedy() {
        let p = parse_completion(br#"{"prompt": [4]}"#, 1, &cfg()).unwrap();
        assert!(!p.stream);
        assert_eq!(p.req.variant, "fp32");
        assert_eq!(p.req.max_new_tokens, 16);
        assert!(p.req.sampling.is_pure_greedy());
    }

    #[test]
    fn server_parse_completion_rejects_bad_bodies() {
        let c = cfg();
        let cases: Vec<(&[u8], &str)> = vec![
            (b"not json", "invalid JSON"),
            (br#"{"max_tokens": 4}"#, "missing `prompt`"),
            (br#"{"prompt": []}"#, "must not be empty"),
            (br#"{"prompt": [999]}"#, "out of range"),
            (br#"{"prompt": [1.5]}"#, "out of range"),
            (br#"{"prompt": [1], "model": "nope"}"#, "unknown model"),
            (br#"{"prompt": [1], "max_tokens": 0}"#, "max_tokens"),
            (br#"{"prompt": [1], "temperature": -1}"#, "temperature"),
            (br#"{"prompt": [1], "top_p": 1.5}"#, "top_p"),
            (br#"{"prompt": [1], "stop": 7}"#, "stop"),
            (br#"{"prompt": [1], "logit_bias": {"x": 1}}"#, "not a token id"),
            (br#"{"prompt": [1], "stream": "yes"}"#, "stream"),
        ];
        for (body, frag) in cases {
            let err = parse_completion(body, 1, &c).unwrap_err();
            assert!(err.contains(frag), "body {body:?}: {err:?} missing {frag:?}");
        }
    }

    #[test]
    fn server_chunk_text_concatenation_matches_render_text() {
        let toks = [7u32, 19, 3];
        let mut text = String::new();
        for (i, &t) in toks.iter().enumerate() {
            let chunk = chunk_json(1, "fp32", &Event::Token { tok: t, index: i });
            let v = Json::parse(&chunk).unwrap();
            let c = &v.arr_field("choices").unwrap()[0];
            text.push_str(c.str_field("text").unwrap());
            assert_eq!(c.usize_field("token").unwrap(), t as usize);
        }
        assert_eq!(text, render_text(&toks));
    }

    #[test]
    fn server_completion_json_shape() {
        let fin = FinishedRequest {
            id: 5,
            generated: vec![7, 19],
            finish_reason: FinishReason::Length,
            ttft_s: 0.01,
            total_s: 0.05,
            prompt_len: 3,
            spec: None,
        };
        let v = Json::parse(&completion_json(5, "fp32", &fin)).unwrap();
        assert_eq!(v.str_field("id").unwrap(), "cmpl-5");
        assert_eq!(v.str_field("object").unwrap(), "text_completion");
        let c = &v.arr_field("choices").unwrap()[0];
        assert_eq!(c.str_field("text").unwrap(), "7 19");
        assert_eq!(c.str_field("finish_reason").unwrap(), "length");
        let u = v.get("usage").unwrap();
        assert_eq!(u.usize_field("prompt_tokens").unwrap(), 3);
        assert_eq!(u.usize_field("completion_tokens").unwrap(), 2);
        assert_eq!(u.usize_field("total_tokens").unwrap(), 5);
    }

    #[test]
    fn server_finish_reason_strings() {
        assert_eq!(finish_reason_str(FinishReason::Length), "length");
        assert_eq!(finish_reason_str(FinishReason::StopToken), "stop");
        assert_eq!(finish_reason_str(FinishReason::StopSequence), "stop");
        assert_eq!(finish_reason_str(FinishReason::Cancelled), "cancelled");
        assert_eq!(finish_reason_str(FinishReason::Deadline), "deadline");
        assert_eq!(finish_reason_str(FinishReason::WorkerDied), "worker_died");
        assert_eq!(finish_reason_str(FinishReason::Preempted), "preempted");
        assert_eq!(finish_reason_str(FinishReason::Overloaded), "overloaded");
    }

    #[test]
    fn server_stop_sequence_with_newline_parses_and_serializes_one_line() {
        // `stop` strings may contain raw newlines; they must survive the
        // body parse and the serializer must keep every response body on a
        // single line (SSE frames rely on it — raw newlines would split a
        // frame mid-payload without the multi-line `data:` encoding)
        let p = parse_completion(br#"{"prompt": [1], "stop": "12\n7"}"#, 1, &cfg()).unwrap();
        assert_eq!(p.req.sampling.stop_sequences, vec!["12\n7".to_string()]);
        let msg = format!("stopped at {:?}", p.req.sampling.stop_sequences[0]);
        let body = error_json(&msg, "test");
        assert!(!body.contains('\n'), "serialized body must be newline-free: {body:?}");
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.get("error").unwrap().str_field("message").unwrap(), msg);
    }
}
