//! Minimal HTTP/1.1 wire handling for the serving frontend — the same
//! dependency-free `std::net` approach as [`crate::obs::scrape`], extended
//! with request-body reads and SSE (`text/event-stream`) writes.
//!
//! Scope is deliberately small: `Content-Length` bodies only (no chunked
//! uploads), and bounded header/body sizes so a misbehaving client cannot
//! balloon memory.  Connections are kept alive per HTTP/1.1 semantics
//! (`Connection: close` honored, HTTP/1.0 defaults to close); the caller
//! bounds how many requests one connection may serve.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// Upper bound on the request head (request line + headers).
const MAX_HEAD_BYTES: usize = 64 * 1024;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// whether the client may send another request on this connection
    /// (HTTP/1.1 default unless `Connection: close`; HTTP/1.0 only with
    /// an explicit `Connection: keep-alive`)
    pub keep_alive: bool,
}

/// First position of `needle` in `haystack`.
pub fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || haystack.len() < needle.len() {
        return None;
    }
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read and parse one request from the stream (blocking, with a read
/// timeout so an idle half-open connection cannot pin the thread).
///
/// `carry` holds bytes read past the previous request's body (a pipelining
/// client may batch requests into one write); leftover bytes past this
/// request's body are put back into it.  Returns `Ok(None)` when the
/// connection reaches EOF cleanly between requests — the normal end of a
/// keep-alive connection, not an error.
pub fn read_request(
    stream: &mut TcpStream,
    max_body: usize,
    carry: &mut Vec<u8>,
) -> Result<Option<HttpRequest>> {
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut buf: Vec<u8> = std::mem::take(carry);
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
        }
        let n = stream.read(&mut chunk).context("reading request head")?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None); // clean close between requests
            }
            bail!("connection closed before request head completed");
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("request head is not UTF-8")?;
    let mut lines = head.lines();
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("HTTP/1.1");
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line: {request_line:?}");
    }
    // HTTP/1.1 defaults to persistent connections; 1.0 to one-shot
    let mut keep_alive = !version.eq_ignore_ascii_case("HTTP/1.0");
    let mut content_length: Option<usize> = None;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                let n: usize = value.trim().parse().context("bad Content-Length")?;
                // duplicate Content-Length headers with differing values are
                // a request-smuggling vector — reject instead of letting the
                // last one silently win
                if content_length.is_some_and(|prev| prev != n) {
                    bail!("conflicting Content-Length headers");
                }
                content_length = Some(n);
            } else if name.eq_ignore_ascii_case("transfer-encoding") {
                // this frontend frames bodies by Content-Length only; a
                // Transfer-Encoding header (chunked or otherwise) would
                // desynchronize body parsing, so it is rejected outright
                bail!("Transfer-Encoding not supported (Content-Length bodies only)");
            } else if name.eq_ignore_ascii_case("connection") {
                let v = value.trim();
                if v.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if v.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            }
        }
    }
    let content_length = content_length.unwrap_or(0);
    if content_length > max_body {
        bail!("request body {content_length} bytes exceeds limit {max_body}");
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).context("reading request body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    // bytes past the body belong to the next pipelined request
    *carry = body.split_off(content_length);
    Ok(Some(HttpRequest { method, path, body, keep_alive }))
}

/// Write a complete response and flush.  `keep_alive` picks the
/// `Connection:` framing — the caller decides it from the request *and*
/// its own per-connection budget.
pub fn write_response(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    body: &str,
    keep_alive: bool,
) -> Result<()> {
    write_response_extra(stream, status, content_type, &[], body, keep_alive)
}

/// [`write_response`] with additional response headers (e.g. `Retry-After`
/// on a 429).  Header values must be single-line tokens — no validation is
/// done here.
pub fn write_response_extra(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &str,
    keep_alive: bool,
) -> Result<()> {
    let mut response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n",
        body.len()
    );
    for (name, value) in extra_headers {
        response.push_str(&format!("{name}: {value}\r\n"));
    }
    response.push_str(if keep_alive {
        "Connection: keep-alive\r\n\r\n"
    } else {
        "Connection: close\r\n\r\n"
    });
    response.push_str(body);
    stream.write_all(response.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Start an SSE response: headers only; frames follow via
/// [`write_sse_data`].
pub fn write_sse_headers(stream: &mut TcpStream) -> Result<()> {
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n\
          Connection: close\r\n\r\n",
    )?;
    stream.flush()?;
    Ok(())
}

/// Render one SSE frame.  Per the SSE spec a payload newline becomes a
/// line break *between* `data:` lines of the same frame (clients rejoin
/// them with `\n`), so a multi-line payload can never terminate a frame
/// early — `data: {data}\n\n` with an embedded newline would.
pub fn sse_frame(data: &str) -> String {
    let mut frame = String::with_capacity(data.len() + 16);
    for line in data.split('\n') {
        frame.push_str("data: ");
        frame.push_str(line);
        frame.push('\n');
    }
    frame.push('\n');
    frame
}

/// One SSE frame, flushed immediately (each frame is one streamed event —
/// TTFT on the wire is TTFT in the engine).
pub fn write_sse_data(stream: &mut TcpStream, data: &str) -> Result<()> {
    stream.write_all(sse_frame(data).as_bytes())?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_sse_frame_splits_payload_newlines_per_spec() {
        assert_eq!(sse_frame("plain"), "data: plain\n\n");
        assert_eq!(sse_frame(""), "data: \n\n");
        let frame = sse_frame("line1\nline2\n");
        assert_eq!(frame, "data: line1\ndata: line2\ndata: \n\n");
        // a conforming client strips one "data: " prefix per line and
        // rejoins with '\n' — the payload round-trips exactly
        let payload = frame
            .strip_suffix("\n\n")
            .unwrap()
            .split('\n')
            .map(|l| l.strip_prefix("data: ").unwrap())
            .collect::<Vec<_>>()
            .join("\n");
        assert_eq!(payload, "line1\nline2\n");
        // no intermediate line ever ends a frame: "\n\n" appears only at
        // the very end, so framing survives any payload
        assert_eq!(find_subslice(frame.as_bytes(), b"\n\n"), Some(frame.len() - 2));
    }

    #[test]
    fn find_subslice_positions() {
        assert_eq!(find_subslice(b"abcd", b"cd"), Some(2));
        assert_eq!(find_subslice(b"abcd", b"ab"), Some(0));
        assert_eq!(find_subslice(b"abcd", b"xy"), None);
        assert_eq!(find_subslice(b"ab", b"abcd"), None);
        assert_eq!(find_subslice(b"abcd", b""), None);
    }
}
