//! Minimal HTTP/1.1 wire handling for the serving frontend — the same
//! dependency-free `std::net` approach as [`crate::obs::scrape`], extended
//! with request-body reads and SSE (`text/event-stream`) writes.
//!
//! Scope is deliberately small: one request per connection
//! (`Connection: close`), `Content-Length` bodies only (no chunked
//! uploads), and bounded header/body sizes so a misbehaving client cannot
//! balloon memory.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// Upper bound on the request head (request line + headers).
const MAX_HEAD_BYTES: usize = 64 * 1024;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// First position of `needle` in `haystack`.
pub fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || haystack.len() < needle.len() {
        return None;
    }
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read and parse one request from the stream (blocking, with a read
/// timeout so an idle half-open connection cannot pin the thread).
pub fn read_request(stream: &mut TcpStream, max_body: usize) -> Result<HttpRequest> {
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
        }
        let n = stream.read(&mut chunk).context("reading request head")?;
        if n == 0 {
            bail!("connection closed before request head completed");
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("request head is not UTF-8")?;
    let mut lines = head.lines();
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line: {request_line:?}");
    }
    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().context("bad Content-Length")?;
            }
        }
    }
    if content_length > max_body {
        bail!("request body {content_length} bytes exceeds limit {max_body}");
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).context("reading request body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(HttpRequest { method, path, body })
}

/// Write a complete response and flush (`Connection: close` framing).
pub fn write_response(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    body: &str,
) -> Result<()> {
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Start an SSE response: headers only; frames follow via
/// [`write_sse_data`].
pub fn write_sse_headers(stream: &mut TcpStream) -> Result<()> {
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n\
          Connection: close\r\n\r\n",
    )?;
    stream.flush()?;
    Ok(())
}

/// One SSE frame: `data: <payload>\n\n`, flushed immediately (each frame
/// is one streamed event — TTFT on the wire is TTFT in the engine).
pub fn write_sse_data(stream: &mut TcpStream, data: &str) -> Result<()> {
    stream.write_all(format!("data: {data}\n\n").as_bytes())?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_subslice_positions() {
        assert_eq!(find_subslice(b"abcd", b"cd"), Some(2));
        assert_eq!(find_subslice(b"abcd", b"ab"), Some(0));
        assert_eq!(find_subslice(b"abcd", b"xy"), None);
        assert_eq!(find_subslice(b"ab", b"abcd"), None);
        assert_eq!(find_subslice(b"abcd", b""), None);
    }
}
