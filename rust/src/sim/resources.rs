//! FPGA resource model (Table IV / Fig. 10): composes per-primitive
//! LUT/FF/DSP/BRAM costs over the units each module instantiates.
//!
//! Primitive costs are standard Virtex-7 synthesis results: an int8 MAC in
//! fabric ≈ 45 LUT, a 16×16 fixed multiply = 1 DSP48, an FP16 mult ≈ 2 DSP +
//! control, etc.  The paper's headline comparisons are *relative* (which
//! module dominates which resource; NAU vs FP16-unit savings), which this
//! composition reproduces.

use crate::config::AcceleratorConfig;

use super::buffer::BufferPlan;
use crate::config::ModelConfig;

/// Resource vector.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Resources {
    pub lut: u64,
    pub ff: u64,
    pub dsp: u64,
    pub bram: u64,
}

impl Resources {
    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            dsp: self.dsp + o.dsp,
            bram: self.bram + o.bram,
        }
    }

    pub fn scale(&self, k: u64) -> Resources {
        Resources { lut: self.lut * k, ff: self.ff * k, dsp: self.dsp * k, bram: self.bram * k }
    }
}

// ---- primitive costs (Virtex-7 class) ----

/// int8 multiply-add in LUT fabric (the paper: "8-bit MAT units are mainly
/// implemented using LUT units").
pub const INT8_MAC: Resources = Resources { lut: 45, ff: 16, dsp: 0, bram: 0 };
/// 16-bit fixed multiply on a DSP48E1.
pub const FX16_MUL: Resources = Resources { lut: 12, ff: 32, dsp: 1, bram: 0 };
/// 16-bit fixed add in fabric.
pub const FX16_ADD: Resources = Resources { lut: 16, ff: 16, dsp: 0, bram: 0 };
/// FP16 multiplier (DSP-based) — used by the Half Float Nonlinear Unit.
pub const FP16_MUL: Resources = Resources { lut: 120, ff: 120, dsp: 1, bram: 0 };
/// FP16 adder.
pub const FP16_ADD: Resources = Resources { lut: 200, ff: 120, dsp: 1, bram: 0 };
/// FP16 special-function evaluator stage (range reduction + poly, per lane).
pub const FP16_SFU_STAGE: Resources = Resources { lut: 260, ff: 150, dsp: 2, bram: 0 };
/// control/sequencing overhead per module
pub const MODULE_CTRL: Resources = Resources { lut: 1800, ff: 2400, dsp: 0, bram: 0 };

/// Hadamard-based Linear Module (6 groups × {4 HAT64 + 64 MAT4-int8}).
pub fn linear_module(acc: &AcceleratorConfig) -> Resources {
    let g = acc.linear_groups as u64;
    // HAT: 64-input add/sub butterfly = 63 16-bit adders + sign muxes
    let hat = FX16_ADD.scale((acc.hat_width - 1) as u64)
        .add(&Resources { lut: 700, ff: 500, dsp: 0, bram: 0 });
    let hats = hat.scale((acc.hats_per_group) as u64 * g);
    // int8 MAT of width 4: 4 MACs + tree + 21b accumulator
    let mat = INT8_MAC.scale(acc.linear_mat_width as u64)
        .add(&Resources { lut: 40, ff: 42, dsp: 0, bram: 0 });
    let mats = mat.scale(acc.mats_per_group as u64 * g);
    // requantization (×s_coe, >>s_shift): one DSP multiplier per group lane
    let requant = FX16_MUL.scale(4 * g).add(&FX16_ADD.scale(4 * g));
    hats.add(&mats).add(&requant).add(&MODULE_CTRL.scale(2))
}

/// Convolution Module (32 MAT4, 16-bit fixed → DSP MACs).
pub fn conv_module(acc: &AcceleratorConfig) -> Resources {
    let mat = FX16_MUL.scale(acc.conv_kernel as u64)
        .add(&FX16_ADD.scale(acc.conv_kernel as u64 - 1))
        .add(&Resources { lut: 30, ff: 40, dsp: 0, bram: 0 });
    mat.scale(acc.conv_mats as u64)
        .add(&FX16_MUL.scale(acc.conv_mats as u64)) // requant
        .add(&MODULE_CTRL)
}

/// The 24-lane Nonlinear Approximation Unit (Fig. 8).
pub fn nau_unit(acc: &AcceleratorConfig) -> Resources {
    let lanes = acc.nau_lanes as u64;
    // per lane: ×log2e (1 DSP), u/v split (fabric), PWL mult-add (1 DSP +
    // adds), barrel shift, RPU negate, delay regs, post-add
    let per_lane = FX16_MUL
        .add(&FX16_MUL)
        .add(&FX16_ADD.scale(3))
        .add(&Resources { lut: 90, ff: 120, dsp: 0, bram: 0 }); // shift+LUT+delay
    per_lane.scale(lanes).add(&Resources { lut: 400, ff: 600, dsp: 0, bram: 0 })
}

/// FP16 nonlinear unit of the same 24-lane throughput (the Fig. 10
/// comparison baseline): per lane an FP16 SFU pipeline (~4 stages) plus
/// FP16 mult/add pre/post processing.
pub fn half_float_nonlinear_unit(acc: &AcceleratorConfig) -> Resources {
    let lanes = acc.nau_lanes as u64;
    let per_lane = FP16_SFU_STAGE
        .add(&FP16_MUL)
        .add(&FP16_ADD)
        .add(&Resources { lut: 60, ff: 60, dsp: 0, bram: 0 });
    per_lane.scale(lanes).add(&Resources { lut: 500, ff: 800, dsp: 0, bram: 0 })
}

/// SSM Module: Step1 {PAU24+NAU24}, Step2 {PMU24+NAU24, PMU64},
/// Step3 {32×(PMU8+PMA8+MAT8)} + final PMA32.  16-bit fixed → DSP-heavy.
pub fn ssm_module(acc: &AcceleratorConfig) -> Resources {
    let pau24 = FX16_ADD.scale(24);
    let naus = nau_unit(acc).scale(2);
    let pmu24 = FX16_MUL.scale(24);
    let pmu64 = FX16_MUL.scale(64);
    let step3_unit = FX16_MUL
        .scale(acc.ssm_step3_width as u64) // PMU8
        .add(&FX16_MUL.scale(acc.ssm_step3_width as u64)) // PMA mul
        .add(&FX16_ADD.scale(acc.ssm_step3_width as u64)) // PMA add
        .add(&FX16_MUL.scale(acc.ssm_step3_width as u64)) // MAT mul
        .add(&FX16_ADD.scale(acc.ssm_step3_width as u64 - 1)); // MAT tree
    let step3 = step3_unit.scale(acc.ssm_step3_units as u64);
    let final_pma = FX16_MUL.scale(32).add(&FX16_ADD.scale(32));
    pau24
        .add(&naus)
        .add(&pmu24)
        .add(&pmu64)
        .add(&step3)
        .add(&final_pma)
        .add(&MODULE_CTRL.scale(3))
}

/// RMS Norm + SiLU floating-point group (16 FP lanes × two modules, plus
/// rsqrt/sigmoid SFUs).
pub fn float_modules(_acc: &AcceleratorConfig) -> Resources {
    let lanes = 16u64;
    let fp_mac = FP16_MUL.add(&FP16_ADD);
    let rms = fp_mac.scale(lanes).add(&FP16_SFU_STAGE.scale(4)); // rsqrt
    let silu = fp_mac.scale(lanes).add(&FP16_SFU_STAGE.scale(8)); // sigmoid
    rms.add(&silu).add(&MODULE_CTRL.scale(2))
}

/// Buffer region (Table IV row "Buffer"): BRAM for the 130M working set +
/// addressing fabric.
pub fn buffer_region(acc: &AcceleratorConfig) -> Resources {
    let plan = BufferPlan::for_layer(&ModelConfig::mamba2_130m(), 64, 1.0);
    let brams = plan.brams().min(acc.total_bram36);
    Resources { lut: 13_000, ff: 60_000, dsp: 0, bram: brams }
}

/// Interconnect/control/DMA ("Others" row).
pub fn others() -> Resources {
    Resources { lut: 44_000, ff: 46_000, dsp: 192, bram: 0 }
}

/// Full Table IV–style report.
#[derive(Debug, Clone)]
pub struct UtilizationReport {
    pub rows: Vec<(String, Resources)>,
    pub total: Resources,
    pub budget: Resources,
}

pub fn utilization(acc: &AcceleratorConfig) -> UtilizationReport {
    let rows = vec![
        ("Linear".to_string(), linear_module(acc)),
        ("Convolution".to_string(), conv_module(acc)),
        ("SSM".to_string(), ssm_module(acc)),
        ("RMS Norm. & SiLU".to_string(), float_modules(acc)),
        ("Buffer".to_string(), buffer_region(acc)),
        ("Others".to_string(), others()),
    ];
    let total = rows
        .iter()
        .fold(Resources::default(), |a, (_, r)| a.add(r));
    UtilizationReport {
        rows,
        total,
        budget: Resources {
            lut: acc.total_lut,
            ff: acc.total_ff,
            dsp: acc.total_dsp,
            bram: acc.total_bram36,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn acc() -> AcceleratorConfig {
        AcceleratorConfig::default()
    }

    #[test]
    fn fits_the_chip() {
        let u = utilization(&acc());
        assert!(u.total.lut <= u.budget.lut, "LUT {} > {}", u.total.lut, u.budget.lut);
        assert!(u.total.dsp <= u.budget.dsp, "DSP {} > {}", u.total.dsp, u.budget.dsp);
        assert!(u.total.bram <= u.budget.bram);
        assert!(u.total.ff <= u.budget.ff);
    }

    #[test]
    fn ssm_dominates_dsp_like_table4() {
        // Table IV: SSM uses 66% of DSPs — by far the largest consumer.
        let u = utilization(&acc());
        let ssm = u.rows.iter().find(|(n, _)| n == "SSM").unwrap().1;
        for (name, r) in &u.rows {
            if name != "SSM" {
                assert!(ssm.dsp > r.dsp, "SSM {} vs {name} {}", ssm.dsp, r.dsp);
            }
        }
        let frac = ssm.dsp as f64 / u.total.dsp as f64;
        assert!(frac > 0.5, "SSM DSP share {frac}");
    }

    #[test]
    fn linear_dominates_lut_like_table4() {
        // Table IV: the int8 MAT arrays put Linear on top of the LUT column.
        let u = utilization(&acc());
        let lin = u.rows.iter().find(|(n, _)| n == "Linear").unwrap().1;
        let ssm = u.rows.iter().find(|(n, _)| n == "SSM").unwrap().1;
        assert!(lin.lut > ssm.lut);
        assert_eq!(lin.dsp < 200, true, "linear mostly LUT-based: {}", lin.dsp);
    }

    #[test]
    fn buffer_owns_all_bram() {
        let u = utilization(&acc());
        for (name, r) in &u.rows {
            if name != "Buffer" {
                assert_eq!(r.bram, 0, "{name}");
            }
        }
    }

    #[test]
    fn fig10_nau_saves_dsp_and_ff() {
        // Fig. 10: NAU saves ~56% DSP and ~49% FF vs the FP16 unit.
        let nau = nau_unit(&acc());
        let fp = half_float_nonlinear_unit(&acc());
        let dsp_save = 1.0 - nau.dsp as f64 / fp.dsp as f64;
        let ff_save = 1.0 - nau.ff as f64 / fp.ff as f64;
        assert!(dsp_save > 0.4 && dsp_save < 0.75, "DSP saving {dsp_save}");
        assert!(ff_save > 0.3 && ff_save < 0.65, "FF saving {ff_save}");
    }
}
