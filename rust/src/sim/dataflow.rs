//! Data Flow Handler (paper Fig. 4): schedules the functional modules over
//! a token stream.
//!
//! FastMamba's modules form a chain per layer (RMSNorm → Linear → Conv →
//! SSM → gated Norm → Linear); with the paper's "pipelined execution
//! dataflow" the chain operates as a token-level pipeline — steady-state
//! throughput is set by the slowest stage, not the sum of stages.  The
//! scheduler here computes both the pipelined and the naive sequential
//! schedule; the difference is the paper's pipelining gain (ablation bench).

/// One pipeline stage: steady-state cycles per token plus a one-time fill.
#[derive(Debug, Clone)]
pub struct Stage {
    pub name: String,
    pub per_token: u64,
    pub fill: u64,
}

impl Stage {
    pub fn new(name: &str, per_token: u64, fill: u64) -> Self {
        Self { name: name.to_string(), per_token, fill }
    }
}

/// Result of scheduling `tokens` through a stage chain.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub total_cycles: u64,
    pub bottleneck: String,
    /// per-stage busy fraction in the pipelined schedule
    pub utilization: Vec<(String, f64)>,
}

/// Token-level pipelined schedule: every stage processes token t while the
/// next stage processes token t-1.
pub fn pipelined(stages: &[Stage], tokens: u64) -> Schedule {
    assert!(!stages.is_empty());
    let slowest = stages.iter().max_by_key(|s| s.per_token).unwrap();
    let fills: u64 = stages.iter().map(|s| s.fill).sum();
    // fill the pipe with one token through every stage, then stream at the
    // bottleneck rate
    let first_token: u64 = stages.iter().map(|s| s.per_token).sum();
    let total = fills + first_token + tokens.saturating_sub(1) * slowest.per_token;
    let utilization = stages
        .iter()
        .map(|s| {
            (
                s.name.clone(),
                s.per_token as f64 / slowest.per_token.max(1) as f64,
            )
        })
        .collect();
    Schedule {
        total_cycles: total,
        bottleneck: slowest.name.clone(),
        utilization,
    }
}

/// Naive sequential schedule (no overlap): the ablation baseline.
pub fn sequential(stages: &[Stage], tokens: u64) -> Schedule {
    let per_token: u64 = stages.iter().map(|s| s.per_token).sum();
    let fills: u64 = stages.iter().map(|s| s.fill).sum();
    let slowest = stages.iter().max_by_key(|s| s.per_token).unwrap();
    Schedule {
        total_cycles: fills + per_token * tokens,
        bottleneck: slowest.name.clone(),
        utilization: stages.iter().map(|s| (s.name.clone(), 1.0)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Vec<Stage> {
        vec![
            Stage::new("norm", 10, 2),
            Stage::new("linear", 100, 16),
            Stage::new("conv", 20, 8),
            Stage::new("ssm", 80, 12),
        ]
    }

    #[test]
    fn pipelined_bounded_by_bottleneck() {
        let s = pipelined(&chain(), 1000);
        // ≈ 1000 * 100 + fills
        assert!(s.total_cycles < 110 * 1000);
        assert_eq!(s.bottleneck, "linear");
    }

    #[test]
    fn sequential_is_sum() {
        let s = sequential(&chain(), 1000);
        assert_eq!(s.total_cycles, 38 + 210 * 1000);
    }

    #[test]
    fn pipelining_gain_approaches_sum_over_max() {
        let p = pipelined(&chain(), 100_000).total_cycles as f64;
        let q = sequential(&chain(), 100_000).total_cycles as f64;
        let gain = q / p;
        assert!((gain - 2.1).abs() < 0.05, "{gain}"); // 210/100
    }

    #[test]
    fn single_token_is_latency_sum() {
        let s = pipelined(&chain(), 1);
        assert_eq!(s.total_cycles, 38 + 210);
    }

    #[test]
    fn utilization_of_bottleneck_is_one() {
        let s = pipelined(&chain(), 10);
        let u: f64 = s
            .utilization
            .iter()
            .find(|(n, _)| n == "linear")
            .map(|(_, u)| *u)
            .unwrap();
        assert_eq!(u, 1.0);
    }
}
