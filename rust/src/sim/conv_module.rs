//! Convolution Module (paper §IV-A): 32 MAT units, each performing the
//! kernel-size-4 dot product of a 1-D depthwise causal convolution.
//!
//! One MAT produces one output channel-sample per cycle (vector length 4 ==
//! kernel size), so the module processes 32 channels per cycle and a full
//! `(l, conv_dim)` activation in `l * conv_dim / 32` cycles.

use crate::config::AcceleratorConfig;
use crate::quant::pot;

/// Cycle count for the depthwise conv over `(l, conv_dim)`.
pub fn conv_cycles(acc: &AcceleratorConfig, l: u64, conv_dim: u64) -> u64 {
    let per_cycle = acc.conv_mats as u64;
    l * conv_dim.div_ceil(per_cycle) + 8 // pipeline fill
}

/// Functional PoT-quantized conv on the module (mirrors the FastMamba
/// variant of the golden model: per-channel PoT taps, per-channel PoT
/// activations, fp accumulate on the PoT grid).
pub struct ConvModule<'a> {
    pub acc: &'a AcceleratorConfig,
}

impl<'a> ConvModule<'a> {
    pub fn new(acc: &'a AcceleratorConfig) -> Self {
        Self { acc }
    }

    /// x: `(l, c)` row-major; w: `(c, k)`; b: `(c,)`.  Returns (y, cycles)
    /// *before* the SiLU (the float group applies activation).
    pub fn forward(&self, x: &[f32], l: usize, c: usize, w: &[f32], k: usize,
                   b: &[f32]) -> (Vec<f32>, u64) {
        let mut wq = w.to_vec();
        pot::pot_fake_quant_grouped(&mut wq, k, 16);
        let mut xq = x.to_vec();
        pot::pot_fake_quant_per_col(&mut xq, l, c, 16);
        let mut y = vec![0.0f32; l * c];
        for t in 0..l {
            for ch in 0..c {
                let mut acc_v = b[ch];
                for tap in 0..k {
                    let ti = t as i64 - (k - 1 - tap) as i64;
                    if ti >= 0 {
                        acc_v += wq[ch * k + tap] * xq[ti as usize * c + ch];
                    }
                }
                y[t * c + ch] = acc_v;
            }
        }
        (y, conv_cycles(self.acc, l as u64, c as u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn causal_and_close_to_float() {
        let acc = AcceleratorConfig::default();
        let m = ConvModule::new(&acc);
        let mut rng = Rng::new(2);
        let (l, c, k) = (20, 64, 4);
        let x = rng.normal_vec(l * c, 1.0);
        let w = rng.normal_vec(c * k, 0.3);
        let b = rng.normal_vec(c, 0.1);
        let (y, _) = m.forward(&x, l, c, &w, k, &b);
        // float reference
        for t in 0..l {
            for ch in 0..c {
                let mut want = b[ch];
                for tap in 0..k {
                    let ti = t as i64 - (k - 1 - tap) as i64;
                    if ti >= 0 {
                        want += w[ch * k + tap] * x[ti as usize * c + ch];
                    }
                }
                let got = y[t * c + ch];
                assert!((got - want).abs() < 0.05, "t={t} ch={ch} {got} vs {want}");
            }
        }
    }

    #[test]
    fn causality_holds() {
        let acc = AcceleratorConfig::default();
        let m = ConvModule::new(&acc);
        let mut rng = Rng::new(3);
        let (l, c, k) = (16, 32, 4);
        let mut x = rng.normal_vec(l * c, 1.0);
        let w = rng.normal_vec(c * k, 0.3);
        let b = vec![0.0f32; c];
        let (y0, _) = m.forward(&x, l, c, &w, k, &b);
        for v in &mut x[8 * c..] {
            *v += 10.0; // perturb tokens >= 8
        }
        let (y1, _) = m.forward(&x, l, c, &w, k, &b);
        // outputs before t=8 unchanged (up to requant noise of the column)
        for t in 0..8 {
            for ch in 0..c {
                let d = (y0[t * c + ch] - y1[t * c + ch]).abs();
                assert!(d < 0.2, "t={t} ch={ch} d={d}");
            }
        }
    }

    #[test]
    fn cycles_formula() {
        let acc = AcceleratorConfig::default();
        // 1792 channels / 32 MATs = 56 cycles per token
        assert_eq!(conv_cycles(&acc, 1, 1792), 56 + 8);
        assert_eq!(conv_cycles(&acc, 100, 1792), 5600 + 8);
    }
}
