//! The Nonlinear Approximation Unit (paper Fig. 8): a 24-lane multi-mode
//! pipeline computing `exp` (Eq. 3) or `SoftPlus` (Eq. 6) on 16-bit fixed
//! point.
//!
//! Structure mirrored here: Preprocessing (RPU negate + Delay Unit) →
//! EXP-INT (×log2e, u/v split, 8-segment PWL of 2^v, shift) →
//! Postprocessing (adder).  The functional path is bit-identical to
//! `nonlinear::{exp,softplus}_fixed` — one shared datapath, exactly like
//! the multiplexed hardware.

use crate::config::FixedSpec;
use crate::nonlinear::{exp_fixed, softplus_fixed, PwlTable};

use super::vpu::{ADD_LAT, MUL_LAT};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NauMode {
    Exp,
    SoftPlus,
}

/// A `lanes`-wide NAU instance.
#[derive(Debug, Clone)]
pub struct Nau {
    pub lanes: usize,
    pub spec: FixedSpec,
    table: PwlTable,
}

impl Nau {
    pub fn new(lanes: usize) -> Self {
        let spec = FixedSpec::default();
        let table = PwlTable::new(&spec);
        Self { lanes, spec, table }
    }

    /// Pipeline depth: RPU(1) + mult(3) + split(1) + PWL mult-add(4) +
    /// shift(1) + post-add(1).
    pub fn depth(&self) -> u64 {
        ADD_LAT + MUL_LAT + 1 + (MUL_LAT + ADD_LAT) + 1 + ADD_LAT
    }

    /// Cycles to process `n` scalars: ceil(n/lanes) vector issues, pipelined.
    pub fn cycles(&self, n: u64) -> u64 {
        if n == 0 {
            0
        } else {
            n.div_ceil(self.lanes as u64) + self.depth()
        }
    }

    /// Functional evaluation over a fixed-point vector (any length; the
    /// hardware streams ceil(n/lanes) beats).
    pub fn eval(&self, x_fx: &[i32], mode: NauMode, out: &mut [i32]) {
        debug_assert_eq!(x_fx.len(), out.len());
        match mode {
            NauMode::Exp => {
                for (o, x) in out.iter_mut().zip(x_fx) {
                    *o = exp_fixed((*x).min(0), &self.table, &self.spec);
                }
            }
            NauMode::SoftPlus => {
                for (o, x) in out.iter_mut().zip(x_fx) {
                    *o = softplus_fixed(*x, &self.table, &self.spec);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fixed::{from_fixed, to_fixed};

    #[test]
    fn exp_mode_matches_nonlinear_module() {
        let nau = Nau::new(24);
        let s = nau.spec;
        let xs: Vec<i32> = (0..100).map(|i| to_fixed(-8.0 * i as f32 / 100.0, &s)).collect();
        let mut out = vec![0i32; 100];
        nau.eval(&xs, NauMode::Exp, &mut out);
        let t = PwlTable::new(&s);
        for (x, o) in xs.iter().zip(&out) {
            assert_eq!(*o, exp_fixed(*x, &t, &s));
        }
    }

    #[test]
    fn softplus_mode_positive_branch() {
        let nau = Nau::new(24);
        let s = nau.spec;
        let x = to_fixed(3.0, &s);
        let mut out = vec![0i32];
        nau.eval(&[x], NauMode::SoftPlus, &mut out);
        // x + exp(-x): ≈ 3.0 + 0.0498
        let got = from_fixed(out[0], &s);
        assert!((got - 3.0498).abs() < 0.01, "{got}");
    }

    #[test]
    fn cycle_model_scales_with_lanes() {
        let nau = Nau::new(24);
        assert_eq!(nau.cycles(0), 0);
        assert_eq!(nau.cycles(24), 1 + nau.depth());
        assert_eq!(nau.cycles(25), 2 + nau.depth());
        assert_eq!(nau.cycles(240), 10 + nau.depth());
    }

    #[test]
    fn modes_share_datapath() {
        // For x <= 0 SoftPlus ≡ exp (Eq. 6 upper branch): same outputs.
        let nau = Nau::new(24);
        let s = nau.spec;
        let xs: Vec<i32> = (0..50).map(|i| to_fixed(-5.0 * i as f32 / 50.0, &s)).collect();
        let mut e = vec![0i32; 50];
        let mut p = vec![0i32; 50];
        nau.eval(&xs, NauMode::Exp, &mut e);
        nau.eval(&xs, NauMode::SoftPlus, &mut p);
        assert_eq!(e, p);
    }
}
