//! End-to-end performance model: composes the per-module cycle counts and
//! the DRAM streaming model into prefill latency (Fig. 9), runtime
//! breakdowns (Fig. 1-style) and decode throughput (Table III).

use crate::config::{AcceleratorConfig, ModelConfig};

use super::buffer::{dram_cycles, weight_stream_bytes};
use super::conv_module::conv_cycles;
use super::dataflow::{pipelined, sequential, Stage};
use super::float_module::{rmsnorm_cycles, silu_cycles};
use super::linear_module::linear_cycles;
use super::ssm_module::ssm_cycles_per_token;

/// Per-component cycles for one forward pass (the Fig. 1 decomposition).
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    pub linear: u64,
    pub conv: u64,
    pub ssm: u64,
    pub norm_silu: u64,
    pub dram: u64,
}

impl Breakdown {
    pub fn compute_total(&self) -> u64 {
        self.linear + self.conv + self.ssm + self.norm_silu
    }

    /// Fractions of compute (Fig. 1 bars).
    pub fn fractions(&self) -> [(&'static str, f64); 4] {
        let t = self.compute_total().max(1) as f64;
        [
            ("linear", self.linear as f64 / t),
            ("conv", self.conv as f64 / t),
            ("ssm", self.ssm as f64 / t),
            ("norm_silu", self.norm_silu as f64 / t),
        ]
    }
}

#[derive(Debug, Clone)]
pub struct PrefillPerf {
    pub seq_len: usize,
    pub cycles: u64,
    pub seconds: f64,
    pub tokens_per_s: f64,
    pub breakdown: Breakdown,
    pub bottleneck: String,
}

#[derive(Debug, Clone)]
pub struct DecodePerf {
    pub batch: usize,
    pub cycles_per_step: u64,
    pub seconds_per_step: f64,
    pub tokens_per_s: f64,
    pub compute_bound: bool,
    pub breakdown: Breakdown,
}

/// The FastMamba accelerator performance model.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub acc: AcceleratorConfig,
    pub cfg: ModelConfig,
    /// pipelined dataflow (paper) vs sequential (ablation)
    pub pipelined_dataflow: bool,
}

impl PerfModel {
    pub fn new(acc: AcceleratorConfig, cfg: ModelConfig) -> Self {
        Self { acc, cfg, pipelined_dataflow: true }
    }

    /// Stages of one layer at `l` tokens (per-token steady-state cycles).
    fn layer_stages(&self, _l: u64) -> Vec<Stage> {
        let acc = &self.acc;
        let cfg = &self.cfg;
        let d = cfg.d_model as u64;
        vec![
            Stage::new("norm", rmsnorm_cycles(acc, 1, d), 4),
            Stage::new(
                "linear.in_proj",
                linear_cycles(acc, 1, d, cfg.d_in_proj() as u64) - 16,
                16,
            ),
            Stage::new(
                "conv",
                conv_cycles(acc, 1, cfg.conv_dim() as u64) - 8,
                8,
            ),
            Stage::new(
                "silu",
                silu_cycles(acc, cfg.conv_dim() as u64) - 8,
                8,
            ),
            Stage::new("ssm", ssm_cycles_per_token(acc, cfg), 12),
            Stage::new(
                "gated_norm",
                rmsnorm_cycles(acc, 1, cfg.d_inner() as u64),
                4,
            ),
            Stage::new(
                "linear.out_proj",
                linear_cycles(acc, 1, cfg.d_inner() as u64, d) - 16,
                16,
            ),
        ]
    }

    fn accumulate_breakdown(&self, stages: &[Stage], l: u64, bd: &mut Breakdown) {
        for s in stages {
            let c = s.per_token * l;
            if s.name.starts_with("linear") {
                bd.linear += c;
            } else if s.name == "conv" {
                bd.conv += c;
            } else if s.name == "ssm" {
                bd.ssm += c;
            } else {
                bd.norm_silu += c;
            }
        }
    }

    /// Prefill latency for `seq_len` tokens (lm head on the final token).
    pub fn prefill(&self, seq_len: usize) -> PrefillPerf {
        let l = seq_len as u64;
        let cfg = &self.cfg;
        let stages = self.layer_stages(l);
        let sched = if self.pipelined_dataflow {
            pipelined(&stages, l)
        } else {
            sequential(&stages, l)
        };
        let mut compute = sched.total_cycles * cfg.n_layer as u64;
        let mut bd = Breakdown::default();
        self.accumulate_breakdown(&stages, l, &mut bd);
        // scale all components by n_layer (accumulate did one layer)
        let nl = cfg.n_layer as u64;
        bd.linear *= nl;
        bd.conv *= nl;
        bd.ssm *= nl;
        bd.norm_silu *= nl;
        // final norm + lm head on last token
        let lm = linear_cycles(&self.acc, 1, cfg.d_model as u64, cfg.vocab_size as u64);
        compute += lm + rmsnorm_cycles(&self.acc, 1, cfg.d_model as u64);
        bd.linear += lm;
        // weights streamed once per pass, overlapped with compute
        let dram = dram_cycles(&self.acc, weight_stream_bytes(cfg));
        bd.dram = dram;
        let cycles = compute.max(dram);
        let seconds = cycles as f64 / self.acc.clock_hz as f64;
        PrefillPerf {
            seq_len,
            cycles,
            seconds,
            tokens_per_s: seq_len as f64 / seconds,
            breakdown: bd,
            bottleneck: if dram > compute { "dram".into() } else { sched.bottleneck },
        }
    }

    /// Decode throughput at `batch` concurrent sequences (weights streamed
    /// once per step and shared across the batch).
    pub fn decode(&self, batch: usize) -> DecodePerf {
        let cfg = &self.cfg;
        let stages = self.layer_stages(1);
        let per_layer: u64 = stages.iter().map(|s| s.per_token).sum();
        let fills: u64 = stages.iter().map(|s| s.fill).sum();
        let lm = linear_cycles(&self.acc, 1, cfg.d_model as u64, cfg.vocab_size as u64);
        let compute_one = per_layer * cfg.n_layer as u64 + fills + lm;
        let compute = compute_one * batch as u64; // batch shares weights
        let dram = dram_cycles(&self.acc, weight_stream_bytes(cfg));
        let cycles = compute.max(dram);
        let mut bd = Breakdown::default();
        self.accumulate_breakdown(&stages, 1, &mut bd);
        // scale all components by n_layer (accumulate did one layer), then
        // add the single lm-head matmul
        let nl = cfg.n_layer as u64;
        bd.linear *= nl;
        bd.conv *= nl;
        bd.ssm *= nl;
        bd.norm_silu *= nl;
        bd.linear += lm;
        bd.dram = dram;
        let seconds = cycles as f64 / self.acc.clock_hz as f64;
        DecodePerf {
            batch,
            cycles_per_step: cycles,
            seconds_per_step: seconds,
            tokens_per_s: batch as f64 / seconds,
            compute_bound: compute >= dram,
            breakdown: bd,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_130m() -> PerfModel {
        PerfModel::new(AcceleratorConfig::default(), ModelConfig::mamba2_130m())
    }

    fn model_2_7b() -> PerfModel {
        PerfModel::new(AcceleratorConfig::default(), ModelConfig::mamba2_2_7b())
    }

    #[test]
    fn prefill_scales_sublinearly_then_linearly() {
        let m = model_130m();
        let t256 = m.prefill(256).seconds;
        let t1024 = m.prefill(1024).seconds;
        let r = t1024 / t256;
        assert!(r > 3.0 && r < 4.3, "{r}");
    }

    #[test]
    fn prefill_130m_throughput_order_of_magnitude() {
        // compute-bound prefill ≈ thousands of tokens/s at 250 MHz
        let p = model_130m().prefill(512);
        assert!(
            p.tokens_per_s > 1_000.0 && p.tokens_per_s < 100_000.0,
            "{}",
            p.tokens_per_s
        );
        assert_ne!(p.bottleneck, "dram");
    }

    #[test]
    fn decode_2_7b_matches_table3_class() {
        // Table III: 5.68 token/s on Mamba2-2.7B — bandwidth-bound
        let d = model_2_7b().decode(1);
        assert!(!d.compute_bound, "2.7B decode must be DRAM-bound");
        assert!(
            d.tokens_per_s > 3.0 && d.tokens_per_s < 9.0,
            "tok/s = {}",
            d.tokens_per_s
        );
    }

    #[test]
    fn decode_batching_amortizes_weight_stream() {
        let m = model_2_7b();
        let t1 = m.decode(1).tokens_per_s;
        let t8 = m.decode(8).tokens_per_s;
        assert!(t8 > 4.0 * t1, "B8 {t8} vs B1 {t1}");
    }

    #[test]
    fn pipelining_ablation_shows_gain() {
        let mut m = model_130m();
        let piped = m.prefill(512).cycles;
        m.pipelined_dataflow = false;
        let seq = m.prefill(512).cycles;
        assert!(
            seq as f64 / piped as f64 > 1.3,
            "pipelining gain {}",
            seq as f64 / piped as f64
        );
    }

    #[test]
    fn breakdown_fractions_sum_to_one() {
        let p = model_130m().prefill(256);
        let s: f64 = p.breakdown.fractions().iter().map(|(_, f)| f).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linear_dominates_130m_compute() {
        // in_proj is by far the widest op at these dims
        let p = model_130m().prefill(256);
        assert!(p.breakdown.linear > p.breakdown.conv);
        assert!(p.breakdown.linear > p.breakdown.norm_silu);
    }

    #[test]
    fn decode_breakdown_scales_all_components_by_layers() {
        // regression: decode's linear component was missing the n_layer
        // factor, under-counting the dominant op by 24x on 130M.  A decode
        // step is a one-token pass, so its per-component compute must equal
        // prefill at L = 1.
        let m = model_130m();
        let d = m.decode(1).breakdown;
        let p = m.prefill(1).breakdown;
        assert_eq!(d.linear, p.linear);
        assert_eq!(d.conv, p.conv);
        assert_eq!(d.ssm, p.ssm);
        assert_eq!(d.norm_silu, p.norm_silu);
    }
}
