//! Speculative-decoding cycle model on the VC709 performance model.
//!
//! Decode on the accelerator is DRAM-bound (Table III): every generated
//! token streams the full weight set once.  Speculative decoding changes
//! the streaming economics — a round of `k` drafter steps plus one
//! verify pass commits `E[m] + 1` tokens for `k` (cheaper) drafter
//! streams and a single verifier stream, because the verify call scores
//! all `k + 1` positions under one weight pass, exactly like prefill.
//!
//! The model composes [`PerfModel`] cycle counts with two speculative
//! parameters: the per-token draft acceptance probability `p` (measured
//! at serve time by `coordinator::metrics`) and the drafter's cost ratio
//! relative to a verifier decode step (< 1 for a lower-precision or
//! distilled drafter whose weight stream is smaller).

use crate::config::{AcceleratorConfig, ModelConfig};

use super::perf::PerfModel;

/// Predicted performance of one speculative configuration.
#[derive(Debug, Clone)]
pub struct SpecPoint {
    pub k: usize,
    pub accept_rate: f64,
    /// expected committed tokens per round (E[m] + 1)
    pub committed_per_round: f64,
    pub round_seconds: f64,
    pub tokens_per_s: f64,
    /// vs plain verifier decode at B = 1
    pub speedup: f64,
}

/// Speculative decoding performance model over the FastMamba accelerator.
#[derive(Debug, Clone)]
pub struct SpecSim {
    pub perf: PerfModel,
    /// drafter decode-step cost relative to a verifier decode step.
    /// Decode is weight-stream-bound, so this is approximately the ratio
    /// of streamed weight bytes: 0.5 models a drafter at half the
    /// verifier's weight precision (e.g. W4 drafts for a W8 verifier) or
    /// a distilled half-size drafter.
    pub draft_cost_ratio: f64,
}

impl SpecSim {
    pub fn new(acc: AcceleratorConfig, cfg: ModelConfig) -> Self {
        Self { perf: PerfModel::new(acc, cfg), draft_cost_ratio: 0.5 }
    }

    /// Expected accepted-prefix length for i.i.d. per-token acceptance
    /// probability `p`: E[m] = Σ_{i=1..k} p^i (the prefix survives to
    /// draft i only if all i drafts match).
    pub fn expected_accepted(k: usize, p: f64) -> f64 {
        let mut e = 0.0;
        let mut pi = 1.0;
        for _ in 0..k {
            pi *= p;
            e += pi;
        }
        e
    }

    /// Committed tokens per round: the accepted prefix plus the verifier's
    /// bonus token (every round commits at least one token).
    pub fn committed_per_round(k: usize, p: f64) -> f64 {
        Self::expected_accepted(k, p) + 1.0
    }

    /// Wall time of one draft-k / verify-1 round.
    pub fn round_seconds(&self, k: usize) -> f64 {
        let step = self.perf.decode(1).seconds_per_step;
        let draft = k as f64 * step * self.draft_cost_ratio;
        // the verifier scores k+1 positions in one prefill-style pass
        let verify = self.perf.prefill(k + 1).seconds;
        draft + verify
    }

    pub fn point(&self, k: usize, p: f64) -> SpecPoint {
        let committed = Self::committed_per_round(k, p);
        let round = self.round_seconds(k);
        let tokens_per_s = committed / round;
        let base = self.perf.decode(1).tokens_per_s;
        SpecPoint {
            k,
            accept_rate: p,
            committed_per_round: committed,
            round_seconds: round,
            tokens_per_s,
            speedup: tokens_per_s / base,
        }
    }

    pub fn speedup(&self, k: usize, p: f64) -> f64 {
        self.point(k, p).speedup
    }

    /// Smallest acceptance rate (1% grid) at which speculation beats plain
    /// decode for draft length `k`; `None` if even p = 1.0 loses.
    pub fn break_even_acceptance(&self, k: usize) -> Option<f64> {
        (0..=100)
            .map(|i| i as f64 / 100.0)
            .find(|&p| self.speedup(k, p) >= 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> SpecSim {
        // the paper's decode model: Mamba2-2.7B, DRAM-bound
        SpecSim::new(AcceleratorConfig::default(), ModelConfig::mamba2_2_7b())
    }

    #[test]
    fn expected_accepted_limits() {
        assert_eq!(SpecSim::expected_accepted(4, 1.0), 4.0);
        assert_eq!(SpecSim::expected_accepted(4, 0.0), 0.0);
        // geometric partial sum, monotone in p
        let lo = SpecSim::expected_accepted(8, 0.5);
        let hi = SpecSim::expected_accepted(8, 0.9);
        assert!(lo < hi && hi < 8.0);
        assert!((SpecSim::expected_accepted(2, 0.5) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn perfect_acceptance_beats_baseline() {
        let s = sim();
        for k in [2usize, 4, 8] {
            let sp = s.speedup(k, 1.0);
            assert!(sp > 1.0, "k={k}: speedup {sp}");
        }
    }

    #[test]
    fn zero_acceptance_loses() {
        let s = sim();
        for k in [2usize, 4, 8] {
            let sp = s.speedup(k, 0.0);
            assert!(sp < 1.0, "k={k}: speedup {sp} should be < 1 at p=0");
        }
    }

    #[test]
    fn speedup_monotone_in_acceptance() {
        let s = sim();
        let mut last = 0.0;
        for p in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let sp = s.speedup(4, p);
            assert!(sp > last, "p={p}: {sp} <= {last}");
            last = sp;
        }
    }

    #[test]
    fn break_even_sits_between_extremes() {
        let s = sim();
        let be = s.break_even_acceptance(4).expect("p=1 must win at k=4");
        assert!(be > 0.0 && be < 1.0, "{be}");
        assert!(s.speedup(4, be) >= 1.0);
    }

    #[test]
    fn cheaper_drafter_raises_speedup() {
        let mut s = sim();
        let base = s.speedup(4, 0.9);
        s.draft_cost_ratio = 0.25;
        assert!(s.speedup(4, 0.9) > base);
    }
}
