//! On-chip buffer and external-memory model (paper Fig. 4: Global Memory →
//! On-chip Buffer → functional modules, managed by the Data Flow Handler).
//!
//! BRAM36 blocks hold 36 Kib each; the buffer model checks that working sets
//! fit the VC709's 956-block allocation (Table IV) and converts DRAM traffic
//! into cycles at the board's DDR3 bandwidth — the constraint that makes
//! large-model decode bandwidth-bound (Table III).

use crate::config::{AcceleratorConfig, ModelConfig};

pub const BRAM36_BYTES: u64 = 36 * 1024 / 8; // 4.5 KiB per block

/// A named on-chip buffer allocation.
#[derive(Debug, Clone)]
pub struct BufferPlan {
    pub entries: Vec<(String, u64)>, // (name, bytes)
}

/// Output-column tile of the weight stream buffers (double-buffered halves
/// ping-pong against the DRAM stream, like the Data Flow Handler's schedule).
pub const WEIGHT_Q_TILE: u64 = 512;
/// Hard cap on any single weight stream buffer (≈400 BRAM36) — wide layers
/// additionally tile their input dimension with partial-sum accumulation.
pub const WEIGHT_TILE_MAX_BYTES: u64 = 400 * BRAM36_BYTES;

impl BufferPlan {
    /// *Streaming* working-set plan for one layer of `cfg` at prefill tile
    /// `l_tile`: weight buffers hold a double-buffered q-tile (or the whole
    /// matrix when smaller), plus activation tiles and the SSM state.
    pub fn for_layer(cfg: &ModelConfig, l_tile: u64, weight_bytes_per: f64) -> Self {
        let d = cfg.d_model as u64;
        let wtile = |d_in: u64, q: u64| -> u64 {
            let full = (d_in * q) as f64 * weight_bytes_per;
            let tiled = (d_in * WEIGHT_Q_TILE * 2) as f64 * weight_bytes_per;
            (full.min(tiled) as u64).min(WEIGHT_TILE_MAX_BYTES)
        };
        let entries = vec![
            ("weights.in_proj".into(), wtile(d, cfg.d_in_proj() as u64)),
            ("weights.out_proj".into(), wtile(cfg.d_inner() as u64, d)),
            ("weights.conv".into(), cfg.conv_dim() as u64 * cfg.d_conv as u64 * 2),
            ("act.zxbcdt".into(), l_tile * cfg.d_in_proj() as u64 * 2),
            ("act.xbc".into(), l_tile * cfg.conv_dim() as u64 * 2),
            (
                "state.h".into(),
                cfg.nheads() as u64 * cfg.headdim as u64 * cfg.d_state as u64 * 2,
            ),
            ("act.y".into(), l_tile * cfg.d_inner() as u64 * 2),
        ];
        Self { entries }
    }

    /// *Resident* plan: every weight of the model on chip (no streaming) —
    /// what one would need to escape the DRAM bound entirely.
    pub fn resident(cfg: &ModelConfig, weight_bytes_per: f64) -> Self {
        Self {
            entries: vec![(
                "weights.all".into(),
                (cfg.n_params() as f64 * weight_bytes_per) as u64,
            )],
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.entries.iter().map(|(_, b)| *b).sum()
    }

    pub fn brams(&self) -> u64 {
        // each named buffer rounds up to whole BRAM blocks (banked)
        self.entries
            .iter()
            .map(|(_, b)| b.div_ceil(BRAM36_BYTES))
            .sum()
    }

    pub fn fits(&self, acc: &AcceleratorConfig, budget_frac: f64) -> bool {
        (self.brams() as f64) <= acc.total_bram36 as f64 * budget_frac
    }
}

/// Cycles to stream `bytes` from DRAM at the board bandwidth.
pub fn dram_cycles(acc: &AcceleratorConfig, bytes: f64) -> u64 {
    let secs = bytes / acc.dram_bw_bytes;
    (secs * acc.clock_hz as f64).ceil() as u64
}

/// Weight bytes for one full forward pass at the accelerator's precisions:
/// int8 linears, 16-bit conv/SSM params, fp16 norms.
pub fn weight_stream_bytes(cfg: &ModelConfig) -> f64 {
    let d = cfg.d_model as f64;
    let per_layer = (cfg.d_in_proj() as f64 * d + d * cfg.d_inner() as f64) * 1.0 // int8
        + cfg.conv_dim() as f64 * (cfg.d_conv as f64 + 1.0) * 2.0 // conv w+b, 16b
        + 3.0 * cfg.nheads() as f64 * 2.0 // dt_bias, A, D
        + (d + cfg.d_inner() as f64) * 2.0; // norms
    cfg.n_layer as f64 * per_layer
        + cfg.vocab_size as f64 * d * 1.0 // tied lm head, int8
        + d * 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_layer_fits_on_chip() {
        let cfg = ModelConfig::tiny();
        let plan = BufferPlan::for_layer(&cfg, 64, 1.0);
        assert!(plan.fits(&AcceleratorConfig::default(), 0.65));
    }

    #[test]
    fn m130_layer_fits_within_table4_budget() {
        // Table IV: buffers use 956 BRAM (65%); one 130M layer + tiles must fit.
        let cfg = ModelConfig::mamba2_130m();
        let plan = BufferPlan::for_layer(&cfg, 64, 1.0);
        assert!(
            plan.fits(&AcceleratorConfig::default(), 0.66),
            "brams = {}",
            plan.brams()
        );
    }

    #[test]
    fn full_residency_impossible_beyond_tiny() {
        // whole-model on-chip residency (the only way to escape the DRAM
        // bound) is impossible for 130M and 2.7B -> decode streams weights
        // and is bandwidth-bound (Table III)
        let acc = AcceleratorConfig::default();
        assert!(!BufferPlan::resident(&ModelConfig::mamba2_130m(), 1.0).fits(&acc, 1.0));
        assert!(!BufferPlan::resident(&ModelConfig::mamba2_2_7b(), 1.0).fits(&acc, 1.0));
    }

    #[test]
    fn streaming_plan_fits_even_for_2_7b() {
        // the streaming tile plan is size-independent enough to fit
        let cfg = ModelConfig::mamba2_2_7b();
        let plan = BufferPlan::for_layer(&cfg, 16, 1.0);
        assert!(plan.fits(&AcceleratorConfig::default(), 1.0), "{}", plan.brams());
    }

    #[test]
    fn dram_cycles_linear_in_bytes() {
        let acc = AcceleratorConfig::default();
        let a = dram_cycles(&acc, 1e6);
        let b = dram_cycles(&acc, 2e6);
        assert!((b as f64 / a as f64 - 2.0).abs() < 0.01);
    }

    #[test]
    fn weight_stream_2_7b_near_3gb() {
        let cfg = ModelConfig::mamba2_2_7b();
        let bytes = weight_stream_bytes(&cfg);
        // ~2.7B params mostly int8 → ~2.8-3.2 GB
        assert!(bytes > 2.4e9 && bytes < 3.5e9, "{bytes}");
    }
}
