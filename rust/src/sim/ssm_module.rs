//! SSM Module (paper Fig. 7): the three-step pipelined fixed-point engine.
//!
//! * **Step 1** — PAU(24) + NAU(24, SoftPlus mode): Δ̃ = SoftPlus(Δ + bias).
//! * **Step 2** — PMU(24) + NAU(24, exp mode): Ā = exp(Δ̃ · A);
//!   PMU(64): Q = Δ̃ · X per head.
//! * **Step 3** — 32-parallel PMU/PMA generate H ∈ R^{32×8} tiles of the
//!   hidden state, 32-parallel MAT reads out H·C, a final 32-input PMA adds
//!   the D·x bypass.
//!
//! Functional execution is entirely on the Q6.10 datapath (i32 lanes, wide
//! tree accumulators), making this the reference the hardware would be
//! verified against.  Timing follows the unit counts above.

use crate::config::{AcceleratorConfig, FixedSpec, ModelConfig};
use crate::quant::fixed::{fx_mac, fx_mul, fx_renorm, from_fixed, sat_add, to_fixed};

use super::nau::{Nau, NauMode};

/// Per-token cycle count of the SSM module for one layer.
pub fn ssm_cycles_per_token(acc: &AcceleratorConfig, cfg: &ModelConfig) -> u64 {
    let nheads = cfg.nheads() as u64;
    let lanes = acc.nau_lanes as u64;
    let nau = Nau::new(acc.nau_lanes);

    // Step 1: softplus over nheads dt values
    let step1 = nheads.div_ceil(lanes) + nau.depth();
    // Step 2: exp over nheads + dt·x over d_inner (64-wide PMU)
    let step2 = nheads.div_ceil(lanes).max(cfg.d_inner() as u64 / 64) + nau.depth();
    // Step 3: per head, headdim×d_state state elements through the
    // 32×8 PMU/PMA/MAT array (one fused update+readout pass)
    let tile = (acc.ssm_step3_units * acc.ssm_step3_width) as u64;
    let per_head = (cfg.headdim as u64 * cfg.d_state as u64).div_ceil(tile);
    let step3 = nheads * per_head + 12; // array pipeline depth
    // Steps are pipelined across tokens; per-token latency is their max,
    // but throughput-wise the bound is the slowest stage.
    step1.max(step2).max(step3)
}

/// Full-sequence SSM cycles (steady-state pipelined over tokens).
pub fn ssm_cycles(acc: &AcceleratorConfig, cfg: &ModelConfig, l: u64) -> u64 {
    l * ssm_cycles_per_token(acc, cfg) + 32
}

/// Functional fixed-point SSM for one layer over a sequence.
pub struct SsmModule {
    pub spec: FixedSpec,
    nau: Nau,
}

/// Per-head fixed-point state (owned by the state manager during decode).
pub struct FixedState {
    /// (nheads × headdim × d_state) Q6.10 values.
    pub h: Vec<i32>,
}

impl SsmModule {
    pub fn new(acc: &AcceleratorConfig) -> Self {
        Self { spec: FixedSpec::default(), nau: Nau::new(acc.nau_lanes) }
    }

    /// One token step on the fixed datapath.
    ///
    /// Inputs are f32 (from the float group / conv module); all SSM math is
    /// Q6.10.  `x`: (nheads*headdim,), `dt_raw`: (nheads,), `a_neg`: (nheads,)
    /// negative per-head A, `b`/`c`: (d_state,), `d`: (nheads,).
    /// Returns y (nheads*headdim,) in f32.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        x: &[f32],
        dt_raw: &[f32],
        dt_bias: &[f32],
        a_neg: &[f32],
        b: &[f32],
        c: &[f32],
        d: &[f32],
        state: &mut FixedState,
        cfg: &ModelConfig,
    ) -> Vec<f32> {
        let s = &self.spec;
        let nheads = cfg.nheads();
        let headdim = cfg.headdim;
        let d_state = cfg.d_state;

        // Step 1: PAU + NAU(SoftPlus)
        let dt_pre: Vec<i32> = dt_raw
            .iter()
            .zip(dt_bias)
            .map(|(r, bi)| sat_add(to_fixed(*r, s), to_fixed(*bi, s), s))
            .collect();
        let mut dt = vec![0i32; nheads];
        self.nau.eval(&dt_pre, NauMode::SoftPlus, &mut dt);

        // Step 2: PMU(dt·a) + NAU(exp)
        let prod: Vec<i32> = dt
            .iter()
            .zip(a_neg)
            .map(|(dtv, av)| fx_mul(*dtv, to_fixed(*av, s), s))
            .collect();
        let mut abar = vec![0i32; nheads];
        self.nau.eval(&prod, NauMode::Exp, &mut abar);

        let b_fx: Vec<i32> = b.iter().map(|v| to_fixed(*v, s)).collect();
        let c_fx: Vec<i32> = c.iter().map(|v| to_fixed(*v, s)).collect();

        // Step 3: PMU/PMA state tiles + MAT readout + bypass PMA
        let mut y = vec![0.0f32; nheads * headdim];
        for h in 0..nheads {
            let ab = abar[h];
            let d_fx = to_fixed(d[h], s);
            for p in 0..headdim {
                let x_fx = to_fixed(x[h * headdim + p], s);
                let q = fx_mul(dt[h], x_fx, s); // PMU64: Δ̃·x
                let row = &mut state.h
                    [(h * headdim + p) * d_state..(h * headdim + p + 1) * d_state];
                let mut acc = 0i64;
                for n in 0..d_state {
                    // PMA: h = ab*h + q*B[n]
                    let hv = sat_add(fx_mul(ab, row[n], s), fx_mul(q, b_fx[n], s), s);
                    row[n] = hv;
                    acc = fx_mac(acc, hv, c_fx[n]); // MAT readout
                }
                let dot = fx_renorm(acc, s);
                let out = sat_add(dot, fx_mul(d_fx, x_fx, s), s); // bypass PMA
                y[h * headdim + p] = from_fixed(out, s);
            }
        }
        y
    }

    pub fn zero_state(cfg: &ModelConfig) -> FixedState {
        FixedState { h: vec![0; cfg.nheads() * cfg.headdim * cfg.d_state] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny()
    }

    /// float reference of one step
    #[allow(clippy::too_many_arguments)]
    fn ref_step(
        x: &[f32], dt_raw: &[f32], dt_bias: &[f32], a_neg: &[f32], b: &[f32],
        c: &[f32], d: &[f32], h: &mut [f32], cfg: &ModelConfig,
    ) -> Vec<f32> {
        let nheads = cfg.nheads();
        let (hd, ds) = (cfg.headdim, cfg.d_state);
        let mut y = vec![0.0f32; nheads * hd];
        for hh in 0..nheads {
            let dt = {
                let v: f32 = dt_raw[hh] + dt_bias[hh];
                if v > 0.0 { v + (-v).exp().ln_1p() } else { v.exp().ln_1p() }
            };
            let ab = (dt * a_neg[hh]).exp();
            for p in 0..hd {
                let q = dt * x[hh * hd + p];
                let row = &mut h[(hh * hd + p) * ds..(hh * hd + p + 1) * ds];
                let mut dot = 0.0f32;
                for n in 0..ds {
                    row[n] = ab * row[n] + q * b[n];
                    dot += row[n] * c[n];
                }
                y[hh * hd + p] = dot + d[hh] * x[hh * hd + p];
            }
        }
        y
    }

    #[test]
    fn fixed_step_tracks_float_reference() {
        let cfg = tiny();
        let acc = AcceleratorConfig::default();
        let m = SsmModule::new(&acc);
        let mut rng = Rng::new(4);
        let nh = cfg.nheads();
        let mut st = SsmModule::zero_state(&cfg);
        let mut hf = vec![0.0f32; st.h.len()];
        let dt_bias: Vec<f32> = (0..nh).map(|_| rng.range_f64(-4.0, -2.0) as f32).collect();
        let a_neg: Vec<f32> = (0..nh).map(|_| -(rng.range_f64(0.5, 4.0) as f32)).collect();
        let d: Vec<f32> = (0..nh).map(|_| rng.normal() as f32 * 0.5).collect();
        for step_i in 0..12 {
            let x = rng.normal_vec(nh * cfg.headdim, 1.0);
            let dt_raw = rng.normal_vec(nh, 0.3);
            let b = rng.normal_vec(cfg.d_state, 0.4);
            let c = rng.normal_vec(cfg.d_state, 0.4);
            let y_fx = m.step(&x, &dt_raw, &dt_bias, &a_neg, &b, &c, &d, &mut st, &cfg);
            let y_f = ref_step(&x, &dt_raw, &dt_bias, &a_neg, &b, &c, &d, &mut hf, &cfg);
            let rms_ref = (y_f.iter().map(|v| v * v).sum::<f32>()
                / y_f.len() as f32).sqrt().max(1e-3);
            let rms_err = (y_fx.iter().zip(&y_f).map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>() / y_f.len() as f32).sqrt();
            // Q6.10 truncation accumulates through the recurrence; ~10%
            // RMS after a dozen steps is the expected datapath noise.
            assert!(rms_err / rms_ref < 0.15,
                    "step {step_i}: rel rms {}", rms_err / rms_ref);
        }
    }

    #[test]
    fn zero_input_decays_state() {
        let cfg = tiny();
        let acc = AcceleratorConfig::default();
        let m = SsmModule::new(&acc);
        let mut st = SsmModule::zero_state(&cfg);
        // seed the state
        for v in st.h.iter_mut() {
            *v = 512; // 0.5 in Q6.10
        }
        let nh = cfg.nheads();
        let x = vec![0.0f32; nh * cfg.headdim];
        let dt_raw = vec![2.0f32; nh]; // big dt -> strong decay
        let dt_bias = vec![0.0f32; nh];
        let a_neg = vec![-2.0f32; nh];
        let b = vec![0.0f32; cfg.d_state];
        let c = vec![0.1f32; cfg.d_state];
        let d = vec![0.0f32; nh];
        let before: i64 = st.h.iter().map(|v| (*v as i64).abs()).sum();
        m.step(&x, &dt_raw, &dt_bias, &a_neg, &b, &c, &d, &mut st, &cfg);
        let after: i64 = st.h.iter().map(|v| (*v as i64).abs()).sum();
        assert!(after < before / 10, "{after} vs {before}");
    }

    #[test]
    fn cycles_formula_130m() {
        let acc = AcceleratorConfig::default();
        let cfg = ModelConfig::mamba2_130m();
        // step3 dominates: 24 heads × (64·128/256)=32 → 768 + 12
        let per_tok = ssm_cycles_per_token(&acc, &cfg);
        assert_eq!(per_tok, 24 * 32 + 12);
        assert_eq!(ssm_cycles(&acc, &cfg, 10), 10 * per_tok + 32);
    }

    #[test]
    fn step3_scales_with_heads() {
        let acc = AcceleratorConfig::default();
        let a = ssm_cycles_per_token(&acc, &ModelConfig::mamba2_130m());
        let b = ssm_cycles_per_token(&acc, &ModelConfig::mamba2_2_7b());
        // 80 heads vs 24 heads
        assert!(b as f64 / a as f64 > 3.0);
    }
}
