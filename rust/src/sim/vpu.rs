//! Vector Processing Units (paper Table I / Fig. 5): the five primitive
//! units every FastMamba module is built from.
//!
//! | VPU | inputs            | output | function            |
//! |-----|-------------------|--------|---------------------|
//! | PAU | A:n, B:n          | P:n    | A + B               |
//! | PMU | A:n, B:n          | P:n    | A × B               |
//! | PMA | A:n, B:n, C:n     | P:n    | A × B + C           |
//! | HAT | A:n               | P:1    | Σ A_i (adder tree)  |
//! | MAT | A:n, B:n          | P:1    | Σ A_i × B_i         |
//!
//! Functional ops run on the Q6.10 fixed-point datapath (i32 lanes, wide
//! i64 accumulators in the trees, exactly like the "4 × 21b" accumulation
//! of Fig. 6).  The cycle model is: throughput 1 vector issue/cycle,
//! pipeline latency = `depth()` cycles to drain.

use crate::config::FixedSpec;
use crate::quant::fixed::{fx_mac, fx_mul, fx_renorm, sat_add};

/// Pipeline depths in cycles (DSP48 multiply = 3-stage, adder = 1-stage,
/// tree = log2(n) adder stages).
pub const ADD_LAT: u64 = 1;
pub const MUL_LAT: u64 = 3;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VpuKind {
    Pau,
    Pmu,
    Pma,
    Hat,
    Mat,
}

/// A VPU instance of a fixed vector width.
#[derive(Debug, Clone)]
pub struct Vpu {
    pub kind: VpuKind,
    pub width: usize,
    pub spec: FixedSpec,
}

impl Vpu {
    pub fn new(kind: VpuKind, width: usize) -> Self {
        Self { kind, width, spec: FixedSpec::default() }
    }

    /// Pipeline latency of one vector operation.
    pub fn depth(&self) -> u64 {
        let tree = (self.width.max(2) as f64).log2().ceil() as u64 * ADD_LAT;
        match self.kind {
            VpuKind::Pau => ADD_LAT,
            VpuKind::Pmu => MUL_LAT,
            VpuKind::Pma => MUL_LAT + ADD_LAT,
            VpuKind::Hat => tree,
            VpuKind::Mat => MUL_LAT + tree,
        }
    }

    /// Cycles to issue `n_vectors` back-to-back operations (pipelined).
    pub fn cycles(&self, n_vectors: u64) -> u64 {
        if n_vectors == 0 {
            0
        } else {
            n_vectors + self.depth()
        }
    }

    // ---- functional fixed-point ops ----

    pub fn pau(&self, a: &[i32], b: &[i32], out: &mut [i32]) {
        debug_assert_eq!(self.kind, VpuKind::Pau);
        for i in 0..a.len() {
            out[i] = sat_add(a[i], b[i], &self.spec);
        }
    }

    pub fn pmu(&self, a: &[i32], b: &[i32], out: &mut [i32]) {
        debug_assert_eq!(self.kind, VpuKind::Pmu);
        for i in 0..a.len() {
            out[i] = fx_mul(a[i], b[i], &self.spec);
        }
    }

    pub fn pma(&self, a: &[i32], b: &[i32], c: &[i32], out: &mut [i32]) {
        debug_assert_eq!(self.kind, VpuKind::Pma);
        for i in 0..a.len() {
            out[i] = sat_add(fx_mul(a[i], b[i], &self.spec), c[i], &self.spec);
        }
    }

    /// Adder tree: Σ A_i with a wide accumulator, renormalized at the root.
    pub fn hat(&self, a: &[i32]) -> i32 {
        debug_assert_eq!(self.kind, VpuKind::Hat);
        let acc: i64 = a.iter().map(|v| *v as i64).sum();
        acc.clamp(self.spec.qmin() as i64, self.spec.qmax() as i64) as i32
    }

    /// Multiplier-adder tree: Σ A_i × B_i (wide accumulate, renormalize).
    pub fn mat(&self, a: &[i32], b: &[i32]) -> i32 {
        debug_assert_eq!(self.kind, VpuKind::Mat);
        let mut acc = 0i64;
        for i in 0..a.len() {
            acc = fx_mac(acc, a[i], b[i]);
        }
        fx_renorm(acc, &self.spec)
    }

    /// int8 MAT (the Hadamard Linear Module's 8-bit arrays): exact i32 sum.
    pub fn mat_i8(a: &[i8], b: &[i8]) -> i32 {
        let mut acc = 0i32;
        for i in 0..a.len() {
            acc += a[i] as i32 * b[i] as i32;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fixed::to_fixed;

    fn spec() -> FixedSpec {
        FixedSpec::default()
    }

    #[test]
    fn table1_functional_contracts() {
        let s = spec();
        let a: Vec<i32> = [1.0f32, 2.0, -3.0, 0.5].iter().map(|v| to_fixed(*v, &s)).collect();
        let b: Vec<i32> = [0.5f32, -1.0, 2.0, 4.0].iter().map(|v| to_fixed(*v, &s)).collect();
        let c: Vec<i32> = [10.0f32, 10.0, 10.0, 10.0].iter().map(|v| to_fixed(*v, &s)).collect();
        let mut out = vec![0i32; 4];

        Vpu::new(VpuKind::Pau, 4).pau(&a, &b, &mut out);
        assert_eq!(out[0], to_fixed(1.5, &s));
        assert_eq!(out[2], to_fixed(-1.0, &s));

        Vpu::new(VpuKind::Pmu, 4).pmu(&a, &b, &mut out);
        assert_eq!(out[1], to_fixed(-2.0, &s));
        assert_eq!(out[3], to_fixed(2.0, &s));

        Vpu::new(VpuKind::Pma, 4).pma(&a, &b, &c, &mut out);
        assert_eq!(out[0], to_fixed(10.5, &s));

        let hat = Vpu::new(VpuKind::Hat, 4);
        assert_eq!(hat.hat(&a), to_fixed(0.5, &s));

        let mat = Vpu::new(VpuKind::Mat, 4);
        // 1*0.5 + 2*(-1) + (-3)*2 + 0.5*4 = -5.5
        assert_eq!(mat.mat(&a, &b), to_fixed(-5.5, &s));
    }

    #[test]
    fn mat_i8_exact() {
        let a = [100i8, -100, 127, -128];
        let b = [100i8, 100, 127, -128];
        assert_eq!(Vpu::mat_i8(&a, &b), 10000 - 10000 + 16129 + 16384);
    }

    #[test]
    fn pipeline_cycle_model() {
        let pmu = Vpu::new(VpuKind::Pmu, 24);
        assert_eq!(pmu.cycles(0), 0);
        assert_eq!(pmu.cycles(1), 1 + MUL_LAT);
        assert_eq!(pmu.cycles(100), 100 + MUL_LAT); // pipelined
        let mat64 = Vpu::new(VpuKind::Mat, 64);
        assert_eq!(mat64.depth(), MUL_LAT + 6); // log2(64)=6 tree stages
    }

    #[test]
    fn saturation_in_tree() {
        let s = spec();
        let big = vec![s.qmax(); 8];
        let hat = Vpu::new(VpuKind::Hat, 8);
        assert_eq!(hat.hat(&big), s.qmax()); // saturates, doesn't wrap
    }

    #[test]
    fn pma_matches_separate_ops() {
        let s = spec();
        let n = 16;
        let a: Vec<i32> = (0..n).map(|i| to_fixed(i as f32 * 0.25 - 2.0, &s)).collect();
        let b: Vec<i32> = (0..n).map(|i| to_fixed(1.0 - i as f32 * 0.125, &s)).collect();
        let c: Vec<i32> = (0..n).map(|i| to_fixed(i as f32 * 0.5, &s)).collect();
        let mut pma_out = vec![0i32; n];
        Vpu::new(VpuKind::Pma, n).pma(&a, &b, &c, &mut pma_out);
        let mut mul_out = vec![0i32; n];
        Vpu::new(VpuKind::Pmu, n).pmu(&a, &b, &mut mul_out);
        let mut add_out = vec![0i32; n];
        Vpu::new(VpuKind::Pau, n).pau(&mul_out, &c, &mut add_out);
        assert_eq!(pma_out, add_out);
    }
}
