//! Hadamard-based Linear Module (paper Fig. 6): 6 parallel computing
//! groups, each with 4 HAT units (the Hadamard transform of a 64-wide
//! activation slice) feeding 64 int8 MAT units (the matrix product).
//!
//! Functional path: Algorithm 1 with the same integer arithmetic as
//! `quant::hadamard` (which tests assert); timing path: the group-parallel,
//! 4-column-per-cycle HAT schedule and the 4-lane-per-cycle MAT schedule,
//! overlapped as a two-stage pipeline.

use crate::config::AcceleratorConfig;
use crate::quant::hadamard::{hadamard_linear, PreparedWeight};

/// Cycle count for an `(l, d) × (d, q)` quantized linear layer.
///
/// Per token and per 64-wide slice: the 4 HATs emit 4 Hadamard outputs per
/// cycle → `hat_width / hats_per_group` cycles per slice; the 64 MATs then
/// consume the quantized slice 4 int8 lanes per cycle for 64 output columns
/// in parallel.  The 6 groups run distinct slices concurrently and the two
/// stages overlap, so the module's steady-state rate is governed by the MAT
/// stage unless d is tiny.
pub fn linear_cycles(acc: &AcceleratorConfig, l: u64, d: u64, q: u64) -> u64 {
    let g = acc.linear_groups as u64;
    let hw = acc.hat_width as u64; // 64
    let slices = d.div_ceil(hw); // d/64 Hadamard groups
    let slice_rounds = slices.div_ceil(g); // rounds of 6 parallel groups

    // HAT stage: hw/hats cycles per slice (4 outputs/cycle)
    let hat_cycles_per_slice = hw / acc.hats_per_group as u64;
    // MAT stage: per slice, each output column needs hw/mat_width beats; 64
    // columns run in parallel, so q columns need ceil(q/64) passes.
    let mat_passes = q.div_ceil(acc.mats_per_group as u64);
    let mat_cycles_per_slice = (hw / acc.linear_mat_width as u64) * mat_passes;

    // two-stage pipeline: max of stage rates, plus one fill of the shorter
    let per_token = slice_rounds * hat_cycles_per_slice.max(mat_cycles_per_slice)
        + hat_cycles_per_slice.min(mat_cycles_per_slice);
    l * per_token + 16 // pipeline fill/drain
}

/// Functional execution on the module (Algorithm 1, same bits as the golden
/// quant library).  Returns the cycle count alongside the result.
pub struct LinearModule<'a> {
    pub acc: &'a AcceleratorConfig,
}

impl<'a> LinearModule<'a> {
    pub fn new(acc: &'a AcceleratorConfig) -> Self {
        Self { acc }
    }

    /// Execute `y = x @ w^T` (x: `(l, d)` row-major) on the simulated
    /// module; returns (y, cycles).
    pub fn forward(
        &self,
        x: &[f32],
        l: usize,
        pw: &PreparedWeight,
        bias: Option<&[f32]>,
    ) -> (Vec<f32>, u64) {
        let mut y = vec![0.0f32; l * pw.q];
        hadamard_linear(x, l, pw, bias, &mut y);
        let cyc = linear_cycles(self.acc, l as u64, pw.d as u64, pw.q as u64);
        (y, cyc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::quant::hadamard::prepare_weight;
    use crate::util::rng::Rng;

    #[test]
    fn functional_matches_algorithm1() {
        let acc = AcceleratorConfig::default();
        let module = LinearModule::new(&acc);
        let mut rng = Rng::new(1);
        let (l, d, q) = (8, 128, 64);
        let x = rng.normal_vec(l * d, 1.0);
        let w = rng.normal_vec(q * d, 0.1);
        let pw = prepare_weight(&w, q, d, 64);
        let (y, cyc) = module.forward(&x, l, &pw, None);
        let mut want = vec![0.0f32; l * q];
        hadamard_linear(&x, l, &pw, None, &mut want);
        assert_eq!(y, want);
        assert!(cyc > 0);
    }

    #[test]
    fn cycles_scale_linearly_in_tokens() {
        let acc = AcceleratorConfig::default();
        let c1 = linear_cycles(&acc, 64, 768, 1536);
        let c2 = linear_cycles(&acc, 128, 768, 1536);
        let ratio = c2 as f64 / c1 as f64;
        assert!((ratio - 2.0).abs() < 0.05, "{ratio}");
    }

    #[test]
    fn cycles_match_hand_count_130m_inproj() {
        // d=768 → 12 slices → 2 rounds of 6 groups; q=3352 → 53 MAT passes;
        // per slice-round: max(16 HAT, 16*53 MAT)=848; per token 2*848+16.
        let acc = AcceleratorConfig::default();
        let per_tok = 2 * (16 * 53).max(16) + 16;
        assert_eq!(linear_cycles(&acc, 1, 768, 3352), per_tok as u64 + 16);
    }

    #[test]
    fn mat_stage_dominates_for_wide_outputs() {
        let acc = AcceleratorConfig::default();
        // doubling q roughly doubles cycles (MAT-bound)
        let a = linear_cycles(&acc, 16, 768, 768);
        let b = linear_cycles(&acc, 16, 768, 1536);
        let r = b as f64 / a as f64;
        assert!(r > 1.8 && r < 2.2, "{r}");
    }

    #[test]
    fn throughput_sanity_int8_macs() {
        // steady state ≈ linear_macs_per_cycle effective MACs/cycle
        let acc = AcceleratorConfig::default();
        let (l, d, q) = (256u64, 1536, 1536);
        let cycles = linear_cycles(&acc, l, d, q);
        let macs = l * d * q;
        let rate = macs as f64 / cycles as f64;
        let peak = acc.linear_macs_per_cycle() as f64;
        assert!(rate <= peak * 1.01, "rate {rate} > peak {peak}");
        assert!(rate > peak * 0.5, "rate {rate} ≪ peak {peak}");
    }
}
