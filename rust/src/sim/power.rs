//! Power model (Table III energy-efficiency): per-resource dynamic power
//! coefficients at 250 MHz plus static power, Virtex-7 28 nm class.
//!
//! Coefficients are in the range Xilinx XPE reports for this family; the
//! total lands in the ~9 W class the paper's 0.61 token/(s·W) at
//! 5.68 token/s implies (≈ 9.3 W board power).

use crate::config::AcceleratorConfig;

use super::resources::{utilization, Resources};

/// Dynamic power per resource unit at 250 MHz, watts (toggle-rate-averaged).
pub const W_PER_LUT: f64 = 6.0e-6;
pub const W_PER_FF: f64 = 1.2e-6;
pub const W_PER_DSP: f64 = 1.1e-3;
pub const W_PER_BRAM: f64 = 1.6e-3;
/// Device static power + clocking, watts.
pub const STATIC_W: f64 = 1.4;
/// DDR3 interface power, watts.
pub const DRAM_W: f64 = 1.8;

/// Estimated board power for a resource vector, assuming `activity` mean
/// toggle activity on the compute fabric (0..1).
pub fn power_w(r: &Resources, activity: f64) -> f64 {
    STATIC_W
        + DRAM_W
        + activity
            * (r.lut as f64 * W_PER_LUT
                + r.ff as f64 * W_PER_FF
                + r.dsp as f64 * W_PER_DSP
                + r.bram as f64 * W_PER_BRAM)
}

/// Full-accelerator power at the given activity factor.
pub fn accelerator_power_w(acc: &AcceleratorConfig, activity: f64) -> f64 {
    power_w(&utilization(acc).total, activity)
}

/// Energy efficiency in tokens/(s·W).
pub fn tokens_per_s_per_w(tokens_per_s: f64, watts: f64) -> f64 {
    tokens_per_s / watts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_power_in_paper_class() {
        // Table III implies ≈ 9.3 W (5.68 tok/s ÷ 0.61 tok/s/W).
        let p = accelerator_power_w(&AcceleratorConfig::default(), 0.85);
        assert!(p > 6.0 && p < 13.0, "power {p} W");
    }

    #[test]
    fn power_monotone_in_activity() {
        let acc = AcceleratorConfig::default();
        assert!(accelerator_power_w(&acc, 0.9) > accelerator_power_w(&acc, 0.3));
    }

    #[test]
    fn static_floor() {
        let p = power_w(&Resources::default(), 1.0);
        assert!((p - (STATIC_W + DRAM_W)).abs() < 1e-12);
    }

    #[test]
    fn efficiency_math() {
        assert!((tokens_per_s_per_w(5.68, 9.3) - 0.6107).abs() < 1e-3);
    }
}
