//! Floating-point computing group (paper Fig. 4): RMS Normalization Module
//! and SiLU Module.  The paper keeps these in floating point because their
//! share of total compute is small (Fig. 1) and quantizing them costs
//! accuracy; we model a modest vector unit (16 FP lanes per module).

use crate::config::AcceleratorConfig;
use crate::nonlinear;

/// FP lanes per float module (an implementation constant consistent with
/// the DSP budget Table IV assigns to the RMS Norm / SiLU group).
pub const FP_LANES: u64 = 16;
/// fp pipeline depth (mult + add + special-function stages).
pub const FP_DEPTH: u64 = 8;

/// Cycles for an RMSNorm over `(l, d)`: square+reduce pass and scale pass.
pub fn rmsnorm_cycles(_acc: &AcceleratorConfig, l: u64, d: u64) -> u64 {
    let per_tok = 2 * d.div_ceil(FP_LANES) + FP_DEPTH; // reduce + scale
    l * per_tok
}

/// Cycles for a SiLU over `n` elements.
pub fn silu_cycles(_acc: &AcceleratorConfig, n: u64) -> u64 {
    n.div_ceil(FP_LANES) + FP_DEPTH
}

/// Functional wrappers (same math as the nonlinear module — fp32 here
/// stands in for the FPGA's fp16, which Table II shows is accuracy-neutral).
pub struct FloatModule;

impl FloatModule {
    pub fn rmsnorm(x: &mut [f32], w: &[f32], eps: f32) {
        nonlinear::rmsnorm(x, w, eps);
    }

    pub fn silu(x: &mut [f32]) {
        for v in x.iter_mut() {
            *v = nonlinear::silu(*v);
        }
    }

    pub fn gated_rmsnorm(y: &mut [f32], z: &[f32], w: &[f32], eps: f32) {
        nonlinear::gated_rmsnorm(y, z, w, eps);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silu_matches_scalar() {
        let mut x = vec![-2.0f32, 0.0, 1.0, 3.5];
        FloatModule::silu(&mut x);
        assert_eq!(x[1], 0.0);
        assert!((x[2] - 0.731_058_6).abs() < 1e-5);
    }

    #[test]
    fn cycles_scale() {
        let acc = AcceleratorConfig::default();
        assert_eq!(silu_cycles(&acc, 16), 1 + FP_DEPTH);
        assert_eq!(silu_cycles(&acc, 17), 2 + FP_DEPTH);
        let a = rmsnorm_cycles(&acc, 1, 768);
        let b = rmsnorm_cycles(&acc, 2, 768);
        assert_eq!(b, 2 * a);
    }
}
