//! Cycle-level simulator of the FastMamba FPGA microarchitecture (paper §IV).
//!
//! The simulator has two coupled halves:
//!
//! * **Functional models** — bit-faithful fixed-point execution of each
//!   module (VPUs on Q6.10 lanes, int8 MAT arrays, the multi-mode NAU),
//!   validated against the Rust golden model and, transitively, against the
//!   Pallas kernels.
//! * **Timing models** — cycle counts derived from the paper's published
//!   unit counts, vector widths and pipeline structure (Fig. 4–8), plus a
//!   DRAM streaming model for the weight traffic that bounds decode.
//!
//! [`perf`] composes the per-module cycle counts into end-to-end prefill
//! latency (Fig. 9) and decode throughput (Table III); [`resources`] and
//! [`power`] produce Table IV / Fig. 10 and the energy-efficiency numbers;
//! [`speculative`] extends the decode model to the draft/verify loop of
//! `coordinator::speculative` (speedup vs acceptance rate and draft length).

pub mod buffer;
pub mod conv_module;
pub mod dataflow;
pub mod float_module;
pub mod linear_module;
pub mod nau;
pub mod perf;
pub mod power;
pub mod resources;
pub mod speculative;
pub mod ssm_module;
pub mod vpu;

pub use perf::{DecodePerf, PerfModel, PrefillPerf};
pub use speculative::{SpecPoint, SpecSim};
