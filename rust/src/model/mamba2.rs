//! Mamba2 forward passes (prefill + recurrent decode) under the paper's five
//! quantization variants — the Rust mirror of `python/compile/mamba2.py`.
//!
//! This implementation serves three roles:
//! 1. **Golden model** — integration tests compare it against the PJRT
//!    executables lowered from JAX.
//! 2. **CPU baseline** — its measured single-thread throughput calibrates
//!    the Fig. 9 CPU comparison.
//! 3. **Table II evaluator** — the synthetic perplexity/accuracy harness
//!    runs every variant through this code.

use crate::config::{FixedSpec, ModelConfig};
use crate::nonlinear::{self, PwlTable};
use crate::quant::hadamard::{self, PreparedWeight};
use crate::quant::{int8, pot};

use super::weights::{LayerWeights, ModelWeights};

/// The five Table II quantization configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Full precision (stands in for the paper's FP16 baseline).
    Fp32,
    /// Per-tensor absmax W8A8, linear layers only.
    NormalQ,
    /// SmoothQuant W8A8, linear layers only.
    SmoothQ,
    /// Hadamard W8A8 (Algorithm 1), linear layers only.
    FastMambaLq,
    /// Hadamard linears + PoT conv/SSM + PWL nonlinears — the accelerator's
    /// exact arithmetic.
    FastMamba,
}

impl Variant {
    pub const ALL: [Variant; 5] = [
        Variant::Fp32,
        Variant::NormalQ,
        Variant::SmoothQ,
        Variant::FastMambaLq,
        Variant::FastMamba,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Variant::Fp32 => "fp32",
            Variant::NormalQ => "normalq",
            Variant::SmoothQ => "smoothq",
            Variant::FastMambaLq => "fastmamba_lq",
            Variant::FastMamba => "fastmamba",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|v| v.name() == s)
    }

    fn hadamard(&self) -> bool {
        matches!(self, Variant::FastMambaLq | Variant::FastMamba)
    }
}

/// Hadamard group size (must match `mamba2.HADAMARD_GROUP` in Python).
pub const HADAMARD_GROUP: usize = 64;

/// Per-request recurrent state (what the coordinator's state manager pools).
#[derive(Debug, Clone)]
pub struct DecodeState {
    /// (n_layer, d_conv-1, conv_dim) rolling pre-conv window.
    pub conv: Vec<f32>,
    /// (n_layer, nheads, headdim, d_state) SSM hidden state.
    pub ssm: Vec<f32>,
}

impl DecodeState {
    pub fn zeros(cfg: &ModelConfig) -> Self {
        Self {
            conv: vec![0.0; cfg.conv_state_len()],
            ssm: vec![0.0; cfg.ssm_state_len()],
        }
    }

    /// Bytes per request — the O(1) admission cost Mamba serving enjoys
    /// instead of a length-proportional KV cache.
    pub fn nbytes(cfg: &ModelConfig) -> usize {
        4 * (cfg.conv_state_len() + cfg.ssm_state_len())
    }
}

/// A model bound to weights with per-variant prepared (offline-quantized)
/// linear weights.
pub struct Mamba2 {
    pub w: ModelWeights,
    pub spec: FixedSpec,
    pwl: PwlTable,
    /// (in_proj, out_proj, lm_head) Hadamard-prepared per layer; lazy.
    prepared: Option<Prepared>,
}

struct Prepared {
    in_proj: Vec<PreparedWeight>,
    out_proj: Vec<PreparedWeight>,
    lm_head: PreparedWeight,
}

impl Mamba2 {
    pub fn new(w: ModelWeights) -> Self {
        let spec = FixedSpec::default();
        let pwl = PwlTable::new(&spec);
        Self { w, spec, pwl, prepared: None }
    }

    fn cfg(&self) -> &ModelConfig {
        &self.w.cfg
    }

    /// Offline weight preparation for the Hadamard variants (Algorithm 1
    /// lines 6/8/11 run once, like the FPGA's weight preprocessing).
    pub fn prepare(&mut self) {
        if self.prepared.is_some() {
            return;
        }
        let cfg = self.cfg().clone();
        let mut in_proj = Vec::new();
        let mut out_proj = Vec::new();
        for lw in &self.w.layers {
            in_proj.push(hadamard::prepare_weight(
                &lw.in_proj_w, cfg.d_in_proj(), cfg.d_model, HADAMARD_GROUP));
            out_proj.push(hadamard::prepare_weight(
                &lw.out_proj_w, cfg.d_model, cfg.d_inner(), HADAMARD_GROUP));
        }
        let lm_head = hadamard::prepare_weight(
            &self.w.embed, cfg.vocab_size, cfg.d_model, HADAMARD_GROUP);
        self.prepared = Some(Prepared { in_proj, out_proj, lm_head });
    }

    // -- linear dispatch ----------------------------------------------------

    fn linear(
        &self,
        x: &[f32],
        rows: usize,
        w: &[f32],
        q: usize,
        d: usize,
        variant: Variant,
        prepared: Option<&PreparedWeight>,
        out: &mut [f32],
    ) {
        match variant {
            Variant::Fp32 => {
                for r in 0..rows {
                    for j in 0..q {
                        let mut acc = 0.0f32;
                        let xr = &x[r * d..(r + 1) * d];
                        let wr = &w[j * d..(j + 1) * d];
                        for k in 0..d {
                            acc += xr[k] * wr[k];
                        }
                        out[r * q + j] = acc;
                    }
                }
            }
            Variant::NormalQ => int8::normalq_linear(x, rows, w, q, d, None, out),
            Variant::SmoothQ => {
                int8::smoothq_linear(x, rows, w, q, d, None, 0.5, out)
            }
            Variant::FastMambaLq | Variant::FastMamba => match prepared {
                Some(pw) => hadamard::hadamard_linear(x, rows, pw, None, out),
                None => {
                    let pw = hadamard::prepare_weight(w, q, d, HADAMARD_GROUP);
                    hadamard::hadamard_linear(x, rows, &pw, None, out);
                }
            },
        }
    }

    /// Linear over a batch of *independent* rows (one per sequence).  The
    /// quantized variants calibrate activation scales per call (absmax over
    /// every row passed in), so batching rows would couple sequences and
    /// change their outputs; they run one row per call instead, keeping
    /// batch-major decode token-exact with single-sequence stepping.  Fp32
    /// has no calibration, so its rows batch into a single matmul.
    #[allow(clippy::too_many_arguments)]
    fn linear_rows(
        &self,
        x: &[f32],
        rows: usize,
        w: &[f32],
        q: usize,
        d: usize,
        variant: Variant,
        prepared: Option<&PreparedWeight>,
        out: &mut [f32],
    ) {
        if variant == Variant::Fp32 || rows == 1 {
            self.linear(x, rows, w, q, d, variant, prepared, out);
        } else {
            for r in 0..rows {
                self.linear(
                    &x[r * d..(r + 1) * d],
                    1,
                    w,
                    q,
                    d,
                    variant,
                    prepared,
                    &mut out[r * q..(r + 1) * q],
                );
            }
        }
    }

    fn softplus(&self, x: f32, variant: Variant) -> f32 {
        if variant == Variant::FastMamba {
            nonlinear::softplus_approx(x, &self.pwl, &self.spec)
        } else {
            // numerically stable ln(1+e^x)
            if x > 0.0 { x + (-x).exp().ln_1p() } else { x.exp().ln_1p() }
        }
    }

    fn exp_neg(&self, x: f32, variant: Variant) -> f32 {
        if variant == Variant::FastMamba {
            nonlinear::exp_approx(x, &self.pwl, &self.spec)
        } else {
            x.exp()
        }
    }

    // -- prefill -------------------------------------------------------------

    /// Full-sequence forward from a fresh (zero) state.  Returns logits
    /// `(L, vocab)` and the decode state seeded for continuation.
    pub fn prefill(&self, tokens: &[u32], variant: Variant) -> (Vec<f32>, DecodeState) {
        let mut state = DecodeState::zeros(self.cfg());
        let logits = self.prefill_chunk(tokens, variant, &mut state);
        (logits, state)
    }

    /// Chunked prefill: forward one chunk *continuing* from `state` (the
    /// recurrent state left by earlier chunks or decode steps), updating it
    /// in place.  Mirrors the Python `block_prefill(conv_state0, ssm_state0)`
    /// contract the AOT prefill artifacts lower: the carried conv window
    /// supplies the receptive-field history of the first `d_conv - 1`
    /// positions, so chaining chunks is exact (bit-identical to one full
    /// prefill under fp32, where no cross-chunk quantization statistics
    /// exist).
    pub fn prefill_chunk(
        &self,
        tokens: &[u32],
        variant: Variant,
        state: &mut DecodeState,
    ) -> Vec<f32> {
        let cfg = self.cfg().clone();
        let l = tokens.len();
        let d = cfg.d_model;
        let mut x = vec![0.0f32; l * d];
        for (t, tok) in tokens.iter().enumerate() {
            x[t * d..(t + 1) * d]
                .copy_from_slice(&self.w.embed[*tok as usize * d..(*tok as usize + 1) * d]);
        }
        for (li, lw) in self.w.layers.iter().enumerate() {
            self.block_prefill(li, lw, &mut x, l, variant, state);
        }
        // final norm + tied lm head
        for t in 0..l {
            nonlinear::rmsnorm(&mut x[t * d..(t + 1) * d], &self.w.norm_f_w, 1e-5);
        }
        let mut logits = vec![0.0f32; l * cfg.vocab_size];
        let pw = self.prepared.as_ref().map(|p| &p.lm_head);
        self.linear(&x, l, &self.w.embed, cfg.vocab_size, d, variant,
                    if variant.hadamard() { pw } else { None }, &mut logits);
        logits
    }

    fn block_prefill(
        &self,
        li: usize,
        lw: &LayerWeights,
        x: &mut [f32],
        l: usize,
        variant: Variant,
        state: &mut DecodeState,
    ) {
        let cfg = self.cfg();
        let d = cfg.d_model;
        let d_inner = cfg.d_inner();
        let d_state = cfg.d_state;
        let conv_dim = cfg.conv_dim();
        let nheads = cfg.nheads();
        let headdim = cfg.headdim;
        let k = cfg.d_conv;
        let d_in_proj = cfg.d_in_proj();

        // pre-norm
        let mut xn = x.to_vec();
        for t in 0..l {
            nonlinear::rmsnorm(&mut xn[t * d..(t + 1) * d], &lw.norm_w, 1e-5);
        }

        // in_proj
        let mut zxbcdt = vec![0.0f32; l * d_in_proj];
        let pw = self.prepared.as_ref().map(|p| &p.in_proj[li]);
        self.linear(&xn, l, &lw.in_proj_w, d_in_proj, d, variant,
                    if variant.hadamard() { pw } else { None }, &mut zxbcdt);

        // split z / xBC / dt
        let mut z = vec![0.0f32; l * d_inner];
        let mut xbc_pre = vec![0.0f32; l * conv_dim];
        let mut dt_raw = vec![0.0f32; l * nheads];
        for t in 0..l {
            let row = &zxbcdt[t * d_in_proj..(t + 1) * d_in_proj];
            z[t * d_inner..(t + 1) * d_inner].copy_from_slice(&row[..d_inner]);
            xbc_pre[t * conv_dim..(t + 1) * conv_dim]
                .copy_from_slice(&row[d_inner..d_inner + conv_dim]);
            dt_raw[t * nheads..(t + 1) * nheads]
                .copy_from_slice(&row[d_inner + conv_dim..]);
        }

        // extended pre-conv rows: carried history (K-1 rows from `state`,
        // zeros on a fresh sequence) ++ this chunk — the Python side's
        // `xbc_ext = concat([conv_state0, xbc_pre])`
        let ext = (k - 1) + l;
        let mut xbc_ext = vec![0.0f32; ext * conv_dim];
        xbc_ext[..(k - 1) * conv_dim].copy_from_slice(
            &state.conv[li * (k - 1) * conv_dim..(li + 1) * (k - 1) * conv_dim]);
        xbc_ext[(k - 1) * conv_dim..].copy_from_slice(&xbc_pre);

        // new carried history = last K-1 *unquantized* extended rows
        // (handles l < K-1: old rows roll forward)
        state.conv[li * (k - 1) * conv_dim..(li + 1) * (k - 1) * conv_dim]
            .copy_from_slice(&xbc_ext[l * conv_dim..]);

        // depthwise causal conv (+PoT for FastMamba) then SiLU
        let mut conv_w = lw.conv_w.clone();
        let mut xbc_in = xbc_ext;
        if variant == Variant::FastMamba {
            pot::pot_fake_quant_grouped(&mut conv_w, k, 16); // per-channel taps
            pot::pot_fake_quant_per_col(&mut xbc_in, ext, conv_dim, 16);
        }
        // output position t sees extended rows t..t+K-1 (exactly the carried
        // history for the first K-1 positions of the chunk)
        let mut xbc = vec![0.0f32; l * conv_dim];
        for t in 0..l {
            for c in 0..conv_dim {
                let mut acc = lw.conv_b[c];
                for tap in 0..k {
                    acc += conv_w[c * k + tap] * xbc_in[(t + tap) * conv_dim + c];
                }
                xbc[t * conv_dim + c] = nonlinear::silu(acc);
            }
        }

        // split x / B / C
        let mut xh = vec![0.0f32; l * d_inner];
        let mut b_mat = vec![0.0f32; l * d_state];
        let mut c_mat = vec![0.0f32; l * d_state];
        for t in 0..l {
            let row = &xbc[t * conv_dim..(t + 1) * conv_dim];
            xh[t * d_inner..(t + 1) * d_inner].copy_from_slice(&row[..d_inner]);
            b_mat[t * d_state..(t + 1) * d_state]
                .copy_from_slice(&row[d_inner..d_inner + d_state]);
            c_mat[t * d_state..(t + 1) * d_state]
                .copy_from_slice(&row[d_inner + d_state..]);
        }

        // Step 1-2: dt = softplus(dt_raw + bias); abar = exp(dt * a)
        let mut dt = vec![0.0f32; l * nheads];
        let mut abar = vec![0.0f32; l * nheads];
        for t in 0..l {
            for h in 0..nheads {
                let dtv = self.softplus(dt_raw[t * nheads + h] + lw.dt_bias[h], variant);
                dt[t * nheads + h] = dtv;
                abar[t * nheads + h] = self.exp_neg(-lw.a_log[h].exp() * dtv, variant);
            }
        }

        if variant == Variant::FastMamba {
            // fine-grained PoT on the SSM operands (per head / per tensor)
            pot::pot_fake_quant_per_col(&mut dt, l, nheads, 16);
            pot::pot_fake_quant_per_col(&mut abar, l, nheads, 16);
            pot::pot_fake_quant(&mut b_mat, 16);
            pot::pot_fake_quant(&mut c_mat, 16);
            // per-head x: heads are contiguous headdim slices of each row
            for h in 0..nheads {
                let mut am = 0.0f32;
                for t in 0..l {
                    for p in 0..headdim {
                        am = am.max(xh[t * d_inner + h * headdim + p].abs());
                    }
                }
                let pexp = pot::pot_exponent(am, 16);
                for t in 0..l {
                    for p in 0..headdim {
                        let v = &mut xh[t * d_inner + h * headdim + p];
                        *v = pot::pot_fake_quant_scalar(*v, pexp, 16);
                    }
                }
            }
        }

        // Step 3: the recurrence (H stays "on chip" per head)
        let mut y = vec![0.0f32; l * d_inner];
        let ssm = &mut state.ssm[li * nheads * headdim * d_state
            ..(li + 1) * nheads * headdim * d_state];
        for h in 0..nheads {
            let hst = &mut ssm[h * headdim * d_state..(h + 1) * headdim * d_state];
            for t in 0..l {
                let ab = abar[t * nheads + h];
                let dtv = dt[t * nheads + h];
                let brow = &b_mat[t * d_state..(t + 1) * d_state];
                let crow = &c_mat[t * d_state..(t + 1) * d_state];
                for p in 0..headdim {
                    let xv = dtv * xh[t * d_inner + h * headdim + p];
                    let hrow = &mut hst[p * d_state..(p + 1) * d_state];
                    let mut dot = 0.0f32;
                    for n in 0..d_state {
                        let hv = ab * hrow[n] + xv * brow[n];
                        hrow[n] = hv;
                        dot += hv * crow[n];
                    }
                    y[t * d_inner + h * headdim + p] =
                        dot + lw.d[h] * xh[t * d_inner + h * headdim + p];
                }
            }
        }

        // gated RMSNorm + out_proj + residual
        let pw_out = self.prepared.as_ref().map(|p| &p.out_proj[li]);
        let mut out = vec![0.0f32; l * d];
        for t in 0..l {
            nonlinear::gated_rmsnorm(
                &mut y[t * d_inner..(t + 1) * d_inner],
                &z[t * d_inner..(t + 1) * d_inner],
                &lw.norm_g_w,
                1e-5,
            );
        }
        self.linear(&y, l, &lw.out_proj_w, d, d_inner, variant,
                    if variant.hadamard() { pw_out } else { None }, &mut out);
        for i in 0..l * d {
            x[i] += out[i];
        }
    }

    // -- decode ---------------------------------------------------------------

    /// One recurrent step.  Returns logits `(vocab,)`; `state` is updated.
    /// (A batch-1 view of [`Mamba2::decode_batch`] — one code path.)
    pub fn decode_step(
        &self,
        token: u32,
        state: &mut DecodeState,
        variant: Variant,
    ) -> Vec<f32> {
        self.decode_batch(&[token], variant, &mut state.conv, &mut state.ssm)
    }

    /// One recurrent step over a batch of independent sequences, batch-major:
    /// `conv` is `(B, n_layer, d_conv-1, conv_dim)` and `ssm` is
    /// `(B, n_layer, nheads, headdim, d_state)`, both advanced **in place**.
    /// Returns logits `(B, vocab)`.
    ///
    /// The whole batch makes one pass through the layer stack (each layer's
    /// weights are streamed once per step instead of once per sequence — the
    /// weight-reuse the paper's batched decode depends on), and no
    /// per-sequence state is copied out and back.  Token-exact with B
    /// separate [`Mamba2::decode_step`] calls: the fp32 linears batch rows
    /// into one matmul (per-row accumulation is unchanged), while the
    /// quantized variants keep per-sequence activation scales (`linear_rows`).
    pub fn decode_batch(
        &self,
        tokens: &[u32],
        variant: Variant,
        conv: &mut [f32],
        ssm: &mut [f32],
    ) -> Vec<f32> {
        let cfg = self.cfg().clone();
        let b = tokens.len();
        let d = cfg.d_model;
        let conv_len = cfg.conv_state_len();
        let ssm_len = cfg.ssm_state_len();
        assert_eq!(conv.len(), b * conv_len, "conv is not (B, n_layer, K-1, conv_dim)");
        assert_eq!(ssm.len(), b * ssm_len, "ssm is not (B, n_layer, nheads, P, N)");

        let mut x = vec![0.0f32; b * d];
        for (r, tok) in tokens.iter().enumerate() {
            x[r * d..(r + 1) * d].copy_from_slice(
                &self.w.embed[*tok as usize * d..(*tok as usize + 1) * d]);
        }
        for (li, lw) in self.w.layers.iter().enumerate() {
            self.block_decode_batch(li, lw, &mut x, b, variant, conv, ssm,
                                    conv_len, ssm_len);
        }
        for r in 0..b {
            nonlinear::rmsnorm(&mut x[r * d..(r + 1) * d], &self.w.norm_f_w, 1e-5);
        }
        let mut logits = vec![0.0f32; b * cfg.vocab_size];
        let pw = self.prepared.as_ref().map(|p| &p.lm_head);
        self.linear_rows(&x, b, &self.w.embed, cfg.vocab_size, d, variant,
                         if variant.hadamard() { pw } else { None }, &mut logits);
        logits
    }

    #[allow(clippy::too_many_arguments)]
    fn block_decode_batch(
        &self,
        li: usize,
        lw: &LayerWeights,
        x: &mut [f32],
        b: usize,
        variant: Variant,
        conv: &mut [f32],
        ssm: &mut [f32],
        conv_len: usize,
        ssm_len: usize,
    ) {
        let cfg = self.cfg();
        let d = cfg.d_model;
        let d_inner = cfg.d_inner();
        let d_state = cfg.d_state;
        let conv_dim = cfg.conv_dim();
        let nheads = cfg.nheads();
        let headdim = cfg.headdim;
        let k = cfg.d_conv;
        let d_in_proj = cfg.d_in_proj();

        let mut xn = x.to_vec();
        for r in 0..b {
            nonlinear::rmsnorm(&mut xn[r * d..(r + 1) * d], &lw.norm_w, 1e-5);
        }

        let mut zxbcdt = vec![0.0f32; b * d_in_proj];
        let pw = self.prepared.as_ref().map(|p| &p.in_proj[li]);
        self.linear_rows(&xn, b, &lw.in_proj_w, d_in_proj, d, variant,
                         if variant.hadamard() { pw } else { None }, &mut zxbcdt);

        // conv taps are sequence-invariant: quantize them (FastMamba) once
        // per layer per step, not once per sequence
        let conv_w_q: Option<Vec<f32>> = (variant == Variant::FastMamba).then(|| {
            let mut cw = lw.conv_w.clone();
            pot::pot_fake_quant_grouped(&mut cw, k, 16);
            cw
        });
        let conv_w: &[f32] = conv_w_q.as_deref().unwrap_or(&lw.conv_w);

        // conv window + SSM recurrence stay per-sequence (the recurrent state
        // is independent per sequence, and FastMamba's PoT calibration is
        // per-sequence by contract), writing straight into the batch-major
        // buffers — no per-sequence state marshalling
        let mut y_all = vec![0.0f32; b * d_inner];
        for r in 0..b {
            let row = &zxbcdt[r * d_in_proj..(r + 1) * d_in_proj];
            let z = &row[..d_inner];
            let xbc_new = &row[d_inner..d_inner + conv_dim];
            let dt_raw = &row[d_inner + conv_dim..];

            // rolling conv window: state rows [0..k-2] ++ new row
            let cs_off = r * conv_len + li * (k - 1) * conv_dim;
            let mut window = vec![0.0f32; k * conv_dim];
            window[..(k - 1) * conv_dim]
                .copy_from_slice(&conv[cs_off..cs_off + (k - 1) * conv_dim]);
            window[(k - 1) * conv_dim..].copy_from_slice(xbc_new);

            let window_q: Vec<f32>;
            let window_in: &[f32] = if variant == Variant::FastMamba {
                let mut wq = window.clone();
                pot::pot_fake_quant_per_col(&mut wq, k, conv_dim, 16);
                window_q = wq;
                &window_q
            } else {
                &window
            };
            let mut xbc = vec![0.0f32; conv_dim];
            for c in 0..conv_dim {
                let mut acc = lw.conv_b[c];
                for tap in 0..k {
                    acc += conv_w[c * k + tap] * window_in[tap * conv_dim + c];
                }
                xbc[c] = nonlinear::silu(acc);
            }
            // advance state (unquantized window rows, as in prefill)
            conv[cs_off..cs_off + (k - 1) * conv_dim]
                .copy_from_slice(&window[conv_dim..]);

            let mut xh = xbc[..d_inner].to_vec();
            let mut b_t = xbc[d_inner..d_inner + d_state].to_vec();
            let mut c_t = xbc[d_inner + d_state..].to_vec();

            let mut dt = vec![0.0f32; nheads];
            let mut abar = vec![0.0f32; nheads];
            for h in 0..nheads {
                let dtv = self.softplus(dt_raw[h] + lw.dt_bias[h], variant);
                dt[h] = dtv;
                abar[h] = self.exp_neg(-lw.a_log[h].exp() * dtv, variant);
            }

            if variant == Variant::FastMamba {
                pot::pot_fake_quant_grouped(&mut xh, headdim, 16); // per head
                pot::pot_fake_quant(&mut b_t, 16);
                pot::pot_fake_quant(&mut c_t, 16);
                pot::pot_fake_quant(&mut dt, 16);
                pot::pot_fake_quant(&mut abar, 16);
            }

            let ssm_off = r * ssm_len + li * nheads * headdim * d_state;
            let y = &mut y_all[r * d_inner..(r + 1) * d_inner];
            for h in 0..nheads {
                for p in 0..headdim {
                    let xv = dt[h] * xh[h * headdim + p];
                    let hrow = &mut ssm[ssm_off + (h * headdim + p) * d_state
                        ..ssm_off + (h * headdim + p + 1) * d_state];
                    let mut dot = 0.0f32;
                    for n in 0..d_state {
                        let hv = abar[h] * hrow[n] + xv * b_t[n];
                        hrow[n] = hv;
                        dot += hv * c_t[n];
                    }
                    y[h * headdim + p] = dot + lw.d[h] * xh[h * headdim + p];
                }
            }

            nonlinear::gated_rmsnorm(y, z, &lw.norm_g_w, 1e-5);
        }

        let pw_out = self.prepared.as_ref().map(|p| &p.out_proj[li]);
        let mut out = vec![0.0f32; b * d];
        self.linear_rows(&y_all, b, &lw.out_proj_w, d, d_inner, variant,
                         if variant.hadamard() { pw_out } else { None }, &mut out);
        for i in 0..b * d {
            x[i] += out[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> Mamba2 {
        let cfg = ModelConfig::tiny();
        Mamba2::new(ModelWeights::random(&cfg, 3))
    }

    fn toks(n: usize, seed: u64) -> Vec<u32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s % 512) as u32
            })
            .collect()
    }

    #[test]
    fn prefill_shapes_and_finite() {
        let m = tiny_model();
        let t = toks(12, 1);
        let (logits, state) = m.prefill(&t, Variant::Fp32);
        assert_eq!(logits.len(), 12 * 512);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(state.ssm.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn decode_matches_prefill_fp32() {
        let m = tiny_model();
        let t = toks(10, 2);
        let (logits_full, _) = m.prefill(&t, Variant::Fp32);
        let (_, mut state) = m.prefill(&t[..9], Variant::Fp32);
        let logits_step = m.decode_step(t[9], &mut state, Variant::Fp32);
        let last = &logits_full[9 * 512..];
        let mut max_err = 0.0f32;
        for (a, b) in logits_step.iter().zip(last) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 1e-3, "max err {max_err}");
    }

    #[test]
    fn pure_decode_chain_matches_prefill() {
        let m = tiny_model();
        let t = toks(6, 3);
        let (logits_full, _) = m.prefill(&t, Variant::Fp32);
        let mut state = DecodeState::zeros(&m.w.cfg);
        for (i, tok) in t.iter().enumerate() {
            let lg = m.decode_step(*tok, &mut state, Variant::Fp32);
            let want = &logits_full[i * 512..(i + 1) * 512];
            for (a, b) in lg.iter().zip(want) {
                assert!((a - b).abs() < 1e-3, "t={i}");
            }
        }
    }

    #[test]
    fn chunked_prefill_chains_exactly() {
        // chained chunks (incl. one shorter than the conv window) must
        // reproduce the one-shot prefill — the contract the Engine's
        // chunked admission and the NativeBackend rely on
        let m = tiny_model();
        let t = toks(23, 5);
        let (full, full_state) = m.prefill(&t, Variant::Fp32);
        let mut state = DecodeState::zeros(&m.w.cfg);
        let mut got = Vec::new();
        for chunk in [&t[..9], &t[9..11], &t[11..]] {
            got.extend(m.prefill_chunk(chunk, Variant::Fp32, &mut state));
        }
        assert_eq!(got.len(), full.len());
        let mut max_err = 0.0f32;
        for (a, b) in got.iter().zip(&full) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 1e-5, "chunked logits err {max_err}");
        let mut s_err = 0.0f32;
        for (a, b) in state.ssm.iter().zip(&full_state.ssm) {
            s_err = s_err.max((a - b).abs());
        }
        assert!(s_err < 1e-5, "chunked ssm state err {s_err}");
        // the conv window carries unquantized pre-conv rows — bit-exact
        assert_eq!(state.conv, full_state.conv);
    }

    #[test]
    fn chunked_prefill_then_decode_matches_full() {
        let m = tiny_model();
        let t = toks(14, 6);
        let (full, _) = m.prefill(&t, Variant::Fp32);
        let mut state = DecodeState::zeros(&m.w.cfg);
        let _ = m.prefill_chunk(&t[..10], Variant::Fp32, &mut state);
        for i in 10..14 {
            let lg = m.decode_step(t[i], &mut state, Variant::Fp32);
            let want = &full[i * 512..(i + 1) * 512];
            for (a, b) in lg.iter().zip(want) {
                assert!((a - b).abs() < 1e-3, "t={i}");
            }
        }
    }

    #[test]
    fn decode_batch_bit_identical_to_single_steps_all_variants() {
        // the batch-major step must reproduce B independent decode_step
        // calls bit-for-bit — logits AND advanced states — under every
        // variant (quantized activation scales stay per-sequence)
        let mut m = tiny_model();
        m.prepare();
        let cfg = m.w.cfg.clone();
        let (cl, sl) = {
            let s = DecodeState::zeros(&cfg);
            (s.conv.len(), s.ssm.len())
        };
        for v in Variant::ALL {
            let mut states: Vec<DecodeState> = Vec::new();
            let mut toks: Vec<u32> = Vec::new();
            for s in 0..3usize {
                let t = toks_seed(10 + s as u64);
                let (_, st) = m.prefill(&t, v);
                states.push(st);
                toks.push(t[t.len() - 1]);
            }
            let mut conv: Vec<f32> =
                states.iter().flat_map(|s| s.conv.iter().copied()).collect();
            let mut ssm: Vec<f32> =
                states.iter().flat_map(|s| s.ssm.iter().copied()).collect();
            let logits = m.decode_batch(&toks, v, &mut conv, &mut ssm);
            for (i, st) in states.iter_mut().enumerate() {
                let lg = m.decode_step(toks[i], st, v);
                assert_eq!(lg, logits[i * 512..(i + 1) * 512], "{v:?} seq {i} logits");
                assert_eq!(st.conv, conv[i * cl..(i + 1) * cl], "{v:?} seq {i} conv");
                assert_eq!(st.ssm, ssm[i * sl..(i + 1) * sl], "{v:?} seq {i} ssm");
            }
        }
    }

    fn toks_seed(seed: u64) -> Vec<u32> {
        toks(8, seed)
    }

    #[test]
    fn all_variants_finite_and_distinct() {
        let mut m = tiny_model();
        m.prepare();
        let t = toks(8, 4);
        let (fp, _) = m.prefill(&t, Variant::Fp32);
        for v in [Variant::NormalQ, Variant::SmoothQ, Variant::FastMambaLq,
                  Variant::FastMamba] {
            let (lg, _) = m.prefill(&t, v);
            assert!(lg.iter().all(|x| x.is_finite()), "{v:?}");
            let diff: f32 = lg.iter().zip(&fp).map(|(a, b)| (a - b).abs()).sum();
            assert!(diff > 0.0, "{v:?} identical to fp32");
            // and close: quantization, not corruption
            let rms_fp = (fp.iter().map(|v| v * v).sum::<f32>() / fp.len() as f32).sqrt();
            let rms_e = (lg.iter().zip(&fp).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
                / fp.len() as f32)
                .sqrt();
            assert!(rms_e < 0.5 * rms_fp, "{v:?} rel err {}", rms_e / rms_fp);
        }
    }

    #[test]
    fn hadamard_beats_normalq_with_outliers() {
        let cfg = ModelConfig::tiny();
        let mut w = ModelWeights::random(&cfg, 5);
        w.inject_outliers(10, 12.0, 6);
        let mut m = Mamba2::new(w);
        m.prepare();
        let t = toks(16, 7);
        let (fp, _) = m.prefill(&t, Variant::Fp32);
        let err = |v: Variant| -> f64 {
            let (lg, _) = m.prefill(&t, v);
            lg.iter().zip(&fp).map(|(a, b)| ((a - b) * (a - b)) as f64).sum::<f64>()
        };
        let e_norm = err(Variant::NormalQ);
        let e_lq = err(Variant::FastMambaLq);
        assert!(e_lq < e_norm, "hadamard {e_lq} vs normalq {e_norm}");
    }

    #[test]
    fn state_bytes_constant_in_seq_len() {
        let cfg = ModelConfig::tiny();
        // O(1) state: same size regardless of how long the prompt was
        let m = tiny_model();
        let (_, s1) = m.prefill(&toks(4, 8), Variant::Fp32);
        let (_, s2) = m.prefill(&toks(64, 8), Variant::Fp32);
        assert_eq!(s1.ssm.len(), s2.ssm.len());
        assert_eq!(s1.conv.len(), s2.conv.len());
        assert_eq!(DecodeState::nbytes(&cfg), 4 * (s1.ssm.len() + s1.conv.len()));
    }
}
