//! Analytical per-component operation counts for a Mamba2 forward pass —
//! the common workload model behind the CPU baseline, the GPU roofline
//! model (Fig. 1 / Fig. 9 / Table III), and the simulator's sanity checks.
//!
//! Counts are multiply-accumulates (MACs) for matmul-like ops and scalar
//! elementwise operations otherwise, per the runtime-breakdown methodology
//! of Fig. 1 (linear / conv / SSM / norm+SiLU).

use crate::config::ModelConfig;

/// Op counts for one forward pass, split by the paper's four components.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ComponentOps {
    /// Linear-layer MACs (in_proj, out_proj, lm head).
    pub linear_macs: f64,
    /// Convolution MACs.
    pub conv_macs: f64,
    /// SSM elementwise ops (state update + readout + dt/abar prep).
    pub ssm_ops: f64,
    /// Nonlinear function evaluations routed through the NAU (exp+softplus).
    pub nau_ops: f64,
    /// Floating-point norm + SiLU elementwise ops.
    pub norm_silu_ops: f64,
}

impl ComponentOps {
    pub fn total(&self) -> f64 {
        self.linear_macs + self.conv_macs + self.ssm_ops + self.nau_ops + self.norm_silu_ops
    }
}

/// Per-token op counts for one layer.
fn layer_ops_per_token(cfg: &ModelConfig) -> ComponentOps {
    let d = cfg.d_model as f64;
    let d_inner = cfg.d_inner() as f64;
    let d_state = cfg.d_state as f64;
    let conv_dim = cfg.conv_dim() as f64;
    let nheads = cfg.nheads() as f64;
    let k = cfg.d_conv as f64;
    let d_in_proj = cfg.d_in_proj() as f64;

    let linear_macs = d * d_in_proj + d_inner * d;
    let conv_macs = conv_dim * k;
    // state update: abar*h + (dt x) B over (nheads, headdim, d_state) = 2 MAC
    // readout: h·C (1 MAC); total ≈ 3 ops per state element + feedthrough
    let state_elems = nheads * cfg.headdim as f64 * d_state;
    let ssm_ops = 3.0 * state_elems + 2.0 * d_inner;
    let nau_ops = 2.0 * nheads; // softplus(dt) + exp(dt*a)
    let norm_silu_ops = 2.0 * d + 3.0 * d_inner + conv_dim; // norms + silu + gate
    ComponentOps { linear_macs, conv_macs, ssm_ops, nau_ops, norm_silu_ops }
}

/// Ops for a prefill over `seq_len` tokens (whole model incl. lm head).
pub fn prefill_ops(cfg: &ModelConfig, seq_len: usize) -> ComponentOps {
    let per_tok = layer_ops_per_token(cfg);
    let l = seq_len as f64;
    let n = cfg.n_layer as f64;
    ComponentOps {
        linear_macs: l * (n * per_tok.linear_macs
            + cfg.vocab_size as f64 * cfg.d_model as f64),
        conv_macs: l * n * per_tok.conv_macs,
        ssm_ops: l * n * per_tok.ssm_ops,
        nau_ops: l * n * per_tok.nau_ops,
        norm_silu_ops: l * n * per_tok.norm_silu_ops + l * cfg.d_model as f64,
    }
}

/// Ops for one decode step (single token, whole model incl. lm head).
pub fn decode_ops(cfg: &ModelConfig) -> ComponentOps {
    prefill_ops(cfg, 1)
}

/// Weight bytes touched by one decode step (every weight read once) — the
/// quantity that makes GPU decode bandwidth-bound.
pub fn decode_weight_bytes(cfg: &ModelConfig, bytes_per_weight: f64) -> f64 {
    cfg.n_params() as f64 * bytes_per_weight
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_dominates_at_short_seq() {
        // Fig. 1: at L=64 the linear layer is the largest component.
        let cfg = ModelConfig::mamba2_130m();
        let ops = prefill_ops(&cfg, 64);
        assert!(ops.linear_macs > ops.ssm_ops);
        assert!(ops.linear_macs > ops.conv_macs);
    }

    #[test]
    fn ops_scale_linearly_with_seq() {
        let cfg = ModelConfig::mamba2_130m();
        let a = prefill_ops(&cfg, 128);
        let b = prefill_ops(&cfg, 256);
        assert!((b.total() / a.total() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ssm_share_grows_with_state() {
        // larger d_state shifts work into the SSM block
        let mut cfg = ModelConfig::mamba2_130m();
        let base = prefill_ops(&cfg, 128);
        cfg.d_state *= 2;
        let big = prefill_ops(&cfg, 128);
        assert!(big.ssm_ops / big.total() > base.ssm_ops / base.total());
    }

    #[test]
    fn decode_bytes_dominated_by_params() {
        let cfg = ModelConfig::mamba2_2_7b();
        let b = decode_weight_bytes(&cfg, 2.0); // fp16
        assert!(b > 4e9 && b < 8e9, "{b}"); // ~2.7B params * 2B
    }

    #[test]
    fn nau_ops_count() {
        let cfg = ModelConfig::mamba2_130m();
        let ops = decode_ops(&cfg);
        // 24 layers * 24 heads * 2 evaluations
        assert_eq!(ops.nau_ops as u64, 24 * 24 * 2);
    }
}
