//! Native Mamba2 implementation — the fp32 golden model, the measured CPU
//! baseline, and the quantization-variant evaluator behind Table II.
//!
//! [`weights`] loads the build-time-trained tiny checkpoint from
//! `artifacts/`; [`mamba2`] runs prefill/decode under any of the paper's
//! five quantization variants; [`flops`] is the analytical op-count model
//! shared by the CPU/GPU baselines and the simulator.

pub mod flops;
pub mod mamba2;
pub mod weights;

pub use mamba2::{Mamba2, Variant};
pub use weights::ModelWeights;
