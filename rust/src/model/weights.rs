//! Weight loading: the `artifacts/` checkpoint written by the AOT pipeline
//! (manifest order == `mamba2.flatten_params` order), plus deterministic
//! synthetic weights for the large paper configurations we cannot download.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Per-layer parameter tensors (row-major).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub norm_w: Vec<f32>,     // (d_model,)
    pub in_proj_w: Vec<f32>,  // (d_in_proj, d_model)
    pub conv_w: Vec<f32>,     // (conv_dim, d_conv)
    pub conv_b: Vec<f32>,     // (conv_dim,)
    pub dt_bias: Vec<f32>,    // (nheads,)
    pub a_log: Vec<f32>,      // (nheads,)
    pub d: Vec<f32>,          // (nheads,)
    pub norm_g_w: Vec<f32>,   // (d_inner,)
    pub out_proj_w: Vec<f32>, // (d_model, d_inner)
}

/// Full model checkpoint (tied embedding).
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub cfg: ModelConfig,
    pub embed: Vec<f32>, // (vocab, d_model)
    pub norm_f_w: Vec<f32>,
    pub layers: Vec<LayerWeights>,
}

/// One parameter entry from the manifest.
#[derive(Debug, Clone)]
pub struct ManifestParam {
    pub index: usize,
    pub name: String,
    pub shape: Vec<usize>,
    pub file: String,
}

/// One lowered-graph entry from the manifest.
#[derive(Debug, Clone)]
pub struct ManifestArtifact {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub variant: Option<String>,
    pub seq_len: Option<usize>,
    pub batch: Option<usize>,
    pub n_params: Option<usize>,
    /// number of prepared-weight inputs (Hadamard variants; 0 for fp32)
    pub n_prepared: usize,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub serve_config: String,
    pub prefill_lens: Vec<usize>,
    pub decode_batches: Vec<usize>,
    pub variants: Vec<String>,
    pub params: Vec<ManifestParam>,
    pub artifacts: Vec<ManifestArtifact>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let text = fs::read_to_string(artifacts_dir.join("manifest.json"))
            .map_err(|e| anyhow!("manifest.json missing (run `make artifacts`): {e}"))?;
        let v = Json::parse(&text)?;
        let usizes = |arr: &[Json]| -> Vec<usize> {
            arr.iter().filter_map(Json::as_usize).collect()
        };
        let params = v
            .arr_field("params")?
            .iter()
            .map(|p| {
                Ok(ManifestParam {
                    index: p.usize_field("index")?,
                    name: p.str_field("name")?.to_string(),
                    shape: usizes(p.arr_field("shape")?),
                    file: p.str_field("file")?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let artifacts = v
            .arr_field("artifacts")?
            .iter()
            .map(|a| {
                Ok(ManifestArtifact {
                    name: a.str_field("name")?.to_string(),
                    file: a.str_field("file")?.to_string(),
                    kind: a.str_field("kind")?.to_string(),
                    variant: a.get("variant").and_then(Json::as_str).map(String::from),
                    seq_len: a.get("seq_len").and_then(Json::as_usize),
                    batch: a.get("batch").and_then(Json::as_usize),
                    n_params: a.get("n_params").and_then(Json::as_usize),
                    n_prepared: a.get("n_prepared").and_then(Json::as_usize).unwrap_or(0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            serve_config: v.str_field("serve_config")?.to_string(),
            prefill_lens: usizes(v.arr_field("prefill_lens")?),
            decode_batches: usizes(v.arr_field("decode_batches")?),
            variants: v
                .arr_field("variants")?
                .iter()
                .filter_map(Json::as_str)
                .map(String::from)
                .collect(),
            params,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ManifestArtifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

fn read_f32_file(path: &Path, expect: usize) -> Result<Vec<f32>> {
    let bytes = fs::read(path)?;
    ensure!(
        bytes.len() == expect * 4,
        "{}: expected {} f32s, file has {} bytes",
        path.display(),
        expect,
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

impl ModelWeights {
    /// Load the trained tiny checkpoint from `artifacts/`.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let cfg = ModelConfig::by_name(&manifest.serve_config)
            .ok_or_else(|| anyhow!("unknown config {}", manifest.serve_config))?;

        let get = |name: &str| -> Result<Vec<f32>> {
            let p = manifest
                .params
                .iter()
                .find(|p| p.name == name)
                .ok_or_else(|| anyhow!("param {name} not in manifest"))?;
            let n: usize = p.shape.iter().product::<usize>().max(1);
            read_f32_file(&artifacts_dir.join(&p.file), n)
        };

        let mut layers = Vec::with_capacity(cfg.n_layer);
        for i in 0..cfg.n_layer {
            layers.push(LayerWeights {
                norm_w: get(&format!("layers.{i}.norm_w"))?,
                in_proj_w: get(&format!("layers.{i}.in_proj_w"))?,
                conv_w: get(&format!("layers.{i}.conv_w"))?,
                conv_b: get(&format!("layers.{i}.conv_b"))?,
                dt_bias: get(&format!("layers.{i}.dt_bias"))?,
                a_log: get(&format!("layers.{i}.a_log"))?,
                d: get(&format!("layers.{i}.d"))?,
                norm_g_w: get(&format!("layers.{i}.norm_g_w"))?,
                out_proj_w: get(&format!("layers.{i}.out_proj_w"))?,
            });
        }
        Ok(Self {
            embed: get("embed")?,
            norm_f_w: get("norm_f_w")?,
            layers,
            cfg,
        })
    }

    /// Flat parameter list in manifest order (what the PJRT executables take).
    pub fn flat(&self) -> Vec<(&'static str, &[f32])> {
        let mut out: Vec<(&'static str, &[f32])> =
            vec![("embed", &self.embed), ("norm_f_w", &self.norm_f_w)];
        for lw in &self.layers {
            out.push(("norm_w", &lw.norm_w));
            out.push(("in_proj_w", &lw.in_proj_w));
            out.push(("conv_w", &lw.conv_w));
            out.push(("conv_b", &lw.conv_b));
            out.push(("dt_bias", &lw.dt_bias));
            out.push(("a_log", &lw.a_log));
            out.push(("d", &lw.d));
            out.push(("norm_g_w", &lw.norm_g_w));
            out.push(("out_proj_w", &lw.out_proj_w));
        }
        out
    }

    /// Deterministic synthetic weights with Mamba2's init statistics — used
    /// for the 130M-dimension benchmarks where no checkpoint exists.
    pub fn random(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::with_capacity(cfg.n_layer);
        for _ in 0..cfg.n_layer {
            let dt: Vec<f32> = (0..cfg.nheads())
                .map(|_| rng.range_f64((1e-3f64).ln(), (1e-1f64).ln()).exp() as f32)
                .collect();
            layers.push(LayerWeights {
                norm_w: vec![1.0; cfg.d_model],
                in_proj_w: rng.normal_vec(cfg.d_in_proj() * cfg.d_model, 0.02),
                conv_w: rng.normal_vec(cfg.conv_dim() * cfg.d_conv, 0.3),
                conv_b: vec![0.0; cfg.conv_dim()],
                dt_bias: dt.iter().map(|d| d + (-(-d).exp_m1()).ln()).collect(),
                a_log: (0..cfg.nheads())
                    .map(|_| (rng.range_f64(1.0, 16.0) as f32).ln())
                    .collect(),
                d: vec![1.0; cfg.nheads()],
                norm_g_w: vec![1.0; cfg.d_inner()],
                out_proj_w: rng.normal_vec(cfg.d_model * cfg.d_inner(), 0.02),
            });
        }
        Self {
            embed: rng.normal_vec(cfg.vocab_size * cfg.d_model, 0.02),
            norm_f_w: vec![1.0; cfg.d_model],
            layers,
            cfg: cfg.clone(),
        }
    }

    /// Inject per-channel activation outliers (scale RMSNorm gains) — the
    /// Fig. 3 heavy-tail generator used by synthetic accuracy experiments.
    pub fn inject_outliers(&mut self, n_channels: usize, gain: f32, seed: u64) {
        let mut rng = Rng::new(seed);
        for lw in &mut self.layers {
            for _ in 0..n_channels {
                let idx = rng.below(lw.norm_w.len());
                lw.norm_w[idx] *= gain;
            }
        }
    }
}

/// Default artifacts directory (repo root), overridable via FASTMAMBA_ARTIFACTS.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("FASTMAMBA_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Look upward from CWD for an `artifacts/manifest.json`.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_weights_have_right_shapes() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(&cfg, 0);
        assert_eq!(w.embed.len(), cfg.vocab_size * cfg.d_model);
        assert_eq!(w.layers.len(), cfg.n_layer);
        let lw = &w.layers[0];
        assert_eq!(lw.in_proj_w.len(), cfg.d_in_proj() * cfg.d_model);
        assert_eq!(lw.conv_w.len(), cfg.conv_dim() * cfg.d_conv);
        assert_eq!(lw.out_proj_w.len(), cfg.d_model * cfg.d_inner());
    }

    #[test]
    fn random_weights_deterministic() {
        let cfg = ModelConfig::tiny();
        let a = ModelWeights::random(&cfg, 7);
        let b = ModelWeights::random(&cfg, 7);
        assert_eq!(a.embed, b.embed);
        assert_eq!(a.layers[1].in_proj_w, b.layers[1].in_proj_w);
    }

    #[test]
    fn flat_order_matches_python_contract() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(&cfg, 0);
        let flat = w.flat();
        assert_eq!(flat.len(), 2 + 9 * cfg.n_layer);
        assert_eq!(flat[0].0, "embed");
        assert_eq!(flat[2].0, "norm_w");
        assert_eq!(flat[10].0, "out_proj_w");
    }

    #[test]
    fn outlier_injection_changes_gains() {
        let cfg = ModelConfig::tiny();
        let mut w = ModelWeights::random(&cfg, 0);
        w.inject_outliers(4, 8.0, 1);
        let big = w.layers[0].norm_w.iter().filter(|v| **v > 4.0).count();
        assert!(big >= 1);
    }

    #[test]
    fn loads_artifacts_checkpoint_if_present() {
        let dir = artifacts_dir();
        if dir.join("manifest.json").exists() {
            let w = ModelWeights::load(&dir).expect("load failed");
            assert_eq!(w.cfg.name, "mamba2-tiny");
            let s: f32 = w.layers[0].in_proj_w.iter().map(|v| v.abs()).sum();
            assert!(s > 0.0);
        }
    }

    #[test]
    fn manifest_artifact_lookup_if_present() {
        let dir = artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifact("mamba2-tiny_decode_fp32_B1").is_some());
            assert!(m.artifact("missing").is_none());
            assert_eq!(m.params.len(), 2 + 9 * 4);
        }
    }
}
