//! Baseline platforms for Fig. 1 / Fig. 9 / Table III: a *measured* CPU
//! baseline (this host running the golden model, calibrated to the paper's
//! Xeon 4210R) and an *analytical* GPU model (roofline + kernel-launch
//! overhead, calibrated to the paper's RTX 3090 observations).

pub mod cpu;
pub mod gpu_model;

pub use cpu::CpuBaseline;
pub use gpu_model::GpuModel;
