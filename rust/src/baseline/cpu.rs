//! Measured CPU baseline.
//!
//! The paper's CPU column is an Intel Xeon Silver 4210R running fp32 Mamba2.
//! We measure *this* host's single-thread throughput on the same algorithm
//! (the golden model), derive per-op rates, and compose them with the
//! analytical op counts to predict prefill/decode times at any model size —
//! then optionally rescale to the 4210R's published class so the Fig. 9
//! ratios are comparable.  Both raw-measured and calibrated numbers are
//! reported; EXPERIMENTS.md records which is which.

use std::time::Instant;

use crate::config::ModelConfig;
use crate::model::flops::{prefill_ops, ComponentOps};
use crate::model::{Mamba2, ModelWeights, Variant};

/// Measured per-op-class rates (ops/second, single thread).
#[derive(Debug, Clone)]
pub struct CpuCalibration {
    pub matmul_macs_per_s: f64,
    pub elem_ops_per_s: f64,
}

/// Ratio of the paper's Xeon 4210R running the torch reference
/// implementation to our naive single-thread loops.  The paper's CPU
/// numbers imply an effective rate of only a few GFLOP/s — the sequential
/// SSM scan and framework dispatch dominate, far below MKL GEMM peak — so
/// the calibration is pinned to the paper's reported FPGA/CPU ratio class
/// (avg 55.7x), not to the chip's datasheet.  Documented in EXPERIMENTS.md.
pub const XEON_4210R_SCALE: f64 = 10.0;

pub struct CpuBaseline {
    pub cal: CpuCalibration,
}

impl CpuBaseline {
    /// Micro-benchmark this host (≈150 ms).
    pub fn measure() -> Self {
        // matmul rate: 256x512x512 fp32 naive
        let (l, d, q) = (64usize, 512usize, 512usize);
        let x = vec![1.001f32; l * d];
        let w = vec![0.999f32; q * d];
        let mut y = vec![0.0f32; l * q];
        let t0 = Instant::now();
        let mut reps = 0u64;
        while t0.elapsed().as_secs_f64() < 0.08 {
            for r in 0..l {
                for j in 0..q {
                    let mut acc = 0.0f32;
                    let xr = &x[r * d..(r + 1) * d];
                    let wr = &w[j * d..(j + 1) * d];
                    for k in 0..d {
                        acc += xr[k] * wr[k];
                    }
                    y[r * q + j] = acc;
                }
            }
            reps += 1;
        }
        std::hint::black_box(&y);
        let matmul_macs_per_s =
            (reps as f64 * (l * d * q) as f64) / t0.elapsed().as_secs_f64();

        // elementwise rate (mul-add chains)
        let mut v = vec![1.0f32; 1 << 16];
        let t1 = Instant::now();
        let mut reps2 = 0u64;
        while t1.elapsed().as_secs_f64() < 0.04 {
            for x in v.iter_mut() {
                *x = *x * 0.9999 + 1e-4;
            }
            reps2 += 1;
        }
        std::hint::black_box(&v);
        let elem_ops_per_s = (reps2 as f64 * v.len() as f64) / t1.elapsed().as_secs_f64();

        Self { cal: CpuCalibration { matmul_macs_per_s, elem_ops_per_s } }
    }

    /// Predicted prefill seconds from op counts (this host, single thread).
    pub fn prefill_seconds(&self, cfg: &ModelConfig, seq_len: usize) -> f64 {
        let ops = prefill_ops(cfg, seq_len);
        self.seconds(&ops)
    }

    fn seconds(&self, ops: &ComponentOps) -> f64 {
        (ops.linear_macs + ops.conv_macs) / self.cal.matmul_macs_per_s
            + (ops.ssm_ops + ops.nau_ops + ops.norm_silu_ops) / self.cal.elem_ops_per_s
    }

    /// Same, rescaled to the paper's Xeon class.
    pub fn prefill_seconds_calibrated(&self, cfg: &ModelConfig, seq_len: usize) -> f64 {
        self.prefill_seconds(cfg, seq_len) / XEON_4210R_SCALE
    }

    /// Directly measure an actual prefill on the golden model (tiny/small
    /// configs only — used to validate the composed prediction).
    pub fn measure_prefill(w: &ModelWeights, seq_len: usize) -> f64 {
        let m = Mamba2::new(w.clone());
        let tokens: Vec<u32> = (0..seq_len as u32)
            .map(|i| i % w.cfg.vocab_size as u32)
            .collect();
        let t0 = Instant::now();
        let (lg, _) = m.prefill(&tokens, Variant::Fp32);
        std::hint::black_box(&lg);
        t0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_rates_sane() {
        let b = CpuBaseline::measure();
        assert!(b.cal.matmul_macs_per_s > 1e7, "{}", b.cal.matmul_macs_per_s);
        assert!(b.cal.elem_ops_per_s > 1e7);
    }

    #[test]
    fn prediction_tracks_measurement_on_tiny() {
        let b = CpuBaseline::measure();
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(&cfg, 1);
        let measured = CpuBaseline::measure_prefill(&w, 64);
        let predicted = b.prefill_seconds(&cfg, 64);
        let ratio = measured / predicted;
        // composed model within ~4x of reality (loop overheads differ by op)
        assert!(ratio > 0.25 && ratio < 4.0, "measured {measured} predicted {predicted}");
    }

    #[test]
    fn prefill_scales_with_seq() {
        let b = CpuBaseline::measure();
        let cfg = ModelConfig::mamba2_130m();
        let a = b.prefill_seconds(&cfg, 128);
        let c = b.prefill_seconds(&cfg, 512);
        assert!((c / a - 4.0).abs() < 0.2);
    }
}
