//! Analytical RTX 3090 model — roofline (FP16 tensor-core FLOPs, HBM
//! bandwidth) plus per-kernel launch overhead.
//!
//! The launch-overhead term is what the paper's Fig. 1 measures indirectly:
//! Mamba2's SSM block executes many small elementwise kernels per layer, so
//! at small batch/model sizes the GPU is launch-bound and its runtime share
//! of SSM *grows* with sequence length (chunked scan => more kernels).
//! Constants are calibrated against the two absolute observations the paper
//! reports: 111 token/s decode on Mamba2-2.7B (Table III) and the Fig. 1
//! breakdown trend.

use crate::config::ModelConfig;
use crate::model::flops::{decode_weight_bytes, prefill_ops};

/// RTX 3090 datasheet / calibrated constants.
#[derive(Debug, Clone)]
pub struct GpuModel {
    /// effective FP16 GEMM throughput at L=64, FLOP/s — batch-1 GEMMs on
    /// small models reach only ~1-2 TFLOP/s; efficiency grows with the row
    /// count (see `gemm_flops_at`)
    pub eff_flops: f64,
    /// GEMM efficiency growth cap (x over eff_flops at long L)
    pub gemm_growth_cap: f64,
    /// effective HBM bandwidth for large streaming reads (weight loads), B/s
    pub eff_bw: f64,
    /// achieved bandwidth of the SSM block's small, strided elementwise
    /// tensors at batch 1 and L=64 (a few % of peak — these ops are
    /// latency/occupancy-bound in the reference implementation), B/s
    pub ssm_elem_bw_base: f64,
    /// bandwidth utilization improves as tensors grow with L (per octave)
    pub ssm_bw_growth_per_octave: f64,
    /// per-kernel launch + dispatch overhead, seconds
    pub launch_s: f64,
    /// elementwise kernels per layer in the SSM block (chunked scan path)
    pub ssm_kernels_per_layer: f64,
    /// other kernels per layer (linears, conv, norms, glue)
    pub misc_kernels_per_layer: f64,
    /// SSD chunk length used by the reference GPU implementation
    pub chunk_len: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        Self {
            // 3090 peak FP16 w/ FP32 acc ≈ 71 TFLOP/s; small-model GEMMs at
            // L≤2k reach a few % .. tens of % of peak.
            eff_flops: 1.2e12,
            gemm_growth_cap: 8.0,
            eff_bw: 824e9, // 936 GB/s peak, ~88% achievable on streaming reads
            ssm_elem_bw_base: 66e9, // ~7% of peak on tiny strided tensors
            ssm_bw_growth_per_octave: 0.0,
            launch_s: 4.0e-6,
            ssm_kernels_per_layer: 18.0,
            misc_kernels_per_layer: 12.0,
            chunk_len: 64.0,
        }
    }
}

/// Per-component GPU prefill seconds (the Fig. 1 bars).
#[derive(Debug, Clone, Default)]
pub struct GpuBreakdown {
    pub linear_s: f64,
    pub conv_s: f64,
    pub ssm_s: f64,
    pub norm_silu_s: f64,
}

impl GpuBreakdown {
    pub fn total(&self) -> f64 {
        self.linear_s + self.conv_s + self.ssm_s + self.norm_silu_s
    }

    pub fn fractions(&self) -> [(&'static str, f64); 4] {
        let t = self.total().max(1e-30);
        [
            ("linear", self.linear_s / t),
            ("conv", self.conv_s / t),
            ("ssm", self.ssm_s / t),
            ("norm_silu", self.norm_silu_s / t),
        ]
    }
}

impl GpuModel {
    /// Prefill latency breakdown for `(cfg, seq_len)` at batch 1.
    pub fn prefill_breakdown(&self, cfg: &ModelConfig, seq_len: usize) -> GpuBreakdown {
        let ops = prefill_ops(cfg, seq_len);
        let nl = cfg.n_layer as f64;
        let l = seq_len as f64;

        // GEMMs: compute-bound term + launch overhead (2 linears/layer);
        // batch-1 GEMM efficiency grows with the token count
        let gemm_flops = self.eff_flops * (l / 64.0).clamp(1.0, self.gemm_growth_cap);
        let linear_s = 2.0 * ops.linear_macs / gemm_flops + nl * 2.0 * self.launch_s;
        // conv: tiny compute, one kernel per layer
        let conv_s = 2.0 * ops.conv_macs / gemm_flops + nl * self.launch_s;
        // SSM: small strided elementwise tensors run at a few % of peak
        // bandwidth at batch 1 (calibrated to the paper's Fig. 1 / Fig. 9
        // observations); utilization improves as tensors grow with L.
        let chunks = (l / self.chunk_len).ceil().max(1.0);
        let octaves = (l / 64.0).max(1.0).log2();
        let ssm_bw = self.ssm_elem_bw_base * (1.0 + self.ssm_bw_growth_per_octave * octaves);
        let ssm_bytes = ops.ssm_ops * 3.0 * 2.0; // ~3 tensor touches, fp16
        let ssm_s = ssm_bytes / ssm_bw
            + nl * self.ssm_kernels_per_layer * self.launch_s * chunks.min(16.0);
        let norm_bytes = ops.norm_silu_ops * 2.0 * 2.0;
        let norm_silu_s = norm_bytes / self.eff_bw
            + nl * self.misc_kernels_per_layer * self.launch_s;
        GpuBreakdown { linear_s, conv_s, ssm_s, norm_silu_s }
    }

    pub fn prefill_seconds(&self, cfg: &ModelConfig, seq_len: usize) -> f64 {
        self.prefill_breakdown(cfg, seq_len).total()
    }

    /// Decode throughput at batch 1: bandwidth-bound weight streaming +
    /// per-step kernel launches.  The decode path uses the fused recurrent
    /// step (far fewer kernels than the chunked prefill scan).
    pub fn decode_tokens_per_s(&self, cfg: &ModelConfig) -> f64 {
        let bytes = decode_weight_bytes(cfg, 2.0); // fp16 weights
        let t_bw = bytes / self.eff_bw;
        let decode_kernels_per_layer = 8.0;
        let t_launch = cfg.n_layer as f64 * decode_kernels_per_layer * self.launch_s;
        1.0 / (t_bw + t_launch)
    }

    /// RTX 3090 board power under LLM decode (Table III uses ~300 W class).
    pub fn decode_power_w(&self) -> f64 {
        300.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_2_7b_near_paper_111_toks() {
        let g = GpuModel::default();
        let t = g.decode_tokens_per_s(&ModelConfig::mamba2_2_7b());
        assert!(t > 80.0 && t < 150.0, "GPU 2.7B decode {t} tok/s (paper: 111)");
    }

    #[test]
    fn fig1_ssm_share_grows_with_seq_len() {
        let g = GpuModel::default();
        let cfg = ModelConfig::mamba2_130m();
        let short = g.prefill_breakdown(&cfg, 64);
        let long = g.prefill_breakdown(&cfg, 2048);
        let f_short = short.ssm_s / short.total();
        let f_long = long.ssm_s / long.total();
        assert!(f_long > f_short, "SSM share {f_short} -> {f_long}");
    }

    #[test]
    fn fig1_ssm_and_linear_dominate() {
        let g = GpuModel::default();
        let cfg = ModelConfig::mamba2_130m();
        let b = g.prefill_breakdown(&cfg, 512);
        let major = (b.ssm_s + b.linear_s) / b.total();
        assert!(major > 0.7, "{major}");
    }

    #[test]
    fn prefill_grows_with_seq() {
        let g = GpuModel::default();
        let cfg = ModelConfig::mamba2_130m();
        assert!(g.prefill_seconds(&cfg, 1024) > g.prefill_seconds(&cfg, 128));
    }

    #[test]
    fn decode_efficiency_near_table3() {
        // Table III: 0.37 token/(s·W) on the GPU
        let g = GpuModel::default();
        let eff = g.decode_tokens_per_s(&ModelConfig::mamba2_2_7b()) / g.decode_power_w();
        assert!(eff > 0.25 && eff < 0.55, "{eff}");
    }
}
