//! Symmetric int8 helpers shared by every W8A8 quantizer, plus the NormalQ
//! and SmoothQuant baselines of Table II.

use super::round_ties_even;

/// Tensor absolute maximum (`FindScale` numerator in Algorithm 1).
pub fn absmax(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// Symmetric round-to-nearest-even int8 quantization into `out`.
pub fn quantize_int8_into(x: &[f32], scale: f32, out: &mut [i8]) {
    debug_assert_eq!(x.len(), out.len());
    let inv = 1.0 / scale;
    for (o, v) in out.iter_mut().zip(x) {
        *o = round_ties_even(v * inv).clamp(-128.0, 127.0) as i8;
    }
}

/// NormalQ (Table II): plain per-tensor absmax W8A8 matmul, no outlier
/// handling.  `x` is `(rows, d)`, `w` is `(q, d)`; returns `(rows, q)`.
pub fn normalq_linear(
    x: &[f32],
    rows: usize,
    w: &[f32],
    q: usize,
    d: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    let s_x = absmax(x).max(1e-8) / 127.0;
    let s_w = absmax(w).max(1e-8) / 127.0;
    let mut xq = vec![0i8; x.len()];
    let mut wq = vec![0i8; w.len()];
    quantize_int8_into(x, s_x, &mut xq);
    quantize_int8_into(w, s_w, &mut wq);
    let dq = s_x * s_w;
    for r in 0..rows {
        for j in 0..q {
            let mut acc: i32 = 0;
            for k in 0..d {
                acc += xq[r * d + k] as i32 * wq[j * d + k] as i32;
            }
            out[r * q + j] = acc as f32 * dq + bias.map_or(0.0, |b| b[j]);
        }
    }
}

/// SmoothQuant (Table II): per-input-channel rebalancing
/// `s_j = max|X_j|^alpha / max|W_j|^(1-alpha)` then per-tensor W8A8.
pub fn smoothq_linear(
    x: &[f32],
    rows: usize,
    w: &[f32],
    q: usize,
    d: usize,
    bias: Option<&[f32]>,
    alpha: f32,
    out: &mut [f32],
) {
    // per-channel absmax of activations and weights
    let mut xa = vec![1e-5f32; d];
    for r in 0..rows {
        for k in 0..d {
            xa[k] = xa[k].max(x[r * d + k].abs());
        }
    }
    let mut wa = vec![1e-5f32; d];
    for j in 0..q {
        for k in 0..d {
            wa[k] = wa[k].max(w[j * d + k].abs());
        }
    }
    let s: Vec<f32> = xa
        .iter()
        .zip(&wa)
        .map(|(a, b)| (a.powf(alpha) / b.powf(1.0 - alpha)).clamp(1e-5, 1e5))
        .collect();

    let mut xs = vec![0.0f32; x.len()];
    for r in 0..rows {
        for k in 0..d {
            xs[r * d + k] = x[r * d + k] / s[k];
        }
    }
    let mut ws = vec![0.0f32; w.len()];
    for j in 0..q {
        for k in 0..d {
            ws[j * d + k] = w[j * d + k] * s[k];
        }
    }
    normalq_linear(&xs, rows, &ws, q, d, bias, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(6364136223846793005).max(1);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn quantize_saturates() {
        let x = vec![1000.0f32, -1000.0, 0.0];
        let mut q = vec![0i8; 3];
        quantize_int8_into(&x, 1.0, &mut q);
        assert_eq!(q, vec![127, -128, 0]);
    }

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let x = rand_vec(1000, 1);
        let s = absmax(&x) / 127.0;
        let mut q = vec![0i8; 1000];
        quantize_int8_into(&x, s, &mut q);
        for (v, qi) in x.iter().zip(&q) {
            assert!((*qi as f32 * s - v).abs() <= s / 2.0 + 1e-7);
        }
    }

    #[test]
    fn normalq_close_without_outliers() {
        let (rows, d, q) = (8, 64, 16);
        let x = rand_vec(rows * d, 2);
        let w = rand_vec(q * d, 3);
        let mut y = vec![0.0f32; rows * q];
        normalq_linear(&x, rows, &w, q, d, None, &mut y);
        let mut rel: f32 = 0.0;
        for r in 0..rows {
            for j in 0..q {
                let fp: f32 = (0..d).map(|k| x[r * d + k] * w[j * d + k]).sum();
                rel = rel.max((y[r * q + j] - fp).abs());
            }
        }
        assert!(rel < 0.5, "abs err {rel}");
    }

    #[test]
    fn smoothq_beats_normalq_with_outliers() {
        let (rows, d, q) = (16, 64, 16);
        let mut x = rand_vec(rows * d, 4);
        for r in 0..rows {
            x[r * d + 9] *= 60.0;
        }
        let w = rand_vec(q * d, 5);
        let mut yn = vec![0.0f32; rows * q];
        let mut ys = vec![0.0f32; rows * q];
        normalq_linear(&x, rows, &w, q, d, None, &mut yn);
        smoothq_linear(&x, rows, &w, q, d, None, 0.5, &mut ys);
        let (mut en, mut es) = (0.0f64, 0.0f64);
        for r in 0..rows {
            for j in 0..q {
                let fp: f32 = (0..d).map(|k| x[r * d + k] * w[j * d + k]).sum();
                en += (yn[r * q + j] - fp).abs() as f64;
                es += (ys[r * q + j] - fp).abs() as f64;
            }
        }
        assert!(es < en, "smooth {es} normal {en}");
    }
}
