//! Hadamard transform and Algorithm 1 (Hadamard-based linear quantization).
//!
//! The transform "evenly disperses the outliers of activation values and
//! weights across channels" (paper §III-A / Fig. 3), which is what makes
//! 8-bit symmetric quantization of the linear layers accurate.

use super::int8::{absmax, quantize_int8_into};

/// Sylvester-construction Hadamard matrix of order `n = 2^k`, entries ±1.
/// (`FindHadamard` in Algorithm 1.)
pub fn hadamard_matrix(n: usize) -> Vec<f32> {
    assert!(n.is_power_of_two() && n >= 1, "order must be 2^k, got {n}");
    let mut h = vec![1.0f32];
    let mut m = 1;
    while m < n {
        let mut next = vec![0.0f32; 4 * m * m];
        for r in 0..m {
            for c in 0..m {
                let v = h[r * m + c];
                next[r * 2 * m + c] = v;
                next[r * 2 * m + (c + m)] = v;
                next[(r + m) * 2 * m + c] = v;
                next[(r + m) * 2 * m + (c + m)] = -v;
            }
        }
        h = next;
        m *= 2;
    }
    h
}

/// In-place fast Walsh–Hadamard transform of a `group`-length slice
/// (natural/Sylvester order, unnormalized — matches `x @ H`).
///
/// This is the butterfly network the 4 parallel HAT adder trees implement:
/// log2(group) add/sub stages, no multipliers.
pub fn fwht_inplace(x: &mut [f32]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// Blocked Hadamard transform along the last axis of a row-major `(rows, d)`
/// matrix: each `group`-wide slice is transformed independently (line 5 of
/// Algorithm 1 with m = d/group groups).
pub fn hadamard_transform(x: &[f32], rows: usize, d: usize, group: usize) -> Vec<f32> {
    assert_eq!(x.len(), rows * d);
    assert_eq!(d % group, 0, "dim {d} not divisible by group {group}");
    let mut out = x.to_vec();
    for r in 0..rows {
        for g in 0..d / group {
            let s = r * d + g * group;
            fwht_inplace(&mut out[s..s + group]);
        }
    }
    out
}

/// Offline-prepared Hadamard-domain int8 weight (Algorithm 1 lines 6, 8, 11).
#[derive(Debug, Clone)]
pub struct PreparedWeight {
    /// int8 W_H, stored transposed as (d, q) for the activation product.
    pub w_q_t: Vec<i8>,
    pub d: usize,
    pub q: usize,
    pub scale: f32,
    pub group: usize,
}

/// Transform + quantize a `(q, d)` weight matrix (output-major, y = x W^T).
pub fn prepare_weight(w: &[f32], q: usize, d: usize, group: usize) -> PreparedWeight {
    let w_h = hadamard_transform(w, q, d, group);
    let scale = absmax(&w_h).max(1e-8) / 127.0;
    let mut wq = vec![0i8; q * d];
    quantize_int8_into(&w_h, scale, &mut wq);
    // transpose (q, d) -> (d, q)
    let mut w_q_t = vec![0i8; d * q];
    for r in 0..q {
        for c in 0..d {
            w_q_t[c * q + r] = wq[r * d + c];
        }
    }
    PreparedWeight { w_q_t, d, q, scale, group }
}

/// Full Algorithm 1 forward: `y = x @ w^T` with W8A8 Hadamard quantization.
/// `x` is `(rows, d)` row-major; returns `(rows, q)`.
pub fn hadamard_linear(
    x: &[f32],
    rows: usize,
    pw: &PreparedWeight,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    let (d, q, group) = (pw.d, pw.q, pw.group);
    assert_eq!(x.len(), rows * d);
    assert_eq!(out.len(), rows * q);
    let x_h = hadamard_transform(x, rows, d, group);
    let s_x = absmax(&x_h).max(1e-8) / 127.0;
    let mut x_q = vec![0i8; rows * d];
    quantize_int8_into(&x_h, s_x, &mut x_q);

    let dequant = s_x * pw.scale / group as f32;
    for r in 0..rows {
        let xrow = &x_q[r * d..(r + 1) * d];
        let orow = &mut out[r * q..(r + 1) * q];
        for (j, o) in orow.iter_mut().enumerate() {
            let mut acc: i32 = 0;
            for k in 0..d {
                acc += xrow[k] as i32 * pw.w_q_t[k * q + j] as i32;
            }
            *o = acc as f32 * dequant + bias.map_or(0.0, |b| b[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        // xorshift — deterministic, no rand dep needed in unit tests
        let mut s = seed.wrapping_mul(2685821657736338717).max(1);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn matrix_orthogonal() {
        for n in [1usize, 2, 4, 8, 64] {
            let h = hadamard_matrix(n);
            for i in 0..n {
                for j in 0..n {
                    let dot: f32 = (0..n).map(|k| h[i * n + k] * h[j * n + k]).sum();
                    let want = if i == j { n as f32 } else { 0.0 };
                    assert_eq!(dot, want, "n={n} i={i} j={j}");
                }
            }
        }
    }

    #[test]
    fn fwht_matches_matrix_product() {
        let n = 64;
        let h = hadamard_matrix(n);
        let x = rand_vec(n, 3);
        let mut fast = x.clone();
        fwht_inplace(&mut fast);
        for j in 0..n {
            let slow: f32 = (0..n).map(|k| x[k] * h[k * n + j]).sum();
            assert!((fast[j] - slow).abs() < 1e-3, "{} vs {slow}", fast[j]);
        }
    }

    #[test]
    fn fwht_involution() {
        let n = 128;
        let x = rand_vec(n, 7);
        let mut y = x.clone();
        fwht_inplace(&mut y);
        fwht_inplace(&mut y);
        for i in 0..n {
            assert!((y[i] - n as f32 * x[i]).abs() < 1e-2);
        }
    }

    #[test]
    fn outlier_dispersal() {
        // Fig. 3: one huge channel spreads uniformly over the group.
        let mut x = vec![0.0f32; 64];
        x[17] = 100.0;
        fwht_inplace(&mut x);
        for v in &x {
            assert_eq!(v.abs(), 100.0);
        }
    }

    #[test]
    fn linear_close_to_fp32() {
        let (rows, d, q, group) = (16, 128, 32, 64);
        let x = rand_vec(rows * d, 1);
        let w = rand_vec(q * d, 2);
        let pw = prepare_weight(&w, q, d, group);
        let mut y = vec![0.0f32; rows * q];
        hadamard_linear(&x, rows, &pw, None, &mut y);
        let mut maxerr: f32 = 0.0;
        let mut maxref: f32 = 0.0;
        for r in 0..rows {
            for j in 0..q {
                let fp: f32 = (0..d).map(|k| x[r * d + k] * w[j * d + k]).sum();
                maxerr = maxerr.max((y[r * q + j] - fp).abs());
                maxref = maxref.max(fp.abs());
            }
        }
        assert!(maxerr / maxref < 0.03, "rel err {}", maxerr / maxref);
    }

    #[test]
    fn linear_beats_naive_int8_under_outliers() {
        let (rows, d, q, group) = (8, 128, 16, 64);
        let mut x = rand_vec(rows * d, 4);
        for r in 0..rows {
            x[r * d + 5] *= 80.0; // severe channel outlier
        }
        let w = rand_vec(q * d, 5);
        let pw = prepare_weight(&w, q, d, group);
        let mut y = vec![0.0f32; rows * q];
        hadamard_linear(&x, rows, &pw, None, &mut y);

        // naive per-tensor int8 (NormalQ)
        let sx = absmax(&x) / 127.0;
        let sw = absmax(&w) / 127.0;
        let mut xq = vec![0i8; x.len()];
        let mut wq = vec![0i8; w.len()];
        quantize_int8_into(&x, sx, &mut xq);
        quantize_int8_into(&w, sw, &mut wq);

        let (mut e_had, mut e_norm) = (0.0f64, 0.0f64);
        for r in 0..rows {
            for j in 0..q {
                let fp: f32 = (0..d).map(|k| x[r * d + k] * w[j * d + k]).sum();
                let ni: i32 = (0..d)
                    .map(|k| xq[r * d + k] as i32 * wq[j * d + k] as i32)
                    .sum();
                e_had += (y[r * q + j] - fp).abs() as f64;
                e_norm += (ni as f32 * sx * sw - fp).abs() as f64;
            }
        }
        assert!(e_had * 2.0 < e_norm, "had {e_had} norm {e_norm}");
    }

    #[test]
    fn bias_applied() {
        let (rows, d, q, group) = (2, 64, 4, 64);
        let x = rand_vec(rows * d, 8);
        let w = rand_vec(q * d, 9);
        let bias = vec![1.0f32, -2.0, 3.0, 0.5];
        let pw = prepare_weight(&w, q, d, group);
        let mut y0 = vec![0.0f32; rows * q];
        let mut y1 = vec![0.0f32; rows * q];
        hadamard_linear(&x, rows, &pw, None, &mut y0);
        hadamard_linear(&x, rows, &pw, Some(&bias), &mut y1);
        for r in 0..rows {
            for j in 0..q {
                assert!((y1[r * q + j] - y0[r * q + j] - bias[j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_non_pow2() {
        hadamard_matrix(3);
    }
}
