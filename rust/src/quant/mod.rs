//! Quantization substrates: Hadamard W8A8 (Algorithm 1), power-of-two
//! scaling, int8 helpers, and the Q-format fixed-point arithmetic the
//! simulator's datapath runs on.
//!
//! Rounding matches the Python side bit-for-bit: all float→int conversions
//! use round-half-to-even (numpy/jnp semantics), all fixed-point shifts are
//! arithmetic (floor), exactly like the RTL the paper describes.

pub mod fixed;
pub mod hadamard;
pub mod int8;
pub mod pot;

/// Round-half-to-even, matching `jnp.round` / IEEE `roundTiesToEven`.
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    // f32::round_ties_even is stable since 1.77
    x.round_ties_even()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ties_go_to_even() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
        assert_eq!(round_ties_even(3.2), 3.0);
    }
}
