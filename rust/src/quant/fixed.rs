//! Q-format fixed-point arithmetic — the 16-bit datapath of the SSM module.
//!
//! Values are carried in `i32` lanes holding Q6.10 (by default) numbers;
//! every operation saturates to the 16-bit range exactly like the RTL.
//! Shifts are arithmetic (floor), multiplication keeps the full 32-bit
//! product before renormalizing.

use crate::config::FixedSpec;
use crate::quant::round_ties_even;

/// A fixed-point value bound to a [`FixedSpec`] (zero-cost newtype over i32).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Fx(pub i32);

/// Float → saturating Q-format (round-half-even, matching `ref.to_fixed`).
pub fn to_fixed(x: f32, spec: &FixedSpec) -> i32 {
    let q = round_ties_even(x * spec.scale() as f32);
    (q as i64).clamp(spec.qmin() as i64, spec.qmax() as i64) as i32
}

/// Q-format → float.
pub fn from_fixed(x: i32, spec: &FixedSpec) -> f32 {
    x as f32 / spec.scale() as f32
}

/// Saturating add on the datapath width.
pub fn sat_add(a: i32, b: i32, spec: &FixedSpec) -> i32 {
    ((a as i64 + b as i64).clamp(spec.qmin() as i64, spec.qmax() as i64)) as i32
}

/// Fixed-point multiply: full product then arithmetic shift right by F.
pub fn fx_mul(a: i32, b: i32, spec: &FixedSpec) -> i32 {
    let prod = (a as i64 * b as i64) >> spec.frac_bits;
    prod.clamp(spec.qmin() as i64, spec.qmax() as i64) as i32
}

/// Fixed-point multiply-accumulate without intermediate saturation — the
/// MAT units accumulate in a wide register (paper Fig. 6: "4 x 21b").
pub fn fx_mac(acc: i64, a: i32, b: i32) -> i64 {
    acc + a as i64 * b as i64
}

/// Renormalize a wide MAC accumulator back to the datapath width.
pub fn fx_renorm(acc: i64, spec: &FixedSpec) -> i32 {
    (acc >> spec.frac_bits).clamp(spec.qmin() as i64, spec.qmax() as i64) as i32
}

/// Vectorized conversions.
pub fn to_fixed_vec(x: &[f32], spec: &FixedSpec) -> Vec<i32> {
    x.iter().map(|v| to_fixed(*v, spec)).collect()
}

pub fn from_fixed_vec(x: &[i32], spec: &FixedSpec) -> Vec<f32> {
    x.iter().map(|v| from_fixed(*v, spec)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> FixedSpec {
        FixedSpec::default()
    }

    #[test]
    fn roundtrip_small_values() {
        let s = spec();
        for v in [-3.5f32, -1.0, -0.0009765625, 0.0, 0.25, 1.4375, 31.0] {
            let fx = to_fixed(v, &s);
            assert!((from_fixed(fx, &s) - v).abs() <= 0.5 / s.scale() as f32);
        }
    }

    #[test]
    fn saturates() {
        let s = spec();
        assert_eq!(to_fixed(1e9, &s), s.qmax());
        assert_eq!(to_fixed(-1e9, &s), s.qmin());
        assert_eq!(sat_add(s.qmax(), s.qmax(), &s), s.qmax());
        assert_eq!(sat_add(s.qmin(), s.qmin(), &s), s.qmin());
    }

    #[test]
    fn mul_exact_on_grid() {
        let s = spec();
        // 1.5 * 2.25 = 3.375, exactly representable in Q6.10
        let a = to_fixed(1.5, &s);
        let b = to_fixed(2.25, &s);
        assert_eq!(from_fixed(fx_mul(a, b, &s), &s), 3.375);
    }

    #[test]
    fn mul_shift_is_floor() {
        let s = spec();
        // (-1 * 1) >> 10 with -1 lsb: floor semantics → -1 not 0
        assert_eq!(fx_mul(-1, 1, &s), -1 >> s.frac_bits);
    }

    #[test]
    fn mac_renorm_matches_sequential_mul_add_when_exact() {
        let s = spec();
        let a = [to_fixed(0.5, &s), to_fixed(-1.25, &s), to_fixed(2.0, &s)];
        let b = [to_fixed(4.0, &s), to_fixed(0.5, &s), to_fixed(-0.75, &s)];
        let mut acc = 0i64;
        for i in 0..3 {
            acc = fx_mac(acc, a[i], b[i]);
        }
        let got = from_fixed(fx_renorm(acc, &s), &s);
        assert_eq!(got, 0.5 * 4.0 + -1.25 * 0.5 + 2.0 * -0.75);
    }

    #[test]
    fn rounding_matches_numpy_half_even() {
        let s = spec();
        // 0.5/1024 ties: 512.5 scale points -> depends on parity
        assert_eq!(to_fixed(0.00048828125, &s), 0); // 0.5 lsb -> even 0
        assert_eq!(to_fixed(0.00146484375, &s), 2); // 1.5 lsb -> even 2
    }
}
