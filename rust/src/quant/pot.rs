//! Fine-grained power-of-two (PoT) quantization (paper §III-B).
//!
//! Scaling factors are constrained to 2^p so de/re-quantization is a barrel
//! shift on the FPGA — no DSP multipliers.  "Fine-grained" = independent
//! exponents per channel/group rather than per tensor.

use super::round_ties_even;

/// Smallest exponent p such that `absmax / 2^p` fits in `bits`-bit signed.
pub fn pot_exponent(absmax: f32, bits: u32) -> i32 {
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    (absmax.max(1e-20) / qmax).log2().ceil() as i32
}

/// Quantize-dequantize one value on the 2^p grid.
#[inline]
pub fn pot_fake_quant_scalar(x: f32, p: i32, bits: u32) -> f32 {
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let scale = (p as f32).exp2();
    let q = round_ties_even(x / scale).clamp(-qmax - 1.0, qmax);
    q * scale
}

/// Per-tensor PoT fake-quant (in place).
pub fn pot_fake_quant(x: &mut [f32], bits: u32) -> i32 {
    let am = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let p = pot_exponent(am, bits);
    for v in x.iter_mut() {
        *v = pot_fake_quant_scalar(*v, p, bits);
    }
    p
}

/// Fine-grained PoT: independent exponent per contiguous `chunk`-sized group
/// (e.g. per channel when the channel is the innermost axis).
pub fn pot_fake_quant_grouped(x: &mut [f32], chunk: usize, bits: u32) -> Vec<i32> {
    assert_eq!(x.len() % chunk, 0);
    x.chunks_mut(chunk).map(|c| pot_fake_quant(c, bits)).collect()
}

/// Fine-grained PoT across strided channels: `x` is row-major `(rows, cols)`
/// and each *column* gets its own exponent (per-channel over the row axis).
pub fn pot_fake_quant_per_col(x: &mut [f32], rows: usize, cols: usize, bits: u32) -> Vec<i32> {
    assert_eq!(x.len(), rows * cols);
    let mut ps = Vec::with_capacity(cols);
    for c in 0..cols {
        let mut am = 0.0f32;
        for r in 0..rows {
            am = am.max(x[r * cols + c].abs());
        }
        let p = pot_exponent(am, bits);
        for r in 0..rows {
            x[r * cols + c] = pot_fake_quant_scalar(x[r * cols + c], p, bits);
        }
        ps.push(p);
    }
    ps
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn exponent_covers_range() {
        let p = pot_exponent(100.0, 16);
        let scale = (p as f32).exp2();
        assert!(100.0 / scale <= 32767.0);
        assert!(100.0 / scale > 32767.0 / 2.0); // smallest such p
    }

    #[test]
    fn error_bounded_by_half_step() {
        let mut x = rand_vec(4096, 1);
        let orig = x.clone();
        let p = pot_fake_quant(&mut x, 16);
        let step = (p as f32).exp2();
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() <= step / 2.0 + 1e-9);
        }
    }

    #[test]
    fn idempotent() {
        let mut x = rand_vec(256, 2);
        pot_fake_quant(&mut x, 12);
        let once = x.clone();
        pot_fake_quant(&mut x, 12);
        assert_eq!(once, x);
    }

    #[test]
    fn values_on_pot_grid() {
        let mut x = rand_vec(100, 3);
        let p = pot_fake_quant(&mut x, 16);
        let scale = (p as f32).exp2();
        for v in &x {
            let q = v / scale;
            assert!((q - q.round()).abs() < 1e-4, "{v} not on 2^{p} grid");
        }
    }

    #[test]
    fn fine_grained_beats_per_tensor() {
        // one channel 100x larger: per-column exponents keep the small
        // channels' precision (the paper's motivation for fine-grained PoT).
        let rows = 64;
        let cols = 8;
        let mut big = rand_vec(rows * cols, 4);
        for r in 0..rows {
            big[r * cols] *= 100.0;
        }
        let orig = big.clone();

        let mut per_tensor = big.clone();
        pot_fake_quant(&mut per_tensor, 8);
        let mut per_col = big.clone();
        pot_fake_quant_per_col(&mut per_col, rows, cols, 8);

        let err = |q: &[f32]| -> f64 {
            q.iter().zip(&orig).map(|(a, b)| (a - b).abs() as f64).sum()
        };
        assert!(err(&per_col) < err(&per_tensor));
    }

    #[test]
    fn grouped_matches_manual() {
        let mut x = rand_vec(64, 5);
        let manual: Vec<f32> = {
            let mut a = x[..32].to_vec();
            let mut b = x[32..].to_vec();
            pot_fake_quant(&mut a, 16);
            pot_fake_quant(&mut b, 16);
            a.into_iter().chain(b).collect()
        };
        pot_fake_quant_grouped(&mut x, 32, 16);
        assert_eq!(x, manual);
    }
}
