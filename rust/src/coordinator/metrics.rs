//! Serving metrics: TTFT / per-token latency / throughput accounting, plus
//! decode-batch padding waste, speculative-decoding acceptance tracking,
//! streaming lifecycle counters (inter-token latency, cancellations,
//! deadline expiries), and — for the multi-worker pool — per-worker
//! queue-depth/utilization roll-ups merged into one aggregate view
//! ([`Metrics::merge`]).

use std::time::Instant;

use super::request::FinishReason;

/// Per-worker roll-up attached to a merged [`Metrics`] by the multi-worker
/// pool dispatcher (`coordinator::router::serve_pool`).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerStat {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    /// peak pending+active requests the worker's engine held
    pub queue_depth_peak: u64,
    /// busy-time fraction of the worker's wall clock, in [0, 1]
    pub utilization: f64,
    /// admissions this worker seeded from the shared state cache
    pub cache_hits: u64,
    /// prompt tokens this worker skipped prefilling via cached state
    pub cache_tokens_saved: u64,
    /// requests this worker retired with [`FinishReason::Cancelled`]
    pub cancelled: u64,
    /// requests this worker retired with [`FinishReason::Deadline`]
    pub deadline_expired: u64,
    /// this worker's median inter-token latency, seconds
    pub tpot_p50_s: f64,
}

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prompt_tokens: u64,
    pub prefill_chunks: u64,
    pub decode_steps: u64,
    pub decode_padded_slots: u64,
    /// total decode-batch slots dispatched (real + padding) — the
    /// denominator that makes [`Metrics::padding_frac`] a true fraction
    pub decode_batch_slots: u64,
    /// speculative decoding: draft tokens proposed by the drafter
    pub draft_tokens: u64,
    /// speculative decoding: draft tokens accepted by the verifier
    pub draft_accepted: u64,
    /// speculative decoding: draft/verify rounds executed
    pub spec_rounds: u64,
    /// speculative decoding: chunked-prefill verify calls issued
    pub verify_calls: u64,
    /// speculative decoding: drafter state rollbacks (mid-round rejections)
    pub rollbacks: u64,
    /// speculative decoding: extra drafter catch-up steps (after full
    /// accepts, and replaying residual debt after a drafter re-seed)
    pub resync_steps: u64,
    /// speculative decoding: drafter re-seeds from the verifier's exact
    /// state at debt-consolidation points (bounds quantized-state drift)
    pub drafter_reseeds: u64,
    /// state cache: admissions seeded from a cached snapshot (longest
    /// prefix or session resume)
    pub cache_hits: u64,
    /// state cache: admissions that probed the cache and found nothing
    /// (only counted while a cache is attached)
    pub cache_misses: u64,
    /// state cache: prompt tokens whose prefill was skipped because a
    /// cached snapshot already covered them
    pub cache_tokens_saved: u64,
    /// streaming lifecycle: requests retired with
    /// [`FinishReason::Cancelled`]
    pub cancelled_requests: u64,
    /// streaming lifecycle: requests retired with
    /// [`FinishReason::Deadline`]
    pub deadline_expired: u64,
    /// inter-token latency (TPOT) samples: seconds between consecutive
    /// token emissions of one request.  The speculative engine commits a
    /// round's accepted run at once, so intra-round tokens record ~0 and
    /// the round's first token carries the verify-call latency — the
    /// honest arrival-time view a streaming client sees.  Unlike the
    /// per-request sample vectors, this grows per *token*, so it is
    /// bounded: past [`TPOT_SAMPLE_CAP`] samples, [`Metrics::note_tpot`]
    /// overwrites ring-buffer style and the percentiles describe the most
    /// recent window.
    pub tpot_s: Vec<f64>,
    /// per-request draft acceptance rate, pushed at retire time
    pub per_request_acceptance: Vec<f64>,
    pub ttft_s: Vec<f64>,
    pub request_latency_s: Vec<f64>,
    /// peak pending+active requests observed by the engine (max across
    /// workers after a merge)
    pub queue_depth_peak: u64,
    /// wall time accumulated by scheduler steps that had work queued or
    /// active — the numerator of [`Metrics::utilization`] (summed across
    /// workers after a merge)
    pub busy_s: f64,
    /// per-worker roll-ups, attached by the pool dispatcher on merge
    pub worker_stats: Vec<WorkerStat>,
    /// total TPOT samples observed (drives the ring-buffer overwrite
    /// position once `tpot_s` is at capacity)
    tpot_seen: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// Memory bound for [`Metrics::tpot_s`]: one sample per generated token
/// would grow without limit in a long-lived serving process, so past this
/// many samples the buffer wraps (512 KiB of f64s).
pub const TPOT_SAMPLE_CAP: usize = 65_536;

impl Metrics {
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        self.finished = Some(Instant::now());
    }

    pub fn wall_s(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            (Some(a), None) => a.elapsed().as_secs_f64(),
            _ => 0.0,
        }
    }

    pub fn decode_tokens_per_s(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_s().max(1e-12)
    }

    fn pct(v: &[f64], p: f64) -> f64 {
        if v.is_empty() {
            return 0.0;
        }
        let mut s = v.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[((s.len() as f64 * p) as usize).min(s.len() - 1)]
    }

    pub fn ttft_p50(&self) -> f64 {
        Self::pct(&self.ttft_s, 0.50)
    }

    pub fn ttft_p95(&self) -> f64 {
        Self::pct(&self.ttft_s, 0.95)
    }

    pub fn latency_p50(&self) -> f64 {
        Self::pct(&self.request_latency_s, 0.50)
    }

    pub fn latency_p95(&self) -> f64 {
        Self::pct(&self.request_latency_s, 0.95)
    }

    /// Record one inter-token latency sample (ring-buffered at
    /// [`TPOT_SAMPLE_CAP`] so per-token accounting stays bounded).
    pub fn note_tpot(&mut self, seconds: f64) {
        if self.tpot_s.len() < TPOT_SAMPLE_CAP {
            self.tpot_s.push(seconds);
        } else {
            self.tpot_s[(self.tpot_seen as usize) % TPOT_SAMPLE_CAP] = seconds;
        }
        self.tpot_seen += 1;
    }

    /// Median inter-token latency (seconds).
    pub fn tpot_p50(&self) -> f64 {
        Self::pct(&self.tpot_s, 0.50)
    }

    pub fn tpot_p95(&self) -> f64 {
        Self::pct(&self.tpot_s, 0.95)
    }

    /// Count a retirement's lifecycle reason (normal reasons are already
    /// covered by `requests_completed`).
    pub fn note_finish_reason(&mut self, reason: FinishReason) {
        match reason {
            FinishReason::Cancelled => self.cancelled_requests += 1,
            FinishReason::Deadline => self.deadline_expired += 1,
            _ => {}
        }
    }

    /// Fraction of dispatched decode-batch slots wasted on padding.
    pub fn padding_frac(&self) -> f64 {
        if self.decode_batch_slots == 0 {
            return 0.0;
        }
        self.decode_padded_slots as f64 / self.decode_batch_slots as f64
    }

    /// Overall draft-token acceptance rate (0.0 when nothing was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.draft_tokens == 0 {
            return 0.0;
        }
        self.draft_accepted as f64 / self.draft_tokens as f64
    }

    /// Median per-request acceptance rate (speculative requests only).
    pub fn acceptance_p50(&self) -> f64 {
        Self::pct(&self.per_request_acceptance, 0.50)
    }

    /// State-cache hit rate over admissions that probed the cache
    /// (0.0 when no cache was attached).
    pub fn cache_hit_rate(&self) -> f64 {
        let probes = self.cache_hits + self.cache_misses;
        if probes == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / probes as f64
    }

    /// Busy-time fraction of the wall clock.  For a single engine this is
    /// in [0, 1]; for a merged multi-worker view `busy_s` sums across
    /// workers, so the value approaches the worker count at full load.
    pub fn utilization(&self) -> f64 {
        let w = self.wall_s();
        if w <= 0.0 {
            return 0.0;
        }
        self.busy_s / w
    }

    /// Record that the engine currently holds `depth` requests
    /// (pending + active), keeping the peak.
    pub fn note_queue_depth(&mut self, depth: usize) {
        self.queue_depth_peak = self.queue_depth_peak.max(depth as u64);
    }

    /// Fold another engine's metrics into this one (the multi-worker
    /// aggregate): counters add, latency samples concatenate, the wall
    /// clock spans the earliest start to the latest stop, and the queue
    /// depth keeps the per-worker peak.
    pub fn merge(&mut self, other: &Metrics) {
        self.requests_completed += other.requests_completed;
        self.tokens_generated += other.tokens_generated;
        self.prompt_tokens += other.prompt_tokens;
        self.prefill_chunks += other.prefill_chunks;
        self.decode_steps += other.decode_steps;
        self.decode_padded_slots += other.decode_padded_slots;
        self.decode_batch_slots += other.decode_batch_slots;
        self.draft_tokens += other.draft_tokens;
        self.draft_accepted += other.draft_accepted;
        self.spec_rounds += other.spec_rounds;
        self.verify_calls += other.verify_calls;
        self.rollbacks += other.rollbacks;
        self.resync_steps += other.resync_steps;
        self.drafter_reseeds += other.drafter_reseeds;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.cache_tokens_saved += other.cache_tokens_saved;
        self.cancelled_requests += other.cancelled_requests;
        self.deadline_expired += other.deadline_expired;
        for &s in &other.tpot_s {
            self.note_tpot(s);
        }
        self.per_request_acceptance
            .extend_from_slice(&other.per_request_acceptance);
        self.ttft_s.extend_from_slice(&other.ttft_s);
        self.request_latency_s.extend_from_slice(&other.request_latency_s);
        self.queue_depth_peak = self.queue_depth_peak.max(other.queue_depth_peak);
        self.busy_s += other.busy_s;
        self.worker_stats.extend(other.worker_stats.iter().cloned());
        self.started = match (self.started, other.started) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.finished = match (self.finished, other.finished) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }

    pub fn summary(&self) -> String {
        let accept = if self.draft_tokens > 0 {
            format!("{:.1}%", self.acceptance_rate() * 100.0)
        } else {
            "n/a".to_string()
        };
        let cache = if self.cache_hits + self.cache_misses > 0 {
            format!(
                " cache_hit={:.0}% saved_toks={}",
                self.cache_hit_rate() * 100.0,
                self.cache_tokens_saved
            )
        } else {
            String::new()
        };
        let lifecycle = if self.cancelled_requests + self.deadline_expired > 0 {
            format!(
                " cancelled={} deadline_expired={}",
                self.cancelled_requests, self.deadline_expired
            )
        } else {
            String::new()
        };
        let workers = if self.worker_stats.is_empty() {
            String::new()
        } else {
            let utils: Vec<String> = self
                .worker_stats
                .iter()
                .map(|w| format!("{:.0}%", w.utilization * 100.0))
                .collect();
            let depths: Vec<String> = self
                .worker_stats
                .iter()
                .map(|w| w.queue_depth_peak.to_string())
                .collect();
            let tpots: Vec<String> = self
                .worker_stats
                .iter()
                .map(|w| format!("{:.2}", w.tpot_p50_s * 1e3))
                .collect();
            format!(
                " workers={} util=[{}] qdepth=[{}] tpot_ms=[{}]",
                self.worker_stats.len(),
                utils.join("/"),
                depths.join("/"),
                tpots.join("/")
            )
        };
        format!(
            "requests={} prompt_toks={} gen_toks={} wall={:.3}s gen_tok/s={:.1} \
             ttft_p50={:.1}ms ttft_p95={:.1}ms lat_p50={:.1}ms lat_p95={:.1}ms \
             tpot_p50={:.2}ms tpot_p95={:.2}ms \
             prefill_chunks={} decode_steps={} pad_waste={:.1}% accept={}{}{} \
             qdepth_peak={} util={:.0}%{}",
            self.requests_completed,
            self.prompt_tokens,
            self.tokens_generated,
            self.wall_s(),
            self.decode_tokens_per_s(),
            self.ttft_p50() * 1e3,
            self.ttft_p95() * 1e3,
            self.latency_p50() * 1e3,
            self.latency_p95() * 1e3,
            self.tpot_p50() * 1e3,
            self.tpot_p95() * 1e3,
            self.prefill_chunks,
            self.decode_steps,
            self.padding_frac() * 100.0,
            accept,
            cache,
            lifecycle,
            self.queue_depth_peak,
            self.utilization() * 100.0,
            workers,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::default();
        m.ttft_s = vec![0.1, 0.2, 0.3, 0.4, 1.0];
        assert_eq!(m.ttft_p50(), 0.3);
        assert_eq!(m.ttft_p95(), 1.0);
    }

    #[test]
    fn empty_safe() {
        let m = Metrics::default();
        assert_eq!(m.ttft_p50(), 0.0);
        assert_eq!(m.decode_tokens_per_s(), 0.0);
        let _ = m.summary();
    }

    #[test]
    fn wall_clock_runs() {
        let mut m = Metrics::default();
        m.start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.stop();
        assert!(m.wall_s() >= 0.004);
    }

    #[test]
    fn padding_frac_is_a_fraction_of_slots() {
        let mut m = Metrics::default();
        m.decode_batch_slots = 16;
        m.decode_padded_slots = 4;
        assert!((m.padding_frac() - 0.25).abs() < 1e-12);
        let empty = Metrics::default();
        assert_eq!(empty.padding_frac(), 0.0);
    }

    #[test]
    fn acceptance_rate_tracks_drafts() {
        let mut m = Metrics::default();
        assert_eq!(m.acceptance_rate(), 0.0);
        m.draft_tokens = 10;
        m.draft_accepted = 8;
        assert!((m.acceptance_rate() - 0.8).abs() < 1e-12);
        m.per_request_acceptance = vec![0.5, 0.8, 0.9];
        assert_eq!(m.acceptance_p50(), 0.8);
    }

    #[test]
    fn queue_depth_and_utilization_in_summary() {
        let mut m = Metrics::default();
        m.note_queue_depth(3);
        m.note_queue_depth(7);
        m.note_queue_depth(2);
        assert_eq!(m.queue_depth_peak, 7);
        m.start();
        std::thread::sleep(std::time::Duration::from_millis(4));
        m.busy_s = m.wall_s() * 0.5;
        m.stop();
        let u = m.utilization();
        assert!(u > 0.0 && u <= 1.0, "utilization {u}");
        let s = m.summary();
        assert!(s.contains("qdepth_peak=7"), "{s}");
        assert!(s.contains("util="), "{s}");
        assert!(!s.contains("workers="), "no per-worker block before merge: {s}");
    }

    #[test]
    fn per_worker_stats_in_summary() {
        let mut m = Metrics::default();
        m.worker_stats = vec![
            WorkerStat {
                requests_completed: 3,
                tokens_generated: 30,
                queue_depth_peak: 4,
                utilization: 0.9,
                cache_hits: 2,
                cache_tokens_saved: 64,
                cancelled: 1,
                deadline_expired: 0,
                tpot_p50_s: 0.0015,
            },
            WorkerStat {
                requests_completed: 2,
                tokens_generated: 20,
                queue_depth_peak: 2,
                utilization: 0.5,
                cache_hits: 0,
                cache_tokens_saved: 0,
                cancelled: 0,
                deadline_expired: 0,
                tpot_p50_s: 0.0005,
            },
        ];
        let s = m.summary();
        assert!(s.contains("workers=2"), "{s}");
        assert!(s.contains("util=[90%/50%]"), "{s}");
        assert!(s.contains("qdepth=[4/2]"), "{s}");
        assert!(s.contains("tpot_ms=[1.50/0.50]"), "{s}");
    }

    #[test]
    fn merge_sums_counters_and_spans_wall() {
        let mut a = Metrics::default();
        a.start();
        a.requests_completed = 2;
        a.tokens_generated = 20;
        a.decode_steps = 5;
        a.ttft_s = vec![0.1];
        a.queue_depth_peak = 3;
        a.busy_s = 0.5;
        std::thread::sleep(std::time::Duration::from_millis(3));
        a.stop();

        let mut b = Metrics::default();
        b.start();
        b.requests_completed = 3;
        b.tokens_generated = 10;
        b.decode_steps = 7;
        b.ttft_s = vec![0.2, 0.3];
        b.queue_depth_peak = 5;
        b.busy_s = 0.25;
        std::thread::sleep(std::time::Duration::from_millis(3));
        b.stop();

        let mut m = Metrics::default();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.requests_completed, 5);
        assert_eq!(m.tokens_generated, 30);
        assert_eq!(m.decode_steps, 12);
        assert_eq!(m.ttft_s.len(), 3);
        assert_eq!(m.queue_depth_peak, 5); // max, not sum
        assert!((m.busy_s - 0.75).abs() < 1e-12); // sum
        // the merged wall spans a's start to b's stop, so it is at least
        // as long as either worker's own span
        assert!(m.wall_s() >= a.wall_s());
        assert!(m.wall_s() >= b.wall_s());
    }

    #[test]
    fn cache_counters_merge_and_summary() {
        let m = Metrics::default();
        assert_eq!(m.cache_hit_rate(), 0.0);
        assert!(!m.summary().contains("cache_hit="), "no cache block before probes");

        let mut a = Metrics::default();
        a.cache_hits = 3;
        a.cache_misses = 1;
        a.cache_tokens_saved = 96;
        let mut b = Metrics::default();
        b.cache_hits = 1;
        b.cache_misses = 3;
        b.cache_tokens_saved = 32;

        let mut m = Metrics::default();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.cache_hits, 4);
        assert_eq!(m.cache_misses, 4);
        assert_eq!(m.cache_tokens_saved, 128);
        assert!((m.cache_hit_rate() - 0.5).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("cache_hit=50%"), "{s}");
        assert!(s.contains("saved_toks=128"), "{s}");
    }

    #[test]
    fn lifecycle_counters_merge_and_summary() {
        let m = Metrics::default();
        assert!(
            !m.summary().contains("cancelled="),
            "no lifecycle block before any cancellation/expiry"
        );
        assert!(m.summary().contains("tpot_p50=0.00ms"), "{}", m.summary());

        let mut a = Metrics::default();
        a.note_finish_reason(FinishReason::Cancelled);
        a.note_finish_reason(FinishReason::Length); // not counted
        a.note_finish_reason(FinishReason::StopToken); // not counted
        a.tpot_s = vec![0.001, 0.002];
        let mut b = Metrics::default();
        b.note_finish_reason(FinishReason::Deadline);
        b.note_finish_reason(FinishReason::Cancelled);
        b.tpot_s = vec![0.004];

        let mut m = Metrics::default();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.cancelled_requests, 2);
        assert_eq!(m.deadline_expired, 1);
        assert_eq!(m.tpot_s.len(), 3);
        assert_eq!(m.tpot_p50(), 0.002);
        assert_eq!(m.tpot_p95(), 0.004);
        let s = m.summary();
        assert!(s.contains("cancelled=2"), "{s}");
        assert!(s.contains("deadline_expired=1"), "{s}");
        assert!(s.contains("tpot_p50=2.00ms"), "{s}");
    }

    #[test]
    fn tpot_ring_buffer_stays_bounded() {
        let mut m = Metrics::default();
        for i in 0..(TPOT_SAMPLE_CAP + 100) {
            m.note_tpot(i as f64);
        }
        assert_eq!(m.tpot_s.len(), TPOT_SAMPLE_CAP, "per-token samples stay bounded");
        // the oldest samples were overwritten by the newest, in order
        assert_eq!(m.tpot_s[0], TPOT_SAMPLE_CAP as f64);
        assert_eq!(m.tpot_s[99], (TPOT_SAMPLE_CAP + 99) as f64);
        assert_eq!(m.tpot_s[100], 100.0);
    }

    #[test]
    fn summary_shows_padding_and_acceptance() {
        let mut m = Metrics::default();
        m.decode_batch_slots = 10;
        m.decode_padded_slots = 1;
        let s = m.summary();
        assert!(s.contains("pad_waste=10.0%"), "{s}");
        assert!(s.contains("accept=n/a"), "{s}");
        m.draft_tokens = 4;
        m.draft_accepted = 3;
        assert!(m.summary().contains("accept=75.0%"), "{}", m.summary());
    }
}
