//! Serving metrics: TTFT / per-token latency / throughput accounting, plus
//! decode-batch padding waste and speculative-decoding acceptance tracking.

use std::time::Instant;

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prompt_tokens: u64,
    pub prefill_chunks: u64,
    pub decode_steps: u64,
    pub decode_padded_slots: u64,
    /// total decode-batch slots dispatched (real + padding) — the
    /// denominator that makes [`Metrics::padding_frac`] a true fraction
    pub decode_batch_slots: u64,
    /// speculative decoding: draft tokens proposed by the drafter
    pub draft_tokens: u64,
    /// speculative decoding: draft tokens accepted by the verifier
    pub draft_accepted: u64,
    /// speculative decoding: draft/verify rounds executed
    pub spec_rounds: u64,
    /// speculative decoding: chunked-prefill verify calls issued
    pub verify_calls: u64,
    /// speculative decoding: drafter state rollbacks (mid-round rejections)
    pub rollbacks: u64,
    /// speculative decoding: extra drafter catch-up steps after full accepts
    pub resync_steps: u64,
    /// per-request draft acceptance rate, pushed at retire time
    pub per_request_acceptance: Vec<f64>,
    pub ttft_s: Vec<f64>,
    pub request_latency_s: Vec<f64>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Metrics {
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        self.finished = Some(Instant::now());
    }

    pub fn wall_s(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            (Some(a), None) => a.elapsed().as_secs_f64(),
            _ => 0.0,
        }
    }

    pub fn decode_tokens_per_s(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_s().max(1e-12)
    }

    fn pct(v: &[f64], p: f64) -> f64 {
        if v.is_empty() {
            return 0.0;
        }
        let mut s = v.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[((s.len() as f64 * p) as usize).min(s.len() - 1)]
    }

    pub fn ttft_p50(&self) -> f64 {
        Self::pct(&self.ttft_s, 0.50)
    }

    pub fn ttft_p95(&self) -> f64 {
        Self::pct(&self.ttft_s, 0.95)
    }

    pub fn latency_p50(&self) -> f64 {
        Self::pct(&self.request_latency_s, 0.50)
    }

    pub fn latency_p95(&self) -> f64 {
        Self::pct(&self.request_latency_s, 0.95)
    }

    /// Fraction of dispatched decode-batch slots wasted on padding.
    pub fn padding_frac(&self) -> f64 {
        if self.decode_batch_slots == 0 {
            return 0.0;
        }
        self.decode_padded_slots as f64 / self.decode_batch_slots as f64
    }

    /// Overall draft-token acceptance rate (0.0 when nothing was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.draft_tokens == 0 {
            return 0.0;
        }
        self.draft_accepted as f64 / self.draft_tokens as f64
    }

    /// Median per-request acceptance rate (speculative requests only).
    pub fn acceptance_p50(&self) -> f64 {
        Self::pct(&self.per_request_acceptance, 0.50)
    }

    pub fn summary(&self) -> String {
        let accept = if self.draft_tokens > 0 {
            format!("{:.1}%", self.acceptance_rate() * 100.0)
        } else {
            "n/a".to_string()
        };
        format!(
            "requests={} prompt_toks={} gen_toks={} wall={:.3}s gen_tok/s={:.1} \
             ttft_p50={:.1}ms ttft_p95={:.1}ms lat_p50={:.1}ms lat_p95={:.1}ms \
             prefill_chunks={} decode_steps={} pad_waste={:.1}% accept={}",
            self.requests_completed,
            self.prompt_tokens,
            self.tokens_generated,
            self.wall_s(),
            self.decode_tokens_per_s(),
            self.ttft_p50() * 1e3,
            self.ttft_p95() * 1e3,
            self.latency_p50() * 1e3,
            self.latency_p95() * 1e3,
            self.prefill_chunks,
            self.decode_steps,
            self.padding_frac() * 100.0,
            accept,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::default();
        m.ttft_s = vec![0.1, 0.2, 0.3, 0.4, 1.0];
        assert_eq!(m.ttft_p50(), 0.3);
        assert_eq!(m.ttft_p95(), 1.0);
    }

    #[test]
    fn empty_safe() {
        let m = Metrics::default();
        assert_eq!(m.ttft_p50(), 0.0);
        assert_eq!(m.decode_tokens_per_s(), 0.0);
        let _ = m.summary();
    }

    #[test]
    fn wall_clock_runs() {
        let mut m = Metrics::default();
        m.start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.stop();
        assert!(m.wall_s() >= 0.004);
    }

    #[test]
    fn padding_frac_is_a_fraction_of_slots() {
        let mut m = Metrics::default();
        m.decode_batch_slots = 16;
        m.decode_padded_slots = 4;
        assert!((m.padding_frac() - 0.25).abs() < 1e-12);
        let empty = Metrics::default();
        assert_eq!(empty.padding_frac(), 0.0);
    }

    #[test]
    fn acceptance_rate_tracks_drafts() {
        let mut m = Metrics::default();
        assert_eq!(m.acceptance_rate(), 0.0);
        m.draft_tokens = 10;
        m.draft_accepted = 8;
        assert!((m.acceptance_rate() - 0.8).abs() < 1e-12);
        m.per_request_acceptance = vec![0.5, 0.8, 0.9];
        assert_eq!(m.acceptance_p50(), 0.8);
    }

    #[test]
    fn summary_shows_padding_and_acceptance() {
        let mut m = Metrics::default();
        m.decode_batch_slots = 10;
        m.decode_padded_slots = 1;
        let s = m.summary();
        assert!(s.contains("pad_waste=10.0%"), "{s}");
        assert!(s.contains("accept=n/a"), "{s}");
        m.draft_tokens = 4;
        m.draft_accepted = 3;
        assert!(m.summary().contains("accept=75.0%"), "{}", m.summary());
    }
}
