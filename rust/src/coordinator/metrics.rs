//! Serving metrics: TTFT / per-token latency / throughput accounting.

use std::time::Instant;

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prompt_tokens: u64,
    pub prefill_chunks: u64,
    pub decode_steps: u64,
    pub decode_padded_slots: u64,
    pub ttft_s: Vec<f64>,
    pub request_latency_s: Vec<f64>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Metrics {
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        self.finished = Some(Instant::now());
    }

    pub fn wall_s(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            (Some(a), None) => a.elapsed().as_secs_f64(),
            _ => 0.0,
        }
    }

    pub fn decode_tokens_per_s(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_s().max(1e-12)
    }

    fn pct(v: &[f64], p: f64) -> f64 {
        if v.is_empty() {
            return 0.0;
        }
        let mut s = v.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[((s.len() as f64 * p) as usize).min(s.len() - 1)]
    }

    pub fn ttft_p50(&self) -> f64 {
        Self::pct(&self.ttft_s, 0.50)
    }

    pub fn ttft_p95(&self) -> f64 {
        Self::pct(&self.ttft_s, 0.95)
    }

    pub fn latency_p50(&self) -> f64 {
        Self::pct(&self.request_latency_s, 0.50)
    }

    pub fn latency_p95(&self) -> f64 {
        Self::pct(&self.request_latency_s, 0.95)
    }

    /// Fraction of decode-batch slots wasted on padding.
    pub fn padding_frac(&self) -> f64 {
        let total = self.decode_steps.max(1);
        self.decode_padded_slots as f64 / (total as f64).max(1.0)
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} prompt_toks={} gen_toks={} wall={:.3}s gen_tok/s={:.1} \
             ttft_p50={:.1}ms ttft_p95={:.1}ms lat_p50={:.1}ms lat_p95={:.1}ms \
             prefill_chunks={} decode_steps={}",
            self.requests_completed,
            self.prompt_tokens,
            self.tokens_generated,
            self.wall_s(),
            self.decode_tokens_per_s(),
            self.ttft_p50() * 1e3,
            self.ttft_p95() * 1e3,
            self.latency_p50() * 1e3,
            self.latency_p95() * 1e3,
            self.prefill_chunks,
            self.decode_steps,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::default();
        m.ttft_s = vec![0.1, 0.2, 0.3, 0.4, 1.0];
        assert_eq!(m.ttft_p50(), 0.3);
        assert_eq!(m.ttft_p95(), 1.0);
    }

    #[test]
    fn empty_safe() {
        let m = Metrics::default();
        assert_eq!(m.ttft_p50(), 0.0);
        assert_eq!(m.decode_tokens_per_s(), 0.0);
        let _ = m.summary();
    }

    #[test]
    fn wall_clock_runs() {
        let mut m = Metrics::default();
        m.start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.stop();
        assert!(m.wall_s() >= 0.004);
    }
}
