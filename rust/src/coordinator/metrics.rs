//! Serving metrics: TTFT / per-token latency / throughput accounting, plus
//! decode-batch padding waste, speculative-decoding acceptance tracking,
//! streaming lifecycle counters (inter-token latency, cancellations,
//! deadline expiries), and — for the multi-worker pool — per-worker
//! queue-depth/utilization roll-ups merged into one aggregate view
//! ([`Metrics::merge`]).
//!
//! Since the observability layer landed, `Metrics` is the *snapshot* side
//! of a live pair: every mutation goes through a method
//! ([`Metrics::count`], the `note_*` family) that also writes through to
//! an optionally attached [`crate::obs::Telemetry`] — the `Arc`-shared
//! atomic cells a Prometheus scrape or the periodic stdout log reads
//! while the engine is serving.  [`Metrics::from_telemetry`]
//! reconstructs a snapshot from those cells alone, so the live view and
//! the end-of-run summary can never disagree.
//!
//! Per-request latency samples live in fixed-memory log-bucketed
//! [`Histogram`]s (TTFT, end-to-end latency, draft acceptance, and
//! per-call backend prefill/decode latency) instead of one `f64` per
//! request: a long-lived serving process stays bounded, and the
//! cross-worker [`Metrics::merge`] is an exact bucket-wise add rather
//! than a raw-vector concatenation.  Inter-token latency (TPOT)
//! additionally keeps its [`TPOT_SAMPLE_CAP`]-bounded ring of recent raw
//! samples — the recent-window view the summary line reports.

use std::sync::Arc;
use std::time::Instant;

use super::request::FinishReason;
use crate::obs::histogram::Histogram;
use crate::obs::telemetry::{Counter, Gauge, HistKind, Telemetry};
use crate::obs::SortedSamples;
use crate::util::json::{num, obj, s, Json};

/// Per-worker roll-up attached to a merged [`Metrics`] by the multi-worker
/// pool dispatcher (`coordinator::router::serve_pool`).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerStat {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    /// peak pending+active requests the worker's engine held
    pub queue_depth_peak: u64,
    /// busy-time fraction of the worker's wall clock, in [0, 1]
    pub utilization: f64,
    /// admissions this worker seeded from the shared state cache
    pub cache_hits: u64,
    /// prompt tokens this worker skipped prefilling via cached state
    pub cache_tokens_saved: u64,
    /// requests this worker retired with [`FinishReason::Cancelled`]
    pub cancelled: u64,
    /// requests this worker retired with [`FinishReason::Deadline`]
    pub deadline_expired: u64,
    /// this worker's median inter-token latency, seconds
    pub tpot_p50_s: f64,
}

impl WorkerStat {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("requests_completed", num(self.requests_completed as f64)),
            ("tokens_generated", num(self.tokens_generated as f64)),
            ("queue_depth_peak", num(self.queue_depth_peak as f64)),
            ("utilization", num(self.utilization)),
            ("cache_hits", num(self.cache_hits as f64)),
            ("cache_tokens_saved", num(self.cache_tokens_saved as f64)),
            ("cancelled", num(self.cancelled as f64)),
            ("deadline_expired", num(self.deadline_expired as f64)),
            ("tpot_p50_s", num(self.tpot_p50_s)),
        ])
    }
}

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prompt_tokens: u64,
    pub prefill_chunks: u64,
    pub decode_steps: u64,
    pub decode_padded_slots: u64,
    /// total decode-batch slots dispatched (real + padding) — the
    /// denominator that makes [`Metrics::padding_frac`] a true fraction
    pub decode_batch_slots: u64,
    /// speculative decoding: draft tokens proposed by the drafter
    pub draft_tokens: u64,
    /// speculative decoding: draft tokens accepted by the verifier
    pub draft_accepted: u64,
    /// speculative decoding: draft/verify rounds executed
    pub spec_rounds: u64,
    /// speculative decoding: chunked-prefill verify calls issued
    pub verify_calls: u64,
    /// speculative decoding: drafter state rollbacks (mid-round rejections)
    pub rollbacks: u64,
    /// speculative decoding: extra drafter catch-up steps (after full
    /// accepts, and replaying residual debt after a drafter re-seed)
    pub resync_steps: u64,
    /// speculative decoding: drafter re-seeds from the verifier's exact
    /// state at debt-consolidation points (bounds quantized-state drift)
    pub drafter_reseeds: u64,
    /// state cache: admissions seeded from a cached snapshot (longest
    /// prefix or session resume)
    pub cache_hits: u64,
    /// state cache: admissions that probed the cache and found nothing
    /// (only counted while a cache is attached)
    pub cache_misses: u64,
    /// state cache: prompt tokens whose prefill was skipped because a
    /// cached snapshot already covered them
    pub cache_tokens_saved: u64,
    /// streaming lifecycle: requests retired with
    /// [`FinishReason::Cancelled`]
    pub cancelled_requests: u64,
    /// streaming lifecycle: requests retired with
    /// [`FinishReason::Deadline`]
    pub deadline_expired: u64,
    /// scheduling: running requests preempted (snapshot + requeue) to
    /// make room for higher-priority arrivals
    pub preempted_requests: u64,
    /// scheduling: requests refused at admission with
    /// [`FinishReason::Overloaded`] (bounded-queue load shedding)
    pub requests_shed: u64,
    /// scheduling: queued requests dropped before admission
    /// (backlog cancel/deadline/worker-death — no latency sample)
    pub requests_dropped: u64,
    /// scheduling: queue re-orders performed by priority aging
    pub aging_reorders: u64,
    /// inter-token latency (TPOT) samples: seconds between consecutive
    /// token emissions of one request.  The speculative engine commits a
    /// round's accepted run at once, so intra-round tokens record ~0 and
    /// the round's first token carries the verify-call latency — the
    /// honest arrival-time view a streaming client sees.  This is the
    /// *recent-window* raw view: past [`TPOT_SAMPLE_CAP`] samples,
    /// [`Metrics::note_tpot`] overwrites ring-buffer style.  The all-time
    /// distribution lives in the bounded [`Metrics::tpot`] histogram.
    pub tpot_s: Vec<f64>,
    /// all-time TPOT distribution (fixed-memory log buckets)
    pub tpot: Histogram,
    /// per-request draft acceptance rate, observed at retire time
    pub acceptance: Histogram,
    /// time to first token per request, seconds
    pub ttft: Histogram,
    /// end-to-end request latency (submit → retire), seconds
    pub latency: Histogram,
    /// per-call backend prefill latency (chunked prefill + verify calls)
    pub prefill_call: Histogram,
    /// per-call backend decode latency (batched decode + draft steps)
    pub decode_call: Histogram,
    /// peak pending+active requests observed by the engine (max across
    /// workers after a merge)
    pub queue_depth_peak: u64,
    /// wall time accumulated by scheduler steps that had work queued or
    /// active — the numerator of [`Metrics::utilization`] (summed across
    /// workers after a merge)
    pub busy_s: f64,
    /// per-worker roll-ups, attached by the pool dispatcher on merge
    pub worker_stats: Vec<WorkerStat>,
    /// total TPOT samples observed (drives the ring-buffer overwrite
    /// position once `tpot_s` is at capacity)
    tpot_seen: u64,
    /// live write-through target: every counter/sample mutation that goes
    /// through a method also lands in these shared atomic cells
    tel: Option<Arc<Telemetry>>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// Memory bound for [`Metrics::tpot_s`]: one sample per generated token
/// would grow without limit in a long-lived serving process, so past this
/// many samples the buffer wraps (512 KiB of f64s).
pub const TPOT_SAMPLE_CAP: usize = 65_536;

impl Metrics {
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        self.finished = Some(Instant::now());
    }

    pub fn wall_s(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            (Some(a), None) => a.elapsed().as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Attach the live telemetry cells this instance writes through to.
    /// (Counters already accumulated are not replayed; attach before
    /// serving starts.)
    pub fn attach_telemetry(&mut self, tel: Arc<Telemetry>) {
        self.tel = Some(tel);
    }

    pub fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        self.tel.as_ref()
    }

    /// Rebuild a snapshot from live telemetry cells alone — the scrape
    /// view and this snapshot are two reads of the same atomics, so they
    /// agree by construction.  (The TPOT recent-window ring is engine
    /// local and stays empty here; the all-time `tpot` histogram carries
    /// the distribution.)
    pub fn from_telemetry(tel: &Telemetry) -> Metrics {
        let mut m = Metrics {
            requests_completed: tel.get(Counter::RequestsCompleted),
            tokens_generated: tel.get(Counter::TokensGenerated),
            prompt_tokens: tel.get(Counter::PromptTokens),
            prefill_chunks: tel.get(Counter::PrefillChunks),
            decode_steps: tel.get(Counter::DecodeSteps),
            decode_padded_slots: tel.get(Counter::DecodePaddedSlots),
            decode_batch_slots: tel.get(Counter::DecodeBatchSlots),
            draft_tokens: tel.get(Counter::DraftTokens),
            draft_accepted: tel.get(Counter::DraftAccepted),
            spec_rounds: tel.get(Counter::SpecRounds),
            verify_calls: tel.get(Counter::VerifyCalls),
            rollbacks: tel.get(Counter::Rollbacks),
            resync_steps: tel.get(Counter::ResyncSteps),
            drafter_reseeds: tel.get(Counter::DrafterReseeds),
            cache_hits: tel.get(Counter::CacheHits),
            cache_misses: tel.get(Counter::CacheMisses),
            cache_tokens_saved: tel.get(Counter::CacheTokensSaved),
            cancelled_requests: tel.get(Counter::CancelledRequests),
            deadline_expired: tel.get(Counter::DeadlineExpired),
            preempted_requests: tel.get(Counter::PreemptedRequests),
            requests_shed: tel.get(Counter::RequestsShed),
            requests_dropped: tel.get(Counter::RequestsDropped),
            aging_reorders: tel.get(Counter::AgingReorders),
            busy_s: tel.get(Counter::BusyMicros) as f64 / 1e6,
            queue_depth_peak: tel.gauge_peak(Gauge::QueueDepth),
            ..Metrics::default()
        };
        m.ttft = tel.hist(HistKind::Ttft);
        m.latency = tel.hist(HistKind::Latency);
        m.tpot = tel.hist(HistKind::Tpot);
        m.acceptance = tel.hist(HistKind::Acceptance);
        m.prefill_call = tel.hist(HistKind::PrefillCall);
        m.decode_call = tel.hist(HistKind::DecodeCall);
        m
    }

    /// Bump a monotone counter (and its live telemetry cell, when one is
    /// attached).  This is the single mutation path for the `u64` fields —
    /// the engines never touch them directly anymore.
    pub fn count(&mut self, c: Counter, n: u64) {
        match c {
            Counter::RequestsCompleted => self.requests_completed += n,
            Counter::TokensGenerated => self.tokens_generated += n,
            Counter::PromptTokens => self.prompt_tokens += n,
            Counter::PrefillChunks => self.prefill_chunks += n,
            Counter::DecodeSteps => self.decode_steps += n,
            Counter::DecodePaddedSlots => self.decode_padded_slots += n,
            Counter::DecodeBatchSlots => self.decode_batch_slots += n,
            Counter::DraftTokens => self.draft_tokens += n,
            Counter::DraftAccepted => self.draft_accepted += n,
            Counter::SpecRounds => self.spec_rounds += n,
            Counter::VerifyCalls => self.verify_calls += n,
            Counter::Rollbacks => self.rollbacks += n,
            Counter::ResyncSteps => self.resync_steps += n,
            Counter::DrafterReseeds => self.drafter_reseeds += n,
            Counter::CacheHits => self.cache_hits += n,
            Counter::CacheMisses => self.cache_misses += n,
            Counter::CacheTokensSaved => self.cache_tokens_saved += n,
            Counter::CancelledRequests => self.cancelled_requests += n,
            Counter::DeadlineExpired => self.deadline_expired += n,
            Counter::PreemptedRequests => self.preempted_requests += n,
            Counter::RequestsShed => self.requests_shed += n,
            Counter::RequestsDropped => self.requests_dropped += n,
            Counter::AgingReorders => self.aging_reorders += n,
            // busy time goes through note_busy (float seconds field)
            Counter::BusyMicros => {}
        }
        if let Some(t) = &self.tel {
            t.add(c, n);
        }
    }

    pub fn decode_tokens_per_s(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_s().max(1e-12)
    }

    pub fn note_ttft(&mut self, seconds: f64) {
        self.ttft.observe(seconds);
        if let Some(t) = &self.tel {
            t.observe(HistKind::Ttft, seconds);
        }
    }

    pub fn note_latency(&mut self, seconds: f64) {
        self.latency.observe(seconds);
        if let Some(t) = &self.tel {
            t.observe(HistKind::Latency, seconds);
        }
    }

    /// Record one request's draft-acceptance rate at retire time.
    pub fn note_acceptance(&mut self, rate: f64) {
        self.acceptance.observe(rate);
        if let Some(t) = &self.tel {
            t.observe(HistKind::Acceptance, rate);
        }
    }

    /// Record one backend prefill-call latency (chunk or verify window).
    pub fn note_prefill_call(&mut self, seconds: f64) {
        self.prefill_call.observe(seconds);
        if let Some(t) = &self.tel {
            t.observe(HistKind::PrefillCall, seconds);
        }
    }

    /// Record one backend decode-call latency.
    pub fn note_decode_call(&mut self, seconds: f64) {
        self.decode_call.observe(seconds);
        if let Some(t) = &self.tel {
            t.observe(HistKind::DecodeCall, seconds);
        }
    }

    pub fn ttft_p50(&self) -> f64 {
        self.ttft.quantile(0.50)
    }

    pub fn ttft_p95(&self) -> f64 {
        self.ttft.quantile(0.95)
    }

    pub fn latency_p50(&self) -> f64 {
        self.latency.quantile(0.50)
    }

    pub fn latency_p95(&self) -> f64 {
        self.latency.quantile(0.95)
    }

    /// Push into the recent-window ring only (no histogram/telemetry
    /// write-through) — used by [`Metrics::merge`], whose source histogram
    /// counts already include these samples.
    fn tpot_ring_push(&mut self, seconds: f64) {
        if self.tpot_s.len() < TPOT_SAMPLE_CAP {
            self.tpot_s.push(seconds);
        } else {
            self.tpot_s[(self.tpot_seen as usize) % TPOT_SAMPLE_CAP] = seconds;
        }
        self.tpot_seen += 1;
    }

    /// Record one inter-token latency sample: ring-buffered at
    /// [`TPOT_SAMPLE_CAP`] for the recent-window view, plus the all-time
    /// histogram (and its live cell).
    pub fn note_tpot(&mut self, seconds: f64) {
        self.tpot_ring_push(seconds);
        self.tpot.observe(seconds);
        if let Some(t) = &self.tel {
            t.observe(HistKind::Tpot, seconds);
        }
    }

    /// Median inter-token latency (seconds) over the recent window.
    pub fn tpot_p50(&self) -> f64 {
        SortedSamples::new(self.tpot_s.clone()).pct(0.50)
    }

    pub fn tpot_p95(&self) -> f64 {
        SortedSamples::new(self.tpot_s.clone()).pct(0.95)
    }

    /// Count a retirement's lifecycle reason (normal reasons are already
    /// covered by `requests_completed`).
    pub fn note_finish_reason(&mut self, reason: FinishReason) {
        match reason {
            FinishReason::Cancelled => self.count(Counter::CancelledRequests, 1),
            FinishReason::Deadline => self.count(Counter::DeadlineExpired, 1),
            FinishReason::Preempted => self.count(Counter::PreemptedRequests, 1),
            FinishReason::Overloaded => self.count(Counter::RequestsShed, 1),
            _ => {}
        }
    }

    /// Fraction of dispatched decode-batch slots wasted on padding.
    pub fn padding_frac(&self) -> f64 {
        if self.decode_batch_slots == 0 {
            return 0.0;
        }
        self.decode_padded_slots as f64 / self.decode_batch_slots as f64
    }

    /// Overall draft-token acceptance rate (0.0 when nothing was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.draft_tokens == 0 {
            return 0.0;
        }
        self.draft_accepted as f64 / self.draft_tokens as f64
    }

    /// Median per-request acceptance rate (speculative requests only).
    pub fn acceptance_p50(&self) -> f64 {
        self.acceptance.quantile(0.50)
    }

    /// State-cache hit rate over admissions that probed the cache
    /// (0.0 when no cache was attached).
    pub fn cache_hit_rate(&self) -> f64 {
        let probes = self.cache_hits + self.cache_misses;
        if probes == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / probes as f64
    }

    /// Busy-time fraction of the wall clock.  For a single engine this is
    /// in [0, 1]; for a merged multi-worker view `busy_s` sums across
    /// workers, so the value approaches the worker count at full load.
    pub fn utilization(&self) -> f64 {
        let w = self.wall_s();
        if w <= 0.0 {
            return 0.0;
        }
        self.busy_s / w
    }

    /// Accumulate busy wall time (live cell: integer microseconds).
    pub fn note_busy(&mut self, seconds: f64) {
        self.busy_s += seconds;
        if let Some(t) = &self.tel {
            t.add(Counter::BusyMicros, (seconds * 1e6) as u64);
        }
    }

    /// Record that the engine currently holds `depth` requests
    /// (pending + active), keeping the peak (and the live gauge).
    pub fn note_queue_depth(&mut self, depth: usize) {
        self.queue_depth_peak = self.queue_depth_peak.max(depth as u64);
        if let Some(t) = &self.tel {
            t.set_gauge(Gauge::QueueDepth, depth as u64);
        }
    }

    /// Update the live active-slots gauge (state slots bound to in-flight
    /// requests right now); snapshot-only instances ignore it.
    pub fn note_active_slots(&mut self, active: usize) {
        if let Some(t) = &self.tel {
            t.set_gauge(Gauge::ActiveSlots, active as u64);
        }
    }

    /// Heap bytes held by the latency-sample structures — constant once
    /// warm (six fixed bucket arrays plus the capped TPOT ring), where the
    /// old raw vectors grew one `f64` per request forever.
    pub fn sample_heap_bytes(&self) -> usize {
        self.ttft.heap_bytes()
            + self.latency.heap_bytes()
            + self.acceptance.heap_bytes()
            + self.tpot.heap_bytes()
            + self.prefill_call.heap_bytes()
            + self.decode_call.heap_bytes()
            + self.tpot_s.capacity() * std::mem::size_of::<f64>()
    }

    /// Fold another engine's metrics into this one (the multi-worker
    /// aggregate): counters add, histograms merge bucket-wise (exact —
    /// merged quantiles equal pooled-stream quantiles), the TPOT
    /// recent-window rings concatenate within their cap, the wall clock
    /// spans the earliest start to the latest stop, and the queue depth
    /// keeps the per-worker peak.  Fields are written directly — no
    /// telemetry write-through, since the source samples already live in
    /// their own workers' cells.
    pub fn merge(&mut self, other: &Metrics) {
        self.requests_completed += other.requests_completed;
        self.tokens_generated += other.tokens_generated;
        self.prompt_tokens += other.prompt_tokens;
        self.prefill_chunks += other.prefill_chunks;
        self.decode_steps += other.decode_steps;
        self.decode_padded_slots += other.decode_padded_slots;
        self.decode_batch_slots += other.decode_batch_slots;
        self.draft_tokens += other.draft_tokens;
        self.draft_accepted += other.draft_accepted;
        self.spec_rounds += other.spec_rounds;
        self.verify_calls += other.verify_calls;
        self.rollbacks += other.rollbacks;
        self.resync_steps += other.resync_steps;
        self.drafter_reseeds += other.drafter_reseeds;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.cache_tokens_saved += other.cache_tokens_saved;
        self.cancelled_requests += other.cancelled_requests;
        self.deadline_expired += other.deadline_expired;
        self.preempted_requests += other.preempted_requests;
        self.requests_shed += other.requests_shed;
        self.requests_dropped += other.requests_dropped;
        self.aging_reorders += other.aging_reorders;
        for &v in &other.tpot_s {
            self.tpot_ring_push(v);
        }
        self.tpot.merge(&other.tpot);
        self.acceptance.merge(&other.acceptance);
        self.ttft.merge(&other.ttft);
        self.latency.merge(&other.latency);
        self.prefill_call.merge(&other.prefill_call);
        self.decode_call.merge(&other.decode_call);
        self.queue_depth_peak = self.queue_depth_peak.max(other.queue_depth_peak);
        self.busy_s += other.busy_s;
        self.worker_stats.extend(other.worker_stats.iter().cloned());
        self.started = match (self.started, other.started) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.finished = match (self.finished, other.finished) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }

    pub fn summary(&self) -> String {
        let accept = if self.draft_tokens > 0 {
            format!("{:.1}%", self.acceptance_rate() * 100.0)
        } else {
            "n/a".to_string()
        };
        let cache = if self.cache_hits + self.cache_misses > 0 {
            format!(
                " cache_hit={:.0}% saved_toks={}",
                self.cache_hit_rate() * 100.0,
                self.cache_tokens_saved
            )
        } else {
            String::new()
        };
        let lifecycle = if self.cancelled_requests + self.deadline_expired > 0 {
            format!(
                " cancelled={} deadline_expired={}",
                self.cancelled_requests, self.deadline_expired
            )
        } else {
            String::new()
        };
        let sched = if self.preempted_requests
            + self.requests_shed
            + self.requests_dropped
            + self.aging_reorders
            > 0
        {
            format!(
                " preempted={} shed={} dropped={} aging_reorders={}",
                self.preempted_requests,
                self.requests_shed,
                self.requests_dropped,
                self.aging_reorders
            )
        } else {
            String::new()
        };
        let workers = if self.worker_stats.is_empty() {
            String::new()
        } else {
            let utils: Vec<String> = self
                .worker_stats
                .iter()
                .map(|w| format!("{:.0}%", w.utilization * 100.0))
                .collect();
            let depths: Vec<String> = self
                .worker_stats
                .iter()
                .map(|w| w.queue_depth_peak.to_string())
                .collect();
            let tpots: Vec<String> = self
                .worker_stats
                .iter()
                .map(|w| format!("{:.2}", w.tpot_p50_s * 1e3))
                .collect();
            format!(
                " workers={} util=[{}] qdepth=[{}] tpot_ms=[{}]",
                self.worker_stats.len(),
                utils.join("/"),
                depths.join("/"),
                tpots.join("/")
            )
        };
        // one sort for both recent-window TPOT percentiles
        let tpot = SortedSamples::new(self.tpot_s.clone());
        format!(
            "requests={} prompt_toks={} gen_toks={} wall={:.3}s gen_tok/s={:.1} \
             ttft_p50={:.1}ms ttft_p95={:.1}ms lat_p50={:.1}ms lat_p95={:.1}ms \
             tpot_p50={:.2}ms tpot_p95={:.2}ms \
             prefill_chunks={} decode_steps={} pad_waste={:.1}% accept={}{}{}{} \
             qdepth_peak={} util={:.0}%{}",
            self.requests_completed,
            self.prompt_tokens,
            self.tokens_generated,
            self.wall_s(),
            self.decode_tokens_per_s(),
            self.ttft_p50() * 1e3,
            self.ttft_p95() * 1e3,
            self.latency_p50() * 1e3,
            self.latency_p95() * 1e3,
            tpot.pct(0.50) * 1e3,
            tpot.pct(0.95) * 1e3,
            self.prefill_chunks,
            self.decode_steps,
            self.padding_frac() * 100.0,
            accept,
            cache,
            lifecycle,
            sched,
            self.queue_depth_peak,
            self.utilization() * 100.0,
            workers,
        )
    }

    /// Machine-readable final snapshot (`serve --metrics-json PATH`, and
    /// the schema the bench JSON artifacts embed per run).
    pub fn to_json(&self) -> Json {
        fn hist(h: &Histogram) -> Json {
            obj(vec![
                ("count", num(h.count() as f64)),
                ("sum", num(h.sum())),
                ("mean", num(h.mean())),
                ("min", num(h.min())),
                ("max", num(h.max())),
                ("p50", num(h.quantile(0.50))),
                ("p95", num(h.quantile(0.95))),
                ("p99", num(h.quantile(0.99))),
                // exact reconstruction surface: the ≤0-class count plus
                // sparse [bucket_index, count] pairs — enough to recompute
                // quantiles and SLO burn rates offline bit-for-bit
                // (obs::slo::burn_from_buckets)
                ("zero", num(h.zero_count() as f64)),
                (
                    "buckets",
                    Json::Arr(
                        h.nonzero_buckets()
                            .iter()
                            .map(|&(i, c)| Json::Arr(vec![num(i as f64), num(c as f64)]))
                            .collect(),
                    ),
                ),
            ])
        }
        let workers: Vec<Json> = self.worker_stats.iter().map(WorkerStat::to_json).collect();
        obj(vec![
            ("schema", s("fastmamba.metrics.v1")),
            ("requests_completed", num(self.requests_completed as f64)),
            ("prompt_tokens", num(self.prompt_tokens as f64)),
            ("tokens_generated", num(self.tokens_generated as f64)),
            ("prefill_chunks", num(self.prefill_chunks as f64)),
            ("decode_steps", num(self.decode_steps as f64)),
            ("decode_padded_slots", num(self.decode_padded_slots as f64)),
            ("decode_batch_slots", num(self.decode_batch_slots as f64)),
            ("draft_tokens", num(self.draft_tokens as f64)),
            ("draft_accepted", num(self.draft_accepted as f64)),
            ("spec_rounds", num(self.spec_rounds as f64)),
            ("verify_calls", num(self.verify_calls as f64)),
            ("rollbacks", num(self.rollbacks as f64)),
            ("resync_steps", num(self.resync_steps as f64)),
            ("drafter_reseeds", num(self.drafter_reseeds as f64)),
            ("cache_hits", num(self.cache_hits as f64)),
            ("cache_misses", num(self.cache_misses as f64)),
            ("cache_tokens_saved", num(self.cache_tokens_saved as f64)),
            ("cancelled_requests", num(self.cancelled_requests as f64)),
            ("deadline_expired", num(self.deadline_expired as f64)),
            ("preempted_requests", num(self.preempted_requests as f64)),
            ("requests_shed", num(self.requests_shed as f64)),
            ("requests_dropped", num(self.requests_dropped as f64)),
            ("aging_reorders", num(self.aging_reorders as f64)),
            ("queue_depth_peak", num(self.queue_depth_peak as f64)),
            ("wall_s", num(self.wall_s())),
            ("busy_s", num(self.busy_s)),
            ("utilization", num(self.utilization())),
            ("gen_tok_per_s", num(self.decode_tokens_per_s())),
            ("padding_frac", num(self.padding_frac())),
            ("acceptance_rate", num(self.acceptance_rate())),
            ("cache_hit_rate", num(self.cache_hit_rate())),
            ("ttft_s", hist(&self.ttft)),
            ("request_latency_s", hist(&self.latency)),
            ("tpot_s", hist(&self.tpot)),
            ("draft_acceptance", hist(&self.acceptance)),
            ("prefill_call_s", hist(&self.prefill_call)),
            ("decode_call_s", hist(&self.decode_call)),
            ("workers", Json::Arr(workers)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::default();
        for v in [0.1, 0.2, 0.3, 0.4, 1.0] {
            m.note_ttft(v);
        }
        // histogram-backed: within one bucket (≈9%) of the exact
        // nearest-rank quantiles 0.3 / 1.0
        assert!((m.ttft_p50() - 0.3).abs() / 0.3 < 0.10, "{}", m.ttft_p50());
        assert!((m.ttft_p95() - 1.0).abs() / 1.0 < 0.10, "{}", m.ttft_p95());
        assert_eq!(m.ttft.count(), 5);
    }

    #[test]
    fn empty_safe() {
        let m = Metrics::default();
        assert_eq!(m.ttft_p50(), 0.0);
        assert_eq!(m.decode_tokens_per_s(), 0.0);
        let _ = m.summary();
        let _ = m.to_json();
    }

    #[test]
    fn wall_clock_runs() {
        let mut m = Metrics::default();
        m.start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.stop();
        assert!(m.wall_s() >= 0.004);
    }

    #[test]
    fn padding_frac_is_a_fraction_of_slots() {
        let mut m = Metrics::default();
        m.decode_batch_slots = 16;
        m.decode_padded_slots = 4;
        assert!((m.padding_frac() - 0.25).abs() < 1e-12);
        let empty = Metrics::default();
        assert_eq!(empty.padding_frac(), 0.0);
    }

    #[test]
    fn acceptance_rate_tracks_drafts() {
        let mut m = Metrics::default();
        assert_eq!(m.acceptance_rate(), 0.0);
        m.draft_tokens = 10;
        m.draft_accepted = 8;
        assert!((m.acceptance_rate() - 0.8).abs() < 1e-12);
        for v in [0.5, 0.8, 0.9] {
            m.note_acceptance(v);
        }
        assert!((m.acceptance_p50() - 0.8).abs() / 0.8 < 0.10, "{}", m.acceptance_p50());
    }

    #[test]
    fn queue_depth_and_utilization_in_summary() {
        let mut m = Metrics::default();
        m.note_queue_depth(3);
        m.note_queue_depth(7);
        m.note_queue_depth(2);
        assert_eq!(m.queue_depth_peak, 7);
        m.start();
        std::thread::sleep(std::time::Duration::from_millis(4));
        m.busy_s = m.wall_s() * 0.5;
        m.stop();
        let u = m.utilization();
        assert!(u > 0.0 && u <= 1.0, "utilization {u}");
        let s = m.summary();
        assert!(s.contains("qdepth_peak=7"), "{s}");
        assert!(s.contains("util="), "{s}");
        assert!(!s.contains("workers="), "no per-worker block before merge: {s}");
    }

    #[test]
    fn per_worker_stats_in_summary() {
        let mut m = Metrics::default();
        m.worker_stats = vec![
            WorkerStat {
                requests_completed: 3,
                tokens_generated: 30,
                queue_depth_peak: 4,
                utilization: 0.9,
                cache_hits: 2,
                cache_tokens_saved: 64,
                cancelled: 1,
                deadline_expired: 0,
                tpot_p50_s: 0.0015,
            },
            WorkerStat {
                requests_completed: 2,
                tokens_generated: 20,
                queue_depth_peak: 2,
                utilization: 0.5,
                cache_hits: 0,
                cache_tokens_saved: 0,
                cancelled: 0,
                deadline_expired: 0,
                tpot_p50_s: 0.0005,
            },
        ];
        let s = m.summary();
        assert!(s.contains("workers=2"), "{s}");
        assert!(s.contains("util=[90%/50%]"), "{s}");
        assert!(s.contains("qdepth=[4/2]"), "{s}");
        assert!(s.contains("tpot_ms=[1.50/0.50]"), "{s}");
    }

    #[test]
    fn merge_sums_counters_and_spans_wall() {
        let mut a = Metrics::default();
        a.start();
        a.requests_completed = 2;
        a.tokens_generated = 20;
        a.decode_steps = 5;
        a.note_ttft(0.1);
        a.queue_depth_peak = 3;
        a.busy_s = 0.5;
        std::thread::sleep(std::time::Duration::from_millis(3));
        a.stop();

        let mut b = Metrics::default();
        b.start();
        b.requests_completed = 3;
        b.tokens_generated = 10;
        b.decode_steps = 7;
        b.note_ttft(0.2);
        b.note_ttft(0.3);
        b.queue_depth_peak = 5;
        b.busy_s = 0.25;
        std::thread::sleep(std::time::Duration::from_millis(3));
        b.stop();

        let mut m = Metrics::default();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.requests_completed, 5);
        assert_eq!(m.tokens_generated, 30);
        assert_eq!(m.decode_steps, 12);
        assert_eq!(m.ttft.count(), 3, "histogram merge carries all samples");
        assert_eq!(m.queue_depth_peak, 5); // max, not sum
        assert!((m.busy_s - 0.75).abs() < 1e-12); // sum
        // the merged wall spans a's start to b's stop, so it is at least
        // as long as either worker's own span
        assert!(m.wall_s() >= a.wall_s());
        assert!(m.wall_s() >= b.wall_s());
    }

    #[test]
    fn cache_counters_merge_and_summary() {
        let m = Metrics::default();
        assert_eq!(m.cache_hit_rate(), 0.0);
        assert!(!m.summary().contains("cache_hit="), "no cache block before probes");

        let mut a = Metrics::default();
        a.cache_hits = 3;
        a.cache_misses = 1;
        a.cache_tokens_saved = 96;
        let mut b = Metrics::default();
        b.cache_hits = 1;
        b.cache_misses = 3;
        b.cache_tokens_saved = 32;

        let mut m = Metrics::default();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.cache_hits, 4);
        assert_eq!(m.cache_misses, 4);
        assert_eq!(m.cache_tokens_saved, 128);
        assert!((m.cache_hit_rate() - 0.5).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("cache_hit=50%"), "{s}");
        assert!(s.contains("saved_toks=128"), "{s}");
    }

    #[test]
    fn lifecycle_counters_merge_and_summary() {
        let m = Metrics::default();
        assert!(
            !m.summary().contains("cancelled="),
            "no lifecycle block before any cancellation/expiry"
        );
        assert!(m.summary().contains("tpot_p50=0.00ms"), "{}", m.summary());

        let mut a = Metrics::default();
        a.note_finish_reason(FinishReason::Cancelled);
        a.note_finish_reason(FinishReason::Length); // not counted
        a.note_finish_reason(FinishReason::StopToken); // not counted
        a.tpot_s = vec![0.001, 0.002];
        let mut b = Metrics::default();
        b.note_finish_reason(FinishReason::Deadline);
        b.note_finish_reason(FinishReason::Cancelled);
        b.tpot_s = vec![0.004];

        let mut m = Metrics::default();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.cancelled_requests, 2);
        assert_eq!(m.deadline_expired, 1);
        assert_eq!(m.tpot_s.len(), 3);
        assert_eq!(m.tpot_p50(), 0.002);
        assert_eq!(m.tpot_p95(), 0.004);
        let s = m.summary();
        assert!(s.contains("cancelled=2"), "{s}");
        assert!(s.contains("deadline_expired=1"), "{s}");
        assert!(s.contains("tpot_p50=2.00ms"), "{s}");
    }

    #[test]
    fn overload_scheduling_counters_merge_and_summary() {
        let m = Metrics::default();
        assert!(
            !m.summary().contains("preempted="),
            "no scheduling block before any preempt/shed/drop/reorder"
        );

        let mut a = Metrics::default();
        a.note_finish_reason(FinishReason::Preempted);
        a.note_finish_reason(FinishReason::Overloaded);
        a.count(Counter::RequestsDropped, 2);
        let mut b = Metrics::default();
        b.note_finish_reason(FinishReason::Overloaded);
        b.count(Counter::AgingReorders, 3);

        let mut m = Metrics::default();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.preempted_requests, 1);
        assert_eq!(m.requests_shed, 2);
        assert_eq!(m.requests_dropped, 2);
        assert_eq!(m.aging_reorders, 3);
        let s = m.summary();
        assert!(s.contains("preempted=1"), "{s}");
        assert!(s.contains("shed=2"), "{s}");
        assert!(s.contains("dropped=2"), "{s}");
        assert!(s.contains("aging_reorders=3"), "{s}");

        // round-trip through telemetry and the JSON snapshot
        let tel = Arc::new(Telemetry::new());
        let mut live = Metrics::default();
        live.attach_telemetry(Arc::clone(&tel));
        live.note_finish_reason(FinishReason::Preempted);
        live.note_finish_reason(FinishReason::Overloaded);
        live.count(Counter::RequestsDropped, 1);
        live.count(Counter::AgingReorders, 4);
        let snap = Metrics::from_telemetry(&tel);
        assert_eq!(snap.preempted_requests, 1);
        assert_eq!(snap.requests_shed, 1);
        assert_eq!(snap.requests_dropped, 1);
        assert_eq!(snap.aging_reorders, 4);
        let j = crate::util::json::to_string(&live.to_json());
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.usize_field("preempted_requests").unwrap(), 1);
        assert_eq!(back.usize_field("requests_shed").unwrap(), 1);
        assert_eq!(back.usize_field("requests_dropped").unwrap(), 1);
        assert_eq!(back.usize_field("aging_reorders").unwrap(), 4);
    }

    #[test]
    fn tpot_ring_buffer_stays_bounded() {
        let mut m = Metrics::default();
        for i in 0..(TPOT_SAMPLE_CAP + 100) {
            m.note_tpot(i as f64);
        }
        assert_eq!(m.tpot_s.len(), TPOT_SAMPLE_CAP, "per-token samples stay bounded");
        // the oldest samples were overwritten by the newest, in order
        assert_eq!(m.tpot_s[0], TPOT_SAMPLE_CAP as f64);
        assert_eq!(m.tpot_s[99], (TPOT_SAMPLE_CAP + 99) as f64);
        assert_eq!(m.tpot_s[100], 100.0);
        // the all-time histogram kept every sample without growing
        assert_eq!(m.tpot.count(), (TPOT_SAMPLE_CAP + 100) as u64);
    }

    #[test]
    fn summary_shows_padding_and_acceptance() {
        let mut m = Metrics::default();
        m.decode_batch_slots = 10;
        m.decode_padded_slots = 1;
        let s = m.summary();
        assert!(s.contains("pad_waste=10.0%"), "{s}");
        assert!(s.contains("accept=n/a"), "{s}");
        m.draft_tokens = 4;
        m.draft_accepted = 3;
        assert!(m.summary().contains("accept=75.0%"), "{}", m.summary());
    }

    #[test]
    fn histogram_backed_samples_stay_bounded_in_memory() {
        let mut m = Metrics::default();
        // warm: allocate every histogram and fill the TPOT ring past cap
        for i in 0..(TPOT_SAMPLE_CAP + 10) {
            let v = 1e-4 + (i % 1000) as f64 * 1e-5;
            m.note_tpot(v);
        }
        for i in 0..1000 {
            let v = 1e-3 + (i % 100) as f64 * 1e-4;
            m.note_ttft(v);
            m.note_latency(v * 10.0);
            m.note_acceptance((i % 10) as f64 / 10.0);
            m.note_prefill_call(v);
            m.note_decode_call(v);
        }
        let warm = m.sample_heap_bytes();
        // before the histogram migration this loop grew ~3 Vec entries per
        // request forever; now 100k more requests allocate nothing
        for i in 0..100_000 {
            let v = 1e-3 + (i % 997) as f64 * 1e-5;
            m.note_ttft(v);
            m.note_latency(v * 10.0);
            m.note_acceptance((i % 10) as f64 / 10.0);
            m.note_tpot(v / 10.0);
            m.note_prefill_call(v);
            m.note_decode_call(v);
        }
        assert_eq!(m.sample_heap_bytes(), warm, "sample memory is flat");
        assert_eq!(m.ttft.count(), 101_000);
        // sanity bound: six bucket arrays + the f64 ring, < 2 MiB total
        assert!(warm < 2 << 20, "warm sample memory {warm} bytes");
    }

    #[test]
    fn telemetry_write_through_matches_snapshot() {
        let tel = Arc::new(Telemetry::new());
        let mut m = Metrics::default();
        m.attach_telemetry(Arc::clone(&tel));
        m.count(Counter::RequestsCompleted, 3);
        m.count(Counter::TokensGenerated, 48);
        m.count(Counter::PromptTokens, 96);
        m.count(Counter::CacheHits, 2);
        m.note_finish_reason(FinishReason::Cancelled);
        m.note_ttft(0.05);
        m.note_latency(0.5);
        m.note_tpot(0.002);
        m.note_acceptance(0.75);
        m.note_busy(0.25);
        m.note_queue_depth(4);
        m.note_queue_depth(2);
        m.note_active_slots(3);

        let snap = Metrics::from_telemetry(&tel);
        assert_eq!(snap.requests_completed, m.requests_completed);
        assert_eq!(snap.tokens_generated, m.tokens_generated);
        assert_eq!(snap.prompt_tokens, m.prompt_tokens);
        assert_eq!(snap.cache_hits, m.cache_hits);
        assert_eq!(snap.cancelled_requests, m.cancelled_requests);
        assert_eq!(snap.queue_depth_peak, m.queue_depth_peak);
        assert!((snap.busy_s - m.busy_s).abs() < 1e-5);
        assert_eq!(snap.ttft.count(), m.ttft.count());
        assert_eq!(snap.ttft.quantile(0.5), m.ttft.quantile(0.5));
        assert_eq!(snap.latency.count(), 1);
        assert_eq!(snap.tpot.count(), 1);
        assert_eq!(snap.acceptance.count(), 1);
        assert_eq!(tel.gauge(crate::obs::Gauge::ActiveSlots), 3);
    }

    #[test]
    fn metrics_json_histograms_carry_bucket_counts() {
        let mut m = Metrics::default();
        m.note_tpot(0.0); // spec engines legitimately record 0-second gaps
        m.note_tpot(0.002);
        m.note_tpot(0.002);
        m.note_tpot(0.750);
        let text = crate::util::json::to_string(&m.to_json());
        let back = Json::parse(&text).unwrap();
        let h = back.get("tpot_s").unwrap();
        assert_eq!(h.usize_field("count").unwrap(), 4);
        assert_eq!(h.usize_field("zero").unwrap(), 1);
        let buckets = h.arr_field("buckets").unwrap();
        let total: usize = buckets
            .iter()
            .map(|p| p.as_arr().unwrap()[1].as_usize().unwrap())
            .sum();
        assert_eq!(total + 1, 4, "zero class + bucket counts == count");
        // round-trip: the exported pairs rebuild the exact count_over view
        let mut rebuilt = 0u64;
        for p in buckets {
            let p = p.as_arr().unwrap();
            let (i, c) = (p[0].as_usize().unwrap(), p[1].as_usize().unwrap() as u64);
            if Histogram::bucket_upper_edge(i) > 0.01 {
                rebuilt += c;
            }
        }
        assert_eq!(rebuilt, m.tpot.count_over(0.01));
    }

    #[test]
    fn metrics_json_snapshot_has_schema_and_histograms() {
        let mut m = Metrics::default();
        m.count(Counter::RequestsCompleted, 2);
        m.note_ttft(0.1);
        m.note_latency(1.0);
        m.worker_stats.push(WorkerStat {
            requests_completed: 2,
            tokens_generated: 16,
            queue_depth_peak: 1,
            utilization: 0.5,
            cache_hits: 0,
            cache_tokens_saved: 0,
            cancelled: 0,
            deadline_expired: 0,
            tpot_p50_s: 0.001,
        });
        let j = m.to_json();
        let text = crate::util::json::to_string(&j);
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.str_field("schema").unwrap(), "fastmamba.metrics.v1");
        assert_eq!(back.usize_field("requests_completed").unwrap(), 2);
        assert_eq!(back.get("ttft_s").unwrap().usize_field("count").unwrap(), 1);
        assert_eq!(back.arr_field("workers").unwrap().len(), 1);
        let p50 = back.get("ttft_s").unwrap().get("p50").unwrap().as_f64().unwrap();
        assert!((p50 - 0.1).abs() / 0.1 < 0.10, "{p50}");
    }
}
