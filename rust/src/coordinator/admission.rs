//! Admission-time helpers shared by both serving engines.
//!
//! [`seed_from_cache`] is the state-cache seeding block that used to be
//! duplicated between `Engine::admit` and `SpecEngine::admit` (flagged in
//! the PR-4 review).  The two copies must stay in lock-step for cache
//! entries to interchange between the engines — a session entry written by
//! the plain engine must seed a speculative admission and vice versa — so
//! the sharing is structural now, not a review checklist item.
//!
//! Both engines chunk-prefill at most `prompt.len() - 1` tokens (the plain
//! engine reserves the final token for the decode path; the speculative
//! engine's "body" excludes the frontier token), which is what makes one
//! helper serve both: the canonical chunk plan, the session-hit replan, and
//! the prefix-boundary probes are computed over the same token range.

use std::sync::Arc;

use super::batcher::full_bucket_plan;
use super::metrics::Metrics;
use super::request::{Event, FinishReason, FinishedRequest, Request};
use super::state::StatePool;
use crate::obs::trace::TraceCtx;
use crate::obs::{Counter, FlightCtx, FlightKind};
use crate::statecache::StateCache;

/// Outcome of seeding one admission from the shared state cache.
pub(crate) struct AdmissionSeed {
    /// prompt tokens the seeded slot has already consumed (0 on a miss)
    pub offset: usize,
    /// chunks still to prefill, starting at `offset`
    pub chunks: Vec<usize>,
    /// canonical chunk-plan prefix already covered (grown and published as
    /// the remaining chunks complete); empty after a session hit
    pub done_chunks: Vec<usize>,
    /// whether boundary snapshots of this admission may be published (a
    /// session hit disables it: the seeded state's provenance is the
    /// previous turn's trajectory, not this prompt's canonical chunk plan)
    pub prefix_cacheable: bool,
}

/// Probe the state cache for this admission and seed `slot` from the best
/// hit: a session hit (the previous turn's exact end state, which can
/// reach past any bucket boundary) beats the longest bucket-aligned prefix
/// hit of the prompt's own canonical plan.  Either way only the uncovered
/// suffix remains to prefill.  Cache metrics are recorded here; with no
/// cache attached this is a no-op returning the unmodified plan.
///
/// `chunks` is the canonical full-bucket plan over `prompt[..len-1]`; the
/// caller derives its own remainder/debt from `offset` + the returned
/// chunks, so both engines keep their exact pre-helper arithmetic.
pub(crate) fn seed_from_cache(
    cache: Option<&Arc<StateCache>>,
    pool: &mut StatePool,
    metrics: &mut Metrics,
    slot: usize,
    variant: &str,
    prompt: &[u32],
    session_id: Option<u64>,
    buckets: &[usize],
    chunks: Vec<usize>,
) -> AdmissionSeed {
    let mut seed = AdmissionSeed {
        offset: 0,
        chunks,
        done_chunks: Vec::new(),
        prefix_cacheable: cache.is_some(),
    };
    let Some(cache) = cache else { return seed };
    let plan_len = prompt.len() - 1; // both engines chunk at most len-1
    let probed = session_id.is_some() || !seed.chunks.is_empty();
    let mut hit = false;
    if let Some(sid) = session_id {
        if let Some(s) = cache.lookup_session(sid, variant, prompt) {
            // lookup_session bounds coverage at prompt.len() - 1, i.e. at
            // most the whole chunkable range
            if pool.seed(slot, &s.conv, &s.ssm) {
                seed.offset = s.covered;
                seed.chunks = full_bucket_plan(buckets, plan_len - s.covered).0;
                seed.prefix_cacheable = false;
                hit = true;
            }
        }
    }
    if !hit {
        if let Some(p) = cache.lookup_prefix(variant, prompt, &seed.chunks) {
            if pool.seed(slot, &p.conv, &p.ssm) {
                seed.offset = p.covered;
                seed.done_chunks = seed.chunks[..p.chunks_used].to_vec();
                seed.chunks = seed.chunks[p.chunks_used..].to_vec();
                hit = true;
            }
        }
    }
    if hit {
        metrics.count(Counter::CacheHits, 1);
        metrics.count(Counter::CacheTokensSaved, seed.offset as u64);
    } else if probed {
        metrics.count(Counter::CacheMisses, 1);
    }
    seed
}

/// Retire a request that never reached admission (cancelled, past its
/// deadline, or shed at a full queue while still pending): no slot to
/// free, terminal event emitted — the same `FinishedRequest` surface as
/// the normal path.  These requests never produced a token from this
/// admission, so no latency sample is recorded: the latency histogram
/// holds completed requests only.  Non-shed retirements count under
/// `requests_dropped`; `Overloaded` sheds count under `requests_shed`
/// (via `note_finish_reason`).
///
/// A previously preempted request carries its already-streamed transcript
/// in `resume`; the terminal `FinishedRequest` reports those tokens so the
/// client-visible output stays consistent across the preemption.
pub(crate) fn finish_unadmitted(
    metrics: &mut Metrics,
    trace: Option<&TraceCtx>,
    flight: Option<&FlightCtx>,
    finished: &mut Vec<FinishedRequest>,
    mut req: Request,
    reason: FinishReason,
) {
    metrics.note_finish_reason(reason);
    metrics.count(Counter::RequestsCompleted, 1);
    if reason != FinishReason::Overloaded {
        metrics.count(Counter::RequestsDropped, 1);
    }
    let total_s = req.submitted_at.elapsed().as_secs_f64();
    let (generated, ttft_s) = match req.resume.take() {
        Some(mut r) => {
            // release tokens a partial stop-sequence match was holding
            // back — same as the non-StopSequence retire path
            r.stream.flush(&req);
            (
                r.generated,
                r.first_token_at
                    .map(|t| t.saturating_duration_since(req.submitted_at).as_secs_f64())
                    .unwrap_or(0.0),
            )
        }
        None => (Vec::new(), 0.0),
    };
    if let Some(t) = trace {
        if t.sink.sampled(req.id) {
            if reason == FinishReason::Overloaded {
                t.sink.instant(req.id, "shed", Vec::new());
            }
            t.sink.end_request(req.id, &format!("{reason:?}"), generated.len());
        }
    }
    if let Some(f) = flight {
        if reason == FinishReason::Overloaded {
            f.record(req.id, FlightKind::Shed, "queue at shed threshold");
        }
        f.record(
            req.id,
            FlightKind::Finish,
            format!("{reason:?} unadmitted tokens={}", generated.len()),
        );
    }
    let fin = FinishedRequest {
        id: req.id,
        prompt_len: req.prompt.len(),
        generated,
        finish_reason: reason,
        ttft_s,
        total_s,
        spec: None,
    };
    req.emit(Event::Finished(fin.clone()));
    finished.push(fin);
}
