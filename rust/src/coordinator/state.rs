//! SSM state pool: fixed-size per-request recurrent state slots, with
//! versioned snapshots for speculative decoding.
//!
//! Because a Mamba2 request's state size is independent of its prompt or
//! generation length, the pool is a flat arena of identical slots — O(1)
//! allocate/free, zero fragmentation, exact capacity accounting (the
//! admission-control advantage over KV-cache serving).
//!
//! Speculative decoding adds the second requirement transformers don't
//! have: when draft tokens are rejected, the recurrent state must return
//! to the last committed position.  [`StatePool::snapshot`] captures a
//! slot's (conv window, SSM hidden state) under a monotonically increasing
//! version, and [`StatePool::rollback`] restores it in O(state) — a pair
//! of buffer moves, no recompute of the token prefix.

use crate::config::ModelConfig;

/// One request's recurrent state (host-side mirror of what the decode
/// executable consumes/produces).
#[derive(Debug, Clone)]
pub struct StateSlot {
    pub conv: Vec<f32>,
    pub ssm: Vec<f32>,
}

/// Handle to a versioned snapshot taken with [`StatePool::snapshot`].
///
/// Versions are global and monotonic, so a stale id (slot released and
/// re-allocated, or snapshot already consumed) can never silently resolve
/// to another request's state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SnapshotId {
    slot: usize,
    version: u64,
}

impl SnapshotId {
    pub fn slot(&self) -> usize {
        self.slot
    }
}

/// Pool of pre-allocated state slots.
#[derive(Debug)]
pub struct StatePool {
    slots: Vec<StateSlot>,
    free: Vec<usize>,
    conv_len: usize,
    ssm_len: usize,
    /// per-slot stack of (version, saved state), oldest first
    saved: Vec<Vec<(u64, StateSlot)>>,
    next_version: u64,
}

impl StatePool {
    pub fn new(cfg: &ModelConfig, capacity: usize) -> Self {
        let conv_len = cfg.conv_state_len();
        let ssm_len = cfg.ssm_state_len();
        let slots = (0..capacity)
            .map(|_| StateSlot { conv: vec![0.0; conv_len], ssm: vec![0.0; ssm_len] })
            .collect();
        Self {
            slots,
            free: (0..capacity).rev().collect(),
            conv_len,
            ssm_len,
            saved: (0..capacity).map(|_| Vec::new()).collect(),
            next_version: 0,
        }
    }

    /// Allocate a zeroed slot; `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<usize> {
        let idx = self.free.pop()?;
        debug_assert!(self.saved[idx].is_empty());
        self.slots[idx].conv.fill(0.0);
        self.slots[idx].ssm.fill(0.0);
        Some(idx)
    }

    pub fn release(&mut self, idx: usize) {
        debug_assert!(!self.free.contains(&idx));
        self.saved[idx].clear();
        self.free.push(idx);
    }

    /// Capture the slot's current state under a fresh version.  Snapshots
    /// stack per slot (speculative rounds nest), oldest first.
    pub fn snapshot(&mut self, idx: usize) -> SnapshotId {
        self.next_version += 1;
        let copy = self.slots[idx].clone();
        self.saved[idx].push((self.next_version, copy));
        SnapshotId { slot: idx, version: self.next_version }
    }

    /// Restore the slot to `id` and drop `id` plus every newer snapshot of
    /// the slot (they describe a rejected continuation).  O(state): the
    /// saved buffers are moved back, nothing is recomputed.
    ///
    /// Panics on a stale id — rolling back to a state the pool no longer
    /// holds is a scheduling bug, not a recoverable condition.
    pub fn rollback(&mut self, id: SnapshotId) {
        let stack = &mut self.saved[id.slot];
        let pos = stack
            .iter()
            .position(|(v, _)| *v == id.version)
            .expect("rollback of a discarded or stale snapshot");
        let mut tail = stack.split_off(pos);
        let (_, snap) = tail.swap_remove(0);
        self.slots[id.slot] = snap;
    }

    /// Drop a snapshot without restoring it (the accepted-draft path).
    /// Discarding an already-dropped id is a no-op.
    pub fn discard(&mut self, id: SnapshotId) {
        let stack = &mut self.saved[id.slot];
        if let Some(pos) = stack.iter().position(|(v, _)| *v == id.version) {
            stack.remove(pos);
        }
    }

    /// Drop every snapshot held for `idx`.
    pub fn clear_snapshots(&mut self, idx: usize) {
        self.saved[idx].clear();
    }

    /// Snapshots currently held for `idx`.
    pub fn n_snapshots(&self, idx: usize) -> usize {
        self.saved[idx].len()
    }

    /// Bytes currently held by snapshots across the pool (the speculative
    /// overhead the admission accounting must include).
    pub fn snapshot_bytes(&self) -> usize {
        let per = 4 * (self.conv_len + self.ssm_len);
        self.saved.iter().map(|s| s.len() * per).sum()
    }

    /// Overwrite a slot with an externally held snapshot (a state-cache
    /// hit).  Returns false — leaving the slot untouched — when the
    /// snapshot's buffer lengths don't match this pool's model, which can
    /// only happen if a cache is shared across different model shapes.
    pub fn seed(&mut self, idx: usize, conv: &[f32], ssm: &[f32]) -> bool {
        if conv.len() != self.conv_len || ssm.len() != self.ssm_len {
            return false;
        }
        self.slots[idx].conv.copy_from_slice(conv);
        self.slots[idx].ssm.copy_from_slice(ssm);
        true
    }

    pub fn get(&self, idx: usize) -> &StateSlot {
        &self.slots[idx]
    }

    pub fn get_mut(&mut self, idx: usize) -> &mut StateSlot {
        &mut self.slots[idx]
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Bytes per slot — the O(1) admission cost.
    pub fn slot_bytes(&self) -> usize {
        4 * (self.conv_len + self.ssm_len)
    }

    /// Gather `slots` into batch-major contiguous buffers for the decode
    /// executable.
    pub fn gather(&self, idxs: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let mut conv = Vec::with_capacity(idxs.len() * self.conv_len);
        let mut ssm = Vec::with_capacity(idxs.len() * self.ssm_len);
        for &i in idxs {
            conv.extend_from_slice(&self.slots[i].conv);
            ssm.extend_from_slice(&self.slots[i].ssm);
        }
        (conv, ssm)
    }

    /// Scatter batch-major outputs back into the slots.
    pub fn scatter(&mut self, idxs: &[usize], conv: &[f32], ssm: &[f32]) {
        assert_eq!(conv.len(), idxs.len() * self.conv_len);
        assert_eq!(ssm.len(), idxs.len() * self.ssm_len);
        for (b, &i) in idxs.iter().enumerate() {
            self.slots[i]
                .conv
                .copy_from_slice(&conv[b * self.conv_len..(b + 1) * self.conv_len]);
            self.slots[i]
                .ssm
                .copy_from_slice(&ssm[b * self.ssm_len..(b + 1) * self.ssm_len]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> StatePool {
        StatePool::new(&ModelConfig::tiny(), 4)
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.in_use(), 2);
        p.release(a);
        assert_eq!(p.in_use(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(c, a); // LIFO reuse
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut p = pool();
        for _ in 0..4 {
            assert!(p.alloc().is_some());
        }
        assert!(p.alloc().is_none());
    }

    #[test]
    fn alloc_zeroes_recycled_slot() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        p.get_mut(a).ssm[0] = 42.0;
        p.release(a);
        let b = p.alloc().unwrap();
        assert_eq!(b, a);
        assert_eq!(p.get(b).ssm[0], 0.0);
    }

    #[test]
    fn seed_checks_shapes_and_overwrites() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let conv = vec![1.5f32; p.get(a).conv.len()];
        let ssm = vec![-2.5f32; p.get(a).ssm.len()];
        assert!(p.seed(a, &conv, &ssm));
        assert_eq!(p.get(a).conv[0], 1.5);
        assert_eq!(p.get(a).ssm[0], -2.5);
        // wrong shape: rejected, slot untouched
        assert!(!p.seed(a, &conv[1..], &ssm));
        assert_eq!(p.get(a).conv[0], 1.5);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        p.get_mut(a).ssm[3] = 1.5;
        p.get_mut(b).conv[7] = -2.5;
        let (conv, ssm) = p.gather(&[a, b]);
        // mutate then scatter back swapped
        p.scatter(&[b, a], &conv, &ssm);
        assert_eq!(p.get(b).ssm[3], 1.5);
        assert_eq!(p.get(a).conv[7], -2.5);
    }

    #[test]
    fn slot_bytes_matches_model() {
        let p = pool();
        let cfg = ModelConfig::tiny();
        let expect = 4 * (cfg.n_layer * (cfg.d_conv - 1) * cfg.conv_dim()
            + cfg.n_layer * cfg.nheads() * cfg.headdim * cfg.d_state);
        assert_eq!(p.slot_bytes(), expect);
    }

    #[test]
    fn snapshot_rollback_restores_state() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        p.get_mut(a).ssm[0] = 1.0;
        p.get_mut(a).conv[2] = -3.0;
        let snap = p.snapshot(a);
        p.get_mut(a).ssm[0] = 2.0;
        p.get_mut(a).conv[2] = 9.0;
        p.rollback(snap);
        assert_eq!(p.get(a).ssm[0], 1.0);
        assert_eq!(p.get(a).conv[2], -3.0);
        assert_eq!(p.n_snapshots(a), 0); // consumed
    }

    #[test]
    fn discard_keeps_current_state() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        p.get_mut(a).ssm[0] = 1.0;
        let snap = p.snapshot(a);
        p.get_mut(a).ssm[0] = 2.0;
        p.discard(snap);
        assert_eq!(p.get(a).ssm[0], 2.0);
        assert_eq!(p.n_snapshots(a), 0);
        p.discard(snap); // double-discard is a no-op
    }

    #[test]
    fn rollback_drops_newer_snapshots() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        p.get_mut(a).ssm[0] = 1.0;
        let s1 = p.snapshot(a);
        p.get_mut(a).ssm[0] = 2.0;
        let _s2 = p.snapshot(a);
        p.get_mut(a).ssm[0] = 3.0;
        assert_eq!(p.n_snapshots(a), 2);
        p.rollback(s1); // restores 1.0, drops s1 and the newer s2
        assert_eq!(p.get(a).ssm[0], 1.0);
        assert_eq!(p.n_snapshots(a), 0);
    }

    #[test]
    fn rollback_keeps_older_snapshots() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        p.get_mut(a).ssm[0] = 1.0;
        let s1 = p.snapshot(a);
        p.get_mut(a).ssm[0] = 2.0;
        let s2 = p.snapshot(a);
        p.get_mut(a).ssm[0] = 3.0;
        p.rollback(s2);
        assert_eq!(p.get(a).ssm[0], 2.0);
        assert_eq!(p.n_snapshots(a), 1); // s1 survives
        p.rollback(s1);
        assert_eq!(p.get(a).ssm[0], 1.0);
    }

    #[test]
    fn release_clears_snapshots() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        p.snapshot(a);
        assert_eq!(p.snapshot_bytes(), p.slot_bytes());
        p.release(a);
        let b = p.alloc().unwrap();
        assert_eq!(b, a);
        assert_eq!(p.n_snapshots(b), 0);
        assert_eq!(p.snapshot_bytes(), 0);
    }

    #[test]
    fn snapshots_are_per_slot() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        p.get_mut(a).ssm[0] = 1.0;
        p.get_mut(b).ssm[0] = 10.0;
        let sa = p.snapshot(a);
        p.get_mut(a).ssm[0] = 2.0;
        p.get_mut(b).ssm[0] = 20.0;
        p.rollback(sa);
        assert_eq!(p.get(a).ssm[0], 1.0);
        assert_eq!(p.get(b).ssm[0], 20.0); // untouched
    }
}
