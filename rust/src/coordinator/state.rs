//! SSM state pool: fixed-size per-request recurrent state slots.
//!
//! Because a Mamba2 request's state size is independent of its prompt or
//! generation length, the pool is a flat arena of identical slots — O(1)
//! allocate/free, zero fragmentation, exact capacity accounting (the
//! admission-control advantage over KV-cache serving).

use crate::config::ModelConfig;

/// One request's recurrent state (host-side mirror of what the decode
/// executable consumes/produces).
#[derive(Debug, Clone)]
pub struct StateSlot {
    pub conv: Vec<f32>,
    pub ssm: Vec<f32>,
}

/// Pool of pre-allocated state slots.
#[derive(Debug)]
pub struct StatePool {
    slots: Vec<StateSlot>,
    free: Vec<usize>,
    conv_len: usize,
    ssm_len: usize,
}

impl StatePool {
    pub fn new(cfg: &ModelConfig, capacity: usize) -> Self {
        let conv_len = cfg.n_layer * (cfg.d_conv - 1) * cfg.conv_dim();
        let ssm_len = cfg.n_layer * cfg.nheads() * cfg.headdim * cfg.d_state;
        let slots = (0..capacity)
            .map(|_| StateSlot { conv: vec![0.0; conv_len], ssm: vec![0.0; ssm_len] })
            .collect();
        Self { slots, free: (0..capacity).rev().collect(), conv_len, ssm_len }
    }

    /// Allocate a zeroed slot; `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<usize> {
        let idx = self.free.pop()?;
        self.slots[idx].conv.fill(0.0);
        self.slots[idx].ssm.fill(0.0);
        Some(idx)
    }

    pub fn release(&mut self, idx: usize) {
        debug_assert!(!self.free.contains(&idx));
        self.free.push(idx);
    }

    pub fn get(&self, idx: usize) -> &StateSlot {
        &self.slots[idx]
    }

    pub fn get_mut(&mut self, idx: usize) -> &mut StateSlot {
        &mut self.slots[idx]
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Bytes per slot — the O(1) admission cost.
    pub fn slot_bytes(&self) -> usize {
        4 * (self.conv_len + self.ssm_len)
    }

    /// Gather `slots` into batch-major contiguous buffers for the decode
    /// executable.
    pub fn gather(&self, idxs: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let mut conv = Vec::with_capacity(idxs.len() * self.conv_len);
        let mut ssm = Vec::with_capacity(idxs.len() * self.ssm_len);
        for &i in idxs {
            conv.extend_from_slice(&self.slots[i].conv);
            ssm.extend_from_slice(&self.slots[i].ssm);
        }
        (conv, ssm)
    }

    /// Scatter batch-major outputs back into the slots.
    pub fn scatter(&mut self, idxs: &[usize], conv: &[f32], ssm: &[f32]) {
        assert_eq!(conv.len(), idxs.len() * self.conv_len);
        assert_eq!(ssm.len(), idxs.len() * self.ssm_len);
        for (b, &i) in idxs.iter().enumerate() {
            self.slots[i]
                .conv
                .copy_from_slice(&conv[b * self.conv_len..(b + 1) * self.conv_len]);
            self.slots[i]
                .ssm
                .copy_from_slice(&ssm[b * self.ssm_len..(b + 1) * self.ssm_len]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> StatePool {
        StatePool::new(&ModelConfig::tiny(), 4)
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.in_use(), 2);
        p.release(a);
        assert_eq!(p.in_use(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(c, a); // LIFO reuse
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut p = pool();
        for _ in 0..4 {
            assert!(p.alloc().is_some());
        }
        assert!(p.alloc().is_none());
    }

    #[test]
    fn alloc_zeroes_recycled_slot() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        p.get_mut(a).ssm[0] = 42.0;
        p.release(a);
        let b = p.alloc().unwrap();
        assert_eq!(b, a);
        assert_eq!(p.get(b).ssm[0], 0.0);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        p.get_mut(a).ssm[3] = 1.5;
        p.get_mut(b).conv[7] = -2.5;
        let (conv, ssm) = p.gather(&[a, b]);
        // mutate then scatter back swapped
        p.scatter(&[b, a], &conv, &ssm);
        assert_eq!(p.get(b).ssm[3], 1.5);
        assert_eq!(p.get(a).conv[7], -2.5);
    }

    #[test]
    fn slot_bytes_matches_model() {
        let p = pool();
        let cfg = ModelConfig::tiny();
        let expect = 4 * (cfg.n_layer * (cfg.d_conv - 1) * cfg.conv_dim()
            + cfg.n_layer * cfg.nheads() * cfg.headdim * cfg.d_state);
        assert_eq!(p.slot_bytes(), expect);
    }
}
