//! Speculative decoding: quantized drafter + fp32 verifier with SSM state
//! checkpoint/rollback.
//!
//! Decode is the binding constraint in the Table III experiments — one
//! weight stream per generated token.  Speculative decoding breaks that
//! coupling: a cheap drafter proposes `k` tokens with single-token decode
//! steps, and the verifier scores all of them in **one** chunked-prefill
//! style call, committing the longest accepted prefix plus the verifier's
//! own next token (so every round commits at least one token and the
//! output is token-exact with plain greedy verifier decode).
//!
//! Mamba-class models add a problem transformers don't have (SpecMamba,
//! PAPERS.md): the recurrent (conv window, SSM hidden) state advances
//! destructively, so rejected drafts must *roll back*.  Two mechanisms
//! handle this without recomputing any committed prefix:
//!
//! * **Drafter — versioned snapshots.** Before every draft step after the
//!   first, the drafter's state slot is checkpointed via
//!   [`StatePool::snapshot`] (O(state) buffer copies).  On a mid-round
//!   rejection the slot is restored with [`StatePool::rollback`] directly
//!   to the commit point — zero re-decode.
//! * **Verifier — debt-based verify windows.** Prefill artifacts exist
//!   only at bucket lengths, and a right-padded prefill returns a polluted
//!   final state, so the verify call is *stateless*: its output state is
//!   dropped and only its (exact, causal) per-position logits are used.
//!   Committed-but-unconsumed tokens accumulate as the verifier's "debt",
//!   re-sent as the prefix of each verify window; once the debt reaches a
//!   full bucket it is folded into the verifier slot with an exact
//!   chunked-prefill call (the same bit-exact chaining the [`Engine`]
//!   admission path uses).
//!
//! Drafter and verifier are each **any [`InferenceBackend`]** — the
//! classic deployment pairs an in-process [`NativeBackend`] drafter (a
//! drafter step on a host runtime is dominated by per-call marshalling,
//! not FLOPs, so in-process drafting mirrors the FPGA drafter's smaller
//! weight stream) with a PJRT verifier, but drafting on the serving
//! backend itself, or verifying natively on an artifact-free host, are
//! the same code path.  The drafter is seeded from the verifier's exact
//! post-prefill state (same architecture, same state shapes — enforced at
//! construction), which both skips a second prompt prefill and keeps the
//! drafter's trajectory close to the verifier's: acceptance is limited
//! only by int8+PoT quantization noise, not state divergence.
//!
//! [`Engine`]: super::scheduler::Engine
//! [`NativeBackend`]: crate::backend::NativeBackend

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::backend::InferenceBackend;
use crate::obs::trace::TraceCtx;
use crate::obs::{Counter, FlightCtx, FlightKind, Telemetry, TraceSink};
use crate::statecache::StateCache;
use crate::util::json::{num, Json};

use super::admission::{finish_unadmitted, seed_from_cache, AdmissionSeed};
use super::batcher::{full_bucket_plan, smallest_covering};
use super::metrics::Metrics;
use super::request::{
    age_queue, insert_by_priority, Event, FinishReason, FinishedRequest, Request,
    SchedPolicy, SpecStats, SubmitHandle,
};
use super::sampler::{
    keyed_uniform, OutStream, Sampler, SALT_ACCEPT, SALT_RESAMPLE, SALT_SAMPLE,
};
use super::state::{SnapshotId, StatePool};

/// Longest accepted draft prefix under greedy verification.
///
/// `verify[i]` is the verifier's greedy token conditioned on the committed
/// prefix plus drafts `0..i` (so `verify[0]` is conditioned on the frontier
/// alone); `verify.len() == drafts.len() + 1`.  Returns `(m, bonus)`: the
/// first `m` drafts are committed, followed by the verifier's own token at
/// the first disagreement (or after all drafts when everything matched).
pub fn accept_drafts(drafts: &[u32], verify: &[u32]) -> (usize, u32) {
    debug_assert_eq!(verify.len(), drafts.len() + 1);
    let mut m = 0;
    while m < drafts.len() && verify[m] == drafts[m] {
        m += 1;
    }
    (m, verify[m])
}

#[derive(Debug, Clone)]
pub struct SpecConfig {
    /// draft tokens proposed per round (clamped per-round near the
    /// generation budget so the final token always comes from the verifier)
    pub draft_k: usize,
    /// variant executed by the drafter ("fastmamba": int8+PoT)
    pub draft_variant: String,
    /// variant executed by the verifier ("fp32" — the equivalence target)
    pub verify_variant: String,
    /// maximum concurrently active requests (each holds two state slots:
    /// drafter + verifier)
    pub max_active: usize,
    /// re-sync the drafter slot from the verifier's exact state at every
    /// debt-consolidation point (ROADMAP "drafter re-seeding"): the
    /// drafter's quantized trajectory drifts from the verifier's over long
    /// generations, and each re-seed restarts it from exact state, at the
    /// cost of replaying the residual (sub-bucket) debt with draft steps.
    /// Never affects output tokens — only the verifier commits.
    pub reseed_drafter: bool,
}

impl Default for SpecConfig {
    fn default() -> Self {
        Self {
            draft_k: 4,
            draft_variant: "fastmamba".into(),
            verify_variant: "fp32".into(),
            max_active: 8,
            reseed_drafter: true,
        }
    }
}

/// One active speculative request.
#[derive(Debug)]
struct SpecInFlight {
    req: Request,
    draft_slot: usize,
    verify_slot: usize,
    /// committed tokens the verifier slot has not absorbed yet (exclusive
    /// of the frontier); folded into the slot at full-bucket granularity
    debt: Vec<u32>,
    /// last committed token — consumed by the next round's draft/verify
    frontier: u32,
    /// committed tokens the *verifier slot* has consumed (admission chunks
    /// plus consolidated debt) — the exact-state coverage a session-cache
    /// entry can claim at retire time
    consumed: usize,
    generated: Vec<u32>,
    drafted: u64,
    accepted: u64,
    rounds: u64,
    submitted: Instant,
    first_token_at: Option<Instant>,
    /// when the latest committed token was emitted (TPOT anchor)
    last_token_at: Option<Instant>,
    done: bool,
    /// why `done` (set by the round that finished the request)
    reason: FinishReason,
    /// per-request sampling state over *committed* tokens (draft rounds
    /// work on scratch clones; only verifier-approved tokens land here)
    sampler: Sampler,
    /// stop-sequence-aware token emitter
    stream: OutStream,
}

/// The speculative serving engine: drives a draft-k / verify-1 loop per
/// active request, round-robin across admissions.  Token-exact with greedy
/// decoding of the verifier variant (see `examples/spec_decode.rs`).
pub struct SpecEngine<'be> {
    drafter: &'be dyn InferenceBackend,
    verifier: &'be dyn InferenceBackend,
    cfg: SpecConfig,
    pool: StatePool,
    prefill_buckets: Vec<usize>, // ascending (verifier's)
    /// shared SSM state cache for the verifier's prefill path (keys use
    /// `verify_variant`, so entries interchange with the plain engine's)
    cache: Option<Arc<StateCache>>,
    pending: VecDeque<Request>,
    active: Vec<SpecInFlight>,
    pub finished: Vec<FinishedRequest>,
    pub metrics: Metrics,
    /// per-request span tracing; `None` = zero overhead
    trace: Option<TraceCtx>,
    /// flight-recorder attachment; `None` = zero overhead
    flight: Option<FlightCtx>,
    /// overload policy: priority aging + bounded-queue shedding.  The
    /// speculative engine does not preempt (an active request holds two
    /// coupled slots plus verifier debt — no single-state snapshot to
    /// resume from); qualifying traffic preempts on the plain engine.
    policy: SchedPolicy,
}

impl<'be> SpecEngine<'be> {
    /// Draft and verify on the same backend.
    pub fn new(be: &'be dyn InferenceBackend, cfg: SpecConfig) -> Self {
        Self::with_drafter(be, be, cfg)
    }

    /// Pair any drafter backend with any verifier backend.  The drafter
    /// need **not** serve the verifier's exact configuration (a distilled
    /// drafter has its own weights, and may even partition heads
    /// differently); what state seeding requires is that the flat
    /// (conv, ssm) recurrent-state buffers have the same lengths, and
    /// token exchange requires a shared vocabulary.  Output correctness
    /// never depends on the drafter — only the verifier commits tokens.
    pub fn with_drafter(
        drafter: &'be dyn InferenceBackend,
        verifier: &'be dyn InferenceBackend,
        cfg: SpecConfig,
    ) -> Self {
        let state_shape = |c: &crate::config::ModelConfig| {
            (c.conv_state_len(), c.ssm_state_len())
        };
        assert_eq!(
            state_shape(drafter.cfg()),
            state_shape(verifier.cfg()),
            "drafter and verifier must have the same state shape (conv, ssm \
             buffer lengths) — the drafter slot is seeded by copying the \
             verifier's recurrent state"
        );
        assert_eq!(
            drafter.cfg().vocab_size,
            verifier.cfg().vocab_size,
            "drafter and verifier must share a vocabulary"
        );
        assert!(
            drafter.variants().contains(&cfg.draft_variant),
            "drafter backend has no variant {}",
            cfg.draft_variant
        );
        assert!(
            verifier.variants().contains(&cfg.verify_variant),
            "verifier backend has no variant {}",
            cfg.verify_variant
        );
        if cfg.verify_variant != "fp32" {
            // the token-exactness contract needs a chunking-invariant
            // verifier: quantized variants calibrate per verify window
            // (e.g. PoT per-column absmax over the padded chunk), so their
            // speculative output can diverge from plain greedy decode
            eprintln!(
                "warning: verify variant {:?} quantizes per verify window; \
                 speculative output is only guaranteed token-exact with fp32",
                cfg.verify_variant
            );
        }
        let prefill_buckets = verifier.prefill_buckets();
        assert!(!prefill_buckets.is_empty(), "verifier has no prefill buckets");
        let smallest = prefill_buckets[0];
        let largest = *prefill_buckets.last().unwrap();
        assert!(cfg.draft_k >= 1, "draft_k must be >= 1");
        assert!(
            smallest + cfg.draft_k <= largest,
            "draft_k {} too large: verify window (debt < {} plus k+1 drafts) \
             must fit the largest prefill bucket {}",
            cfg.draft_k,
            smallest,
            largest
        );
        let pool = StatePool::new(verifier.cfg(), cfg.max_active * 2);
        Self {
            drafter,
            verifier,
            cfg,
            pool,
            prefill_buckets,
            cache: None,
            pending: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            metrics: Metrics::default(),
            trace: None,
            flight: None,
            policy: SchedPolicy::default(),
        }
    }

    /// Attach a (shared) SSM state cache: admissions seed the verifier
    /// slot from the longest cached prefix (or the session's end-of-turn
    /// state) and prefill only the suffix; the drafter is then seeded from
    /// the verifier as usual.  See [`crate::statecache`].
    pub fn with_cache(mut self, cache: Arc<StateCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Attach a live telemetry cell: every metrics mutation writes through
    /// to it, so a scrape mid-run sees current counts.
    pub fn with_telemetry(mut self, tel: Arc<Telemetry>) -> Self {
        self.metrics.attach_telemetry(tel);
        self
    }

    /// Attach a span-trace sink; `lane` labels this engine's batch spans.
    pub fn with_trace(mut self, sink: Arc<TraceSink>, lane: u32) -> Self {
        self.trace = Some(TraceCtx::new(sink, lane));
        self
    }

    pub(crate) fn set_trace(&mut self, ctx: TraceCtx) {
        self.trace = Some(ctx);
    }

    /// Attach the shared flight recorder under lane `worker` (same
    /// contract as [`Engine::with_flight`](crate::coordinator::Engine)).
    pub fn with_flight(mut self, rec: Arc<crate::obs::FlightRecorder>, worker: u32) -> Self {
        self.flight = Some(FlightCtx::new(rec, worker));
        self
    }

    pub(crate) fn set_flight(&mut self, ctx: FlightCtx) {
        self.flight = Some(ctx);
    }

    /// Attach an overload policy (aging + bounded queue; see
    /// [`SchedPolicy`]).  `preempt_threshold` is ignored here — see the
    /// field note on `policy`.
    pub fn with_policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Queue a request and return its streaming [`SubmitHandle`].  Token
    /// events are emitted only when the verifier consolidates a round —
    /// the stream carries committed tokens, never unverified drafts.
    pub fn submit(&mut self, mut req: Request) -> SubmitHandle {
        let handle = req.attach_events();
        self.enqueue(req);
        handle
    }

    /// Queue a request whose event channel was attached by an external
    /// submit path (the pool worker, or an HTTP frontend feeding requests
    /// through a channel — [`crate::server::ChannelSubmitter`]).
    pub fn enqueue(&mut self, req: Request) {
        if let Some(t) = &self.trace {
            if t.record_queued && t.sink.sampled(req.id) {
                t.sink.begin_request(req.id, req.prompt.len(), req.priority);
            }
        }
        if let Some(f) = &self.flight {
            f.record(
                req.id,
                FlightKind::Enqueue,
                format!("prompt={} priority={}", req.prompt.len(), req.priority),
            );
        }
        // admission control: a full pending queue sheds the arrival
        // immediately with a retriable terminal event (same contract as
        // Engine::enqueue)
        if self.policy.queue_full(self.pending.len()) {
            finish_unadmitted(
                &mut self.metrics,
                self.trace.as_ref(),
                self.flight.as_ref(),
                &mut self.finished,
                req,
                FinishReason::Overloaded,
            );
            return;
        }
        insert_by_priority(&mut self.pending, req);
        self.metrics
            .note_queue_depth(self.pending.len() + self.active.len());
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// One single-token drafter decode on `slot`; returns the logits.
    fn draft_step(&mut self, slot: usize, token: u32) -> Result<Vec<f32>> {
        self.metrics.count(Counter::DecodeSteps, 1);
        self.metrics.count(Counter::DecodeBatchSlots, 1);
        let st = self.pool.get(slot);
        let call_t0 = Instant::now();
        let out = self.drafter.decode(
            &self.cfg.draft_variant,
            1,
            &st.conv,
            &st.ssm,
            &[token as i32],
        )?;
        self.metrics.note_decode_call(call_t0.elapsed().as_secs_f64());
        let stm = self.pool.get_mut(slot);
        stm.conv = out.conv_state;
        stm.ssm = out.ssm_state;
        Ok(out.logits)
    }

    /// Advance the verifier slot over `tokens` with one exact prefill
    /// call; returns the backend call's wall time.
    fn verifier_prefill(&mut self, slot: usize, tokens: &[u32]) -> Result<f64> {
        let toks: Vec<i32> = tokens.iter().map(|t| *t as i32).collect();
        let st = self.pool.get(slot);
        let call_t0 = Instant::now();
        let out =
            self.verifier.prefill(&self.cfg.verify_variant, &toks, &st.conv, &st.ssm)?;
        let call_s = call_t0.elapsed().as_secs_f64();
        let stm = self.pool.get_mut(slot);
        stm.conv = out.conv_state;
        stm.ssm = out.ssm_state;
        self.metrics.note_prefill_call(call_s);
        self.metrics.count(Counter::PrefillChunks, 1);
        Ok(call_s)
    }

    /// Admit pending requests while two state slots remain.  Priority
    /// aging re-sorts the queue first (stable, by effective priority).
    fn admit(&mut self) -> Result<()> {
        if age_queue(&mut self.pending, &self.policy) {
            self.metrics.count(Counter::AgingReorders, 1);
        }
        while !self.pending.is_empty() && self.active.len() < self.cfg.max_active {
            if self.pool.capacity() - self.pool.in_use() < 2 {
                break;
            }
            let req = self.pending.pop_front().unwrap();
            assert!(!req.prompt.is_empty(), "empty prompt");
            // latency anchors at request creation (see Engine::admit)
            let submitted = req.submitted_at;
            let verify_slot = self.pool.alloc().expect("capacity checked");
            let draft_slot = self.pool.alloc().expect("capacity checked");

            // verifier: exact full-bucket prefill of the prompt body; the
            // sub-bucket remainder becomes debt and the last prompt token
            // the frontier (its logits come from the first verify round)
            let body = req.prompt[..req.prompt.len() - 1].to_vec();
            let (chunks, _rest) = full_bucket_plan(&self.prefill_buckets, body.len());
            // state-cache seeding, shared with Engine::admit: the body plan
            // here equals Engine::chunk_plan's chunk list for the same
            // prompt, so prefix entries interchange between the two engines
            // (verify_variant keys them)
            let AdmissionSeed { mut offset, chunks, mut done_chunks, prefix_cacheable } =
                seed_from_cache(
                    self.cache.as_ref(),
                    &mut self.pool,
                    &mut self.metrics,
                    verify_slot,
                    &self.cfg.verify_variant,
                    &req.prompt,
                    req.session_id,
                    &self.prefill_buckets,
                    chunks,
                );
            if let Some(t) = &self.trace {
                if t.sink.sampled(req.id) {
                    t.sink
                        .instant(req.id, "admitted", vec![("slot", num(verify_slot as f64))]);
                    if self.cache.is_some() {
                        t.sink.instant(
                            req.id,
                            "cache_probe",
                            vec![
                                ("hit", Json::Bool(offset > 0)),
                                ("tokens_saved", num(offset as f64)),
                            ],
                        );
                    }
                }
            }
            if let Some(f) = &self.flight {
                f.record(req.id, FlightKind::Admit, format!("slot={verify_slot}"));
                if self.cache.is_some() {
                    f.record(
                        req.id,
                        FlightKind::CacheProbe,
                        format!("hit={} tokens_saved={offset}", offset > 0),
                    );
                }
            }
            for chunk in chunks {
                let toks = body[offset..offset + chunk].to_vec();
                let call_s = self.verifier_prefill(verify_slot, &toks)?;
                if let Some(t) = &self.trace {
                    if t.sink.sampled(req.id) {
                        t.sink.span_request(
                            req.id,
                            "prefill_chunk",
                            call_s,
                            vec![("len", num(chunk as f64))],
                        );
                    }
                }
                offset += chunk;
                if prefix_cacheable {
                    done_chunks.push(chunk);
                    if let Some(cache) = &self.cache {
                        let st = self.pool.get(verify_slot);
                        cache.insert_prefix(
                            &self.cfg.verify_variant,
                            &body[..offset],
                            &done_chunks,
                            &st.conv,
                            &st.ssm,
                        );
                    }
                }
            }
            let consumed = offset;
            let debt: Vec<u32> = body[offset..].to_vec();

            // drafter: seeded from the verifier's exact state, then catches
            // up over the debt with its own quantized decode steps
            let seed = self.pool.get(verify_slot).clone();
            let d = self.pool.get_mut(draft_slot);
            d.conv.copy_from_slice(&seed.conv);
            d.ssm.copy_from_slice(&seed.ssm);
            for &t in &debt {
                let _ = self.draft_step(draft_slot, t)?;
            }

            self.metrics
                .count(Counter::PromptTokens, req.prompt.len() as u64);
            let frontier = *req.prompt.last().unwrap();
            let mut sampler = Sampler::new(req.sampling.clone());
            sampler.observe_context(&req.prompt);
            let stream = OutStream::new(&req.sampling);
            self.active.push(SpecInFlight {
                sampler,
                stream,
                req,
                draft_slot,
                verify_slot,
                debt,
                frontier,
                consumed,
                generated: Vec::new(),
                drafted: 0,
                accepted: 0,
                rounds: 0,
                submitted,
                first_token_at: None,
                last_token_at: None,
                done: false,
                reason: FinishReason::Length,
            });
        }
        Ok(())
    }

    /// Fold full buckets of the verifier's debt into its state slot, then
    /// (when [`SpecConfig::reseed_drafter`] is set) restart the drafter
    /// from the verifier's exact state at the new consolidation point.
    fn consolidate(&mut self, ai: usize) -> Result<()> {
        let min_bucket = self.prefill_buckets[0];
        let mut folded = false;
        while self.active[ai].debt.len() >= min_bucket {
            let len = self.active[ai].debt.len();
            let b = *self
                .prefill_buckets
                .iter()
                .rev()
                .find(|&&b| b <= len)
                .expect("len >= min_bucket");
            let vslot = self.active[ai].verify_slot;
            let toks: Vec<u32> = self.active[ai].debt[..b].to_vec();
            self.verifier_prefill(vslot, &toks)?;
            self.active[ai].debt.drain(..b);
            self.active[ai].consumed += b;
            folded = true;
        }
        if folded && self.cfg.reseed_drafter {
            // drafter re-seeding (ROADMAP): the drafter slot has advanced
            // through its own quantized decode steps since admission and
            // drifts from the verifier's trajectory; restarting it from
            // the verifier's exact state bounds that drift on long
            // generations.  The residual (sub-bucket) debt is replayed
            // with draft steps so the drafter lands back just behind the
            // frontier — the same catch-up the admission path runs.
            // Output tokens never depend on this: only the verifier
            // commits.  No drafter snapshots are live here (each round
            // resolves its own before returning).
            let dslot = self.active[ai].draft_slot;
            let vslot = self.active[ai].verify_slot;
            debug_assert_eq!(self.pool.n_snapshots(dslot), 0);
            let seed = self.pool.get(vslot).clone();
            self.pool.seed(dslot, &seed.conv, &seed.ssm);
            let residual = self.active[ai].debt.clone();
            for &t in &residual {
                let _ = self.draft_step(dslot, t)?;
            }
            self.metrics.count(Counter::DrafterReseeds, 1);
            self.metrics
                .count(Counter::ResyncSteps, residual.len() as u64);
        }
        Ok(())
    }

    /// One draft-k / verify-1 round for active request `ai`.
    fn round(&mut self, ai: usize) -> Result<()> {
        self.consolidate(ai)?;
        let round_t0 = Instant::now();
        let vocab = self.verifier.cfg().vocab_size;
        let (dslot, vslot, frontier, max_new, stop, gen_len) = {
            let a = &self.active[ai];
            (
                a.draft_slot,
                a.verify_slot,
                a.frontier,
                a.req.max_new_tokens,
                a.req.stop_token,
                a.generated.len(),
            )
        };
        // the verifier's bonus token always commits, so draft at most
        // remaining-1 (k = 0 near the budget: a pure verify round)
        let remaining = max_new.saturating_sub(gen_len);
        let k = self.cfg.draft_k.min(remaining.saturating_sub(1));
        let greedy = self.active[ai].sampler.params().is_greedy();
        let seed = self.active[ai].sampler.params().seed;

        // --- draft: k single-token steps on the quantized variant,
        // checkpointing the state before every step after the first
        // (snaps[i] = drafter state at committed position round_start+i+1).
        // Sampling runs on a *scratch clone* of the committed sampler (the
        // round's drafts feed its penalty state, but only accepted tokens
        // feed the real one) using the position-keyed uniforms — the same
        // draw the plain engine would use at the same position, which is
        // what makes a same-backend fp32 drafter propose exactly the plain
        // engine's tokens.
        let mut drafts: Vec<u32> = Vec::with_capacity(k);
        // draft distributions q_i, kept for the rejection-sampling rule
        let mut qdists: Vec<Vec<f32>> = Vec::new();
        let mut snaps: Vec<SnapshotId> = Vec::with_capacity(k.saturating_sub(1));
        let mut round_sampler = self.active[ai].sampler.clone();
        let mut inp = frontier;
        for i in 0..k {
            if i > 0 {
                snaps.push(self.pool.snapshot(dslot));
            }
            let logits = self.draft_step(dslot, inp)?;
            let d = if greedy {
                round_sampler.sample(&logits[..vocab], gen_len + i)
            } else {
                let q = round_sampler.dist(&logits[..vocab]);
                let d =
                    Sampler::pick(&q, keyed_uniform(seed, gen_len + i, SALT_SAMPLE));
                qdists.push(q);
                d
            };
            round_sampler.observe(d);
            drafts.push(d);
            inp = d;
        }

        // --- verify: one chunked-prefill-style call over
        // debt ++ [frontier] ++ drafts, right-padded to a prefill bucket.
        // Causality makes every unpadded position's logits exact; the
        // returned state is polluted by the padding and is dropped.
        let debt_len = self.active[ai].debt.len();
        let need = debt_len + 1 + k;
        let bucket = smallest_covering(&self.prefill_buckets, need).ok_or_else(|| {
            anyhow!("verify window {need} exceeds the largest prefill bucket")
        })?;
        let mut window: Vec<i32> = Vec::with_capacity(bucket);
        window.extend(self.active[ai].debt.iter().map(|t| *t as i32));
        window.push(frontier as i32);
        window.extend(drafts.iter().map(|t| *t as i32));
        let pad = *window.last().unwrap();
        window.resize(bucket, pad);
        let st = self.pool.get(vslot);
        let call_t0 = Instant::now();
        let out =
            self.verifier.prefill(&self.cfg.verify_variant, &window, &st.conv, &st.ssm)?;
        self.metrics.note_prefill_call(call_t0.elapsed().as_secs_f64());
        self.metrics.count(Counter::VerifyCalls, 1);

        // row(i) = verifier logits after consuming frontier + drafts[..i]
        let row = |i: usize| &out.logits[(debt_len + i) * vocab..(debt_len + i + 1) * vocab];

        // --- acceptance.  Greedy: the classic token-equality prefix rule
        // ([`accept_drafts`], bit-exact with plain greedy decode).
        // Sampled: rejection sampling — accept draft d_i with probability
        // min(1, p_i[d]/q_i[d]) against the verifier's distribution p_i;
        // on reject, resample from the residual max(p - q, 0).  The
        // committed marginals equal plain sampling from p (the
        // speculative-decoding losslessness theorem), so sampled
        // speculation changes throughput, not the distribution.
        let (m, bonus) = if greedy {
            // a scratch verifier-side sampler tracks penalty state along
            // the draft prefix so processed logits match what the plain
            // engine would see at each position
            let mut vs = self.active[ai].sampler.clone();
            let mut verify: Vec<u32> = Vec::with_capacity(k + 1);
            for i in 0..=k {
                verify.push(vs.sample(row(i), gen_len + i));
                if i < k {
                    vs.observe(drafts[i]);
                }
            }
            accept_drafts(&drafts, &verify)
        } else {
            let mut vs = self.active[ai].sampler.clone();
            let mut verdict: Option<(usize, u32)> = None;
            for i in 0..k {
                let p = vs.dist(row(i));
                let d = drafts[i] as usize;
                let q_d = qdists[i][d] as f64;
                let ratio = if q_d > 0.0 { ((p[d] as f64) / q_d).min(1.0) } else { 1.0 };
                if keyed_uniform(seed, gen_len + i, SALT_ACCEPT) < ratio {
                    vs.observe(drafts[i]);
                    continue;
                }
                // rejected: resample from the residual distribution
                let adj: Vec<f32> = p
                    .iter()
                    .zip(&qdists[i])
                    .map(|(&pv, &qv)| (pv - qv).max(0.0))
                    .collect();
                let u = keyed_uniform(seed, gen_len + i, SALT_RESAMPLE);
                let t = if adj.iter().any(|&v| v > 0.0) {
                    Sampler::pick(&adj, u)
                } else {
                    // p == q exactly (fp32 self-drafting): residual is
                    // empty, fall back to the verifier's distribution
                    Sampler::pick(&p, u)
                };
                verdict = Some((i, t));
                break;
            }
            verdict.unwrap_or_else(|| {
                // every draft accepted: the bonus token is a plain sample
                // from the verifier's next-position distribution, keyed
                // exactly as the plain engine would key position gen_len+k
                let p = vs.dist(row(k));
                (k, Sampler::pick(&p, keyed_uniform(seed, gen_len + k, SALT_SAMPLE)))
            })
        };

        // --- commit the accepted prefix + the verifier's bonus token.
        // This consolidation point is where the per-request stream advances:
        // every committed token is emitted now — drafts the verifier has
        // not accepted are never visible on the event channel.
        self.metrics.count(Counter::DraftTokens, k as u64);
        self.metrics.count(Counter::DraftAccepted, m as u64);
        self.metrics.count(Counter::SpecRounds, 1);
        let is_first = self.active[ai].first_token_at.is_none();
        let mut done = false;
        let mut n_committed = 0usize;
        let now = Instant::now();
        let prev_emit;
        {
            let a = &mut self.active[ai];
            a.drafted += k as u64;
            a.accepted += m as u64;
            a.rounds += 1;
            prev_emit = a.last_token_at.replace(now);
            if is_first {
                a.first_token_at = Some(now);
                a.req.emit(Event::FirstToken);
            }
            for &t in drafts[..m].iter().chain(std::iter::once(&bonus)) {
                a.generated.push(t);
                a.sampler.observe(t);
                n_committed += 1;
                let stopped_seq = a.stream.push(&a.req, t);
                if stopped_seq {
                    done = true;
                    a.reason = FinishReason::StopSequence;
                    break;
                }
                if stop == Some(t) {
                    done = true;
                    a.reason = FinishReason::StopToken;
                    break;
                }
                if a.generated.len() >= max_new {
                    done = true;
                    a.reason = FinishReason::Length;
                    break;
                }
            }
        }
        // TPOT: the round's first committed token carries the wall time
        // since the previous emission; the rest of the burst arrives with
        // it (~0 inter-token gap — what a streaming client actually sees)
        if let Some(prev) = prev_emit {
            self.metrics.note_tpot((now - prev).as_secs_f64());
        }
        for _ in 1..n_committed {
            self.metrics.note_tpot(0.0);
        }
        self.metrics
            .count(Counter::TokensGenerated, n_committed as u64);
        if is_first {
            self.metrics
                .note_ttft(self.active[ai].submitted.elapsed().as_secs_f64());
        }
        if let Some(t) = &self.trace {
            let rid = self.active[ai].req.id;
            if t.sink.sampled(rid) {
                if is_first {
                    t.sink.instant(rid, "first_token", Vec::new());
                }
                // mid-round rejection (below) restores a drafter snapshot
                let rollback = !done && k >= 1 && m + 1 < k;
                t.sink.span_request(
                    rid,
                    "spec_round",
                    round_t0.elapsed().as_secs_f64(),
                    vec![
                        ("k", num(k as f64)),
                        ("accepted", num(m as f64)),
                        ("committed", num(n_committed as f64)),
                        ("rollback", Json::Bool(rollback)),
                    ],
                );
            }
        }
        if done {
            self.pool.clear_snapshots(dslot);
            self.active[ai].done = true;
            return Ok(());
        }

        // --- resync the drafter to the new commit point.  The drafter has
        // consumed frontier + drafts[..k-1]; the commit point is after
        // drafts[..m] (the bonus token is the new frontier, still pending).
        debug_assert!(k >= 1, "k = 0 implies remaining <= 1 implies done");
        if m == k {
            // full accept: one catch-up step over the last draft
            for s in snaps {
                self.pool.discard(s);
            }
            let _ = self.draft_step(dslot, drafts[k - 1])?;
            self.metrics.count(Counter::ResyncSteps, 1);
        } else if m == k - 1 {
            // the rejected draft was never consumed — already in sync
            for s in snaps {
                self.pool.discard(s);
            }
        } else {
            // mid-round rejection: restore the checkpoint taken at the
            // commit point — O(state), no re-decode of accepted tokens
            self.pool.rollback(snaps[m]);
            for s in &snaps[..m] {
                self.pool.discard(*s);
            }
            self.metrics.count(Counter::Rollbacks, 1);
        }

        // --- the old frontier and accepted drafts become verifier debt;
        // the bonus token is the new frontier
        let a = &mut self.active[ai];
        a.debt.push(frontier);
        a.debt.extend_from_slice(&drafts[..m]);
        a.frontier = bonus;
        Ok(())
    }

    fn retire(&mut self, mut infl: SpecInFlight, reason: FinishReason) {
        // a stop-sequence match withholds the matched tail; any other
        // finish releases held-back partial-match tokens
        if reason != FinishReason::StopSequence {
            infl.stream.flush(&infl.req);
        }
        // session entry: the verifier slot's exact state covers the first
        // `consumed` tokens of the transcript (un-consolidated debt and
        // the frontier stay outside it — a resumed turn prefills them as
        // part of its suffix)
        if let (Some(cache), Some(sid)) = (&self.cache, infl.req.session_id) {
            if infl.consumed > 0 {
                let mut toks = infl.req.prompt.clone();
                toks.extend_from_slice(&infl.generated);
                toks.truncate(infl.consumed);
                let st = self.pool.get(infl.verify_slot);
                cache.insert_session(
                    sid,
                    &self.cfg.verify_variant,
                    &toks,
                    &st.conv,
                    &st.ssm,
                );
            }
        }
        self.pool.release(infl.draft_slot);
        self.pool.release(infl.verify_slot);
        self.metrics.note_finish_reason(reason);
        self.metrics.count(Counter::RequestsCompleted, 1);
        self.metrics
            .note_latency(infl.submitted.elapsed().as_secs_f64());
        if infl.drafted > 0 {
            self.metrics
                .note_acceptance(infl.accepted as f64 / infl.drafted as f64);
        }
        // client-visible output: full `generated` unless a stop sequence
        // withheld a tail (the session entry above already used the
        // untruncated transcript — the verifier really consumed it)
        let mut generated = infl.generated;
        generated.truncate(infl.stream.visible());
        let fin = FinishedRequest {
            id: infl.req.id,
            prompt_len: infl.req.prompt.len(),
            generated,
            finish_reason: reason,
            ttft_s: infl
                .first_token_at
                .map(|t| (t - infl.submitted).as_secs_f64())
                .unwrap_or(0.0),
            total_s: infl.submitted.elapsed().as_secs_f64(),
            spec: Some(SpecStats {
                drafted: infl.drafted,
                accepted: infl.accepted,
                rounds: infl.rounds,
            }),
        };
        if let Some(t) = &self.trace {
            if t.sink.sampled(fin.id) {
                t.sink
                    .end_request(fin.id, &format!("{reason:?}"), fin.generated.len());
            }
        }
        if let Some(f) = &self.flight {
            f.record(
                fin.id,
                FlightKind::Finish,
                format!("{reason:?} tokens={}", fin.generated.len()),
            );
        }
        infl.req.emit(Event::Finished(fin.clone()));
        self.finished.push(fin);
    }

    /// Retire cancelled / past-deadline requests (pending and active).
    /// Active ones go through the normal retire path: both slots freed,
    /// partial `generated` returned, session entry still published (the
    /// verifier slot's exact coverage is `consumed`, unaffected by where
    /// in the draft/verify cycle the cancel landed — no snapshots are live
    /// between rounds).
    fn sweep_lifecycle(&mut self) {
        let mut i = 0;
        while i < self.pending.len() {
            if let Some(reason) = self.pending[i].lifecycle_reason() {
                let req = self.pending.remove(i).expect("index in bounds");
                finish_unadmitted(
                    &mut self.metrics,
                    self.trace.as_ref(),
                    self.flight.as_ref(),
                    &mut self.finished,
                    req,
                    reason,
                );
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.active.len() {
            if let Some(reason) = self.active[i].req.lifecycle_reason() {
                let infl = self.active.swap_remove(i);
                self.retire(infl, reason);
            } else {
                i += 1;
            }
        }
    }

    /// Publish this engine's live request table into its telemetry status
    /// slot (same schema as `Engine::publish_status` — the hub's
    /// `/statusz` table is engine-agnostic).
    fn publish_status(&mut self) {
        let Some(tel) = self.metrics.telemetry() else { return };
        let now = Instant::now();
        let mut rows = Vec::with_capacity(self.pending.len() + self.active.len());
        for r in &self.pending {
            rows.push(super::scheduler::status_row(
                r,
                "pending",
                self.policy.effective_priority(r, now),
                0,
                now,
            ));
        }
        for a in &self.active {
            rows.push(super::scheduler::status_row(
                &a.req,
                "active",
                a.req.priority as i64,
                a.generated.len(),
                now,
            ));
        }
        let status = Json::Obj(vec![
            ("pending".to_string(), num(self.pending.len() as f64)),
            ("active".to_string(), num(self.active.len() as f64)),
            ("max_queue".to_string(), num(self.policy.max_queue as f64)),
            ("requests".to_string(), Json::Arr(rows)),
        ]);
        tel.set_status(status);
    }

    /// One scheduler iteration: resolve cancellations/deadlines, admit,
    /// then one round per active request.
    pub fn step(&mut self) -> Result<()> {
        self.sweep_lifecycle();
        let depth = self.pending.len() + self.active.len();
        self.metrics.note_queue_depth(depth);
        let t0 = Instant::now();
        self.admit()?;
        self.metrics.note_active_slots(self.active.len());
        let mut i = 0;
        while i < self.active.len() {
            self.round(i)?;
            if self.active[i].done {
                let infl = self.active.swap_remove(i);
                let reason = infl.reason;
                self.retire(infl, reason);
            } else {
                i += 1;
            }
        }
        if depth > 0 {
            self.metrics.note_busy(t0.elapsed().as_secs_f64());
        }
        self.publish_status();
        Ok(())
    }

    /// Drive until every submitted request completes.
    pub fn run(&mut self) -> Result<()> {
        self.metrics.start();
        while !self.pending.is_empty() || !self.active.is_empty() {
            self.step()?;
        }
        self.metrics.stop();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::coordinator::request::argmax;
    use crate::coordinator::sampler::SamplingParams;
    use crate::coordinator::scheduler::{Engine, EngineConfig};

    #[test]
    fn accept_drafts_prefix_rules() {
        // all accepted: bonus is the verifier's continuation
        assert_eq!(accept_drafts(&[3, 5, 7], &[3, 5, 7, 9]), (3, 9));
        // first disagreement cuts the prefix; bonus is the verifier's token
        assert_eq!(accept_drafts(&[3, 5, 7], &[3, 6, 7, 9]), (1, 6));
        // immediate rejection still commits the verifier token
        assert_eq!(accept_drafts(&[3, 5, 7], &[4, 5, 7, 9]), (0, 4));
        // no drafts: a pure verify round
        assert_eq!(accept_drafts(&[], &[8]), (0, 8));
    }

    fn be() -> NativeBackend {
        NativeBackend::synthetic(3)
    }

    fn mixed_requests(vocab: usize) -> Vec<Request> {
        let lens = [5usize, 24, 33, 64, 100];
        lens.iter()
            .enumerate()
            .map(|(i, &plen)| {
                let prompt: Vec<u32> =
                    (0..plen).map(|j| ((i * 131 + j * 17) % vocab) as u32).collect();
                let max_new = if i == 0 { 1 } else { 8 + 3 * i };
                Request::new(i as u64, prompt, max_new, "fp32")
            })
            .collect()
    }

    fn greedy_baseline(be: &NativeBackend) -> Vec<(u64, Vec<u32>)> {
        let mut base =
            Engine::new(be, EngineConfig { max_active: 1, greedy_chunking: true });
        for r in mixed_requests(be.cfg().vocab_size) {
            base.submit(r);
        }
        base.run().unwrap();
        let mut want: Vec<(u64, Vec<u32>)> =
            base.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        want.sort();
        want
    }

    #[test]
    fn snapshot_rollback_redecode_bit_identical() {
        // snapshot -> decode n steps -> rollback -> re-decode must
        // reproduce bit-identical states and logits (any backend; runs
        // unconditionally on the native one)
        let be = be();
        let cfg = be.cfg().clone();
        let mut pool = StatePool::new(&cfg, 1);
        let slot = pool.alloc().unwrap();
        let tokens: Vec<i32> =
            (0..32).map(|i| (i * 11) % cfg.vocab_size as i32).collect();
        let out = be
            .prefill("fp32", &tokens, &pool.get(slot).conv, &pool.get(slot).ssm)
            .unwrap();
        pool.get_mut(slot).conv = out.conv_state;
        pool.get_mut(slot).ssm = out.ssm_state;

        let snap = pool.snapshot(slot);
        let run = |pool: &mut StatePool| -> (Vec<Vec<f32>>, Vec<f32>, Vec<f32>) {
            let mut all_logits = Vec::new();
            let mut tok = tokens[31];
            for _ in 0..4 {
                let st = pool.get(slot);
                let o = be.decode("fp32", 1, &st.conv, &st.ssm, &[tok]).unwrap();
                pool.get_mut(slot).conv = o.conv_state;
                pool.get_mut(slot).ssm = o.ssm_state;
                tok = argmax(&o.logits[..cfg.vocab_size]) as i32;
                all_logits.push(o.logits);
            }
            (all_logits, pool.get(slot).conv.clone(), pool.get(slot).ssm.clone())
        };
        let (l1, c1, s1) = run(&mut pool);
        pool.rollback(snap);
        let (l2, c2, s2) = run(&mut pool);
        assert_eq!(c1, c2, "conv state must be bit-identical after rollback");
        assert_eq!(s1, s2, "ssm state must be bit-identical after rollback");
        assert_eq!(l1, l2, "logits must be bit-identical after rollback");
    }

    #[test]
    fn speculative_matches_plain_greedy_fp32() {
        // the PR-1 equivalence contract, now unconditional: the quantized
        // drafter + fp32 verifier must reproduce plain greedy fp32 exactly
        // at every draft length, shared-backend or split-backend
        let be = be();
        let vocab = be.cfg().vocab_size;
        let want = greedy_baseline(&be);

        for k in [1usize, 2, 4] {
            let mut spec = SpecEngine::new(
                &be,
                SpecConfig { draft_k: k, max_active: 2, ..SpecConfig::default() },
            );
            for r in mixed_requests(vocab) {
                spec.submit(r);
            }
            spec.run().unwrap();
            let mut got: Vec<(u64, Vec<u32>)> =
                spec.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
            got.sort();
            assert_eq!(
                want, got,
                "k={k}: speculative output diverged from greedy fp32"
            );
            // accounting invariants
            assert_eq!(spec.metrics.requests_completed, want.len() as u64);
            assert!(spec.metrics.verify_calls >= spec.metrics.spec_rounds);
            assert!(spec.metrics.draft_accepted <= spec.metrics.draft_tokens);
            for f in &spec.finished {
                let s = f.spec.expect("speculative stats attached");
                assert!(s.accepted <= s.drafted);
            }
        }
    }

    #[test]
    fn split_drafter_backend_matches_greedy_fp32() {
        // drafter on its own backend instance (the deployment shape where
        // drafts run in-process next to a device verifier)
        let verifier = be();
        let drafter = be();
        let vocab = verifier.cfg().vocab_size;
        let want = greedy_baseline(&verifier);
        let mut spec = SpecEngine::with_drafter(
            &drafter,
            &verifier,
            SpecConfig { draft_k: 4, max_active: 2, ..SpecConfig::default() },
        );
        for r in mixed_requests(vocab) {
            spec.submit(r);
        }
        spec.run().unwrap();
        let mut got: Vec<(u64, Vec<u32>)> =
            spec.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        got.sort();
        assert_eq!(want, got, "split drafter/verifier diverged from greedy fp32");
    }

    #[test]
    #[should_panic(expected = "state shape")]
    fn mismatched_backends_rejected() {
        // different weights are tolerated (only the verifier commits), but
        // a different state *shape* breaks state seeding and must panic
        let mut cfg = crate::config::ModelConfig::tiny();
        cfg.n_layer = 2;
        cfg.name = "mamba2-tiny-halved".into();
        let small = NativeBackend::new(crate::model::ModelWeights::random(&cfg, 1));
        let full = be();
        let _ = SpecEngine::with_drafter(&small, &full, SpecConfig::default());
    }

    #[test]
    fn distinct_cfg_drafter_accepted_when_state_shapes_match() {
        // the ROADMAP "distilled drafter" shape: a drafter whose config is
        // *not* equal to the verifier's (different name, different weights)
        // but whose flat state buffers match — construction must succeed
        // and the output must stay token-exact with plain greedy fp32
        let verifier = be();
        let mut cfg = crate::config::ModelConfig::tiny();
        cfg.name = "mamba2-tiny-distilled".into();
        let drafter =
            NativeBackend::new(crate::model::ModelWeights::random(&cfg, 11));
        assert_ne!(drafter.cfg(), verifier.cfg(), "configs differ by metadata");

        // small trace: a fresh-weights drafter accepts rarely, so every
        // committed token costs a verify window — keep the budget tight
        let vocab = verifier.cfg().vocab_size;
        let reqs: Vec<Request> = [24usize, 33]
            .iter()
            .enumerate()
            .map(|(i, &plen)| {
                let prompt: Vec<u32> =
                    (0..plen).map(|j| ((i * 131 + j * 17) % vocab) as u32).collect();
                Request::new(i as u64, prompt, 5, "fp32")
            })
            .collect();
        let mut base = Engine::new(
            &verifier,
            EngineConfig { max_active: 1, greedy_chunking: true },
        );
        for r in reqs.clone() {
            base.submit(r);
        }
        base.run().unwrap();
        let mut want: Vec<(u64, Vec<u32>)> =
            base.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        want.sort();

        let mut spec = SpecEngine::with_drafter(
            &drafter,
            &verifier,
            SpecConfig { draft_k: 3, max_active: 2, ..SpecConfig::default() },
        );
        for r in reqs {
            spec.submit(r);
        }
        spec.run().unwrap();
        let mut got: Vec<(u64, Vec<u32>)> =
            spec.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        got.sort();
        assert_eq!(want, got, "distinct-cfg drafter diverged from greedy fp32");
    }

    #[test]
    fn repartitioned_head_drafter_stays_token_exact() {
        // a drafter that partitions the same d_inner into twice as many
        // half-size heads: d_in_proj and every weight shape differ from the
        // verifier's, but conv_dim and the flat ssm volume
        // (nheads * headdim = d_inner) are identical, so state seeding is
        // legal.  Acceptance may be poor; the committed tokens may not be.
        let verifier = be();
        let mut cfg = crate::config::ModelConfig::tiny();
        cfg.headdim /= 2;
        cfg.name = "mamba2-tiny-headdim-half".into();
        let drafter =
            NativeBackend::new(crate::model::ModelWeights::random(&cfg, 12));
        let shape = |c: &crate::config::ModelConfig| {
            (c.conv_state_len(), c.ssm_state_len())
        };
        assert_eq!(shape(drafter.cfg()), shape(verifier.cfg()));
        assert_ne!(drafter.cfg().nheads(), verifier.cfg().nheads());

        let vocab = verifier.cfg().vocab_size;
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();
        let mut base = Engine::new(
            &verifier,
            EngineConfig { max_active: 1, greedy_chunking: true },
        );
        base.submit(Request::new(0, prompt.clone(), 6, "fp32"));
        base.run().unwrap();
        let want = base.finished[0].generated.clone();

        let mut spec = SpecEngine::with_drafter(
            &drafter,
            &verifier,
            SpecConfig { draft_k: 2, max_active: 1, ..SpecConfig::default() },
        );
        spec.submit(Request::new(0, prompt, 6, "fp32"));
        spec.run().unwrap();
        assert_eq!(
            spec.finished[0].generated, want,
            "repartitioned-head drafter diverged from greedy fp32"
        );
    }

    /// Gated end-to-end coverage on the AOT artifacts: a native drafter
    /// paired with a PJRT verifier, and drafting on the PJRT backend
    /// itself, both reproduce plain greedy fp32 on the compiled graphs.
    #[cfg(feature = "pjrt")]
    #[test]
    fn speculative_on_pjrt_matches_plain_greedy_fp32() {
        use crate::backend::PjrtBackend;
        use crate::model::weights::artifacts_dir;
        if !artifacts_dir().join("manifest.json").exists() {
            return;
        }
        let pj = PjrtBackend::load_default().expect("pjrt load");
        let vocab = pj.cfg().vocab_size;
        let mut base =
            Engine::new(&pj, EngineConfig { max_active: 1, greedy_chunking: true });
        for r in mixed_requests(vocab) {
            base.submit(r);
        }
        base.run().unwrap();
        let mut want: Vec<(u64, Vec<u32>)> =
            base.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        want.sort();

        let native_drafter = NativeBackend::load_default().expect("native load");
        let drafters: [&dyn InferenceBackend; 2] = [&native_drafter, &pj];
        for (di, drafter) in drafters.into_iter().enumerate() {
            let mut spec = SpecEngine::with_drafter(
                drafter,
                &pj,
                SpecConfig { draft_k: 4, max_active: 2, ..SpecConfig::default() },
            );
            for r in mixed_requests(vocab) {
                spec.submit(r);
            }
            spec.run().unwrap();
            let mut got: Vec<(u64, Vec<u32>)> =
                spec.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
            got.sort();
            assert_eq!(want, got, "drafter {di}: diverged from greedy fp32 on PJRT");
        }
    }

    /// Small fast model with narrow buckets so debt consolidates (and the
    /// drafter re-seeds) every few committed tokens.
    fn micro() -> NativeBackend {
        let mut cfg = crate::config::ModelConfig::tiny();
        cfg.name = "mamba2-micro".into();
        cfg.d_model = 64;
        cfg.n_layer = 2;
        cfg.d_state = 16;
        cfg.headdim = 16;
        cfg.vocab_size = 128;
        NativeBackend::new(crate::model::ModelWeights::random(&cfg, 9))
            .with_buckets(vec![8, 16, 32], vec![1, 2, 4])
    }

    #[test]
    fn drafter_reseeding_long_generation_stays_token_exact() {
        // ROADMAP "drafter re-seeding": on a long generation the drafter
        // re-syncs from the verifier's exact state at every consolidation
        // point.  The output must be token-exact with plain greedy fp32
        // with re-seeding on AND off — only acceptance may change.
        let be = micro();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..21).map(|j| ((j * 13 + 2) % vocab) as u32).collect();
        let max_new = 40;

        let mut base = Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true });
        base.submit(Request::new(0, prompt.clone(), max_new, "fp32"));
        base.run().unwrap();
        let want = base.finished[0].generated.clone();
        assert_eq!(want.len(), max_new);

        for reseed in [true, false] {
            let mut spec = SpecEngine::new(
                &be,
                SpecConfig {
                    draft_k: 4,
                    max_active: 1,
                    reseed_drafter: reseed,
                    ..SpecConfig::default()
                },
            );
            spec.submit(Request::new(0, prompt.clone(), max_new, "fp32"));
            spec.run().unwrap();
            assert_eq!(
                spec.finished[0].generated, want,
                "reseed={reseed}: long generation diverged from plain greedy"
            );
            if reseed {
                assert!(
                    spec.metrics.drafter_reseeds >= 2,
                    "40 committed tokens over min-bucket-8 debt must consolidate \
                     repeatedly, got {} reseeds",
                    spec.metrics.drafter_reseeds
                );
            } else {
                assert_eq!(spec.metrics.drafter_reseeds, 0);
            }
        }
    }

    #[test]
    fn spec_engine_shares_the_state_cache() {
        use crate::statecache::{CacheConfig, StateCache};
        use std::sync::Arc;
        // two requests sharing a long prompt prefix: the second admission
        // seeds the verifier from the first's boundary snapshot, and the
        // output still matches plain greedy fp32 exactly
        let be = micro();
        let vocab = be.cfg().vocab_size;
        let make_reqs = || -> Vec<Request> {
            let sys: Vec<u32> = (0..33).map(|j| ((j * 7 + 1) % vocab) as u32).collect();
            (0..2usize)
                .map(|i| {
                    let mut prompt = sys.clone();
                    prompt.extend((0..3 + i * 5).map(|j| ((i * 131 + j * 17) % vocab) as u32));
                    Request::new(i as u64, prompt, 6, "fp32").with_session(50 + i as u64)
                })
                .collect()
        };

        let mut base = Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true });
        for r in make_reqs() {
            base.submit(r);
        }
        base.run().unwrap();
        let mut want: Vec<(u64, Vec<u32>)> =
            base.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        want.sort();

        let cache = Arc::new(StateCache::new(CacheConfig::default()));
        let mut spec = SpecEngine::new(
            &be,
            SpecConfig { draft_k: 2, max_active: 1, ..SpecConfig::default() },
        )
        .with_cache(Arc::clone(&cache));
        for r in make_reqs() {
            spec.submit(r);
        }
        spec.run().unwrap();
        let mut got: Vec<(u64, Vec<u32>)> =
            spec.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        got.sort();
        assert_eq!(want, got, "cached speculative admission diverged from greedy");
        // max_active 1 serializes admissions: request 1 hits request 0's
        // shared 32-token boundary snapshot
        assert_eq!(spec.metrics.cache_hits, 1, "{}", spec.metrics.summary());
        assert!(spec.metrics.cache_tokens_saved >= 32);
        // both requests carried session ids, so both end states are stored
        assert!(cache.stats().entries >= 2);
    }

    #[test]
    fn stop_token_halts_speculative_decode() {
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();

        // discover what greedy fp32 generates, then stop on its 3rd token
        let mut probe =
            Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true });
        probe.submit(Request::new(0, prompt.clone(), 8, "fp32"));
        probe.run().unwrap();
        let gen = probe.finished[0].generated.clone();
        let stop = gen[2];
        if gen[..2].contains(&stop) {
            return; // degenerate trace; stop-token position ambiguous
        }

        let mut spec = SpecEngine::new(&be, SpecConfig::default());
        spec.submit(Request::new(0, prompt, 8, "fp32").with_stop_token(stop));
        spec.run().unwrap();
        let got = &spec.finished[0].generated;
        assert_eq!(got.last(), Some(&stop));
        assert_eq!(got.len(), 3, "must halt at the stop token, got {got:?}");
        assert_eq!(spec.finished[0].finish_reason, FinishReason::StopToken);
    }

    #[test]
    fn spec_stream_commits_only_verified_tokens_all_variants() {
        use crate::model::Variant;
        // every Token event must be a verifier-committed token: the drained
        // stream equals the final output exactly — no unverified draft is
        // ever visible, whatever the verify variant quantizes
        let be = micro();
        let vocab = be.cfg().vocab_size;
        for v in Variant::ALL {
            let mut spec = SpecEngine::new(
                &be,
                SpecConfig {
                    draft_k: 2,
                    max_active: 2,
                    verify_variant: v.name().into(),
                    ..SpecConfig::default()
                },
            );
            let prompt: Vec<u32> =
                (0..17).map(|j| ((j * 13 + 2) % vocab) as u32).collect();
            let h = spec.submit(Request::new(0, prompt, 7, v.name()));
            spec.run().unwrap();
            let want = spec.finished[0].generated.clone();
            assert_eq!(want.len(), 7, "verify={}", v.name());

            let mut toks = Vec::new();
            let mut first = false;
            let mut fin = None;
            while let Some(ev) = h.try_event() {
                match ev {
                    Event::FirstToken => {
                        assert!(toks.is_empty(), "FirstToken must precede Token 0");
                        first = true;
                    }
                    Event::Token { tok, index } => {
                        assert_eq!(index, toks.len(), "indexes contiguous");
                        toks.push(tok);
                    }
                    Event::Finished(f) => fin = Some(f),
                }
            }
            assert!(first, "verify={}", v.name());
            assert_eq!(
                toks,
                want,
                "verify={}: stream must carry exactly the committed tokens",
                v.name()
            );
            let fin = fin.expect("terminal event");
            assert_eq!(fin.finish_reason, FinishReason::Length);
            assert!(fin.spec.is_some());
        }
    }

    #[test]
    fn spec_cancel_mid_generation_returns_greedy_prefix() {
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();
        let mut base =
            Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true });
        base.submit(Request::new(0, prompt.clone(), 40, "fp32"));
        base.run().unwrap();
        let want = base.finished[0].generated.clone();

        let mut spec = SpecEngine::new(
            &be,
            SpecConfig { draft_k: 4, max_active: 1, ..SpecConfig::default() },
        );
        let h = spec.submit(Request::new(0, prompt, 40, "fp32"));
        let mut streamed = 0usize;
        while streamed < 5 {
            spec.step().unwrap();
            while let Some(ev) = h.try_event() {
                if matches!(ev, Event::Token { .. }) {
                    streamed += 1;
                }
            }
        }
        h.cancel();
        spec.run().unwrap(); // next step sweeps the cancel and retires
        let f = &spec.finished[0];
        assert_eq!(f.finish_reason, FinishReason::Cancelled);
        let n = f.generated.len();
        assert!(n >= 5 && n < 40, "partial output expected, got {n}");
        assert_eq!(f.generated[..], want[..n], "partial != greedy fp32 prefix");
        assert_eq!(spec.metrics.cancelled_requests, 1);
        assert_eq!(spec.n_active(), 0, "both slots freed");
    }

    #[test]
    fn spec_deadline_expiry_reports_reason() {
        use std::time::Duration;
        let be = be();
        let mut spec = SpecEngine::new(&be, SpecConfig::default());
        let h = spec.submit(
            Request::new(0, vec![1, 2, 3, 4, 5], 8, "fp32")
                .with_deadline(Duration::ZERO),
        );
        spec.run().unwrap();
        assert_eq!(spec.finished[0].finish_reason, FinishReason::Deadline);
        assert!(spec.finished[0].generated.is_empty());
        assert_eq!(spec.metrics.deadline_expired, 1);
        assert!(
            matches!(h.wait_finished(), Some(f) if f.finish_reason == FinishReason::Deadline)
        );
    }

    fn sampled_reqs(vocab: usize) -> Vec<Request> {
        let lens = [5usize, 11, 21];
        lens.iter()
            .enumerate()
            .map(|(i, &plen)| {
                let prompt: Vec<u32> =
                    (0..plen).map(|j| ((i * 131 + j * 17) % vocab) as u32).collect();
                Request::new(i as u64, prompt, 8, "fp32").with_sampling(SamplingParams {
                    temperature: 1.5,
                    seed: 1000 + i as u64,
                    ..SamplingParams::default()
                })
            })
            .collect()
    }

    #[test]
    fn sampled_speculative_is_lossless_vs_plain_sampled_fp32() {
        // the rejection-sampling regression: with an fp32 self-drafting
        // SpecEngine, the drafter's decode trajectory is bit-identical to
        // the plain engine's, so every position's draft distribution q_i
        // equals the plain sampling distribution exactly and the
        // position-keyed draw proposes exactly the plain engine's token.
        // Acceptance ratios p_i[d]/q_i[d] then sit at 1 - O(eps) (the
        // verify row comes from the chunk-exact prefill path, the draft
        // row from the decode path — same math, different FP association),
        // so the sampled speculative output matches plain sampled decoding
        // token-for-token.  reseed_drafter stays off: re-seeding copies
        // the verifier's prefill-path state into the drafter, which is
        // correct but not bit-identical to the plain decode trajectory.
        let be = micro();
        let vocab = be.cfg().vocab_size;
        let mut base =
            Engine::new(&be, EngineConfig { max_active: 2, greedy_chunking: true });
        for r in sampled_reqs(vocab) {
            base.submit(r);
        }
        base.run().unwrap();
        let mut want: Vec<(u64, Vec<u32>)> =
            base.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        want.sort();
        assert!(want.iter().all(|(_, g)| g.len() == 8));

        for k in [1usize, 2, 4] {
            let mut spec = SpecEngine::new(
                &be,
                SpecConfig {
                    draft_k: k,
                    draft_variant: "fp32".into(),
                    verify_variant: "fp32".into(),
                    max_active: 2,
                    reseed_drafter: false,
                },
            );
            for r in sampled_reqs(vocab) {
                spec.submit(r);
            }
            spec.run().unwrap();
            let mut got: Vec<(u64, Vec<u32>)> =
                spec.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
            got.sort();
            assert_eq!(
                want, got,
                "k={k}: sampled speculative output diverged from plain sampled fp32"
            );
        }
    }

    #[test]
    fn sampled_spec_reproducible_same_seed_diverges_different_seed() {
        // quantized drafter + fp32 verifier under sampling: rejections
        // really happen (q != p), but the run is fully deterministic for a
        // fixed seed and diverges across seeds
        let be = micro();
        let vocab = be.cfg().vocab_size;
        let run = |seed_base: u64| -> Vec<(u64, Vec<u32>)> {
            let mut spec = SpecEngine::new(
                &be,
                SpecConfig { draft_k: 3, max_active: 2, ..SpecConfig::default() },
            );
            for (i, r) in sampled_reqs(vocab).into_iter().enumerate() {
                let mut sp = r.sampling.clone();
                sp.seed = seed_base + i as u64;
                spec.submit(r.with_sampling(sp));
            }
            spec.run().unwrap();
            let mut got: Vec<(u64, Vec<u32>)> =
                spec.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
            got.sort();
            got
        };
        let a = run(7000);
        assert_eq!(a, run(7000), "same seed must reproduce the sampled spec run");
        assert_ne!(a, run(7500), "different seeds must diverge");
    }

    #[test]
    fn stop_sequence_halts_speculative_engine() {
        // boundary-spanning stop sequence on the spec engine: discover the
        // greedy trace, stop on the rendered 2nd+3rd tokens
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();
        let mut base =
            Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true });
        base.submit(Request::new(0, prompt.clone(), 8, "fp32"));
        base.run().unwrap();
        let gen = base.finished[0].generated.clone();
        let stop = format!("{} {}", gen[1], gen[2]);

        let mut spec = SpecEngine::new(
            &be,
            SpecConfig { draft_k: 4, max_active: 1, ..SpecConfig::default() },
        );
        let sp = SamplingParams {
            stop_sequences: vec![stop.clone()],
            ..SamplingParams::default()
        };
        spec.submit(Request::new(0, prompt, 8, "fp32").with_sampling(sp));
        spec.run().unwrap();
        let fin = &spec.finished[0];
        assert_eq!(fin.finish_reason, FinishReason::StopSequence);
        assert!(fin.generated.len() < gen.len());
        assert_eq!(fin.generated, gen[..fin.generated.len()]);
        let rendered = fin
            .generated
            .iter()
            .map(|t| t.to_string())
            .collect::<Vec<_>>()
            .join(" ");
        assert!(!rendered.contains(&stop));
        assert_eq!(spec.n_active(), 0, "both slots freed");
    }
}
